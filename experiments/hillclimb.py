"""Perf hillclimb driver: re-lowers the three picked cells with candidate
changes and records roofline-term deltas per iteration.

  PYTHONPATH=src python experiments/hillclimb.py qwen_prefill
  PYTHONPATH=src python experiments/hillclimb.py mixtral_train
  PYTHONPATH=src python experiments/hillclimb.py mamba_train
  PYTHONPATH=src python experiments/hillclimb.py podwise       # beyond-paper
"""

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import json
import sys

from repro.launch.dryrun import run_cell


def show(tag, r):
    row = {
        "tag": tag,
        "t_compute_s": round(r["t_compute_s"], 3),
        "t_memory_s": round(r["t_memory_s"], 3),
        "t_collective_s": round(r["t_collective_s"], 3),
        "dominant": r["dominant"],
        "flops": r["flops"],
        "mem_bytes_fused": r["mem_bytes_fused"],
        "coll_bytes": r["collective_bytes_total"],
        "temp_gib": round(r.get("temp_size_in_bytes", 0) / 2**30, 2),
        "compile_s": r["compile_s"],
    }
    print(json.dumps(row), flush=True)
    return row


def qwen_prefill():
    """qwen2.5-32b/prefill_32k — worst useful-FLOPs cell."""
    base = run_cell("qwen2.5-32b", "prefill_32k", False)
    show("baseline(masked full KV sweep)", base)
    # it 1: causal-skip flash (dynamic fori bound); expected compute ~ /2
    nk = 32768 // 512
    it1 = run_cell("qwen2.5-32b", "prefill_32k", False,
                   extra_cfg={"flash_skip": True}, dynamic_trips=(nk + 1) / 2)
    show("it1: causal-skip flash", it1)
    # it 2: larger blocks (fewer loop iterations, bigger matmuls)
    it2 = run_cell("qwen2.5-32b", "prefill_32k", False,
                   extra_cfg={"flash_skip": True, "attn_block_q": 1024, "attn_block_k": 1024},
                   dynamic_trips=(32768 // 1024 + 1) / 2)
    show("it2: it1 + attn blocks 1024", it2)
    # it 3: blocks 2048
    it3 = run_cell("qwen2.5-32b", "prefill_32k", False,
                   extra_cfg={"flash_skip": True, "attn_block_q": 2048, "attn_block_k": 2048},
                   dynamic_trips=(32768 // 2048 + 1) / 2)
    show("it3: it1 + attn blocks 2048", it3)


def mixtral_train():
    """mixtral-8x22b/train_4k — most collective-bound."""
    base = run_cell("mixtral-8x22b", "train_4k", False)
    show("baseline(accum=16, micro 1/dev)", base)
    # it 1: micro 4/device -> accum 4: FSDP weight all-gathers amortized 4x
    it1 = run_cell("mixtral-8x22b", "train_4k", False, micro_per_device=4)
    show("it1: micro 4/dev (accum 4)", it1)
    # it 2: capacity factor 1.0 (fewer a2a slot bytes, more drops)
    it2 = run_cell("mixtral-8x22b", "train_4k", False, micro_per_device=4,
                   extra_cfg={"capacity_factor": 1.0})
    show("it2: it1 + capacity 1.0", it2)
    # it 3: remat policy dots (trade memory for recompute flops)
    it3 = run_cell("mixtral-8x22b", "train_4k", False, micro_per_device=4,
                   extra_cfg={"remat": "none"})
    show("it3: it1 + no remat (memory for flops)", it3)


def mamba_train():
    """falcon-mamba-7b/train_4k — worst memory dominance."""
    base = run_cell("falcon-mamba-7b", "train_4k", False)
    show("baseline(assoc scan, chunk 128)", base)
    it1 = run_cell("falcon-mamba-7b", "train_4k", False, extra_cfg={"ssm_scan": "seq"})
    show("it1: sequential time scan", it1)
    it2 = run_cell("falcon-mamba-7b", "train_4k", False, extra_cfg={"ssm_chunk": 512})
    show("it2: assoc, chunk 512", it2)
    it3 = run_cell("falcon-mamba-7b", "train_4k", False, extra_cfg={"ssm_chunk": 64})
    show("it3: assoc, chunk 64", it3)
    it4 = run_cell("falcon-mamba-7b", "train_4k", False, micro_per_device=4)
    show("it4: assoc c128, micro 4/dev", it4)


def podwise():
    """Beyond-paper: explicit podwise gradient sync on the multi-pod mesh,
    optionally int8-compressed on the slow (DCN) hop."""
    base = run_cell("qwen2.5-32b", "train_4k", True)
    show("baseline(GSPMD auto sync, 2x16x16)", base)
    p1 = run_cell("qwen2.5-32b", "train_4k", True, grad_sync="podwise")
    show("podwise: explicit inter-pod pmean", p1)
    p2 = run_cell("qwen2.5-32b", "train_4k", True, grad_sync="podwise_int8")
    show("podwise_int8: inter-pod int8+scales", p2)


if __name__ == "__main__":
    {"qwen_prefill": qwen_prefill, "mixtral_train": mixtral_train,
     "mamba_train": mamba_train, "podwise": podwise}[sys.argv[1]]()
