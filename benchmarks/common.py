"""Benchmark harness utilities: timing, CSV row emission."""

from __future__ import annotations

import time
from typing import Callable, List, Tuple

import jax

ROWS: List[Tuple[str, float, str]] = []


def timeit(fn: Callable, *args, reps: int = 5, warmup: int = 2) -> float:
    """Median wall seconds per call (block_until_ready)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2]


def emit(name: str, us_per_call: float, derived: str = ""):
    ROWS.append((name, us_per_call, derived))
    print(f"{name},{us_per_call:.2f},{derived}")
