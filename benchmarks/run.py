# One function per paper table/figure. Prints ``name,us_per_call,derived`` CSV.
"""Benchmark harness: every paper table/figure, plus kernel microbenches.

  PYTHONPATH=src python -m benchmarks.run                    # all, full size
  PYTHONPATH=src python -m benchmarks.run fig4_1 ...         # subset
  PYTHONPATH=src python -m benchmarks.run --smoke            # tiny shapes,
                                                             # 1 rep, CI-safe
"""

import argparse


def main() -> None:
    from benchmarks import (
        chaos_recovery,
        fig4_1_kernel_breakdown,
        fig5_2_load_fraction,
        fig5_3_transfer,
        fig6_2_kernels,
        pipeline_throughput,
        rounds_makespan,
        serve_latency,
        table6_1_speedup,
    )

    suites = {
        "fig4_1": fig4_1_kernel_breakdown.run,
        "fig5_2": fig5_2_load_fraction.run,
        "fig5_3": fig5_3_transfer.run,
        "table6_1": table6_1_speedup.run,
        "fig6_2": fig6_2_kernels.run,
        "pipeline": pipeline_throughput.run,
        "serve": serve_latency.run,
        "rounds": rounds_makespan.run,
        "chaos": chaos_recovery.run,
    }
    ap = argparse.ArgumentParser()
    ap.add_argument("suites", nargs="*", default=[],
                    help=f"subset of suites (default: all of {', '.join(suites)})")
    ap.add_argument("--suite", action="append", default=[],
                    help="same as the positional form (repeatable): "
                         "python -m benchmarks.run --suite table6_1 --smoke")
    ap.add_argument("--skip", action="append", default=[],
                    help="suites to exclude (repeatable) — lets CI run "
                         "'everything except X' without a hand-maintained list")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes, 1 rep — finishes in well under 2 minutes")
    ap.add_argument("--overlap", choices=["on", "off", "both"], default="both",
                    help="fig5_3: modeled makespan with the boundary/interior "
                         "overlap schedule on/off (delta row when 'both')")
    ap.add_argument("--autotune-cache", default=None,
                    help="fig4_1: path to a repro.kernels.autotune cache "
                         "JSON; the Pallas kernel rows use its block-size "
                         "winners (default: $REPRO_AUTOTUNE_CACHE / "
                         "~/.cache/repro-dg/autotune.json, inline smoke "
                         "sweep when absent)")
    ap.add_argument("--devices", type=int, default=1,
                    help="pipeline: add a sharded-fused row over this many "
                         "devices (needs XLA_FLAGS=--xla_force_host_platform_"
                         "device_count=N on CPU)")
    ap.add_argument("--list-scenarios", action="store_true",
                    help="print every registered arch/scenario and exit")
    args = ap.parse_args()

    if args.list_scenarios:
        from repro.configs.registry import format_listing

        print(format_listing())
        return

    requested = list(args.suites) + list(args.suite)
    unknown = [s for s in requested + args.skip if s not in suites]
    if unknown:
        ap.error(f"unknown suites {unknown}; choose from {list(suites)}")
    picked = [s for s in (requested or list(suites)) if s not in args.skip]
    print("name,us_per_call,derived")
    for name in picked:
        kwargs = {"smoke": args.smoke}
        if name == "fig4_1":
            kwargs["autotune_cache"] = args.autotune_cache
        if name == "fig5_3":
            kwargs["overlap"] = args.overlap
        if name == "pipeline":
            kwargs["devices"] = args.devices
        suites[name](**kwargs)


if __name__ == "__main__":
    main()
