# One function per paper table/figure. Prints ``name,us_per_call,derived`` CSV.
"""Benchmark harness: every paper table/figure, plus kernel microbenches.

  PYTHONPATH=src python -m benchmarks.run            # all
  PYTHONPATH=src python -m benchmarks.run fig4_1 ... # subset
"""

import sys


def main() -> None:
    from benchmarks import (
        fig4_1_kernel_breakdown,
        fig5_2_load_fraction,
        fig5_3_transfer,
        fig6_2_kernels,
        table6_1_speedup,
    )

    suites = {
        "fig4_1": fig4_1_kernel_breakdown.run,
        "fig5_2": fig5_2_load_fraction.run,
        "fig5_3": fig5_3_transfer.run,
        "table6_1": table6_1_speedup.run,
        "fig6_2": fig6_2_kernels.run,
    }
    picked = sys.argv[1:] or list(suites)
    print("name,us_per_call,derived")
    for name in picked:
        suites[name]()


if __name__ == "__main__":
    main()
