"""Paper Fig 4.1: breakdown of DG execution time by kernel.

Times each kernel of this repo's solver in isolation (jit'd, CPU) on the
paper's configuration family and reports the percentage breakdown next to
the paper's published averages (volume_loop ~40%, int_flux ~25%, ...).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, timeit
from repro.dg.operators import extract_face, surface_rhs, volume_rhs
from repro.dg.rk import lsrk45_step
from repro.dg.solver import gaussian_pulse, make_two_tree_solver

PAPER_SHARES = {"volume_loop": 40, "int_flux": 25, "interp_q": 8, "lift+rk": 18, "other": 9}


def run(grid=(8, 8, 8), order=5, smoke=False):
    if smoke:
        grid, order = (4, 4, 4), 3
    reps = 1 if smoke else 5
    s = make_two_tree_solver(grid=grid, order=order, extent=(2.0, 1.0, 1.0), dtype="float32")
    q = gaussian_pulse(s, center=(0.5, 0.5, 0.5)).astype(jnp.float32)

    vol = jax.jit(lambda q: volume_rhs(q, s.D, s.metrics, s.rho_j, s.lam_j, s.mu_j))
    surf = jax.jit(lambda q: surface_rhs(q, s.neighbors, s.lift, s.rho_j, s.lam_j, s.mu_j, s.cp_j, s.cs_j))
    interp = jax.jit(lambda q: [extract_face(q, f) for f in range(6)])
    rhs = jax.jit(s.rhs)
    rk = jax.jit(lambda q, r: lsrk45_step(q, r, lambda x: x, 1e-3))

    t_vol = timeit(vol, q, reps=reps)
    t_surf = timeit(surf, q, reps=reps)
    t_interp = timeit(interp, q, reps=reps)
    t_rk = timeit(rk, q, jnp.zeros_like(q), reps=reps)
    t_rhs = timeit(rhs, q, reps=reps)

    total = t_vol + t_surf + t_interp + t_rk
    emit("fig4_1/volume_loop", t_vol * 1e6, f"{100*t_vol/total:.0f}% (paper ~40%)")
    emit("fig4_1/int_flux+lift", t_surf * 1e6, f"{100*t_surf/total:.0f}% (paper ~33%)")
    emit("fig4_1/interp_q", t_interp * 1e6, f"{100*t_interp/total:.0f}% (paper ~8%)")
    emit("fig4_1/rk", t_rk * 1e6, f"{100*t_rk/total:.0f}% (paper ~10%)")
    emit("fig4_1/full_rhs", t_rhs * 1e6, f"K={s.mesh.K} order={order}")
    return {"volume": t_vol, "surface": t_surf, "interp": t_interp, "rk": t_rk}


if __name__ == "__main__":
    run()
