"""Paper Fig 4.1: breakdown of DG execution time by kernel.

Times each kernel of this repo's solver in isolation (jit'd, CPU) on the
paper's configuration family and reports the percentage breakdown next to
the paper's published averages (volume_loop ~40%, int_flux ~25%, ...).

On top of the XLA breakdown, the Pallas hot-spots (``dg_volume_pallas`` /
``dg_flux_pallas``) are timed at their *autotuned* block sizes — the entry
for the current device class from the ``repro.kernels.autotune`` cache
(``--autotune-cache`` / ``$REPRO_AUTOTUNE_CACHE``), falling back to an
inline smoke sweep when no cache is present — and the whole breakdown is
written to ``BENCH_kernels.json`` so the kernel roofline has a tracked
trajectory like BENCH_pipeline/BENCH_serve.
"""

from __future__ import annotations

import json

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timeit
from repro.dg.operators import extract_face, surface_rhs, volume_rhs
from repro.dg.rk import lsrk45_step
from repro.dg.solver import gaussian_pulse, make_two_tree_solver

PAPER_SHARES = {"volume_loop": 40, "int_flux": 25, "interp_q": 8, "lift+rk": 18, "other": 9}

JSON_PATH = "BENCH_kernels.json"


def _autotune_entry(order: int, smoke: bool, autotune_cache=None):
    """The cache entry for the current device class, else an inline smoke
    sweep (not saved: a benchmark run should not silently overwrite the
    user's tuned cache)."""
    from repro.kernels import autotune as at

    entry = at.lookup(order=order, path=autotune_cache)
    if entry is None:
        # any-order entry for this device class: block-size winners are far
        # more stable across order than across device class
        entry = at.lookup(path=autotune_cache)
    if entry is not None:
        return entry, "cache"
    entry = at.autotune(
        order=order,
        be_candidates=at.DEFAULT_BE_CANDIDATES[:2] if smoke else at.DEFAULT_BE_CANDIDATES,
        bf_candidates=at.DEFAULT_BF_CANDIDATES[:2] if smoke else at.DEFAULT_BF_CANDIDATES,
        reps=1 if smoke else 3,
        size_factor=4 if smoke else 8,
        save=False,
    )
    return entry, "inline-sweep"


def run(grid=(8, 8, 8), order=5, smoke=False, autotune_cache=None):
    if smoke:
        grid, order = (4, 4, 4), 3
    reps = 1 if smoke else 5
    s = make_two_tree_solver(grid=grid, order=order, extent=(2.0, 1.0, 1.0), dtype="float32")
    q = gaussian_pulse(s, center=(0.5, 0.5, 0.5)).astype(jnp.float32)

    vol = jax.jit(lambda q: volume_rhs(q, s.D, s.metrics, s.rho_j, s.lam_j, s.mu_j))
    surf = jax.jit(lambda q: surface_rhs(q, s.neighbors, s.lift, s.rho_j, s.lam_j, s.mu_j, s.cp_j, s.cs_j))
    interp = jax.jit(lambda q: [extract_face(q, f) for f in range(6)])
    rhs = jax.jit(s.rhs)
    rk = jax.jit(lambda q, r: lsrk45_step(q, r, lambda x: x, 1e-3))

    t_vol = timeit(vol, q, reps=reps)
    t_surf = timeit(surf, q, reps=reps)
    t_interp = timeit(interp, q, reps=reps)
    t_rk = timeit(rk, q, jnp.zeros_like(q), reps=reps)
    t_rhs = timeit(rhs, q, reps=reps)

    total = t_vol + t_surf + t_interp + t_rk
    emit("fig4_1/volume_loop", t_vol * 1e6, f"{100*t_vol/total:.0f}% (paper ~40%)")
    emit("fig4_1/int_flux+lift", t_surf * 1e6, f"{100*t_surf/total:.0f}% (paper ~33%)")
    emit("fig4_1/interp_q", t_interp * 1e6, f"{100*t_interp/total:.0f}% (paper ~8%)")
    emit("fig4_1/rk", t_rk * 1e6, f"{100*t_rk/total:.0f}% (paper ~10%)")
    emit("fig4_1/full_rhs", t_rhs * 1e6, f"K={s.mesh.K} order={order}")

    # -- the Pallas hot-spots at their autotuned block sizes ----------------
    from repro.dg.basis import diff_matrix, lgl_nodes_weights
    from repro.kernels.dg_flux import dg_flux_pallas
    from repro.kernels.dg_volume import dg_volume_pallas

    entry, source = _autotune_entry(order, smoke, autotune_cache)
    be, bf = int(entry["be"]), int(entry["bf"])
    interpret = bool(entry.get("interpret", jax.devices()[0].platform == "cpu"))
    K = s.mesh.K
    M = order + 1
    x, _ = lgl_nodes_weights(order)
    D = jnp.asarray(diff_matrix(x), jnp.float32)
    rng = np.random.default_rng(0)
    qk = jnp.asarray(rng.standard_normal((K, 9, M, M, M)), jnp.float32)
    ones = jnp.ones(K, jnp.float32)
    pv = jax.jit(lambda q: dg_volume_pallas(
        q, D, (2.0, 2.0, 2.0), ones, ones, jnp.zeros(K, jnp.float32),
        interpret=interpret, be=be))
    F = K * 3  # ~interior faces each shared by two elements
    Sm = jnp.asarray(rng.standard_normal((F, 6, M, M)), jnp.float32)
    vm = jnp.asarray(rng.standard_normal((F, 3, M, M)), jnp.float32)
    Sp = jnp.asarray(rng.standard_normal((F, 6, M, M)), jnp.float32)
    vp = jnp.asarray(rng.standard_normal((F, 3, M, M)), jnp.float32)
    mats = jnp.asarray(np.abs(rng.standard_normal((F, 8))) + 0.5, jnp.float32)
    pf = jax.jit(lambda *a: dg_flux_pallas(*a, 0, 1.0, interpret=interpret, bf=bf))
    t_pv = timeit(pv, qk, reps=reps)
    t_pf = timeit(pf, Sm, vm, Sp, vp, mats, reps=reps)
    emit("fig4_1/pallas_volume", t_pv * 1e6,
         f"BE={be} ({source}) {t_pv/K*1e9:.1f}ns/elem")
    emit("fig4_1/pallas_flux", t_pf * 1e6,
         f"BF={bf} ({source}) {t_pf/F*1e9:.1f}ns/face")

    result = {
        "config": {"grid": list(grid), "order": order, "K": int(K),
                   "smoke": bool(smoke)},
        "autotune": {
            "source": source,
            "device_kind": entry["device_kind"],
            "be": be,
            "bf": bf,
            "sec_per_element": entry["sec_per_element"],
            "launch_overhead_s": entry["launch_overhead_s"],
        },
        "seconds": {
            "volume_loop": t_vol,
            "int_flux_lift": t_surf,
            "interp_q": t_interp,
            "rk": t_rk,
            "full_rhs": t_rhs,
            "pallas_volume": t_pv,
            "pallas_flux": t_pf,
        },
        "shares_vs_paper": {
            "volume_loop": [100 * t_vol / total, PAPER_SHARES["volume_loop"]],
            "int_flux+lift": [100 * t_surf / total,
                              PAPER_SHARES["int_flux"] + PAPER_SHARES["lift+rk"] - 10],
            "interp_q": [100 * t_interp / total, PAPER_SHARES["interp_q"]],
        },
    }
    with open(JSON_PATH, "w") as f:
        json.dump(result, f, indent=2)
    emit("fig4_1/json", 0.0, JSON_PATH)
    return result


if __name__ == "__main__":
    run()
