"""Fused scan-compiled pipeline vs the Python-loop blocked driver.

Measures steps/sec and host dispatches/step for the blocked DG engine's two
drivers on the same engine and split:

* **unfused** — the historical Python-loop driver: 5 LSRK stages x P blocks
  x ~6 separate device calls per RHS evaluation, a fresh ``(K+1, ...)``
  scatter target per call, stage arithmetic dispatched eagerly;
* **fused** — ``runtime.pipeline.FusedStepPipeline``: the whole time loop
  as ONE donated program (``lax.scan`` over steps, scan over stages,
  same-bucket blocks batched into one launch per bucket).

Emits the usual CSV rows plus ``BENCH_pipeline.json`` (uploaded as a CI
artifact) so the fused-vs-unfused throughput ratio is tracked over time.

  PYTHONPATH=src python -m benchmarks.run --suite pipeline --smoke
"""

from __future__ import annotations

import json

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timeit
from repro.dg.rk import LSRK_A, LSRK_B
from repro.dg.solver import gaussian_pulse, make_two_tree_solver
from repro.runtime.executor import BlockedDGEngine, NestedPartitionExecutor

JSON_PATH = "BENCH_pipeline.json"


def _unfused_rhs(eng, q):
    """The seed's per-block rhs: fresh scatter target + sequential blocks."""
    K = eng.solver.mesh.K
    out = jnp.zeros((K + 1,) + tuple(q.shape[1:]), q.dtype)
    for b in eng._blocks:
        if b is None:
            continue
        out = out.at[b["scat"]].set(eng.block_rhs(q, b))
    return out[:K]


def _unfused_run(eng, q, n_steps, dt):
    """The seed's driver: Python loop over steps AND stages, eager updates."""
    res = jnp.zeros_like(q)
    for _ in range(n_steps):
        for s in range(5):
            res = LSRK_A[s] * res + dt * _unfused_rhs(eng, q)
            q = q + LSRK_B[s] * res
    jax.block_until_ready(q)
    return q


def run(grid=(8, 8, 4), order=4, partitions=4, bucket=16, n_steps=20, smoke=False):
    if smoke:
        grid, order, partitions, bucket, n_steps = (6, 4, 4), 2, 3, 8, 10
    reps = 1 if smoke else 3
    solver = make_two_tree_solver(grid=grid, order=order, extent=(2.0, 1.0, 1.0),
                                  dtype="float32")
    K = solver.mesh.K
    q0 = gaussian_pulse(solver, center=(0.5, 0.5, 0.5)).astype(jnp.float32)
    ex = NestedPartitionExecutor(K, partitions, grid_dims=grid, bucket=bucket)
    eng = BlockedDGEngine(solver, ex)
    pipe = eng.pipeline()
    dt = solver.cfl_dt()
    P = int((ex.counts > 0).sum())

    t_unfused = timeit(lambda: _unfused_run(eng, q0, n_steps, dt), reps=reps, warmup=1)
    t_fused = timeit(lambda: pipe.run(q0, n_steps, dt=dt), reps=reps, warmup=1)

    # host dispatches per step — an ANALYTIC count of the drivers timed in
    # THIS file, not a measurement: the `_unfused_run` Python-loop driver
    # issues, per stage, ~6 device calls per block (gather / interior /
    # assemble / boundary / fold / scatter) plus the scatter-target alloc,
    # final slice and 4 eager stage-update ops; the fused driver issues ONE
    # call for the whole run.
    disp_unfused = 5 * (6 * P + 2 + 4)
    disp_fused = 1.0 / n_steps
    sps_unfused = n_steps / t_unfused
    sps_fused = n_steps / t_fused
    speedup = t_unfused / t_fused

    result = {
        "config": {
            "grid": list(grid), "order": order, "K": K, "partitions": partitions,
            "bucket": bucket, "n_steps": n_steps, "smoke": bool(smoke),
            "buckets": [list(s) for s in pipe.bucket_signature],
        },
        "unfused": {"steps_per_sec": sps_unfused, "dispatches_per_step": disp_unfused},
        "fused": {"steps_per_sec": sps_fused, "dispatches_per_step": disp_fused},
        "speedup": speedup,
        # steps_per_sec is measured; dispatches_per_step is the analytic
        # count for the two drivers defined in benchmarks/pipeline_throughput
        "dispatch_model": "unfused: 5 stages x (6 calls x P blocks + alloc + "
                          "slice + 4 stage ops); fused: 1 dispatch / run",
    }
    with open(JSON_PATH, "w") as f:
        json.dump(result, f, indent=2)

    emit("pipeline/unfused_python_loop", t_unfused / n_steps * 1e6,
         f"{sps_unfused:.1f} steps/s; {disp_unfused} dispatches/step")
    emit("pipeline/fused_scan", t_fused / n_steps * 1e6,
         f"{sps_fused:.1f} steps/s; {disp_fused:.2f} dispatches/step")
    emit("pipeline/speedup", speedup, f"K={K} order={order} P={partitions}")
    assert np.isfinite(speedup)
    return result


if __name__ == "__main__":
    run()
