"""Fused scan-compiled pipeline vs the Python-loop blocked driver.

Measures steps/sec and host dispatches/step for the blocked DG engine's two
drivers on the same engine and split:

* **unfused** — the historical Python-loop driver: 5 LSRK stages x P blocks
  x ~6 separate device calls per RHS evaluation, a fresh ``(K+1, ...)``
  scatter target per call, stage arithmetic dispatched eagerly;
* **fused** — ``runtime.pipeline.FusedStepPipeline``: the whole time loop
  as ONE donated program (``lax.scan`` over steps, scan over stages,
  same-bucket blocks batched into one launch per bucket);
* **observe** — the same fused driver with the in-scan observation channel
  on (``run(observe=True)``): one ``run_observed`` dispatch per rebalance
  chunk, the executor fed a wall-attributed ``CalibrationReport`` per
  chunk.  The row's ``overhead_vs_fused`` tracks what continuous
  calibration costs; ``dispatches_per_step`` is measured on the
  ``DispatchStats`` ledger and CI gates it at exactly one dispatch per
  chunk.

With ``--devices N`` (and N visible devices) a third row measures the
**sharded** fused driver — ``runtime.pipeline.ShardedStepPipeline``, the
SPMD slab path's whole time loop as ONE donated ``shard_map`` program with
the ring ``ppermute`` halo exchange inside the compiled step loop.

Emits the usual CSV rows plus ``BENCH_pipeline.json`` (uploaded as a CI
artifact) so the fused-vs-unfused throughput ratio is tracked over time.

  PYTHONPATH=src python -m benchmarks.run --suite pipeline --smoke
  XLA_FLAGS=--xla_force_host_platform_device_count=4 \
      PYTHONPATH=src python -m benchmarks.run --suite pipeline --smoke --devices 4
"""

from __future__ import annotations

import json

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timeit
from repro.dg.rk import LSRK_A, LSRK_B
from repro.dg.solver import gaussian_pulse, make_two_tree_solver
from repro.runtime.executor import BlockedDGEngine, NestedPartitionExecutor

JSON_PATH = "BENCH_pipeline.json"


def _unfused_rhs(eng, q):
    """The seed's per-block rhs: fresh scatter target + sequential blocks."""
    K = eng.solver.mesh.K
    out = jnp.zeros((K + 1,) + tuple(q.shape[1:]), q.dtype)
    for b in eng._blocks:
        if b is None:
            continue
        out = out.at[b["scat"]].set(eng.block_rhs(q, b))
    return out[:K]


def _unfused_run(eng, q, n_steps, dt):
    """The seed's driver: Python loop over steps AND stages, eager updates."""
    res = jnp.zeros_like(q)
    for _ in range(n_steps):
        for s in range(5):
            res = LSRK_A[s] * res + dt * _unfused_rhs(eng, q)
            q = q + LSRK_B[s] * res
    jax.block_until_ready(q)
    return q


def _sharded_row(result, order, n_steps, devices, reps):
    """The multi-device row: the SPMD slab path's ShardedStepPipeline — one
    donated shard_map program (ring ppermute exchange inside the compiled
    step loop) across ``devices`` devices.  Requires the process to see that
    many devices (CI sets XLA_FLAGS=--xla_force_host_platform_device_count);
    emits a skip row otherwise."""
    from repro.dg.partitioned import PartitionedDG
    from repro.jax_compat import make_mesh

    n_avail = len(jax.devices())
    if n_avail < devices:
        emit(f"pipeline/fused_sharded_{devices}dev", 0.0,
             f"SKIPPED: {n_avail} device(s) visible, need {devices} "
             "(set XLA_FLAGS=--xla_force_host_platform_device_count)")
        result["sharded"] = {"devices": devices, "skipped": True,
                             "devices_visible": n_avail}
        return
    # nx must divide by the slab count; keep the element count close to the
    # single-arena rows so steps/sec stays comparable
    grid = (2 * devices, 4, 4)
    solver = make_two_tree_solver(grid=grid, order=order,
                                  extent=(2.0, 1.0, 1.0), dtype="float32")
    q0 = gaussian_pulse(solver, center=(0.5, 0.5, 0.5)).astype(jnp.float32)
    mesh = make_mesh((devices,), ("data",))
    pdg = PartitionedDG(solver=solver, mesh_axes=mesh)
    pipe = pdg.pipeline()
    dt = solver.cfl_dt()
    qp = pdg.permute_in(q0)
    t = timeit(lambda: jax.block_until_ready(pipe.run(qp, n_steps, dt=dt)),
               reps=reps, warmup=1)
    sps = n_steps / t
    emit(f"pipeline/fused_sharded_{devices}dev", t / n_steps * 1e6,
         f"{sps:.1f} steps/s; {1.0 / n_steps:.2f} dispatches/step; "
         f"K={solver.mesh.K}")
    result["sharded"] = {
        "devices": devices, "grid": list(grid), "K": solver.mesh.K,
        "steps_per_sec": sps, "dispatches_per_step": 1.0 / n_steps,
        "host_dispatches_per_run": 1,
    }


def run(grid=(8, 8, 4), order=4, partitions=4, bucket=16, n_steps=20, smoke=False,
        devices=1):
    if smoke:
        grid, order, partitions, bucket, n_steps = (6, 4, 4), 2, 3, 8, 10
    reps = 1 if smoke else 3
    solver = make_two_tree_solver(grid=grid, order=order, extent=(2.0, 1.0, 1.0),
                                  dtype="float32")
    K = solver.mesh.K
    q0 = gaussian_pulse(solver, center=(0.5, 0.5, 0.5)).astype(jnp.float32)
    ex = NestedPartitionExecutor(K, partitions, grid_dims=grid, bucket=bucket)
    eng = BlockedDGEngine(solver, ex)
    pipe = eng.pipeline()
    dt = solver.cfl_dt()
    P = int((ex.counts > 0).sum())

    t_unfused = timeit(lambda: _unfused_run(eng, q0, n_steps, dt), reps=reps, warmup=1)
    t_fused = timeit(lambda: pipe.run(q0, n_steps, dt=dt), reps=reps, warmup=1)

    # observe-overhead row: the in-scan observation channel at rebalance-
    # chunk granularity (run_observed per chunk, one dispatch each) on its
    # own engine/executor, so the timed rebalances never touch the fused
    # row's tables.  The ledgered dispatch count is the acceptance gate CI
    # asserts on: observation must never drop below 1 dispatch per chunk.
    chunk = max(1, n_steps // 4)
    ex_obs = NestedPartitionExecutor(K, partitions, grid_dims=grid, bucket=bucket,
                                     rebalance_every=chunk)
    eng_obs = BlockedDGEngine(solver, ex_obs)
    pipe_obs = eng_obs.pipeline()
    t_observe = timeit(
        lambda: jax.block_until_ready(eng_obs.run(q0, n_steps, dt=dt, observe=True)),
        reps=reps, warmup=1,
    )
    sps_observe = n_steps / t_observe
    disp_observe = pipe_obs.stats.dispatches / max(1, pipe_obs.stats.steps_run)

    # host dispatches per step — an ANALYTIC count of the drivers timed in
    # THIS file, not a measurement: the `_unfused_run` Python-loop driver
    # issues, per stage, ~6 device calls per block (gather / interior /
    # assemble / boundary / fold / scatter) plus the scatter-target alloc,
    # final slice and 4 eager stage-update ops; the fused driver issues ONE
    # call for the whole run.
    disp_unfused = 5 * (6 * P + 2 + 4)
    disp_fused = 1.0 / n_steps
    sps_unfused = n_steps / t_unfused
    sps_fused = n_steps / t_fused
    speedup = t_unfused / t_fused

    result = {
        "config": {
            "grid": list(grid), "order": order, "K": K, "partitions": partitions,
            "bucket": bucket, "n_steps": n_steps, "smoke": bool(smoke),
            "buckets": [list(s) for s in pipe.bucket_signature],
        },
        "unfused": {"steps_per_sec": sps_unfused, "dispatches_per_step": disp_unfused},
        "fused": {"steps_per_sec": sps_fused, "dispatches_per_step": disp_fused},
        "observe": {
            "steps_per_sec": sps_observe,
            # measured on the DispatchStats ledger, not analytic
            "dispatches_per_step": disp_observe,
            "chunk": chunk,
            "observe_chunks": pipe_obs.stats.observe_chunks,
            "overhead_vs_fused": t_observe / t_fused - 1.0,
        },
        "speedup": speedup,
        # steps_per_sec is measured; dispatches_per_step is the analytic
        # count for the two drivers defined in benchmarks/pipeline_throughput
        "dispatch_model": "unfused: 5 stages x (6 calls x P blocks + alloc + "
                          "slice + 4 stage ops); fused: 1 dispatch / run",
    }
    if devices > 1:
        _sharded_row(result, order, n_steps, devices, reps)
    with open(JSON_PATH, "w") as f:
        json.dump(result, f, indent=2)

    emit("pipeline/unfused_python_loop", t_unfused / n_steps * 1e6,
         f"{sps_unfused:.1f} steps/s; {disp_unfused} dispatches/step")
    emit("pipeline/fused_scan", t_fused / n_steps * 1e6,
         f"{sps_fused:.1f} steps/s; {disp_fused:.2f} dispatches/step")
    emit("pipeline/fused_observe", t_observe / n_steps * 1e6,
         f"{sps_observe:.1f} steps/s; {disp_observe:.2f} dispatches/step; "
         f"chunk={chunk}; overhead {100 * (t_observe / t_fused - 1.0):+.1f}% "
         "vs fused")
    emit("pipeline/speedup", speedup, f"K={K} order={order} P={partitions}")
    assert np.isfinite(speedup)
    return result


if __name__ == "__main__":
    run()
