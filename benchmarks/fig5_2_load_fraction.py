"""Paper Fig 5.2: estimated CPU/MIC runtimes vs accelerator load fraction;
the crossing is the optimal split.  Reproduces the published optimum
(K_MIC/K_CPU ~= 1.6) from the calibrated models and sweeps the sensitivity
(per-stage vs per-step halo exchange; pure-roofline vs calibrated models).

Extension: the same node run through the ONLINE executor
(``repro.runtime.executor``) — makespan before/after N rebalance rounds from
a naive 50/50 start, and the recovery after a 2x straggler injection.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import emit
from repro.core.cost_model import stampede_node_models, transfer_time_fn
from repro.core.load_balance import solve_two_way
from repro.runtime.executor import NestedPartitionExecutor


def run(K=8192, order=7, smoke=False):
    if smoke:
        K, order = 1024, 3
    t_cpu, t_mic, xfer = stampede_node_models(order)
    # the Fig 5.2 curves: host side vs accel side across fractions
    rows = []
    for frac in np.linspace(0.05, 0.95, 19):
        k_mic = int(K * frac)
        host = t_cpu(K - k_mic) + xfer(k_mic)
        mic = t_mic(k_mic)
        rows.append((frac, host, mic))
    cross = min(rows, key=lambda r: abs(r[1] - r[2]))
    emit("fig5_2/crossing_fraction", cross[0] * 100, f"host {cross[1]*1e3:.1f}ms == mic {cross[2]*1e3:.1f}ms")

    res = solve_two_way(t_cpu, t_mic, K, transfer=xfer)
    emit("fig5_2/solver_ratio", res.ratio * 100, f"K_MIC/K_CPU={res.ratio:.2f} (paper 1.6)")

    # sensitivity: per-RK-stage halo exchange (conservative variant)
    xfer_stage = transfer_time_fn(order, per_stage=True)
    res2 = solve_two_way(t_cpu, t_mic, K, transfer=xfer_stage)
    emit("fig5_2/ratio_perstage_halo", res2.ratio * 100, f"ratio={res2.ratio:.2f}")

    # sensitivity: pure roofline (no measured efficiencies)
    t_cpu_r, t_mic_r, _ = stampede_node_models(order, calibrated=False)
    res3 = solve_two_way(t_cpu_r, t_mic_r, K, transfer=xfer)
    emit("fig5_2/ratio_pure_roofline", res3.ratio * 100,
         f"ratio={res3.ratio:.2f} (peak-derived; the paper's measured tables differ)")

    # --- online executor: makespan before/after N rebalance rounds ---------
    # host charged the PCI transfer (paper section 5.6); naive 50/50 start
    models = [lambda k: t_cpu(k) + xfer(k), t_mic]
    ex = NestedPartitionExecutor(K, 2, bucket=32, time_models=models)
    before = float(max(ex.simulated_times()))
    ex.calibrate(n_steps=1)
    rounds = ex.run_until_balanced(rtol=0.02, max_rounds=6)
    after = ex.predicted_makespan()
    emit("fig5_2/online_makespan_us", after * 1e6,
         f"before={before * 1e6:.0f}us after {rounds} rounds "
         f"(opt {ex.optimal_makespan() * 1e6:.0f}us) counts={ex.counts.tolist()}")

    # straggler recovery: 2x slowdown on the accelerator side
    ex.inject_straggler(1, 2.0)
    hit = float(ex.simulated_times()[1] * 2.0)  # partition 1 now takes 2x
    rounds2 = ex.run_until_balanced(rtol=0.05, max_rounds=6)
    emit("fig5_2/straggler_recovery_us", ex.predicted_makespan() * 1e6,
         f"hit={hit * 1e6:.0f}us rebalanced in {rounds2} rounds "
         f"counts={ex.counts.tolist()}")
    return res


if __name__ == "__main__":
    run()
