"""Chaos recovery: what each failure class costs the supervised fused loop.

Runs the same fused ``SimulatedCluster`` horizon under the
``RunSupervisor`` with one failure class injected per scenario:

* **baseline**    — supervised, failure-free (the reference wall + q);
* **transient**   — an injected chunk fault absorbed by retry;
* **restore**     — retries exhausted: checkpoint restore + replay;
* **straggler_eject** — a 10x straggler flagged by the StepTimer EWMA and
  ejected (weight -> 0, survivors re-spliced);
* **node_leave** / **node_join** — elastic membership mid-run.

Per class it reports recovery latency (seconds spent in backoff +
restore), replayed steps, retries/restarts, makespan overhead vs the
failure-free run — and the two hard gates CI enforces from
``BENCH_chaos.json``: ``bitwise_recovered`` (final q identical to the
uninterrupted run) and ``dispatches_per_chunk == 1.0`` (recovery never
un-fuses the loop, by the ``DispatchStats`` ledger).

  PYTHONPATH=src python -m benchmarks.run --suite chaos --smoke
"""

from __future__ import annotations

import json
import time

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit

JSON_PATH = "BENCH_chaos.json"


def _solver(grid):
    from repro.dg.mesh import make_brick
    from repro.dg.solver import DGSolver

    mesh = make_brick(grid, (1.0, 1.0, 0.5), periodic=True)
    K = mesh.K
    return DGSolver(mesh=mesh, order=2, rho=np.ones(K), lam=np.ones(K), mu=np.zeros(K))


def run(smoke: bool = False):
    from repro.runtime import (
        FailureInjector,
        NodeProfile,
        RunSupervisor,
        SimulatedCluster,
        StepTimer,
    )

    grid = (4, 4, 2) if smoke else (6, 6, 4)
    n_steps = 8 if smoke else 16
    solver = _solver(grid)
    rng = np.random.default_rng(0)
    q0 = jnp.asarray(
        rng.standard_normal((solver.mesh.K, 9, solver.M, solver.M, solver.M))
    )
    dt = solver.cfl_dt()

    def cluster(**kw):
        return SimulatedCluster(
            solver, [NodeProfile(name=f"n{i}") for i in range(3)],
            rebalance_every=2, **kw,
        )

    # uninterrupted fused reference (the bitwise target for every scenario)
    q_ref = np.asarray(cluster().run(q0, n_steps, dt=dt, observe=True))

    def scenario(name, make_sup, mutate=None):
        cl = cluster()
        sup = make_sup(cl)
        if mutate is not None:
            mutate(cl, sup)
        t0 = time.perf_counter()
        q = np.asarray(sup.run(q0, n_steps, dt=dt))
        wall = time.perf_counter() - t0
        led = sup.ledger()
        return {
            "scenario": name,
            "wall_s": round(wall, 4),
            "recovery_s": round(sup.recovery_s, 4),
            "retries": sup.retries,
            "restarts": sup.restarts,
            "replayed_steps": sup.replayed_steps,
            "ejected": list(sup.ejected),
            "chunks_run": sup.chunks_run,
            "bitwise_recovered": bool((q == q_ref).all()),
            "dispatches_per_chunk": (
                led["dispatches"] / led["chunks_run"] if led["chunks_run"] else 0.0
            ),
        }

    results = []

    results.append(scenario("baseline", lambda cl: RunSupervisor(cl)))

    results.append(scenario(
        "transient",
        lambda cl: RunSupervisor(
            cl, max_retries=2,
            injector=FailureInjector({2: "transient"}),
        ),
    ))

    results.append(scenario(
        "restore",
        lambda cl: RunSupervisor(
            cl, max_retries=0, ckpt_every_chunks=1,
            injector=FailureInjector({4: "node-loss"}),
        ),
    ))

    def _straggle(cl, sup):
        cl.inject_straggler(1, 10.0)

    results.append(scenario(
        "straggler_eject",
        lambda cl: RunSupervisor(
            cl, timer=StepTimer(alpha=1.0, straggler_factor=1.5), eject_after=1,
        ),
        mutate=_straggle,
    ))

    def _leave(cl, sup):
        sup.at_step(n_steps // 2, lambda: cl.remove_node(1))

    results.append(scenario("node_leave", lambda cl: RunSupervisor(cl), mutate=_leave))

    def _join(cl, sup):
        from repro.runtime import NodeProfile as NP

        sup.at_step(n_steps // 2, lambda: cl.add_node(NP(name="n3")))

    results.append(scenario("node_join", lambda cl: RunSupervisor(cl), mutate=_join))

    base_wall = results[0]["wall_s"]
    for r in results:
        r["makespan_overhead"] = round(r["wall_s"] / base_wall - 1.0, 4) if base_wall else 0.0
        emit(
            f"chaos_{r['scenario']}",
            r["wall_s"] * 1e6,
            f"bitwise={int(r['bitwise_recovered'])} "
            f"dpc={r['dispatches_per_chunk']:.2f} "
            f"recovery_s={r['recovery_s']} replayed={r['replayed_steps']} "
            f"overhead={r['makespan_overhead']:+.0%}",
        )

    with open(JSON_PATH, "w") as f:
        json.dump(
            {
                "smoke": smoke, "grid": list(grid), "n_steps": n_steps,
                "nodes": 3, "scenarios": results,
            },
            f, indent=2,
        )
    print(f"# wrote {JSON_PATH}")


if __name__ == "__main__":
    run(smoke=True)
