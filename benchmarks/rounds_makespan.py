"""Multi-round re-aggregation makespan benchmark -> BENCH_rounds.json.

Solves :func:`repro.runtime.rounds.plan_rounds` over several heterogeneous
node mixes and compares the modeled end-to-end makespan against the
single-round baseline (apportion once over all workers, then the fastest
worker alone folds every shard).  Multi-round wins when the fleet is wide
and the skew mild — re-aggregating over a shrinking worker set keeps the
merge parallel — and loses to a single aggregator when one worker is so
much faster than the rest that handing it everything after round 1 beats
any tree (r_best * (n_rounds - 1) > sum of rates).  Both regimes are
reported; the ``*_hostile`` row is the honest counter-example, and CI
gates ``speedup > 1`` only on the favourable mixes.

Purely modeled (the round solver is closed-form over calibrated rates), so
the sweep is deterministic and host-speed independent.
"""

from __future__ import annotations

import json

JSON_PATH = "BENCH_rounds.json"

# (name, rates, expect_speedup): wide fleets with mild skew favour the
# round tree; the hostile mix (one 4x node in a small fleet) favours the
# single aggregator and is kept as a model-honesty regression row
MIXES = (
    ("uniform12", [1.0] * 12, True),
    ("skewed12", [2.0, 2.0, 2.0] + [1.0] * 9, True),
    ("twotier12", [4 * [2.0] + 8 * [1.0]][0], True),
    ("hostile8", [4.0, 2.0, 2.0] + [1.0] * 5, False),
)


def run(n_items=4096, shrink=1.6, smoke=False, seed=0):
    from benchmarks.common import emit
    from repro.runtime.rounds import RoundWorker, plan_rounds

    if smoke:
        n_items = 512

    results = []
    for name, rates, expect in MIXES:
        workers = [RoundWorker(f"n{i}", r) for i, r in enumerate(rates)]
        plan = plan_rounds(n_items, workers, shrink=shrink)
        spans = plan.round_makespans
        # equal-cost construction: every round's modeled makespan == round 1's
        assert all(abs(s - spans[0]) < 1e-6 * max(spans[0], 1.0) for s in spans)
        if expect:
            assert plan.speedup_vs_single_round > 1.0, (
                f"{name}: expected multi-round to beat the single aggregator, "
                f"got x{plan.speedup_vs_single_round:.3f}"
            )
        row = {
            "mix": name,
            "rates": list(rates),
            "n_workers": len(rates),
            "n_items": n_items,
            "shrink": shrink,
            "n_rounds": plan.n_rounds,
            "worker_counts": plan.worker_counts,
            "round_makespans_s": spans,
            "makespan_s": plan.makespan,
            "single_round_makespan_s": plan.single_round_makespan,
            "speedup_vs_single_round": plan.speedup_vs_single_round,
        }
        results.append(row)
        emit(
            f"rounds_{name}",
            plan.makespan * 1e6,
            f"rounds={plan.n_rounds} workers={plan.worker_counts} "
            f"single={plan.single_round_makespan * 1e6:.0f}us "
            f"speedup=x{plan.speedup_vs_single_round:.2f}",
        )

    result = {
        "n_items": n_items,
        "shrink": shrink,
        "mixes": results,
        "best_speedup": max(r["speedup_vs_single_round"] for r in results),
    }
    with open(JSON_PATH, "w") as f:
        json.dump(result, f, indent=2, allow_nan=False)
    print(f"wrote {JSON_PATH}")
    return result


if __name__ == "__main__":
    run(smoke=True)
