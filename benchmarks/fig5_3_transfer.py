"""Paper Fig 5.3: CPU<->accelerator transfer time vs message size.

Two curves: (a) measured host<->device transfer on THIS machine
(device_put + device_get of pinned numpy arrays — the PCI analogue), and
(b) the alpha-beta models for the paper's PCI bus and the target fabric
(ICI / DCN) used by the cost model.
"""

from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import emit
from repro.core.topology import DCN_LINK, ICI_LINK, STAMPEDE_PCI


def run(smoke=False):
    sizes = (1, 8) if smoke else (1, 8, 64, 256)
    model_sizes = (1, 8) if smoke else (1, 64, 256)
    for mb in sizes:
        arr = np.random.default_rng(0).standard_normal(mb * 131072).astype(np.float64)  # mb MiB
        t0 = time.perf_counter()
        d = jax.device_put(arr)
        d.block_until_ready()
        _ = np.asarray(d)
        dt = time.perf_counter() - t0
        emit(f"fig5_3/measured_roundtrip_{mb}MiB", dt * 1e6, f"{2*mb/1024/dt:.2f} GiB/s eff")
    for mb in model_sizes:
        nbytes = mb * 2**20
        emit(f"fig5_3/model_pci_{mb}MiB", STAMPEDE_PCI.time(nbytes) * 1e6, "paper PCI 6GB/s")
        emit(f"fig5_3/model_ici_{mb}MiB", ICI_LINK.time(nbytes) * 1e6, "v5e ICI 50GB/s/link")
        emit(f"fig5_3/model_dcn_{mb}MiB", DCN_LINK.time(nbytes) * 1e6, "inter-pod DCN")


if __name__ == "__main__":
    run()
