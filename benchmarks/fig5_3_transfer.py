"""Paper Fig 5.3: CPU<->accelerator transfer time vs message size.

Three sections: (a) measured host<->device transfer on THIS machine
(device_put + device_get of pinned numpy arrays — the PCI analogue), and
(b) the alpha-beta models for the paper's PCI bus and the target fabric
(ICI / DCN) used by the cost model, and (c) the modeled two-way makespan
with the boundary/interior overlap schedule on vs off (``--overlap``):
with overlap the host hides the shared-face transfer under its interior
compute (host side costs ``max(t_host, transfer)`` instead of
``t_host + transfer``), so for transfer-bound shapes the solved makespan is
strictly lower and the delta row reports exactly how much the schedule buys.
"""

from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import emit
from repro.core.cost_model import stampede_node_models, transfer_time_fn
from repro.core.load_balance import solve_two_way
from repro.core.topology import DCN_LINK, ICI_LINK, STAMPEDE_PCI


def _overlap_makespans(K: int, order: int, per_stage: bool):
    """(makespan_off, makespan_on) for the paper's node at problem size K.

    ``per_stage=True`` uses the conservative halo-per-RK-stage transfer
    model (5x the bytes) — the transfer-bound regime where the overlap
    schedule matters most."""
    t_cpu, t_mic, _ = stampede_node_models(order=order)
    xfer = transfer_time_fn(order, per_stage=per_stage)
    off = solve_two_way(t_cpu, t_mic, K, transfer=xfer, overlap=False)
    on = solve_two_way(t_cpu, t_mic, K, transfer=xfer, overlap=True)
    return off, on


def run(smoke=False, overlap="both"):
    sizes = (1, 8) if smoke else (1, 8, 64, 256)
    model_sizes = (1, 8) if smoke else (1, 64, 256)
    for mb in sizes:
        arr = np.random.default_rng(0).standard_normal(mb * 131072).astype(np.float64)  # mb MiB
        t0 = time.perf_counter()
        d = jax.device_put(arr)
        d.block_until_ready()
        _ = np.asarray(d)
        dt = time.perf_counter() - t0
        emit(f"fig5_3/measured_roundtrip_{mb}MiB", dt * 1e6, f"{2*mb/1024/dt:.2f} GiB/s eff")
    for mb in model_sizes:
        nbytes = mb * 2**20
        emit(f"fig5_3/model_pci_{mb}MiB", STAMPEDE_PCI.time(nbytes) * 1e6, "paper PCI 6GB/s")
        emit(f"fig5_3/model_ici_{mb}MiB", ICI_LINK.time(nbytes) * 1e6, "v5e ICI 50GB/s/link")
        emit(f"fig5_3/model_dcn_{mb}MiB", DCN_LINK.time(nbytes) * 1e6, "inter-pod DCN")

    # modeled two-way makespan: boundary/interior overlap schedule on vs off
    Ks = (2048,) if smoke else (2048, 8192)
    for K in Ks:
        off, on = _overlap_makespans(K, order=7, per_stage=True)
        if overlap in ("off", "both"):
            emit(f"fig5_3/makespan_overlap_off_K{K}", off.makespan * 1e6,
                 f"host t+xfer; split {off.counts[0]}/{off.counts[1]}")
        if overlap in ("on", "both"):
            emit(f"fig5_3/makespan_overlap_on_K{K}", on.makespan * 1e6,
                 f"host max(t|xfer); split {on.counts[0]}/{on.counts[1]}")
        if overlap == "both":
            delta = off.makespan - on.makespan
            emit(f"fig5_3/makespan_overlap_delta_K{K}", delta * 1e6,
                 f"{delta / off.makespan:.1%} hidden by the schedule")


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--overlap", choices=["on", "off", "both"], default="both",
                    help="emit the modeled makespan with the overlap schedule on/off")
    a = ap.parse_args()
    print("name,us_per_call,derived")
    run(smoke=a.smoke, overlap=a.overlap)
