"""Paper Fig 6.2: per-kernel baseline vs optimized performance.

Per kernel: 'baseline' = the straightforward formulation (unblocked, no
layout tuning); 'optimized' = this repo's production formulation (fused jit
for the DG kernels, blocked online-softmax for attention).  Reported as
speedup per kernel — the analogue of the paper's vectorized-vs-baseline
bars (their volume_loop ~2x, int_flux ~5x).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, timeit
from repro.dg.operators import deriv, volume_rhs
from repro.dg.solver import gaussian_pulse, make_two_tree_solver
from repro.models.attention import flash_attention, naive_attention


def run(smoke=False):
    reps = 1 if smoke else 3
    grid, order = ((4, 2, 2), 3) if smoke else ((8, 4, 4), 5)
    s = make_two_tree_solver(grid=grid, order=order, extent=(2.0, 1.0, 1.0), dtype="float32")
    q = gaussian_pulse(s, center=(0.5, 0.5, 0.5)).astype(jnp.float32)

    # volume_loop: per-axis unfused derivatives (baseline) vs fused rhs term
    def vol_baseline(q):
        outs = []
        for a in range(3):  # separate launches per axis per field group
            outs.append(jax.jit(lambda u, a=a: deriv(u, s.D, a))(q))
        return outs

    vol_opt = jax.jit(lambda q: volume_rhs(q, s.D, s.metrics, s.rho_j, s.lam_j, s.mu_j))
    t_b = timeit(vol_baseline, q, reps=reps)
    t_o = timeit(vol_opt, q, reps=reps)
    emit("fig6_2/volume_baseline", t_b * 1e6, "")
    emit("fig6_2/volume_optimized", t_o * 1e6, f"{t_b/t_o:.2f}x (paper ~2x)")

    # attention (the LM hot-spot): naive O(S^2) materialized vs blocked flash
    B, H, S, D = (1, 2, 256, 64) if smoke else (1, 8, 1024, 64)
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    qa = jax.random.normal(ks[0], (B, H, S, D), jnp.float32)
    ka = jax.random.normal(ks[1], (B, H, S, D), jnp.float32)
    va = jax.random.normal(ks[2], (B, H, S, D), jnp.float32)
    naive = jax.jit(lambda q, k, v: naive_attention(q, k, v, causal=True))
    flash = jax.jit(lambda q, k, v: flash_attention(q, k, v, causal=True, block_q=256, block_k=256))
    t_n = timeit(naive, qa, ka, va, reps=reps)
    t_f = timeit(flash, qa, ka, va, reps=reps)
    emit("fig6_2/attention_naive", t_n * 1e6, "materialized scores")
    emit("fig6_2/attention_flash", t_f * 1e6, f"{t_n/t_f:.2f}x, O(S*Bk) memory")

    # SWA long-context: full sweep vs windowed slicing
    S2, W = (1024, 128) if smoke else (8192, 512)
    q2 = jax.random.normal(ks[0], (1, 2, S2, 64), jnp.float32)
    k2 = jax.random.normal(ks[1], (1, 2, S2, 64), jnp.float32)
    v2 = jax.random.normal(ks[2], (1, 2, S2, 64), jnp.float32)
    full = jax.jit(lambda q, k, v: flash_attention(q, k, v, causal=True, block_q=256, block_k=256))
    swa = jax.jit(lambda q, k, v: flash_attention(q, k, v, causal=True, window=W, block_q=256, block_k=256))
    t_full = timeit(full, q2, k2, v2, reps=reps)
    t_swa = timeit(swa, q2, k2, v2, reps=reps)
    emit("fig6_2/attn8k_full", t_full * 1e6, "")
    emit("fig6_2/attn8k_swa512", t_swa * 1e6, f"{t_full/t_swa:.2f}x via window slicing")


if __name__ == "__main__":
    run()
