"""Paper Table 6.1: baseline MPI-only vs optimized (vectorized + threaded +
accelerator-offloaded nested partition) wall time.

Two reproductions:

(a) MEASURED on this machine: 'baseline' = the per-rank execution pattern
    (8 independent subdomain rhs calls, unfused — the 8-MPI-ranks analogue);
    'optimized' = the fused whole-node jit (vectorized, single launch).
    This isolates the vectorization/fusion axis of the paper's win.

(b) MODELED on the paper's hardware: the calibrated Stampede cost models +
    the solved nested split -> predicted node wall time baseline vs
    optimized; the paper reports 6.3x on 1 node, 5.6x on 64.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, timeit
from repro.core.cost_model import stampede_calibration, stampede_node_models
from repro.core.load_balance import solve_two_way
from repro.dg.solver import gaussian_pulse, make_two_tree_solver


def run(grid=(8, 8, 4), order=4, n_ranks=8, smoke=False):
    if smoke:
        grid, order = (4, 4, 2), 3
    reps = 1 if smoke else 3
    s = make_two_tree_solver(grid=grid, order=order, extent=(2.0, 1.0, 1.0), dtype="float32")
    q = gaussian_pulse(s, center=(0.5, 0.5, 0.5)).astype(jnp.float32)
    K = s.mesh.K

    # (a) measured: the same rhs executed eagerly op-by-op (the analogue of
    # the unfused, per-kernel baseline) vs the fused whole-node jit
    def baseline(qq):
        with jax.disable_jit():
            return s.rhs(qq)

    fused = jax.jit(s.rhs)
    t_base = timeit(baseline, q, reps=1 if smoke else 2, warmup=1)
    t_opt = timeit(fused, q, reps=reps)
    emit("table6_1/measured_baseline_rhs", t_base * 1e6, "eager op-by-op (unfused)")
    emit("table6_1/measured_optimized_rhs", t_opt * 1e6, "fused whole-node jit")
    emit("table6_1/measured_speedup", t_base / t_opt * 100, f"{t_base/t_opt:.2f}x (fusion/vectorization axis)")

    # (b) modeled Stampede node: baseline = 8 serial-core ranks, optimized =
    # vectorized socket + MIC at the solved split
    tabs = stampede_calibration(order=7)
    cpu_tab = tabs["snb-socket"]
    t_cpu, t_mic, xfer = stampede_node_models(order=7)
    K_paper = 8192
    # baseline: the same socket does ALL K elements, but un-vectorized
    # (paper Fig 6.2 shows ~2-5x kernel gains from vectorization; use 3x)
    t_baseline = t_cpu(K_paper) * 3.0
    res = solve_two_way(t_cpu, t_mic, K_paper, transfer=xfer)
    t_optimized = res.makespan
    emit("table6_1/model_baseline_ms", t_baseline * 1e3, "unvectorized socket, all elements")
    emit("table6_1/model_optimized_ms", t_optimized * 1e3, f"split {res.counts}")
    emit("table6_1/model_speedup", t_baseline / t_optimized * 100,
         f"{t_baseline/t_optimized:.1f}x (paper: 6.3x @1 node)")
    return t_base / t_opt


if __name__ == "__main__":
    run()
