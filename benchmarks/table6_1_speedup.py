"""Paper Table 6.1: baseline MPI-only vs optimized (vectorized + threaded +
accelerator-offloaded nested partition) wall time.

Three reproductions:

(a) MEASURED on this machine: 'baseline' = the per-rank execution pattern
    (8 independent subdomain rhs calls, unfused — the 8-MPI-ranks analogue);
    'optimized' = the fused whole-node jit (vectorized, single launch).
    This isolates the vectorization/fusion axis of the paper's win.

(b) MODELED on the paper's hardware: the calibrated Stampede cost models +
    the solved nested split -> predicted node wall time baseline vs
    optimized; the paper reports 6.3x on 1 node, 5.6x on 64.

(c) WEAK SCALING across simulated node counts (the table's node axis): one
    speedup-vs-nodes CSV row per N — 8192 elements per node, each node a
    Stampede profile, the two-level ``solve_hierarchical`` split, and the
    inter-node halo exchange priced by the InfiniBand alpha-beta model on
    the chunk's Morton-compact surface.  The N=1 row is solved through the
    same hierarchical path and must match the single-node calibrated
    makespan of (b) within tolerance (asserted here, covered in tests).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, timeit
from repro.core.cost_model import (
    inter_node_transfer_fn,
    stampede_calibration,
    stampede_node_models,
)
from repro.core.load_balance import NodeModel, solve_hierarchical, solve_two_way
from repro.dg.solver import gaussian_pulse, make_two_tree_solver


def weak_scaling_rows(node_counts=(1, 2, 4, 8, 16, 32, 64), K_node=8192, order=7):
    """(N, baseline_s, optimized_s, per-node ratio) per simulated node count.

    Weak scaling: K grows with N (8192 elements per node, the paper's
    working set).  Baseline = unvectorized MPI-only socket time plus the
    same halo exchange; optimized = the hierarchical two-level solve."""
    t_cpu, t_mic, xfer = stampede_node_models(order)

    rows = []
    for n in node_counts:
        # shared-surface fraction grows with the fleet: an N=2 chunk shares
        # one face plane, an interior chunk at large N its whole surface
        inter = inter_node_transfer_fn(
            order, surface_fraction=1.0 - 1.0 / n, n_messages=min(n - 1, 6)
        )
        node = NodeModel(
            t_host=t_cpu, t_accel=t_mic, transfer=xfer,
            inter_transfer=inter if n > 1 else None,
        )
        hs = solve_hierarchical([node] * n, K_node * n)
        baseline = t_cpu(K_node) * 3.0 + inter(K_node)  # unvectorized ranks + same halo
        rows.append((n, baseline, hs.makespan, hs.ratios[0]))
    return rows


def run(grid=(8, 8, 4), order=4, n_ranks=8, smoke=False):
    if smoke:
        grid, order = (4, 4, 2), 3
    reps = 1 if smoke else 3
    s = make_two_tree_solver(grid=grid, order=order, extent=(2.0, 1.0, 1.0), dtype="float32")
    q = gaussian_pulse(s, center=(0.5, 0.5, 0.5)).astype(jnp.float32)
    K = s.mesh.K

    # (a) measured: the same rhs executed eagerly op-by-op (the analogue of
    # the unfused, per-kernel baseline) vs the fused whole-node jit
    def baseline(qq):
        with jax.disable_jit():
            return s.rhs(qq)

    fused = jax.jit(s.rhs)
    t_base = timeit(baseline, q, reps=1 if smoke else 2, warmup=1)
    t_opt = timeit(fused, q, reps=reps)
    emit("table6_1/measured_baseline_rhs", t_base * 1e6, "eager op-by-op (unfused)")
    emit("table6_1/measured_optimized_rhs", t_opt * 1e6, "fused whole-node jit")
    emit("table6_1/measured_speedup", t_base / t_opt * 100, f"{t_base/t_opt:.2f}x (fusion/vectorization axis)")

    # (b) modeled Stampede node: baseline = 8 serial-core ranks, optimized =
    # vectorized socket + MIC at the solved split
    tabs = stampede_calibration(order=7)
    cpu_tab = tabs["snb-socket"]
    t_cpu, t_mic, xfer = stampede_node_models(order=7)
    K_paper = 8192
    # baseline: the same socket does ALL K elements, but un-vectorized
    # (paper Fig 6.2 shows ~2-5x kernel gains from vectorization; use 3x)
    t_baseline = t_cpu(K_paper) * 3.0
    res = solve_two_way(t_cpu, t_mic, K_paper, transfer=xfer)
    t_optimized = res.makespan
    emit("table6_1/model_baseline_ms", t_baseline * 1e3, "unvectorized socket, all elements")
    emit("table6_1/model_optimized_ms", t_optimized * 1e3, f"split {res.counts}")
    emit("table6_1/model_speedup", t_baseline / t_optimized * 100,
         f"{t_baseline/t_optimized:.1f}x (paper: 6.3x @1 node)")

    # (c) weak scaling across simulated node counts — one CSV row per N
    node_counts = (1, 2, 4) if smoke else (1, 2, 4, 8, 16, 32, 64)
    for n, base_n, opt_n, ratio in weak_scaling_rows(node_counts, order=7):
        emit(f"table6_1/weak_scaling_n{n}", opt_n * 1e6,
             f"speedup={base_n / opt_n:.2f}x baseline={base_n * 1e3:.1f}ms "
             f"K={8192 * n} K_acc/K_host={ratio:.2f} (paper: 6.3x @1 -> 5.6x @64)")
        if n == 1:
            # acceptance: the hierarchical N=1 row reproduces the single-node
            # calibrated makespan of reproduction (b)
            drift = abs(opt_n - t_optimized) / t_optimized
            assert drift < 1e-6, (opt_n, t_optimized)
            emit("table6_1/weak_n1_matches_single_node", drift * 1e6,
                 f"|hierarchical - two_way| / two_way = {drift:.2e}")
    return t_base / t_opt


if __name__ == "__main__":
    run()
