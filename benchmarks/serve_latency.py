"""Serving-loop latency/throughput benchmark -> BENCH_serve.json.

Drives ``repro.runtime.serving.ContinuousBatchingLoop`` over synthetic
Poisson arrival traces at several offered loads (fractions of the modeled
full-pool service rate) and reports, per load point: request throughput,
token throughput, p50/p99 time-to-first-token, and shed rate.

The loop runs on its virtual clock — decode chunks priced from one real
calibration pass — so the sweep is deterministic, host-speed independent
and CI-safe.  The sub-capacity point doubles as a regression gate: at
0.3x the service rate nothing may be shed (CI asserts shed_rate == 0).
"""

from __future__ import annotations

import json

import numpy as np

JSON_PATH = "BENCH_serve.json"

# offered load as a fraction of the modeled full-pool service rate; ≥ 3
# points per the acceptance bar, spanning under- to over-subscription
LOADS = (0.3, 1.0, 3.0)


def run(arch="qwen2-7b", capacity=4, chunk=4, prompt_len=16, max_new=8,
        n_requests=24, smoke=False, seed=0):
    from benchmarks.common import emit
    from repro.runtime.serving import (
        ContinuousBatchingLoop,
        ServeKernels,
        build_lm,
        poisson_trace,
    )

    if smoke:
        capacity, chunk, prompt_len, max_new, n_requests = 2, 2, 8, 4, 8

    cfg, lm, params, mesh = build_lm(arch, smoke=True, seed=seed)
    kernels = ServeKernels(lm, mesh, max_len=prompt_len + max_new)

    # one calibration, shared across load points: same pricing for every
    # sweep row (and one compile set — the loop reuses the kernels)
    base = ContinuousBatchingLoop(
        kernels, params, capacity=capacity, chunk=chunk, calib_gen=3
    )
    base._ensure_calibrated(
        poisson_trace(capacity, 1.0, prompt_len=prompt_len,
                      vocab=cfg.vocab_size, max_new=max_new, seed=seed)
    )
    report, slo = base.report, base.slo
    rate0 = base.service_rate_rps(max_new)

    results = []
    for load in LOADS:
        loop = ContinuousBatchingLoop(
            kernels, params, capacity=capacity, chunk=chunk, calib_gen=3,
            slo=slo, report=report,
        )
        trace = poisson_trace(
            n_requests, load * rate0, prompt_len=prompt_len,
            vocab=cfg.vocab_size, max_new=max_new, seed=seed,
        )
        summary = loop.run(trace)
        assert summary.dispatches_per_chunk == 1.0, (
            "decode chunk must stay one fused dispatch"
        )
        row = {"offered_load": load, "offered_rps": load * rate0,
               **summary.to_dict()}
        results.append(row)
        emit(
            f"serve_load_{load:g}x",
            summary.ttft_p50_s * 1e6,
            f"p99_ttft={summary.ttft_p99_s * 1e3:.2f}ms "
            f"thru={summary.throughput_tok_s:.0f}tok/s "
            f"shed={summary.shed_rate:.2f}",
        )

    sub = [r for r in results if r["offered_load"] < 1.0]
    result = {
        "arch": cfg.arch_id,
        "capacity": capacity,
        "chunk": chunk,
        "prompt_len": prompt_len,
        "max_new": max_new,
        "n_requests": n_requests,
        "service_rate_rps": rate0,
        "slo": {"ttft_s": slo.ttft_s, "tok_s": slo.tok_s},
        "loads": results,
        "subcapacity_shed_rate": max((r["shed_rate"] for r in sub), default=0.0),
    }
    with open(JSON_PATH, "w") as f:
        json.dump(result, f, indent=2, allow_nan=False)
    print(f"wrote {JSON_PATH}")
    return result


if __name__ == "__main__":
    run(smoke=True)
