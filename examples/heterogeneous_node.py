"""The paper's heterogeneous node, end to end (Table 6.1 driver).

Builds the Fig 6.1 problem, solves the CPU/accelerator split with the
calibrated Stampede models (section 5.6), constructs the nested partition
(boundary -> host, Morton-compact interior block -> accelerator), and
replays one timestep on the cost models to produce the paper's numbers:
host/accelerator timelines, PCI bytes vs the task-offload strawman, and the
modeled node speedup next to the published 6.3x.

Run:  PYTHONPATH=src python examples/heterogeneous_node.py
"""

import numpy as np

from repro.core import build_nested_partition, solve_two_way, surface_faces
from repro.core.cost_model import (
    offload_volume_bytes,
    shared_face_bytes,
    stampede_node_models,
)
from repro.core.partition import face_neighbors


def main():
    K, order = 8192, 7
    grid = (32, 16, 16)

    # 1. calibrated load balance (section 5.6)
    t_cpu, t_mic, xfer = stampede_node_models(order)
    split = solve_two_way(t_cpu, t_mic, K, transfer=xfer)
    k_cpu, k_mic = split.counts
    print(f"[5.6] solve T_MIC(K_MIC) = T_CPU(K-K_MIC) + PCI(K_MIC):")
    print(f"      K_CPU={k_cpu}  K_MIC={k_mic}  ratio={split.ratio:.2f} (paper: 1.6)")
    print(f"      makespan {split.makespan*1e3:.1f} ms/step, imbalance {split.imbalance:.4f}")

    # 2. the nested partition itself (section 5.5)
    part = build_nested_partition(grid, n_nodes=1, accel_counts=[k_mic])
    part.validate()
    node = part.nodes[0]
    nbr = face_neighbors(grid)
    mask = np.zeros(K, bool)
    mask[node.accel] = True
    cut = surface_faces(mask, nbr)
    print(f"[5.5] node partition: boundary={len(node.boundary)} "
          f"host-interior={len(node.host_interior)} accel={len(node.accel)}")
    print(f"      accel surface: {cut} faces "
          f"(~6*K^(2/3) = {6 * len(node.accel) ** (2 / 3):.0f})")

    # 3. slow-link bytes: interior-offload vs task-offload (section 5.5)
    face_b = shared_face_bytes(k_mic, order)
    vol_b = offload_volume_bytes(K, order)
    print(f"[5.5] PCI per step: faces {face_b/2**20:.1f} MiB vs task-offload "
          f"{vol_b/2**20:.1f} MiB ({vol_b/face_b:.0f}x more)")

    # 4. Table 6.1: modeled node speedup
    t_baseline = t_cpu(K) * 3.0  # unvectorized whole-node socket (Fig 6.2 ~3x kernels)
    print(f"[6.1] baseline {t_baseline*1e3:.0f} ms/step -> optimized "
          f"{split.makespan*1e3:.0f} ms/step = {t_baseline/split.makespan:.1f}x "
          f"(paper: 6.3x @ 1 node, 5.6x @ 64)")


if __name__ == "__main__":
    main()
