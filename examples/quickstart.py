"""Quickstart: the paper's nested partition end to end, in five minutes.

1. Build the paper's two-material DG problem (Fig 6.1, scaled down).
2. Partition it with the nested scheme: Morton level-1 splices, asymmetric
   boundary/interior level-2 split sized by the calibrated load balancer
   (reproduces the published K_MIC/K_CPU ~= 1.6).
3. Run the wave solver and verify energy stability.
4. Train a reduced LM from the assigned-architecture zoo for a few steps.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import jax

jax.config.update("jax_enable_x64", True)

import numpy as np

from repro.configs.shapes import ShapeSpec, smoke_config
from repro.core import build_nested_partition, solve_two_way
from repro.core.cost_model import stampede_node_models
from repro.data import make_batch
from repro.dg.solver import gaussian_pulse, make_two_tree_solver
from repro.launch.mesh import debug_mesh
from repro.models.zoo import LM, get_config
from repro.optim import OptConfig, init_opt_state
from repro.parallel.steps import make_shardings, make_train_step


def main():
    # ---- 1+2: the nested partition with paper-calibrated load balance
    t_cpu, t_mic, xfer = stampede_node_models(order=7)
    split = solve_two_way(t_cpu, t_mic, 8192, transfer=xfer)
    print(f"[load balance] K_MIC/K_CPU = {split.ratio:.2f} "
          f"(paper: 1.6), makespan imbalance {split.imbalance:.4f}")
    # the boundary/interior step schedule hides transfer under interior
    # compute: the same solve with the overlap-aware host side
    split_ov = solve_two_way(t_cpu, t_mic, 8192, transfer=xfer, overlap=True)
    print(f"[schedule] overlap on: makespan {split.makespan * 1e3:.2f}ms -> "
          f"{split_ov.makespan * 1e3:.2f}ms "
          f"({1 - split_ov.makespan / split.makespan:.1%} hidden)")

    part = build_nested_partition((16, 16, 16), n_nodes=4,
                                  accel_fraction=split.counts[1] / 8192)
    part.validate()
    print(f"[partition] 4 nodes x {part.offsets[1]} elements; "
          f"boundary {part.boundary_mask.sum()}, offloaded {part.accel_mask.sum()}")

    # ---- 3: the paper's evaluation problem
    solver = make_two_tree_solver(grid=(8, 4, 4), order=4, extent=(2.0, 1.0, 1.0))
    q0 = gaussian_pulse(solver, center=(0.5, 0.5, 0.5))
    e0 = solver.energy(q0)
    q = solver.run(q0, 60)
    e1 = solver.energy(q)
    print(f"[dg] coupled elastic-acoustic, 60 steps: energy {e0:.4f} -> {e1:.4f} "
          f"({'stable' if e1 <= e0 * 1.0001 else 'UNSTABLE'})")

    # ---- 4: one zoo architecture, reduced, a few train steps
    cfg = smoke_config(get_config("qwen2-7b"))
    lm = LM(cfg)
    mesh = debug_mesh()
    sh = make_shardings(lm, mesh, kind="train", accum=True, batch_shardable=False)
    step = jax.jit(make_train_step(lm, OptConfig(peak_lr=1e-3, warmup_steps=2, total_steps=10), sh),
                   donate_argnums=(0, 1))
    params = lm.init(jax.random.PRNGKey(0))
    opt = init_opt_state(params)
    shape = ShapeSpec("qs", seq_len=64, global_batch=4, kind="train")
    losses = []
    for s in range(6):
        params, opt, m = step(params, opt, make_batch(cfg, shape, s, accum=2, micro=2))
        losses.append(float(m["loss"]))
    print(f"[lm] qwen2-7b (reduced): loss {losses[0]:.3f} -> {losses[-1]:.3f}")


if __name__ == "__main__":
    main()
