"""End-to-end driver: train a ~100M-parameter member of the qwen2 family
for a few hundred steps on this machine, with the full production substrate
(grad-accumulation step, AdamW + cosine, async checkpoints, fault-tolerant
supervisor, deterministic pipeline).

~100M config: 12 layers, d_model 768, 12 heads (GQA kv 4), d_ff 2048,
vocab 32000 -> 104M params.  On 1 CPU core a step takes ~1s at batch 8 x
seq 256; pass --steps 300 for the full run (default 40 keeps CI fast).

Run:  PYTHONPATH=src python examples/train_100m.py --steps 300
"""

import argparse
import sys

from repro.launch import train as train_driver
from repro.models.common import ModelConfig
from repro.models.zoo import register

# a real ~100M member of the qwen2 family (GQA + gated-silu + rope)
register(ModelConfig(
    arch_id="qwen2-100m",
    family="dense",
    n_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=4,
    d_ff=2048,
    vocab_size=32000,
    head_dim=64,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    dtype="float32",
    remat="none",
    tp_size=1,
))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=40)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/qwen2_100m_ckpt")
    args = ap.parse_args()

    sys.argv = [
        "train", "--arch", "qwen2-100m", "--smoke",
        "--steps", str(args.steps), "--batch", str(args.batch),
        "--seq-len", str(args.seq_len), "--lr", "6e-4", "--warmup", "20",
        "--ckpt-dir", args.ckpt_dir, "--ckpt-every", "50",
        "--metrics-out", "/tmp/qwen2_100m_metrics.jsonl",
    ]
    # --smoke keeps the 1-device debug mesh but we want the REAL config, so
    # patch smoke_config to identity for this arch
    import repro.launch.train as t
    orig = t.smoke_config
    t.smoke_config = lambda cfg: cfg if cfg.arch_id == "qwen2-100m" else orig(cfg)
    train_driver.main()


if __name__ == "__main__":
    main()
