"""Serving example: batched greedy decoding from a (reduced) Mixtral-style
MoE with rolling SWA caches, via the production serve step.

Run:  PYTHONPATH=src python examples/serve_moe.py
"""

import sys

from repro.launch import serve


def main():
    sys.argv = ["serve", "--arch", "mixtral-8x22b", "--smoke",
                "--batch", "4", "--prompt-len", "48", "--gen", "24"]
    serve.main()


if __name__ == "__main__":
    main()
