"""The paper's experiment, end to end: coupled elastic-acoustic wave
propagation on the two-material brick (Fig 6.1), executed BOTH ways:

  * flat single-array solver (the baseline ``dgae`` execution), and
  * the nested partition across 4 (fake) devices: Morton/slab level-1
    splices, per-stage ring halo exchange overlapped with interior compute.

Prints per-step timing for both and verifies they produce identical fields
(the paper's partition is a reordering, never an approximation).

Run:  PYTHONPATH=src python examples/dg_wave_nested.py
(sets 4 fake host devices before importing jax)
"""

import os

if "--_child" not in os.sys.argv:
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=4"
    )

import time

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np

from repro.dg.partitioned import PartitionedDG
from repro.dg.solver import gaussian_pulse, make_two_tree_solver


def main():
    grid, order, steps = (16, 8, 8), 4, 30
    solver = make_two_tree_solver(grid=grid, order=order, extent=(2.0, 1.0, 1.0))
    print(f"[setup] {solver.mesh.K} elements, order {order} "
          f"({solver.mesh.K * solver.M**3 * 9 / 1e6:.2f}M dof), dt={solver.cfl_dt():.2e}")
    q0 = gaussian_pulse(solver, center=(0.5, 0.5, 0.5))

    t0 = time.perf_counter()
    qf = solver.run(q0, steps)
    jax.block_until_ready(qf)
    t_flat = time.perf_counter() - t0

    mesh = jax.make_mesh((4,), ("data",))
    pdg = PartitionedDG(solver=solver, mesh_axes=mesh)
    qp0 = pdg.permute_in(q0)
    t0 = time.perf_counter()
    qp = pdg.run(qp0, steps)
    jax.block_until_ready(qp)
    t_nested = time.perf_counter() - t0

    err = float(jnp.abs(qf - pdg.permute_out(np.asarray(qp))).max())
    e0, e1 = solver.energy(q0), solver.energy(qf)
    print(f"[flat]   {steps} steps in {t_flat:.2f}s ({t_flat/steps*1e3:.1f} ms/step)")
    print(f"[nested] {steps} steps in {t_nested:.2f}s ({t_nested/steps*1e3:.1f} ms/step) "
          f"on 4 partitions")
    print(f"[check]  max |flat - nested| = {err:.2e}  "
          f"energy {e0:.4f} -> {e1:.4f} ({'stable' if e1 <= e0*1.0001 else 'UNSTABLE'})")
    assert err < 1e-10


if __name__ == "__main__":
    main()
