"""Assembled DGSEM solver on a brick mesh (flat, single-array execution).

The nested-partition execution of the same rhs lives in
``repro/dg/partitioned.py``; both produce identical fields (tested) — the
paper's partition is a reordering, never an approximation.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.dg.basis import diff_matrix, lgl_nodes_weights
from repro.dg.mesh import BrickMesh, make_brick, two_tree_materials
from repro.dg.operators import dg_rhs, stress
from repro.dg.rk import lsrk45_step


@dataclasses.dataclass
class DGSolver:
    mesh: BrickMesh
    order: int
    rho: np.ndarray
    lam: np.ndarray
    mu: np.ndarray
    dtype: str = "float64"
    kernel_impl: str = "xla"  # xla | interpret | pallas (TPU)

    def __post_init__(self):
        x, w = lgl_nodes_weights(self.order)
        self.nodes, self.weights = x, w
        dt = jnp.dtype(self.dtype)
        self.D = jnp.asarray(diff_matrix(x), dt)
        self.metrics = tuple(self.mesh.metric(a) for a in range(3))
        self.lift = tuple(self.mesh.metric(a) / w[0] for a in range(3))
        self.neighbors = jnp.asarray(self.mesh.neighbors)
        self.rho_j = jnp.asarray(self.rho, dt)
        self.lam_j = jnp.asarray(self.lam, dt)
        self.mu_j = jnp.asarray(self.mu, dt)
        self.cp_j = jnp.sqrt((self.lam_j + 2 * self.mu_j) / self.rho_j)
        self.cs_j = jnp.sqrt(self.mu_j / self.rho_j)

    @property
    def M(self) -> int:
        return self.order + 1

    # ------------------------------------------------------------------
    def node_coords(self) -> np.ndarray:
        """Physical coordinates of all nodes: (K, M, M, M, 3)."""
        K = self.mesh.K
        M = self.M
        r = (self.nodes + 1) / 2  # [0,1]
        h = self.mesh.h
        c = self.mesh.centers
        out = np.zeros((K, M, M, M, 3))
        for a in range(3):
            shape = [1, 1, 1]
            shape[a] = M
            coord = c[:, a][:, None, None, None] + (r.reshape(shape) - 0.5) * h[a]
            out[..., a] = np.broadcast_to(coord, (K, M, M, M))
        return out

    def zero_state(self) -> jnp.ndarray:
        return jnp.zeros((self.mesh.K, 9, self.M, self.M, self.M), jnp.dtype(self.dtype))

    def rhs(self, q: jnp.ndarray) -> jnp.ndarray:
        return dg_rhs(
            q, self.D, self.metrics, self.lift, self.neighbors,
            self.rho_j, self.lam_j, self.mu_j, self.cp_j, self.cs_j,
            kernel_impl=self.kernel_impl,
        )

    def cfl_dt(self, cfl: float = 0.3) -> float:
        cp_max = float(np.sqrt((self.lam + 2 * self.mu) / self.rho).max())
        h_min = min(self.mesh.h)
        return cfl * h_min / (cp_max * self.order**2)

    @partial(jax.jit, static_argnums=0)
    def step(self, q, res, dt):
        return lsrk45_step(q, res, self.rhs, dt)

    def run(self, q, n_steps: int, dt: Optional[float] = None, *,
            observe: bool = False, fused: bool = True):
        """Advance ``n_steps`` (the Engine protocol's driver).

        ``fused`` (default) scan-compiles the whole horizon into one
        program; ``fused=False`` is the eager per-step reference.
        ``observe`` is accepted for protocol compatibility and ignored —
        the flat solver has no partitions to attribute time to."""
        del observe
        dt = dt or self.cfl_dt()
        res = jnp.zeros_like(q)

        if not fused:
            step1 = jax.jit(lambda q, res: lsrk45_step(q, res, self.rhs, dt))
            for _ in range(n_steps):
                q, res = step1(q, res)
            return q

        @jax.jit
        def many(q, res):
            def body(carry, _):
                q, res = carry
                q, res = lsrk45_step(q, res, self.rhs, dt)
                return (q, res), None

            (q, res), _ = jax.lax.scan(body, (q, res), None, length=n_steps)
            return q, res

        q, _ = many(q, res)
        return q

    def calibrate(self, q, reps: int = 2, dt: Optional[float] = None) -> "CalibrationReport":
        """Whole-step wall seconds as a single-partition report.  The flat
        solver is one unpartitioned block, so the report carries the total
        in ``interior_s`` (``CalibrationReport.from_totals`` semantics: no
        phase-composition claim)."""
        import time

        from repro.runtime.schedule import CalibrationReport

        dt = dt or self.cfl_dt()
        res = jnp.zeros_like(q)
        step1 = jax.jit(lambda q, res: lsrk45_step(q, res, self.rhs, dt))
        out = step1(q, res)
        jax.block_until_ready(out)  # warmup / compile
        ts = []
        for _ in range(max(1, reps)):
            t0 = time.perf_counter()
            out = step1(q, res)
            jax.block_until_ready(out)
            ts.append(time.perf_counter() - t0)
        ts.sort()
        return CalibrationReport.from_totals([ts[len(ts) // 2]])

    def resplice(self, plan=None) -> None:
        """Engine-protocol no-op: a flat solver has a single partition and
        nothing to re-splice."""
        del plan

    # ------------------------------------------------------------------
    def energy(self, q: jnp.ndarray) -> float:
        """0.5 * int rho|v|^2 + E:C:E  (quadrature-weighted)."""
        w = self.weights
        W = jnp.asarray(np.einsum("i,j,k->ijk", w, w, w), q.dtype) * self.mesh.jacobian
        v = q[:, 6:9]
        kin = 0.5 * self.rho_j[:, None, None, None] * jnp.sum(v**2, axis=1)
        S = stress(q, self.lam_j, self.mu_j)
        E = q[:, :6]
        # E:S with symmetric off-diagonal double counting
        es = (
            E[:, 0] * S[:, 0] + E[:, 1] * S[:, 1] + E[:, 2] * S[:, 2]
            + 2 * (E[:, 3] * S[:, 3] + E[:, 4] * S[:, 4] + E[:, 5] * S[:, 5])
        )
        pot = 0.5 * es
        return float(jnp.sum((kin + pot) * W[None]))


def make_two_tree_solver(grid=(8, 4, 4), order: int = 3, extent=(2.0, 1.0, 1.0),
                         cp=(1.0, 3.0), cs=(0.0, 2.0), rho=(1.0, 1.0), dtype="float64",
                         kernel_impl="xla") -> DGSolver:
    """The paper's Fig 6.1 setup (scaled down by default)."""
    mesh = make_brick(grid, extent)
    rho_e, lam, mu, _ = two_tree_materials(mesh, cp, cs, rho)
    return DGSolver(mesh=mesh, order=order, rho=rho_e, lam=lam, mu=mu, dtype=dtype,
                    kernel_impl=kernel_impl)


def gaussian_pulse(solver: DGSolver, center=(0.5, 0.5, 0.5), width: float = 0.08,
                   component: int = 6) -> jnp.ndarray:
    """v or E component initialized with a Gaussian — standard smoke IC."""
    xyz = solver.node_coords()
    r2 = sum((xyz[..., a] - center[a]) ** 2 for a in range(3))
    blob = np.exp(-r2 / (2 * width**2))
    q = np.zeros((solver.mesh.K, 9, solver.M, solver.M, solver.M))
    q[:, component] = blob
    return jnp.asarray(q, jnp.dtype(solver.dtype))
