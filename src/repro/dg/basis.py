"""Legendre-Gauss-Lobatto nodes, quadrature weights, differentiation matrix.

The collocation DGSEM (paper section 3) uses the same LGL points for
interpolation and quadrature; face values are then plain slices of the
volume tensor (the paper's ``interp_q`` is data movement, not math).
"""

from __future__ import annotations

import numpy as np


def _legendre_and_deriv(N: int, x: np.ndarray):
    """P_N(x) and P'_N(x) via the three-term recurrence."""
    p0 = np.ones_like(x)
    p1 = x.copy()
    if N == 0:
        return p0, np.zeros_like(x)
    for k in range(2, N + 1):
        p0, p1 = p1, ((2 * k - 1) * x * p1 - (k - 1) * p0) / k
    dp = N * (x * p1 - p0) / (x**2 - 1.0 + 1e-300)
    return p1, dp


def lgl_nodes_weights(N: int):
    """LGL nodes (roots of (1-x^2) P'_N) and weights, float64."""
    if N < 1:
        raise ValueError("order must be >= 1")
    # Chebyshev-Gauss-Lobatto initial guess, Newton on q(x) = P'_N(x)
    x = -np.cos(np.pi * np.arange(N + 1) / N)
    for _ in range(100):
        pN, dpN = _legendre_and_deriv(N, x)
        # second derivative from Legendre ODE: (1-x^2)P'' - 2xP' + N(N+1)P = 0
        d2p = (2 * x * dpN - N * (N + 1) * pN) / (1 - x**2 + 1e-300)
        dx = np.where(np.abs(1 - x**2) < 1e-14, 0.0, dpN / (d2p + 1e-300))
        x = x - dx
        if np.max(np.abs(dx)) < 1e-15:
            break
    x[0], x[-1] = -1.0, 1.0
    pN, _ = _legendre_and_deriv(N, x)
    w = 2.0 / (N * (N + 1) * pN**2)
    return x, w


def barycentric_weights(x: np.ndarray) -> np.ndarray:
    n = len(x)
    w = np.ones(n)
    for j in range(n):
        for k in range(n):
            if k != j:
                w[j] /= x[j] - x[k]
    return w


def diff_matrix(x: np.ndarray) -> np.ndarray:
    """Lagrange differentiation matrix at nodes x."""
    n = len(x)
    wb = barycentric_weights(x)
    D = np.zeros((n, n))
    for i in range(n):
        for j in range(n):
            if i != j:
                D[i, j] = wb[j] / (wb[i] * (x[i] - x[j]))
        D[i, i] = -np.sum(D[i, [j for j in range(n) if j != i]])
    return D
