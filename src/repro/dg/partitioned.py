"""Nested-partition execution of the DG solver (paper section 5).

Level 1 — inter-node: elements are split into contiguous x-slabs, one per
device along the ``data`` mesh axis (Morton-ordered within the slab); the
once-per-stage face exchange between slabs is a ring ``ppermute``
(`halo_exchange_1d`).

Level 2 — intra-node boundary/interior: the rhs is a
``repro.runtime.schedule.StepSchedule`` instantiation — slab-edge faces are
packed and launched into the ring (boundary + exchange phases), the volume
kernel + intra-slab fluxes run with no halo dependence (interior phase),
and the received halo folds in last (correction phase).  XLA's scheduler
overlaps the ppermute DMA with the interior compute — the paper's Fig 5.1
expressed as dataflow.

Correctness invariant (tested): the partitioned rhs/run equals the flat
single-array solver bitwise up to float reassociation — the partition is a
reordering, never an approximation.

The heterogeneous (CPU+MIC) level-2 split with calibrated asymmetric sizes
is exercised by `repro.core.load_balance` + `benchmarks/table6_1_speedup.py`
on the cost models; this module is the homogeneous-SPMD incarnation.

Online rebalancing: ``run(..., executor=...)`` adopts the step-driver API of
``repro.runtime.executor.NestedPartitionExecutor`` — measured step times
feed the paper's equalizer and the executor re-solves the nested split on
schedule (``make_executor`` builds one matching this decomposition).  On the
SPMD slab path the shard shapes are fixed, so the re-splice lands in the
executor's ``NestedPartition`` index arrays (level-2 host/accel masks and
the solved per-node counts); ``repro.runtime.executor.BlockedDGEngine`` is
the asymmetric-execution incarnation of the same plan.
"""

from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.overlap import halo_exchange_1d
from repro.dg.mesh import BrickMesh  # noqa: F401 — referenced in docs
from repro.dg.operators import (
    extract_face,
    riemann_correction,
    stress,
    surface_rhs,
    volume_rhs_impl,
)
from repro.dg.rk import lsrk45_step
from repro.dg.solver import DGSolver
from repro.runtime.schedule import StepSchedule

_MATS = ("rho", "cp", "cs", "mu")


def pack_face_payload(S_slab, v_slab, mats: dict):
    """One slab edge -> (ring payload, own face traces).

    ``S_slab``/``v_slab`` are the stress/velocity fields of the edge layer
    with the face already extracted; the payload rows carry the face data
    plus the material line the neighbour needs for the Riemann solve.
    """
    L = S_slab.shape[0]
    mat = jnp.stack([mats[k] for k in _MATS])
    return jnp.concatenate([S_slab.reshape(L, -1), v_slab.reshape(L, -1), mat.T], axis=1)


def unpack_face_payload(buf, L: int, M: int):
    """Inverse of :func:`pack_face_payload`: (S_face, v_face, materials)."""
    nface = 6 * M * M
    Sf = buf[:, :nface].reshape(L, 6, M, M)
    vf = buf[:, nface : nface + 3 * M * M].reshape(L, 3, M, M)
    mat = buf[:, nface + 3 * M * M :]
    return Sf, vf, {k: mat[:, i] for i, k in enumerate(_MATS)}


def slab_neighbors(grid, n_slabs: int) -> Tuple[np.ndarray, np.ndarray]:
    """(order, neighbors_local): elements reordered x-major so each slab is
    contiguous; intra-slab neighbor ids are slab-local; faces crossing slab
    boundaries point at the element ITSELF (-> zero jump -> zero intra
    correction; the halo pass adds the real correction)."""
    nx, ny, nz = grid
    if nx % n_slabs:
        raise ValueError(f"nx={nx} not divisible by {n_slabs} slabs")
    from repro.core.partition import face_neighbors

    K = nx * ny * nz
    nbr = face_neighbors(grid)
    # x-major order: elements sorted by (ix, iy, iz); id = ix + nx*(iy+ny*iz)
    ix = np.arange(K) % nx
    iy = (np.arange(K) // nx) % ny
    iz = np.arange(K) // (nx * ny)
    order = np.lexsort((iz, iy, ix))  # primary key ix
    inv = np.empty(K, np.int64)
    inv[order] = np.arange(K)
    per = nx // n_slabs * ny * nz
    nbr_new = np.full((K, 6), -1, np.int64)
    for f in range(6):
        src = nbr[order, f]
        valid = src >= 0
        mapped = np.where(valid, inv[np.clip(src, 0, None)], -1)
        # faces that cross a slab boundary: -2 (the halo pass adds them)
        same_slab = (mapped // per) == (np.arange(K) // per)
        nbr_new[:, f] = np.where(valid & same_slab, mapped, np.where(valid, -2, -1))
    # local ids within slab (sentinels -1 physical, -2 cross-slab preserved)
    nbr_local = np.where(nbr_new >= 0, nbr_new % per, nbr_new)
    return order, nbr_local


@dataclasses.dataclass
class PartitionedDG:
    """shard_map slab execution of a DGSolver."""

    solver: DGSolver
    mesh_axes: Mesh
    axis: str = "data"

    def __post_init__(self):
        s = self.solver
        self.P = self.mesh_axes.shape[self.axis]
        nx, ny, nz = s.mesh.grid
        self.order_perm, nbr_local = slab_neighbors(s.mesh.grid, self.P)
        self.K_loc = s.mesh.K // self.P
        self.layer = ny * nz  # elements per x-layer
        self.nbr_local = jnp.asarray(nbr_local)
        p = self.order_perm
        self.rho = jnp.asarray(s.rho[p])
        self.lam = jnp.asarray(s.lam[p])
        self.mu = jnp.asarray(s.mu[p])
        self.cp = jnp.sqrt((self.lam + 2 * self.mu) / self.rho)
        self.cs = jnp.sqrt(self.mu / self.rho)
        self.inv_perm = np.empty_like(self.order_perm)
        self.inv_perm[self.order_perm] = np.arange(len(self.order_perm))
        self.spec_q = P(self.axis, None, None, None, None)
        self.spec_e = P(self.axis)

    # ------------------------------------------------------------------
    def permute_in(self, q_flat: jnp.ndarray) -> jnp.ndarray:
        return q_flat[self.order_perm]

    def permute_out(self, q_part: jnp.ndarray) -> jnp.ndarray:
        return q_part[self.inv_perm]

    # ------------------------------------------------------------------
    def _apply_halo(self, out, buf, own_faces, st, side: str, idx):
        """Fold one received slab-edge halo (``lo`` or ``hi``) into ``out``."""
        s = self.solver
        L = self.layer
        sl = slice(None, L) if side == "lo" else slice(-L, None)
        Sm, vm = own_faces
        Sp, vp, mp = unpack_face_payload(buf, L, s.M)
        mm = {k: st[k][sl] for k in _MATS}
        # the global x boundary (first/last device) is already mirrored by
        # the intra pass (nbr == -1): zero the halo correction there
        is_global = (idx == 0) if side == "lo" else (idx == self.P - 1)
        mp = {k: jnp.where(is_global, mm[k], v) for k, v in mp.items()}
        sign = -1.0 if side == "lo" else +1.0
        FE, Fv = riemann_correction(Sm, vm, Sp, vp, 0, sign, mm, mp)
        corr = jnp.concatenate([FE, Fv / st["rho"][sl, None, None, None]], axis=1)
        corr = jnp.where(is_global, 0.0, corr)
        node = 0 if side == "lo" else s.M - 1
        return out.at[sl, :, node, :, :].add(-s.lift[0] * corr)

    def _make_schedule(self, nbr) -> StepSchedule:
        """The slab rhs as the shared four-phase schedule: pack slab-edge
        faces -> ring exchange -> volume + intra-slab fluxes -> halo fold."""
        s = self.solver
        L = self.layer

        def boundary(st):
            # extract both slab-edge faces and pack the ring payloads
            S = stress(st["q"], st["lam"], st["mu"])
            lo_S = extract_face(S[:L], 0)  # -x faces of first layer
            lo_v = extract_face(st["q"][:L, 6:9], 0)
            hi_S = extract_face(S[-L:], 1)  # +x faces of last layer
            hi_v = extract_face(st["q"][-L:, 6:9], 1)
            lo = pack_face_payload(lo_S, lo_v, {k: st[k][:L] for k in _MATS})
            hi = pack_face_payload(hi_S, hi_v, {k: st[k][-L:] for k in _MATS})
            return {"send_lo": lo, "send_hi": hi,
                    "lo_faces": (lo_S, lo_v), "hi_faces": (hi_S, hi_v)}

        def exchange(send, st):
            from_prev, from_next = halo_exchange_1d(
                send["send_lo"], send["send_hi"], self.axis
            )
            return dict(send, from_prev=from_prev, from_next=from_next)

        def interior(st):
            # volume + intra-slab fluxes: no dependence on the ring payload;
            # kernel_impl threads through so the Pallas volume/flux kernels
            # run inside the SPMD slab path too
            out = volume_rhs_impl(st["q"], s.D, s.metrics, st["rho"], st["lam"],
                                  st["mu"], kernel_impl=s.kernel_impl)
            return out + surface_rhs(st["q"], nbr, s.lift, st["rho"], st["lam"],
                                     st["mu"], st["cp"], st["cs"],
                                     kernel_impl=s.kernel_impl)

        def correction(out, recv, st):
            idx = jax.lax.axis_index(self.axis)
            out = self._apply_halo(out, recv["from_prev"], recv["lo_faces"], st, "lo", idx)
            return self._apply_halo(out, recv["from_next"], recv["hi_faces"], st, "hi", idx)

        return StepSchedule(boundary=boundary, exchange=exchange,
                            interior=interior, correction=correction, name="slab-spmd")

    def _rhs_local(self, q, nbr, rho, lam, mu, cp, cs):
        """Per-device rhs with ring halo exchange; runs inside shard_map."""
        state = {"q": q, "rho": rho, "lam": lam, "mu": mu, "cp": cp, "cs": cs}
        return self._make_schedule(nbr).rhs(state)

    # ------------------------------------------------------------------
    def rhs(self, q_part: jnp.ndarray) -> jnp.ndarray:
        """Global-view rhs on the permuted state (sharded over the axis)."""
        from repro.jax_compat import shard_map

        f = shard_map(
            self._rhs_local,
            mesh=self.mesh_axes,
            in_specs=(self.spec_q, P(self.axis, None), self.spec_e, self.spec_e,
                      self.spec_e, self.spec_e, self.spec_e),
            out_specs=self.spec_q,
            check_vma=False,
        )
        return f(q_part, self.nbr_local, self.rho, self.lam, self.mu, self.cp, self.cs)

    def make_executor(self, bucket: int = 16, **kwargs):
        """An online auto-rebalancing executor matching this decomposition
        (one partition per slab)."""
        from repro.runtime.executor import NestedPartitionExecutor

        return NestedPartitionExecutor(
            self.solver.mesh.K,
            self.P,
            grid_dims=self.solver.mesh.grid,
            bucket=bucket,
            **kwargs,
        )

    def run(
        self,
        q_part: jnp.ndarray,
        n_steps: int,
        dt: Optional[float] = None,
        executor=None,
    ) -> jnp.ndarray:
        """Advance ``n_steps``.  With an ``executor`` the run is segmented on
        its rebalance schedule: each segment's wall time is observed
        (synchronous-step attribution) and the nested split re-solved — the
        calibrate->solve->resplice loop running alongside the SPMD compute."""
        dt = dt or self.solver.cfl_dt()
        res = jnp.zeros_like(q_part)

        @partial(jax.jit, static_argnums=2)
        def many(q, res, length):
            def body(carry, _):
                q, res = carry
                q, res = lsrk45_step(q, res, self.rhs, dt)
                return (q, res), None

            (q, res), _ = jax.lax.scan(body, (q, res), None, length=length)
            return q, res

        if executor is None:
            q_part, _ = many(q_part, res, n_steps)
            return q_part

        done = 0
        while done < n_steps:
            chunk = min(executor.rebalance_every, n_steps - done)
            t0 = time.perf_counter()
            q_part, res = many(q_part, res, chunk)
            jax.block_until_ready(q_part)
            wall = time.perf_counter() - t0
            executor.observe_total(wall / chunk)
            executor.advance(chunk)
            done += chunk
        return q_part
