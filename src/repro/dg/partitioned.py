"""Nested-partition execution of the DG solver (paper section 5).

Level 1 — inter-node: elements are split into contiguous x-slabs, one per
device along the ``data`` mesh axis (Morton-ordered within the slab); the
once-per-stage face exchange between slabs is a ring ``ppermute``
(`halo_exchange_1d`).

Level 2 — intra-node boundary/interior: the rhs is *structured* so that the
slab-edge (boundary) face data is extracted and launched into the ring
FIRST, then the volume kernel + intra-slab fluxes (interior work, no
dependence on the halo) are computed, and finally the halo corrections are
added.  XLA's scheduler overlaps the ppermute DMA with the interior
compute — the paper's Fig 5.1 expressed as dataflow.

Correctness invariant (tested): the partitioned rhs/run equals the flat
single-array solver bitwise up to float reassociation — the partition is a
reordering, never an approximation.

The heterogeneous (CPU+MIC) level-2 split with calibrated asymmetric sizes
is exercised by `repro.core.load_balance` + `benchmarks/table6_1_speedup.py`
on the cost models; this module is the homogeneous-SPMD incarnation.

Online rebalancing: ``run(..., executor=...)`` adopts the step-driver API of
``repro.runtime.executor.NestedPartitionExecutor`` — measured step times
feed the paper's equalizer and the executor re-solves the nested split on
schedule (``make_executor`` builds one matching this decomposition).  On the
SPMD slab path the shard shapes are fixed, so the re-splice lands in the
executor's ``NestedPartition`` index arrays (level-2 host/accel masks and
the solved per-node counts); ``repro.runtime.executor.BlockedDGEngine`` is
the asymmetric-execution incarnation of the same plan.
"""

from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.overlap import halo_exchange_1d
from repro.dg.mesh import BrickMesh  # noqa: F401 — referenced in docs
from repro.dg.operators import (
    extract_face,
    riemann_correction,
    stress,
    surface_rhs,
    volume_rhs,
)
from repro.dg.rk import lsrk45_step
from repro.dg.solver import DGSolver


def slab_neighbors(grid, n_slabs: int) -> Tuple[np.ndarray, np.ndarray]:
    """(order, neighbors_local): elements reordered x-major so each slab is
    contiguous; intra-slab neighbor ids are slab-local; faces crossing slab
    boundaries point at the element ITSELF (-> zero jump -> zero intra
    correction; the halo pass adds the real correction)."""
    nx, ny, nz = grid
    if nx % n_slabs:
        raise ValueError(f"nx={nx} not divisible by {n_slabs} slabs")
    from repro.core.partition import face_neighbors

    K = nx * ny * nz
    nbr = face_neighbors(grid)
    # x-major order: elements sorted by (ix, iy, iz); id = ix + nx*(iy+ny*iz)
    ix = np.arange(K) % nx
    iy = (np.arange(K) // nx) % ny
    iz = np.arange(K) // (nx * ny)
    order = np.lexsort((iz, iy, ix))  # primary key ix
    inv = np.empty(K, np.int64)
    inv[order] = np.arange(K)
    per = nx // n_slabs * ny * nz
    nbr_new = np.full((K, 6), -1, np.int64)
    for f in range(6):
        src = nbr[order, f]
        valid = src >= 0
        mapped = np.where(valid, inv[np.clip(src, 0, None)], -1)
        # faces that cross a slab boundary: -2 (the halo pass adds them)
        same_slab = (mapped // per) == (np.arange(K) // per)
        nbr_new[:, f] = np.where(valid & same_slab, mapped, np.where(valid, -2, -1))
    # local ids within slab (sentinels -1 physical, -2 cross-slab preserved)
    nbr_local = np.where(nbr_new >= 0, nbr_new % per, nbr_new)
    return order, nbr_local


@dataclasses.dataclass
class PartitionedDG:
    """shard_map slab execution of a DGSolver."""

    solver: DGSolver
    mesh_axes: Mesh
    axis: str = "data"

    def __post_init__(self):
        s = self.solver
        self.P = self.mesh_axes.shape[self.axis]
        nx, ny, nz = s.mesh.grid
        self.order_perm, nbr_local = slab_neighbors(s.mesh.grid, self.P)
        self.K_loc = s.mesh.K // self.P
        self.layer = ny * nz  # elements per x-layer
        self.nbr_local = jnp.asarray(nbr_local)
        p = self.order_perm
        self.rho = jnp.asarray(s.rho[p])
        self.lam = jnp.asarray(s.lam[p])
        self.mu = jnp.asarray(s.mu[p])
        self.cp = jnp.sqrt((self.lam + 2 * self.mu) / self.rho)
        self.cs = jnp.sqrt(self.mu / self.rho)
        self.spec_q = P(self.axis, None, None, None, None)
        self.spec_e = P(self.axis)

    # ------------------------------------------------------------------
    def permute_in(self, q_flat: jnp.ndarray) -> jnp.ndarray:
        return q_flat[self.order_perm]

    def permute_out(self, q_part: jnp.ndarray) -> jnp.ndarray:
        inv = np.empty_like(self.order_perm)
        inv[self.order_perm] = np.arange(len(self.order_perm))
        return q_part[inv]

    # ------------------------------------------------------------------
    def _rhs_local(self, q, nbr, rho, lam, mu, cp, cs):
        """Per-device rhs with ring halo exchange; runs inside shard_map."""
        s = self.solver
        L = self.layer
        S = stress(q, lam, mu)

        # ---- boundary work first: extract slab-edge faces, launch the ring
        lo_S = extract_face(S[:L], 0)  # -x faces of first layer
        lo_v = extract_face(q[:L, 6:9], 0)
        hi_S = extract_face(S[-L:], 1)  # +x faces of last layer
        hi_v = extract_face(q[-L:, 6:9], 1)
        lo_mat = jnp.stack([rho[:L], cp[:L], cs[:L], mu[:L]])
        hi_mat = jnp.stack([rho[-L:], cp[-L:], cs[-L:], mu[-L:]])
        send_lo = jnp.concatenate([lo_S.reshape(L, -1), lo_v.reshape(L, -1),
                                   lo_mat.T], axis=1)
        send_hi = jnp.concatenate([hi_S.reshape(L, -1), hi_v.reshape(L, -1),
                                   hi_mat.T], axis=1)
        from_prev, from_next = halo_exchange_1d(send_lo, send_hi, self.axis)

        # ---- interior work: volume + intra-slab fluxes (independent of halo)
        out = volume_rhs(q, s.D, s.metrics, rho, lam, mu)
        out = out + surface_rhs(q, nbr, s.lift, rho, lam, mu, cp, cs)

        # ---- boundary corrections from the halo
        idx = jax.lax.axis_index(self.axis)
        M = s.M
        nface = 6 * M * M

        def unpack(buf):
            Sf = buf[:, : nface].reshape(L, 6, M, M)
            vf = buf[:, nface : nface + 3 * M * M].reshape(L, 3, M, M)
            mat = buf[:, nface + 3 * M * M :]
            return Sf, vf, {"rho": mat[:, 0], "cp": mat[:, 1], "cs": mat[:, 2], "mu": mat[:, 3]}

        # -x faces of the first layer (neighbor = prev device's last layer)
        Sp, vp, mp = unpack(from_prev)
        Sm_lo = lo_S
        vm_lo = lo_v
        mm_lo = {"rho": rho[:L], "cp": cp[:L], "cs": cs[:L], "mu": mu[:L]}
        # the global -x boundary (device 0) is already mirrored by the intra
        # pass (nbr == -1): zero the halo correction there
        is_global_lo = idx == 0
        mp = {k: jnp.where(is_global_lo, mm_lo[k], v) for k, v in mp.items()}
        FE, Fv = riemann_correction(Sm_lo, vm_lo, Sp, vp, 0, -1.0, mm_lo, mp)
        corr = jnp.concatenate([FE, Fv / rho[:L, None, None, None]], axis=1)
        corr = jnp.where(is_global_lo, 0.0, corr)
        out = out.at[:L, :, 0, :, :].add(-s.lift[0] * corr)

        # +x faces of the last layer (neighbor = next device's first layer)
        Sp, vp, mp = unpack(from_next)
        Sm_hi = hi_S
        vm_hi = hi_v
        mm_hi = {"rho": rho[-L:], "cp": cp[-L:], "cs": cs[-L:], "mu": mu[-L:]}
        is_global_hi = idx == self.P - 1
        mp = {k: jnp.where(is_global_hi, mm_hi[k], v) for k, v in mp.items()}
        FE, Fv = riemann_correction(Sm_hi, vm_hi, Sp, vp, 0, +1.0, mm_hi, mp)
        corr = jnp.concatenate([FE, Fv / rho[-L:, None, None, None]], axis=1)
        corr = jnp.where(is_global_hi, 0.0, corr)
        out = out.at[-L:, :, s.M - 1, :, :].add(-s.lift[0] * corr)
        return out

    # ------------------------------------------------------------------
    def rhs(self, q_part: jnp.ndarray) -> jnp.ndarray:
        """Global-view rhs on the permuted state (sharded over the axis)."""
        from repro.jax_compat import shard_map

        f = shard_map(
            self._rhs_local,
            mesh=self.mesh_axes,
            in_specs=(self.spec_q, P(self.axis, None), self.spec_e, self.spec_e,
                      self.spec_e, self.spec_e, self.spec_e),
            out_specs=self.spec_q,
            check_vma=False,
        )
        return f(q_part, self.nbr_local, self.rho, self.lam, self.mu, self.cp, self.cs)

    def make_executor(self, bucket: int = 16, **kwargs):
        """An online auto-rebalancing executor matching this decomposition
        (one partition per slab)."""
        from repro.runtime.executor import NestedPartitionExecutor

        return NestedPartitionExecutor(
            self.solver.mesh.K,
            self.P,
            grid_dims=self.solver.mesh.grid,
            bucket=bucket,
            **kwargs,
        )

    def run(
        self,
        q_part: jnp.ndarray,
        n_steps: int,
        dt: Optional[float] = None,
        executor=None,
    ) -> jnp.ndarray:
        """Advance ``n_steps``.  With an ``executor`` the run is segmented on
        its rebalance schedule: each segment's wall time is observed
        (synchronous-step attribution) and the nested split re-solved — the
        calibrate->solve->resplice loop running alongside the SPMD compute."""
        dt = dt or self.solver.cfl_dt()
        res = jnp.zeros_like(q_part)

        @partial(jax.jit, static_argnums=2)
        def many(q, res, length):
            def body(carry, _):
                q, res = carry
                q, res = lsrk45_step(q, res, self.rhs, dt)
                return (q, res), None

            (q, res), _ = jax.lax.scan(body, (q, res), None, length=length)
            return q, res

        if executor is None:
            q_part, _ = many(q_part, res, n_steps)
            return q_part

        done = 0
        while done < n_steps:
            chunk = min(executor.rebalance_every, n_steps - done)
            t0 = time.perf_counter()
            q_part, res = many(q_part, res, chunk)
            jax.block_until_ready(q_part)
            wall = time.perf_counter() - t0
            executor.observe_total(wall / chunk)
            executor.advance(chunk)
            done += chunk
        return q_part
