"""Nested-partition execution of the DG solver (paper section 5).

Level 1 — inter-node: elements are split into contiguous x-slabs, one per
device along the ``data`` mesh axis; the once-per-stage face exchange
between slabs is a ring ``lax.ppermute`` (`halo_exchange_1d`) of the
slab-edge *element layers*.

Level 2 — intra-node boundary/interior: the rhs is a
``repro.runtime.schedule.StepSchedule`` instantiation — the slab-edge
layers are packed and launched into the ring (boundary + exchange phases),
the volume kernel runs on the slab's own elements with no halo dependence
(interior phase), and the received layers are appended to the slab and the
full surface flux folds in last (correction phase).  XLA's scheduler
overlaps the ppermute DMA with the interior compute — the paper's Fig 5.1
expressed as dataflow.

The exchanged payload is the whole edge element layer ``q[:L]`` / ``q[-L:]``
(not just the extracted face traces): the receiving slab then evaluates
``surface_rhs`` on the *extended* block ``q[own ++ halo_lo ++ halo_hi]``
with a neighbour table that resolves cross-slab faces into the halo rows —
exactly the assemble-then-flux structure of
``repro.runtime.executor.BlockedDGEngine``, with the halo gather replaced
by a device-resident collective.  Two deliberate costs versus the old
face-trace payload schedule: ~M/2x more wire bytes per exchange, and the
surface flux (intra-slab faces included) now executes entirely in the
correction phase, so only the volume kernel overlaps the ring DMA — the
same interior=volume / correction=flux phase split ``BlockedDGEngine``
uses, which is also how ``CalibrationReport`` already attributes phase
times for the planner (``boundary_s`` is "face-flux work wherever it
executes").  What that buys is the acceptance invariant:

Correctness invariant (tested in ``tests/test_multidevice.py``): the
partitioned rhs/run equals the flat single-array solver BITWISE — every own
element's six face corrections are computed by the same ``surface_rhs``
arithmetic from the same neighbour values (halo rows carry the exact rows
of the remote elements), so the partition is a reordering, never an
approximation.  Periodic bricks wrap through the same ring (``wrap=True``
ppermute for the x direction; y/z wraps stay intra-slab).

Fused multi-device driver: ``run`` (default ``fused=True``) adopts
``repro.runtime.pipeline.ShardedStepPipeline`` — the whole time loop as ONE
donated ``shard_map`` program spanning all devices, with the ring exchange
inside the compiled step loop.  The per-step jitted driver survives as
``fused=False`` solely for calibration/reference (mirroring how
``BlockedDGEngine`` kept the four-phase path).

Online rebalancing: ``run(..., observe=True)`` adopts the step-driver API of
``repro.runtime.executor.NestedPartitionExecutor`` — each fused chunk runs
through the pipeline's in-scan observation channel
(``ShardedStepPipeline.run_observed``: per-shard accumulators psum-reduced
inside the compiled program, chunk wall time attributed by their shares)
and the bound executor (``bind_executor`` / ``make_executor``) re-solves
the nested split on schedule.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.overlap import halo_exchange_1d
from repro.dg.mesh import BrickMesh  # noqa: F401 — referenced in docs
from repro.dg.operators import surface_rhs, volume_rhs_impl
from repro.dg.rk import lsrk45_step
from repro.dg.solver import DGSolver
from repro.runtime.schedule import StepSchedule


def slab_order(grid) -> Tuple[np.ndarray, np.ndarray]:
    """(order, inv): elements reordered x-major so each x-slab is contiguous
    and each x-layer within a slab is contiguous (rows of a layer sorted by
    (iy, iz) — the ordering both ends of the ring agree on)."""
    nx, ny, nz = grid
    K = nx * ny * nz
    ix = np.arange(K) % nx
    iy = (np.arange(K) // nx) % ny
    iz = np.arange(K) // (nx * ny)
    order = np.lexsort((iz, iy, ix))  # primary key ix
    inv = np.empty(K, np.int64)
    inv[order] = np.arange(K)
    return order, inv


def build_slab_tables(neighbors: np.ndarray, grid, n_slabs: int):
    """Per-slab extended-block tables for the ring halo exchange.

    Each slab's extended block is ``[own (per) ++ halo_lo (L) ++ halo_hi
    (L)]`` where ``halo_lo``/``halo_hi`` are the previous slab's last x-layer
    and the next slab's first x-layer (what `halo_exchange_1d` delivers).
    Returns ``(order, inv, nbr_ext, ext_ids, x_wrap)``:

    * ``nbr_ext`` (P, per+2L, 6): slab-local neighbour table over the
      extended block — own rows resolve every face to an own or halo row
      (or -1 physical mirror); halo rows are -1 (their flux output is
      discarded);
    * ``ext_ids`` (P, per+2L): permuted element ids backing each extended
      row (for gathering the static material lines);
    * ``x_wrap``: whether the x direction wraps (periodic brick) — the ring
      ppermute then wraps too.

    ``neighbors`` is the SOLVER mesh's table, so periodic bricks keep their
    wrapping faces: x-wraps ride the ring, y/z wraps stay intra-slab.
    """
    nx, ny, nz = grid
    if nx % n_slabs:
        raise ValueError(f"nx={nx} not divisible by {n_slabs} slabs")
    K = nx * ny * nz
    per = K // n_slabs
    L = ny * nz
    order, inv = slab_order(grid)
    nbr = np.asarray(neighbors, dtype=np.int64)
    # permuted table: new id -> new ids of its 6 face neighbours (-1 kept)
    nbr_p = np.where(nbr[order] >= 0, inv[np.clip(nbr[order], 0, None)], -1)
    # the ring wraps iff the mesh is x-periodic (an ix=0 element — order[0]
    # is one — has a -x neighbour) AND that wrap actually crosses slabs
    x_wrap = bool(nbr_p[0, 0] >= 0) and n_slabs > 1

    ext_n = per + 2 * L
    nbr_ext = np.full((n_slabs, ext_n, 6), -1, np.int64)
    ext_ids = np.zeros((n_slabs, ext_n), np.int64)
    for d in range(n_slabs):
        own = np.arange(d * per, (d + 1) * per)
        # ring payload sources (permuted ids); at a non-wrapping global
        # boundary the ring delivers zeros and no own face references the
        # halo rows, so the id is only a dummy for finite material lines
        prev_hi = np.arange((((d - 1) % n_slabs) + 1) * per - L,
                            (((d - 1) % n_slabs) + 1) * per)
        next_lo = np.arange(((d + 1) % n_slabs) * per,
                            ((d + 1) % n_slabs) * per + L)
        ext_ids[d] = np.concatenate([own, prev_hi, next_lo])

        nn = nbr_p[own]  # (per, 6) permuted-global neighbour ids
        same = (nn >= 0) & (nn // per == d)
        out = np.where(same, nn - d * per, -1)
        cross = (nn >= 0) & ~same
        # -x cross faces live in the first layer and land on halo_lo row j
        # (layers at both ring ends are (iy, iz)-sorted, so offsets line up)
        if cross[:, 0].any():
            assert not cross[L:, 0].any(), "cross-slab -x face outside the edge layer"
            assert (nn[:L, 0][cross[:L, 0]] == prev_hi[cross[:L, 0]]).all()
            out[:L, 0] = np.where(cross[:L, 0], per + np.arange(L), out[:L, 0])
        if cross[:, 1].any():
            assert not cross[:-L, 1].any(), "cross-slab +x face outside the edge layer"
            assert (nn[-L:, 1][cross[-L:, 1]] == next_lo[cross[-L:, 1]]).all()
            out[-L:, 1] = np.where(cross[-L:, 1], per + L + np.arange(L), out[-L:, 1])
        # slabs span the full y/z extent: no other face can cross
        assert not cross[:, 2:].any(), "cross-slab y/z face (slabs must span y,z)"
        nbr_ext[d, :per] = out
    return order, inv, nbr_ext, ext_ids, x_wrap


@dataclasses.dataclass
class PartitionedDG:
    """shard_map slab execution of a DGSolver."""

    solver: DGSolver
    mesh_axes: Mesh
    axis: str = "data"

    def __post_init__(self):
        s = self.solver
        self.P = self.mesh_axes.shape[self.axis]
        nx, ny, nz = s.mesh.grid
        self.K_loc = s.mesh.K // self.P
        self.layer = ny * nz  # elements per x-layer
        self.order_perm, inv, nbr_ext, ext_ids, self.x_wrap = build_slab_tables(
            s.mesh.neighbors, s.mesh.grid, self.P
        )
        self.inv_perm = inv
        dt = jnp.dtype(s.dtype)
        # global sharded tables: (P * ext_n, ...) with one slab's extended
        # block per device (materials are static — only q rides the ring)
        ids = ext_ids.reshape(-1)
        self.nbr_e = jnp.asarray(nbr_ext.reshape(-1, 6))
        rho = np.asarray(s.rho)[self.order_perm][ids]
        lam = np.asarray(s.lam)[self.order_perm][ids]
        mu = np.asarray(s.mu)[self.order_perm][ids]
        self.rho_e = jnp.asarray(rho, dt)
        self.lam_e = jnp.asarray(lam, dt)
        self.mu_e = jnp.asarray(mu, dt)
        self.cp_e = jnp.sqrt((self.lam_e + 2 * self.mu_e) / self.rho_e)
        self.cs_e = jnp.sqrt(self.mu_e / self.rho_e)
        self.spec_q = P(self.axis, None, None, None, None)
        self.spec_e = P(self.axis)
        self._pipeline = None
        self._step_jit = None
        self._executor = None

    # ------------------------------------------------------------------
    def permute_in(self, q_flat: jnp.ndarray) -> jnp.ndarray:
        return q_flat[self.order_perm]

    def permute_out(self, q_part: jnp.ndarray) -> jnp.ndarray:
        return q_part[self.inv_perm]

    # ------------------------------------------------------------------
    def _make_schedule(self) -> StepSchedule:
        """The slab rhs as the shared four-phase schedule: pack the slab-edge
        element layers -> ring exchange -> volume on own elements -> extended
        surface flux fold.  Runs inside ``shard_map`` (eagerly per stage via
        :meth:`rhs`, or inside the fused compiled loop of
        ``repro.runtime.pipeline.ShardedStepPipeline``)."""
        s = self.solver
        L = self.layer
        per = self.K_loc

        def boundary(st):
            # the pack: both slab-edge element layers (contiguous slices)
            q = st["q"]
            return {"lo": q[:L], "hi": q[-L:]}

        def exchange(send, st):
            from_prev, from_next = halo_exchange_1d(
                send["lo"], send["hi"], self.axis, wrap=self.x_wrap
            )
            return {"from_prev": from_prev, "from_next": from_next}

        def interior(st):
            # volume on own elements: no dependence on the ring payload;
            # kernel_impl threads through so the Pallas volume kernel runs
            # inside the SPMD slab path too
            return volume_rhs_impl(
                st["q"], s.D, s.metrics,
                st["rho"][:per], st["lam"][:per], st["mu"][:per],
                kernel_impl=s.kernel_impl,
            )

        def correction(out, recv, st):
            # extended block [own ++ halo_lo ++ halo_hi]: the same assemble-
            # then-flux structure as BlockedDGEngine, so every own row's six
            # face corrections are bitwise the flat solver's (halo rows'
            # output is dropped by the slice)
            q_ext = jnp.concatenate([st["q"], recv["from_prev"], recv["from_next"]])
            sur = surface_rhs(
                q_ext, st["nbr"], s.lift,
                st["rho"], st["lam"], st["mu"], st["cp"], st["cs"],
                kernel_impl=s.kernel_impl,
            )
            return out + sur[:per]

        return StepSchedule(boundary=boundary, exchange=exchange,
                            interior=interior, correction=correction, name="slab-spmd")

    def _rhs_local(self, q, nbr, rho, lam, mu, cp, cs):
        """Per-device rhs with ring halo exchange; runs inside shard_map."""
        state = {"q": q, "nbr": nbr, "rho": rho, "lam": lam, "mu": mu,
                 "cp": cp, "cs": cs}
        return self._make_schedule().rhs(state)

    def _operands(self):
        """The static sharded tables every rhs evaluation threads through."""
        return (self.nbr_e, self.rho_e, self.lam_e, self.mu_e, self.cp_e, self.cs_e)

    def _operand_specs(self):
        e = self.spec_e
        return (P(self.axis, None), e, e, e, e, e)

    # ------------------------------------------------------------------
    def rhs(self, q_part: jnp.ndarray) -> jnp.ndarray:
        """Global-view rhs on the permuted state (sharded over the axis)."""
        from repro.jax_compat import shard_map

        f = shard_map(
            self._rhs_local,
            mesh=self.mesh_axes,
            in_specs=(self.spec_q,) + self._operand_specs(),
            out_specs=self.spec_q,
            check_vma=False,
        )
        return f(q_part, *self._operands())

    def make_executor(self, bucket: int = 16, **kwargs):
        """An online auto-rebalancing executor matching this decomposition
        (one partition per slab)."""
        from repro.runtime.executor import NestedPartitionExecutor

        return NestedPartitionExecutor(
            self.solver.mesh.K,
            self.P,
            grid_dims=self.solver.mesh.grid,
            bucket=bucket,
            **kwargs,
        )

    def pipeline(self):
        """The fused multi-device step pipeline bound to this decomposition:
        ONE donated shard_map program — step loop, stage scan, and the ring
        ppermute exchange all inside (built lazily, cached)."""
        if self._pipeline is None:
            from repro.runtime.pipeline import ShardedStepPipeline

            self._pipeline = ShardedStepPipeline(self)
        return self._pipeline

    def bind_executor(self, executor=None):
        """Install (or lazily create) the engine-owned executor that
        ``run(observe=True)`` feeds.  Returns it."""
        if executor is not None:
            self._executor = executor
        elif getattr(self, "_executor", None) is None:
            self._executor = self.make_executor()
        return self._executor

    def calibrate(self, q_part: jnp.ndarray, reps: int = 1,
                  dt: Optional[float] = None) -> "CalibrationReport":
        """Synchronous-step calibration: under the SPMD barrier every slab's
        step time equals the wall time, so the report attributes the same
        measured whole-step seconds to each of the P slabs
        (``observe_total`` semantics).  Per-slab skew is not separable on
        this engine — the blocked engine exists for that."""
        from repro.runtime.schedule import CalibrationReport

        dt = dt or self.solver.cfl_dt()
        if self._step_jit is None:
            self._step_jit = jax.jit(
                lambda q, res, dt: lsrk45_step(q, res, self.rhs, dt)
            )
        res = jnp.zeros_like(q_part)
        dt_j = jnp.asarray(dt, q_part.dtype)
        out = self._step_jit(q_part, res, dt_j)
        jax.block_until_ready(out)  # warmup / compile
        ts = []
        for _ in range(max(1, reps)):
            t0 = time.perf_counter()
            out = self._step_jit(q_part, res, dt_j)
            jax.block_until_ready(out)
            ts.append(time.perf_counter() - t0)
        ts.sort()
        return CalibrationReport.from_totals(np.full(self.P, ts[len(ts) // 2]))

    def resplice(self, plan) -> None:
        """Apply a solved plan to the bound executor.  Slab geometry itself
        is SPMD-fixed (equal K/P slabs inside ``shard_map``); the plan
        lands in the executor's bookkeeping/hooks, which is where blocked
        consumers of the same executor pick it up."""
        self.bind_executor().apply(plan)

    def run(
        self,
        q_part: jnp.ndarray,
        n_steps: int,
        dt: Optional[float] = None,
        *,
        observe: bool = False,
        fused: bool = True,
    ) -> jnp.ndarray:
        """Advance ``n_steps``.

        ``fused`` (default) drives the ``ShardedStepPipeline``: the whole
        time loop runs as a single donated device program spanning all
        devices — one host dispatch per run (per rebalance chunk when
        observing), independent of device count, slab count and horizon.
        ``fused=False`` is the eager per-step reference driver (one jitted
        step per host dispatch) kept for calibration and differential tests.

        With ``observe=True`` the run is segmented on the bound executor's
        (``bind_executor`` / ``make_executor``) rebalance schedule: each
        chunk is ONE fused dispatch through the pipeline's in-scan
        observation channel — per-shard cost accumulators psum-reduced
        inside the compiled program, the chunk's wall time attributed by
        their shares — and the nested split re-solved, so the
        calibrate->solve->resplice loop runs at full fused speed alongside
        the SPMD compute."""
        executor = self.bind_executor() if observe else None
        dt = dt or self.solver.cfl_dt()

        if fused:
            pipe = self.pipeline()
            if executor is None:
                return pipe.run(q_part, n_steps, dt=dt)
            done = 0
            while done < n_steps:
                chunk = n_steps - done
                if executor.rebalance_every > 0:
                    chunk = min(executor.rebalance_every, chunk)
                q_part, report = pipe.run_observed(q_part, chunk, dt=dt)
                executor.observe_chunk(report, chunk)
                done += chunk
            return q_part

        # eager reference driver: one jitted step per dispatch (shared
        # compiled step; dt is a traced operand so it compiles once)
        if self._step_jit is None:
            self._step_jit = jax.jit(
                lambda q, res, dt: lsrk45_step(q, res, self.rhs, dt)
            )
        res = jnp.zeros_like(q_part)
        dt_j = jnp.asarray(dt, q_part.dtype)
        done = 0
        while done < n_steps:
            chunk = n_steps - done
            if executor is not None and executor.rebalance_every > 0:
                chunk = min(executor.rebalance_every, chunk)
            t0 = time.perf_counter()
            for _ in range(chunk):
                q_part, res = self._step_jit(q_part, res, dt_j)
            if executor is not None:
                jax.block_until_ready(q_part)
                executor.observe_total((time.perf_counter() - t0) / chunk)
                executor.advance(chunk)
            done += chunk
        return q_part
