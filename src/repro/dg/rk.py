"""Low-storage RK4(5) (Carpenter & Kennedy) — the paper's rk kernel."""

from __future__ import annotations

import numpy as np

LSRK_A = np.array([
    0.0,
    -567301805773.0 / 1357537059087.0,
    -2404267990393.0 / 2016746695238.0,
    -3550918686646.0 / 2091501179385.0,
    -1275806237668.0 / 842570457699.0,
])
LSRK_B = np.array([
    1432997174477.0 / 9575080441755.0,
    5161836677717.0 / 13612068292357.0,
    1720146321549.0 / 2090206949498.0,
    3134564353537.0 / 4481467310338.0,
    2277821191437.0 / 14882151754819.0,
])
LSRK_C = np.array([
    0.0,
    1432997174477.0 / 9575080441755.0,
    2526269341429.0 / 6820363962896.0,
    2006345519317.0 / 3224310063776.0,
    2802321613138.0 / 2924317926251.0,
])


def lsrk45_step(q, res, rhs_fn, dt):
    """One LSRK4(5) step. res is the low-storage register (same shape as q)."""
    for s in range(5):
        res = LSRK_A[s] * res + dt * rhs_fn(q)
        q = q + LSRK_B[s] * res
    return q, res
