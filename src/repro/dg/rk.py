"""Low-storage RK4(5) (Carpenter & Kennedy) — the paper's rk kernel.

Under a trace (every compiled driver: flat ``dg.solver``, SPMD
``dg.partitioned``, the blocked ``runtime.pipeline``) the stage loop is a
``lax.scan`` over the five (A, B) coefficient pairs, so the stage body is
traced exactly once instead of unrolled five times — inside an outer step
loop the whole time integration compiles to one resident program.
Coefficients live on device in the carry dtype (dtype-stable: a float32
field never promotes through a float64 numpy scalar), keeping the update
arithmetic identical to the historical Python loop up to XLA's FMA
contraction of ``a*res + dt*rhs`` (~1 ulp).

Called EAGERLY (concrete arrays — the calibration/reference paths), the
stages run as the historical Python loop instead: an eager ``lax.scan``
would re-trace and re-lower ``rhs_fn`` on every call (~10x host overhead
per step), and caching a compiled step per callable would silently pin
stale closure state (an engine's block tables change on resplice).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

LSRK_A = np.array([
    0.0,
    -567301805773.0 / 1357537059087.0,
    -2404267990393.0 / 2016746695238.0,
    -3550918686646.0 / 2091501179385.0,
    -1275806237668.0 / 842570457699.0,
])
LSRK_B = np.array([
    1432997174477.0 / 9575080441755.0,
    5161836677717.0 / 13612068292357.0,
    1720146321549.0 / 2090206949498.0,
    3134564353537.0 / 4481467310338.0,
    2277821191437.0 / 14882151754819.0,
])
LSRK_C = np.array([
    0.0,
    1432997174477.0 / 9575080441755.0,
    2526269341429.0 / 6820363962896.0,
    2006345519317.0 / 3224310063776.0,
    2802321613138.0 / 2924317926251.0,
])


# the five (A, B) stage pairs, stacked as the stage scan's xs; cast to the
# carry dtype at use (never cached: a dtype cast is itself a traced op, so a
# memoized device constant would leak tracers across jit scopes)
_LSRK_AB = np.stack([LSRK_A, LSRK_B], axis=1)


def lsrk_coeffs(dtype) -> jnp.ndarray:
    """The (5, 2) stage-coefficient table in ``dtype``, on device."""
    return jnp.asarray(_LSRK_AB, jnp.dtype(dtype))


def lsrk45_step(q, res, rhs_fn, dt):
    """One LSRK4(5) step. res is the low-storage register (same shape as q).

    Scan-compiled under a trace, plain Python loop eagerly (see module
    docstring)."""
    dtype = jnp.result_type(q)
    if not (isinstance(q, jax.core.Tracer) or isinstance(res, jax.core.Tracer)):
        dt = float(dt)  # weak-typed, like the coefficients: dtype-stable
        for s in range(5):
            res = float(LSRK_A[s]) * res + dt * rhs_fn(q)
            q = q + float(LSRK_B[s]) * res
        return q, res
    dt = jnp.asarray(dt, dtype)

    def stage(carry, ab):
        q, res = carry
        res = ab[0] * res + dt * rhs_fn(q)
        q = q + ab[1] * res
        return (q, res), None

    (q, res), _ = jax.lax.scan(stage, (q, res), lsrk_coeffs(dtype))
    return q, res
