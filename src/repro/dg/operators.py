"""DGSEM operators: volume derivatives, face extraction, exact Riemann flux,
lift — the paper's volume_loop / interp_q / int_flux / lift kernels, in jnp.

Field layout: q (K, 9, M, M, M) with fields
  0..5 = strain E (xx, yy, zz, yz, xz, xy)   [symmetric, 6 stored]
  6..8 = velocity v (x, y, z)
Element axes are (r1, r2, r3) = (x, y, z) on the affine brick.

Flux formulas are the paper's exact Riemann solutions (Rankine-Hugoniot,
Wilcox et al.): with S_j = S^- - S^+, v_j = v^- - v^+, n = s*e_a,
  k0 = 1/(rho^- cp^- + rho^+ cp^+),  k1 = 1/(rho^- cs^- + rho^+ cs^+)
  (k1 = 0 where mu^- = 0, i.e. the acoustic side),
the strain correction has nonzero components only in row/col a, and the
velocity correction couples through rho^- c^-.  Traction boundaries use the
mirror principle [v]=0, [S] = -2(t_bc - S^- n).

These jnp implementations are ALSO the oracles (`ref.py`) for the Pallas
kernels in repro/kernels/.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

# strain component index for the (a, b) entry of the symmetric tensor
SYM = np.array([
    [0, 5, 4],
    [5, 1, 3],
    [4, 3, 2],
])
# face ordering (-x,+x,-y,+y,-z,+z)
FACE_AXIS = (0, 0, 1, 1, 2, 2)
FACE_SIGN = (-1.0, 1.0, -1.0, 1.0, -1.0, 1.0)
OPPOSITE = (1, 0, 3, 2, 5, 4)


def deriv(u: jnp.ndarray, D: jnp.ndarray, axis: int) -> jnp.ndarray:
    """Apply the differentiation matrix along element axis (0,1,2) of
    u (K, F, M, M, M) — the paper's IIAX/IAIX/AIIX tensor applications."""
    if axis == 0:
        return jnp.einsum("am,kfmjl->kfajl", D, u)
    if axis == 1:
        return jnp.einsum("am,kfiml->kfial", D, u)
    return jnp.einsum("am,kfijm->kfija", D, u)


def stress(q: jnp.ndarray, lam: jnp.ndarray, mu: jnp.ndarray) -> jnp.ndarray:
    """S (K, 6, M, M, M) from strain fields of q; lam/mu (K,)."""
    E = q[:, :6]
    tr = E[:, 0] + E[:, 1] + E[:, 2]
    lam_ = lam[:, None, None, None]
    mu_ = mu[:, None, None, None]
    Sxx = lam_ * tr + 2 * mu_ * E[:, 0]
    Syy = lam_ * tr + 2 * mu_ * E[:, 1]
    Szz = lam_ * tr + 2 * mu_ * E[:, 2]
    Syz = 2 * mu_ * E[:, 3]
    Sxz = 2 * mu_ * E[:, 4]
    Sxy = 2 * mu_ * E[:, 5]
    return jnp.stack([Sxx, Syy, Szz, Syz, Sxz, Sxy], axis=1)


def volume_rhs(
    q: jnp.ndarray,  # (K, 9, M, M, M)
    D: jnp.ndarray,
    metrics: Tuple[float, float, float],  # 2/h per axis
    rho: jnp.ndarray,
    lam: jnp.ndarray,
    mu: jnp.ndarray,
) -> jnp.ndarray:
    """The paper's volume_loop: dE/dt = sym(grad v); rho dv/dt = div S."""
    v = q[:, 6:9]
    dv = [deriv(v, D, a) * metrics[a] for a in range(3)]  # each (K, 3, M,M,M)
    dE = jnp.stack(
        [
            dv[0][:, 0],
            dv[1][:, 1],
            dv[2][:, 2],
            0.5 * (dv[2][:, 1] + dv[1][:, 2]),
            0.5 * (dv[2][:, 0] + dv[0][:, 2]),
            0.5 * (dv[1][:, 0] + dv[0][:, 1]),
        ],
        axis=1,
    )
    S = stress(q, lam, mu)
    # div S rows: x: Sxx,x + Sxy,y + Sxz,z ; using SYM indexing
    dS = [deriv(S, D, a) * metrics[a] for a in range(3)]
    rho_ = rho[:, None, None, None]
    dvx = (dS[0][:, SYM[0, 0]] + dS[1][:, SYM[0, 1]] + dS[2][:, SYM[0, 2]]) / rho_
    dvy = (dS[0][:, SYM[1, 0]] + dS[1][:, SYM[1, 1]] + dS[2][:, SYM[1, 2]]) / rho_
    dvz = (dS[0][:, SYM[2, 0]] + dS[1][:, SYM[2, 1]] + dS[2][:, SYM[2, 2]]) / rho_
    return jnp.concatenate([dE, jnp.stack([dvx, dvy, dvz], axis=1)], axis=1)


def extract_face(u: jnp.ndarray, face: int) -> jnp.ndarray:
    """interp_q (LGL collocation: a slice). u (K, F, M, M, M) -> (K, F, M, M)."""
    ax = FACE_AXIS[face]
    last = u.shape[2 + ax] - 1
    idx = 0 if FACE_SIGN[face] < 0 else last
    if ax == 0:
        return u[:, :, idx, :, :]
    if ax == 1:
        return u[:, :, :, idx, :]
    return u[:, :, :, :, idx]


def riemann_correction(
    Sm: jnp.ndarray,  # (K, 6, M, M) minus-side stress at face nodes
    vm: jnp.ndarray,  # (K, 3, M, M)
    Sp: jnp.ndarray,
    vp: jnp.ndarray,
    axis: int,
    sign: float,
    mat_m: Dict[str, jnp.ndarray],  # rho, cp, cs, mu — (K,) minus side
    mat_p: Dict[str, jnp.ndarray],
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """n.(F* - F) for strain (K,6,M,M) and velocity (K,3,M,M)."""
    e = lambda x: x[:, None, None]
    k0 = 1.0 / (e(mat_m["rho"] * mat_m["cp"]) + e(mat_p["rho"] * mat_p["cp"]))
    denom_s = e(mat_m["rho"] * mat_m["cs"]) + e(mat_p["rho"] * mat_p["cs"])
    # k1 = 0 where the minus side is acoustic (mu^- = 0)
    k1 = jnp.where(e(mat_m["mu"]) > 0, 1.0 / jnp.maximum(denom_s, 1e-300), 0.0)

    S_j = Sm - Sp  # (K, 6, M, M)
    v_j = vm - vp
    a0, a1, a2 = axis, (axis + 1) % 3, (axis + 2) % 3
    S_aa = S_j[:, SYM[a0, a0]]
    S_a1 = S_j[:, SYM[a0, a1]]
    S_a2 = S_j[:, SYM[a0, a2]]
    rcp_p = e(mat_p["rho"] * mat_p["cp"])
    rcs_p = e(mat_p["rho"] * mat_p["cs"])
    rcp_m = e(mat_m["rho"] * mat_m["cp"])
    rcs_m = e(mat_m["rho"] * mat_m["cs"])

    a = k0 * (S_aa + rcp_p * sign * v_j[:, a0])
    FE = jnp.zeros_like(S_j)
    FE = FE.at[:, SYM[a0, a0]].set(a)
    FE = FE.at[:, SYM[a0, a1]].set(0.5 * k1 * (S_a1 + rcs_p * sign * v_j[:, a1]))
    FE = FE.at[:, SYM[a0, a2]].set(0.5 * k1 * (S_a2 + rcs_p * sign * v_j[:, a2]))

    Fv = jnp.zeros_like(v_j)
    Fv = Fv.at[:, a0].set(a * rcp_m * sign)
    Fv = Fv.at[:, a1].set(k1 * rcs_m * (sign * S_a1 + rcs_p * v_j[:, a1]))
    Fv = Fv.at[:, a2].set(k1 * rcs_m * (sign * S_a2 + rcs_p * v_j[:, a2]))
    return FE, Fv


def surface_rhs(
    q: jnp.ndarray,  # (K, 9, M, M, M)
    neighbors: jnp.ndarray,  # (K, 6)
    lift: Tuple[float, float, float],  # metric(a)/w_edge per axis
    rho: jnp.ndarray,
    lam: jnp.ndarray,
    mu: jnp.ndarray,
    cp: jnp.ndarray,
    cs: jnp.ndarray,
    kernel_impl: str = "xla",
) -> jnp.ndarray:
    """int_flux + bound_flux + lift: Riemann corrections on all 6 faces.

    ``kernel_impl`` selects the Riemann-flux body: ``xla`` is the jnp
    reference, ``pallas``/``interpret`` run ``dg_flux_pallas`` (the paper's
    int_flux/godonov_flux hot-spot as a TPU kernel) — one instantiation per
    face direction, exactly the solver's face loop.
    """
    S = stress(q, lam, mu)
    out = jnp.zeros_like(q)
    mats = {"rho": rho, "cp": cp, "cs": cs, "mu": mu}
    for face in range(6):
        ax = FACE_AXIS[face]
        sign = FACE_SIGN[face]
        nbr = neighbors[:, face]
        has_nbr = nbr >= 0
        skip = nbr == -2  # cross-partition face: handled by the halo pass
        nbr_safe = jnp.maximum(nbr, 0)

        Sm = extract_face(S, face)
        vm = extract_face(q[:, 6:9], face)
        Sp_all = extract_face(S, OPPOSITE[face])
        vp_all = extract_face(q[:, 6:9], OPPOSITE[face])
        Sp = Sp_all[nbr_safe]
        vp = vp_all[nbr_safe]
        # physical boundary: traction-free mirror [v]=0, S_j = 2 S^- n
        hn = has_nbr[:, None, None, None]
        Sp = jnp.where(hn, Sp, -Sm)  # S_j = Sm - Sp = 2 Sm
        vp = jnp.where(hn, vp, vm)  # v_j = 0
        mat_m = mats
        mat_p = {k: jnp.where(has_nbr, v[nbr_safe], v) for k, v in mats.items()}

        if kernel_impl == "xla":
            FE, Fv = riemann_correction(Sm, vm, Sp, vp, ax, sign, mat_m, mat_p)
        else:  # pallas | interpret — the flux kernel behind the same switch
            from repro.kernels.dg_flux import dg_flux_pallas

            mats8 = jnp.stack(
                [mat_m["rho"], mat_m["cp"], mat_m["cs"], mat_m["mu"],
                 mat_p["rho"], mat_p["cp"], mat_p["cs"], mat_p["mu"]],
                axis=1,
            )
            FE, Fv = dg_flux_pallas(Sm, vm, Sp, vp, mats8, ax, sign,
                                    interpret=(kernel_impl == "interpret"))
        corr = jnp.concatenate([FE, Fv / rho[:, None, None, None]], axis=1)  # Q^-1 on v rows
        corr = -lift[ax] * corr
        corr = jnp.where(skip[:, None, None, None], 0.0, corr)
        last = q.shape[2 + ax] - 1
        idx = 0 if sign < 0 else last
        if ax == 0:
            out = out.at[:, :, idx, :, :].add(corr)
        elif ax == 1:
            out = out.at[:, :, :, idx, :].add(corr)
        else:
            out = out.at[:, :, :, :, idx].add(corr)
    return out


def volume_rhs_impl(q, D, metrics, rho, lam, mu, kernel_impl: str = "xla"):
    """``volume_rhs`` behind the kernel switch: ``xla`` is the jnp reference,
    ``pallas``/``interpret`` run the paper's volume_loop as a TPU kernel."""
    if kernel_impl == "xla":
        return volume_rhs(q, D, metrics, rho, lam, mu)
    from repro.kernels.dg_volume import dg_volume_pallas

    return dg_volume_pallas(q, D, metrics, rho, lam, mu,
                            interpret=(kernel_impl == "interpret"))


def dg_rhs(q, D, metrics, lift, neighbors, rho, lam, mu, cp, cs, kernel_impl: str = "xla"):
    vol = volume_rhs_impl(q, D, metrics, rho, lam, mu, kernel_impl=kernel_impl)
    return vol + surface_rhs(q, neighbors, lift, rho, lam, mu, cp, cs,
                             kernel_impl=kernel_impl)
