"""Structured hexahedral brick mesh with Morton-ordered elements and the
two-material geometry of the paper's Fig 6.1 (acoustic | elastic halves)."""

from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np

from repro.core.morton import morton_order
from repro.core.partition import face_neighbors

# face index order matches core.partition.face_neighbors: (-x,+x,-y,+y,-z,+z)
FACE_AXIS = np.array([0, 0, 1, 1, 2, 2])
FACE_SIGN = np.array([-1, +1, -1, +1, -1, +1])
OPPOSITE = np.array([1, 0, 3, 2, 5, 4])


@dataclasses.dataclass(frozen=True)
class BrickMesh:
    grid: Tuple[int, int, int]
    extent: Tuple[float, float, float]
    neighbors: np.ndarray  # (K, 6) element id or -1
    centers: np.ndarray  # (K, 3)
    h: Tuple[float, float, float]  # element size per axis

    @property
    def K(self) -> int:
        return int(np.prod(self.grid))

    @property
    def jacobian(self) -> float:
        hx, hy, hz = self.h
        return hx * hy * hz / 8.0

    def metric(self, axis: int) -> float:
        """dr_axis/dx_axis for the affine map: 2/h."""
        return 2.0 / self.h[axis]


def make_brick(grid=(8, 8, 8), extent=(1.0, 1.0, 1.0), periodic: bool = False) -> BrickMesh:
    nx, ny, nz = grid
    hx, hy, hz = extent[0] / nx, extent[1] / ny, extent[2] / nz
    ix, iy, iz = np.meshgrid(np.arange(nx), np.arange(ny), np.arange(nz), indexing="ij")
    eid = (ix + nx * (iy + ny * iz)).ravel()
    centers = np.zeros((nx * ny * nz, 3))
    centers[eid, 0] = (ix.ravel() + 0.5) * hx
    centers[eid, 1] = (iy.ravel() + 0.5) * hy
    centers[eid, 2] = (iz.ravel() + 0.5) * hz
    nbr = face_neighbors(grid)
    if periodic:
        dims = (nx, ny, nz)

        def _id(jx, jy, jz):
            return jx + nx * (jy + ny * jz)

        fx, fy, fz = ix.ravel(), iy.ravel(), iz.ravel()
        wrap = [
            _id((fx - 1) % nx, fy, fz), _id((fx + 1) % nx, fy, fz),
            _id(fx, (fy - 1) % ny, fz), _id(fx, (fy + 1) % ny, fz),
            _id(fx, fy, (fz - 1) % nz), _id(fx, fy, (fz + 1) % nz),
        ]
        for f in range(6):
            m = nbr[eid, f] < 0
            nbr[eid[m], f] = wrap[f][m]
    return BrickMesh(
        grid=grid,
        extent=extent,
        neighbors=nbr,
        centers=centers,
        h=(hx, hy, hz),
    )


def two_tree_materials(mesh: BrickMesh, cp=(1.0, 3.0), cs=(0.0, 2.0), rho=(1.0, 1.0)):
    """Fig 6.1: first half acoustic (cp=1, cs=0), second half elastic
    (cp=3, cs=2), discontinuity at the x midplane.  Returns per-element
    (rho, lam, mu)."""
    half = mesh.centers[:, 0] >= mesh.extent[0] / 2.0
    region = half.astype(np.int64)
    rho_e = np.asarray(rho)[region]
    cp_e = np.asarray(cp)[region]
    cs_e = np.asarray(cs)[region]
    mu = rho_e * cs_e**2
    lam = rho_e * (cp_e**2 - 2 * cs_e**2)
    return rho_e, lam, mu, region
