"""DGSEM coupled elastic-acoustic wave solver — the paper's evaluation
problem (sections 2-3): strain-velocity formulation, exact Riemann flux
(Wilcox et al.), LGL collocation on affine hexahedra, LSRK4(5) stepping,
nested-partition execution (section 5)."""

from repro.dg.basis import lgl_nodes_weights, diff_matrix
from repro.dg.solver import DGSolver
