import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Proves the distribution config is coherent without hardware:
  * ``make_production_mesh()`` builds the 16x16 single-pod or 2x16x16
    multi-pod mesh over 512 forced host devices;
  * every model input/param/state is a ShapeDtypeStruct (eval_shape), so
    nothing is allocated;
  * ``jax.jit(step, in_shardings, out_shardings).lower(...).compile()`` must
    succeed; memory_analysis() proves per-device fit, cost_analysis() +
    loop-aware HLO analysis feed the roofline (EXPERIMENTS.md).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-7b --shape train_4k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both --out experiments/dryrun
"""

import argparse
import json
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.shapes import SHAPES, cells_for
from repro.launch.hlo_analysis import analyze
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import decode_input_specs, prefill_input_specs, train_input_specs
from repro.models.zoo import LM, get_config, list_archs
from repro.optim import OptConfig, init_opt_state
from repro.parallel.steps import (
    accum_layout,
    make_prefill_step,
    make_serve_step,
    make_shardings,
    make_train_step,
)

# v5e roofline constants (assignment)
PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # bytes/s / chip
ICI_BW = 50e9  # bytes/s / link


def build_cell(arch: str, shape_name: str, multi_pod: bool, *, grad_sync: str = "auto",
               fsdp: bool = True, extra_cfg: Optional[Dict[str, Any]] = None,
               micro_per_device: int = 1):
    """Returns (lowered_fn, lower_args) for the cell."""
    cfg = get_config(arch).replace(kernel_impl="xla", **(extra_cfg or {}))
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    dp = mesh.shape["data"] * (mesh.shape.get("pod", 1))
    ep_size = mesh.shape["data"] if cfg.n_experts else 1
    lm = LM(cfg, ep_size=ep_size)
    params_sds = jax.eval_shape(lm.init, jax.random.PRNGKey(0))

    if shape.kind == "train":
        accum, micro = accum_layout(shape.global_batch, dp, target_per_device=micro_per_device)
        sh = make_shardings(lm, mesh, kind="train", accum=True, fsdp=fsdp,
                            batch_shardable=(micro % dp == 0))
        batch = train_input_specs(cfg, shape, accum, micro)
        opt_sds = jax.eval_shape(init_opt_state, params_sds)
        step = make_train_step(lm, OptConfig(), sh, grad_sync=grad_sync)
        jitted = jax.jit(
            step,
            in_shardings=(sh.params, sh.opt, sh.batch),
            out_shardings=(sh.params, sh.opt, None),
            donate_argnums=(0, 1),
        )
        return jitted, (params_sds, opt_sds, batch), mesh, lm

    if shape.kind == "prefill":
        sh = make_shardings(lm, mesh, kind="prefill", fsdp=fsdp,
                            batch_shardable=(shape.global_batch % dp == 0))
        batch = prefill_input_specs(cfg, shape)
        step = make_prefill_step(lm, sh)
        jitted = jax.jit(step, in_shardings=(sh.params, sh.batch))
        return jitted, (params_sds, batch), mesh, lm

    # decode
    sh = make_shardings(lm, mesh, kind="decode", fsdp=fsdp,
                        batch_shardable=(shape.global_batch % dp == 0))
    tok_specs, cache_sds = decode_input_specs(lm, shape)
    step = make_serve_step(lm, sh)
    jitted = jax.jit(
        step,
        in_shardings=(sh.params, sh.cache, sh.batch["tokens"]),
        donate_argnums=(1,),
    )
    return jitted, (params_sds, cache_sds, tok_specs["tokens"]), mesh, lm


def run_cell(arch: str, shape_name: str, multi_pod: bool, *, full_analysis: bool = True,
             grad_sync: str = "auto", fsdp: bool = True,
             extra_cfg: Optional[Dict[str, Any]] = None,
             micro_per_device: int = 1,
             dynamic_trips: Optional[float] = None) -> Dict[str, Any]:
    mesh_name = "2x16x16" if multi_pod else "16x16"
    rec: Dict[str, Any] = {"arch": arch, "shape": shape_name, "mesh": mesh_name}
    # perf_counter: lower/compile durations must survive NTP clock steps
    t0 = time.perf_counter()
    jitted, args, mesh, lm = build_cell(arch, shape_name, multi_pod,
                                        grad_sync=grad_sync, fsdp=fsdp, extra_cfg=extra_cfg,
                                        micro_per_device=micro_per_device)
    lowered = jitted.lower(*args)
    rec["lower_s"] = round(time.perf_counter() - t0, 1)
    t1 = time.perf_counter()
    compiled = lowered.compile()
    rec["compile_s"] = round(time.perf_counter() - t1, 1)

    mem = compiled.memory_analysis()
    # CPU backend exposes these attributes; guard for portability
    for attr in ("temp_size_in_bytes", "argument_size_in_bytes", "output_size_in_bytes",
                 "generated_code_size_in_bytes"):
        v = getattr(mem, attr, None)
        if v is not None:
            rec[attr] = int(v)
    ca = compiled.cost_analysis() or {}
    rec["xla_cost_flops"] = float(ca.get("flops", -1.0))
    rec["xla_cost_bytes"] = float(ca.get("bytes accessed", -1.0))

    if full_analysis:
        text = compiled.as_text()
        rec["hlo_chars"] = len(text)
        rec.update(analyze(text, dynamic_trips=dynamic_trips))
        chips = 512 if multi_pod else 256
        rec["chips"] = chips
        rec["t_compute_s"] = rec["flops"] / PEAK_FLOPS
        # memory term: the TPU-fused (lower-bound) estimate; the unfused
        # upper bound is kept as t_memory_upper_s (methodology: DESIGN.md)
        rec["t_memory_s"] = rec["mem_bytes_fused"] / HBM_BW
        rec["t_memory_upper_s"] = rec["mem_bytes"] / HBM_BW
        rec["t_collective_s"] = rec["collective_bytes_total"] / ICI_BW
        dom = max(("compute", "memory", "collective"),
                  key=lambda k: rec[f"t_{k}_s"])
        rec["dominant"] = dom
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--grad-sync", default="auto", choices=["auto", "podwise", "podwise_int8"])
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--fast", action="store_true", help="skip HLO text analysis")
    ap.add_argument("--out", default=None, help="directory for per-cell JSON records")
    ap.add_argument("--cluster-plan", type=int, default=None, metavar="N",
                    help="print the two-level nested-partition plan (Morton "
                         "inter-node splice + per-node boundary/interior/accel "
                         "split) for N simulated heterogeneous nodes, then exit")
    ap.add_argument("--plan-grid", default="16,16,8",
                    help="element grid for --cluster-plan (nx,ny,nz)")
    ap.add_argument("--plan-order", type=int, default=7,
                    help="DG polynomial order for --cluster-plan cost models")
    ap.add_argument("--plan-speeds", default=None,
                    help="comma-separated per-node relative speeds for "
                         "--cluster-plan (default: homogeneous)")
    args = ap.parse_args()

    if args.cluster_plan is not None:
        from repro.runtime.cluster import format_cluster_plan

        grid = tuple(int(x) for x in args.plan_grid.split(","))
        speeds = (
            [float(x) for x in args.plan_speeds.split(",")]
            if args.plan_speeds else None
        )
        print(format_cluster_plan(grid, args.cluster_plan, order=args.plan_order,
                                  speeds=speeds))
        return 0

    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    if args.all:
        cells = []
        for aid in list_archs():
            for c in cells_for(get_config(aid)):
                cells.append(c)
    else:
        if not args.arch or not args.shape:
            ap.error("--arch and --shape required unless --all")
        cells = [c for c in cells_for(get_config(args.arch)) if c.shape.name == args.shape]

    n_ok = n_skip = n_fail = 0
    for cell in cells:
        for mp in meshes:
            name = f"{cell.arch_id}/{cell.shape.name}/{'2x16x16' if mp else '16x16'}"
            if cell.skip:
                print(f"SKIP  {name}: {cell.skip}", flush=True)
                rec = {"arch": cell.arch_id, "shape": cell.shape.name,
                       "mesh": "2x16x16" if mp else "16x16", "skipped": cell.skip}
                n_skip += 1
            else:
                try:
                    rec = run_cell(cell.arch_id, cell.shape.name, mp,
                                   full_analysis=not args.fast,
                                   grad_sync=args.grad_sync, fsdp=not args.no_fsdp)
                    print(f"OK    {name}: lower {rec['lower_s']}s compile {rec['compile_s']}s "
                          f"temp/dev {rec.get('temp_size_in_bytes', 0)/2**30:.2f} GiB "
                          f"dom={rec.get('dominant', '?')}", flush=True)
                    n_ok += 1
                except Exception as e:  # noqa: BLE001 — record and continue
                    print(f"FAIL  {name}: {e}", flush=True)
                    traceback.print_exc()
                    rec = {"arch": cell.arch_id, "shape": cell.shape.name,
                           "mesh": "2x16x16" if mp else "16x16", "error": str(e)[:2000]}
                    n_fail += 1
            if args.out:
                os.makedirs(args.out, exist_ok=True)
                fn = f"{cell.arch_id}_{cell.shape.name}_{'multi' if mp else 'single'}.json"
                with open(os.path.join(args.out, fn.replace('/', '_')), "w") as f:
                    json.dump(rec, f, indent=1)
    print(f"\ndry-run summary: {n_ok} ok, {n_skip} skipped, {n_fail} failed", flush=True)
    return 1 if n_fail else 0


if __name__ == "__main__":
    raise SystemExit(main())
