"""Batched serving driver: prefill a prompt batch, decode with greedy
sampling, report per-token latency/throughput.

  PYTHONPATH=src python -m repro.launch.serve --arch mixtral-8x22b --smoke \
      --batch 4 --prompt-len 64 --gen 32
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.shapes import smoke_config
from repro.data.pipeline import _rng
from repro.launch.mesh import debug_mesh, make_production_mesh
from repro.models.zoo import LM, get_config
from repro.parallel.steps import make_serve_step, make_shardings


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if cfg.is_encoder_only:
        raise SystemExit(f"{cfg.arch_id} is encoder-only: no decode serving")
    if args.smoke:
        cfg = smoke_config(cfg)
        mesh = debug_mesh()
    else:
        mesh = make_production_mesh(multi_pod=(args.mesh == "multi"))
    ep = max(1, min(cfg.n_experts, mesh.shape["data"])) if cfg.n_experts else 1
    lm = LM(cfg, ep_size=ep)
    params = lm.init(jax.random.PRNGKey(args.seed))

    g = _rng(args.seed, 0)
    prompts = g.integers(0, cfg.vocab_size, (args.batch, args.prompt_len), dtype=np.int32)
    batch = {"tokens": jnp.asarray(prompts)}

    sh = make_shardings(lm, mesh, kind="decode", batch_shardable=False)
    serve_step = jax.jit(make_serve_step(lm, sh), donate_argnums=(1,))
    prefill = jax.jit(lambda p, b: lm.prefill(p, b, max_len=args.prompt_len + args.gen + 8))

    t0 = time.time()
    logits, cache = prefill(params, batch)
    logits = jnp.where(jnp.arange(logits.shape[-1]) < cfg.vocab_size, logits, -jnp.inf)
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    jax.block_until_ready(tok)
    t_prefill = time.time() - t0

    out_tokens = [np.asarray(tok)]
    t1 = time.time()
    for _ in range(args.gen - 1):
        tok, cache = serve_step(params, cache, tok)
        out_tokens.append(np.asarray(tok))
    jax.block_until_ready(tok)
    t_decode = time.time() - t1

    gen = np.stack(out_tokens, axis=1)
    assert gen.shape == (args.batch, args.gen)
    assert (gen >= 0).all() and (gen < cfg.vocab_size).all()
    per_tok = t_decode / max(1, args.gen - 1)
    print(f"arch={cfg.arch_id} batch={args.batch} prefill({args.prompt_len} tok)={t_prefill*1e3:.1f}ms "
          f"decode={per_tok*1e3:.2f} ms/step throughput={args.batch/per_tok:.1f} tok/s")
    print("sample:", gen[0, :16].tolist())


if __name__ == "__main__":
    main()
