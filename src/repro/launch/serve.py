"""Batched serving driver: prefill a prompt batch, decode with greedy
sampling, report per-token latency/throughput.

The request batch is spliced across ``--partitions`` virtual partitions by
an online ``repro.runtime.executor.NestedPartitionExecutor`` instead of the
old ad-hoc static split: a calibration pass times each partition's phases
into a ``CalibrationReport`` (prefill as the boundary phase — per-request
setup cost — and decode as the interior phase), the executor re-solves the
row split from that report (``plan_from_report``, paper section 5.6 run
online), and the serving pass uses the calibrated counts.  With
``--partitions 1`` (default) the flow is the classic single-batch path, but
still driven through the executor's step API.

The greedy decode loop itself is fused by default (``--fused-decode``): the
whole generation is one ``lax.scan``-compiled, cache-donating device
program — 1 host dispatch per sub-batch instead of one per token — the
serving-side twin of the blocked engine's ``FusedStepPipeline``.
``--no-fused-decode`` restores the per-token Python loop.

  PYTHONPATH=src python -m repro.launch.serve --arch mixtral-8x22b --smoke \
      --batch 4 --prompt-len 64 --gen 32 --partitions 2
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.shapes import smoke_config
from repro.data.pipeline import _rng
from repro.launch.mesh import debug_mesh, make_production_mesh
from repro.models.zoo import LM, get_config
from repro.parallel.steps import make_serve_step, make_shardings
from repro.runtime import CalibrationReport, NestedPartitionExecutor


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--partitions", type=int, default=1,
                    help="virtual partitions the request batch is spliced over")
    ap.add_argument("--calib-gen", type=int, default=4,
                    help="decode steps per partition in the calibration pass")
    ap.add_argument("--fused-decode", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="scan-compile the greedy decode loop into one "
                         "donated dispatch per sub-batch (default on)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=None,
                    help="write the generated (batch, gen) token matrix as "
                         ".npy — lets the determinism tests diff two runs "
                         "(and fused vs unfused decode) bitwise")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if cfg.is_encoder_only:
        raise SystemExit(f"{cfg.arch_id} is encoder-only: no decode serving")
    if args.smoke:
        cfg = smoke_config(cfg)
        mesh = debug_mesh()
    else:
        mesh = make_production_mesh(multi_pod=(args.mesh == "multi"))
    ep = max(1, min(cfg.n_experts, mesh.shape["data"])) if cfg.n_experts else 1
    lm = LM(cfg, ep_size=ep)
    params = lm.init(jax.random.PRNGKey(args.seed))

    g = _rng(args.seed, 0)
    prompts = g.integers(0, cfg.vocab_size, (args.batch, args.prompt_len), dtype=np.int32)

    sh = make_shardings(lm, mesh, kind="decode", batch_shardable=False)
    raw_step = make_serve_step(lm, sh)
    serve_step = jax.jit(raw_step, donate_argnums=(1,))
    prefill = jax.jit(lambda p, b: lm.prefill(p, b, max_len=args.prompt_len + args.gen + 8))

    from functools import partial

    @partial(jax.jit, static_argnums=(2,), donate_argnums=(1,))
    def decode_scan(p, carry, n):
        """n greedy decode steps as ONE program: lax.scan over tokens with
        the (cache, tok) carry donated.  The final cache is returned (even
        though serving discards it) so every donated leaf aliases an output
        — otherwise jax warns 'donated buffers were not usable' per run."""

        def body(carry, _):
            cache, tok = carry
            tok, cache = raw_step(p, cache, tok)
            return (cache, tok), tok

        (cache, tok), toks = jax.lax.scan(body, carry, None, length=n)
        return toks, tok, cache

    def decode_rows(rows: np.ndarray, n_gen: int):
        """Prefill + greedy-decode a sub-batch; returns
        (gen, prefill_seconds, decode_seconds)."""
        t0 = time.time()
        logits, cache = prefill(params, {"tokens": jnp.asarray(rows)})
        logits = jnp.where(jnp.arange(logits.shape[-1]) < cfg.vocab_size, logits, -jnp.inf)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        jax.block_until_ready(tok)
        t_prefill = time.time() - t0
        out = [np.asarray(tok)]
        t1 = time.time()
        if args.fused_decode and n_gen > 1:
            toks, tok, _ = decode_scan(params, (cache, tok), n_gen - 1)
            jax.block_until_ready(toks)
            out.extend(np.asarray(toks))  # (n_gen-1, B) rows
        else:
            for _ in range(n_gen - 1):
                tok, cache = serve_step(params, cache, tok)
                out.append(np.asarray(tok))
            jax.block_until_ready(tok)
        return np.stack(out, axis=1), t_prefill, time.time() - t1

    P = max(1, min(args.partitions, args.batch))
    executor = NestedPartitionExecutor(args.batch, P, bucket=1, smoothing=1.0)

    warmed = set()

    def warm(offsets, n_gen=3):
        """Compile every sub-batch shape before it is timed.  Unfused: 3
        steps cover prefill plus both decode cache layouts (the donated
        cache changes layout after the first serve_step call).  Fused: the
        scan length is part of the compiled program, so warm with the real
        generation length — this executes one throwaway full generation per
        distinct shape (AOT ``lower().compile()`` would avoid the execution
        but does not populate jit's dispatch cache), the standard
        warmup-for-steady-state tradeoff; the timed pass stays compile-free."""
        n = n_gen if args.fused_decode else 3
        for p in range(P):
            rows = prompts[offsets[p]:offsets[p + 1]]
            if len(rows) and (len(rows), n) not in warmed:
                decode_rows(rows, n)
                warmed.add((len(rows), n))

    if P > 1:
        # calibration pass: time each partition's phases on the current
        # (equal) split — prefill is the boundary phase (per-request setup),
        # decode the interior phase — then re-solve the row counts from the
        # phase-resolved report
        t_prefill = np.zeros(P)
        t_decode = np.zeros(P)
        offs = executor.offsets
        warm(offs, max(2, args.calib_gen))
        for p in range(P):
            rows = prompts[offs[p]:offs[p + 1]]
            if len(rows) == 0:
                continue
            _, tp, td = decode_rows(rows, max(2, args.calib_gen))
            t_prefill[p], t_decode[p] = tp, td
        report = CalibrationReport(boundary_s=t_prefill, interior_s=t_decode,
                                   transfer_s=np.zeros(P))
        executor.observe(report.step_s)
        executor.plan_from_report(report)
        print("calibration report:")
        print(report.summary())
        print(f"calibrated split: counts={executor.counts.tolist()} "
              f"(round {executor.round}, predicted makespan "
              f"{executor.predicted_makespan() * 1e3:.1f}ms)")

    # serving pass on the (re)calibrated splice; contiguous splice keeps the
    # original row order under concatenation.  Warm unconditionally (P=1
    # included) so the timed pass never measures prefill/scan compilation.
    warm(executor.offsets, args.gen)
    parts, per_part = [], []
    t_prefill_all, t_decode_all = 0.0, 0.0
    offs = executor.offsets
    for p in range(P):
        rows = prompts[offs[p]:offs[p + 1]]
        if len(rows) == 0:
            continue
        gen_p, tp, td = decode_rows(rows, args.gen)
        parts.append(gen_p)
        per_part.append((p, int(len(rows)), tp + td))
        t_prefill_all += tp
        t_decode_all += td
    gen = np.concatenate(parts, axis=0)

    assert gen.shape == (args.batch, args.gen)
    assert (gen >= 0).all() and (gen < cfg.vocab_size).all()
    per_tok = t_decode_all / max(1, args.gen - 1)
    disp = 1 if args.fused_decode else args.gen - 1
    print(f"arch={cfg.arch_id} batch={args.batch} partitions={P} "
          f"prefill({args.prompt_len} tok)={t_prefill_all * 1e3:.1f}ms "
          f"decode={per_tok * 1e3:.2f} ms/step throughput={args.batch / per_tok:.1f} tok/s "
          f"decode-dispatches/sub-batch={disp} "
          f"({'fused scan' if args.fused_decode else 'python loop'})")
    for p, n, dt in per_part:
        print(f"  partition {p}: rows={n} wall={dt * 1e3:.1f}ms")
    print("sample:", gen[0, :16].tolist())
    if args.out:
        np.save(args.out, gen)
        print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
