"""Serving CLI — argument parsing over ``repro.runtime.serving``.

Two modes:

* **one-shot** (default): prefill a prompt batch, decode ``--gen`` greedy
  tokens, report per-token latency/throughput.  With ``--partitions P`` the
  batch is spliced across P virtual partitions by an online
  ``NestedPartitionExecutor``: a calibration pass times each partition's
  phases into a ``CalibrationReport`` (prefill = boundary, decode =
  interior), the executor re-solves the row split (``plan_from_report``,
  paper section 5.6 run online), and the serving pass uses the calibrated
  counts.  The decode loop is fused by default (``--fused-decode``): one
  ``lax.scan``-compiled, cache-donating dispatch per sub-batch;
  ``--no-fused-decode`` restores the per-token Python loop.

* **``--serve-loop``**: the continuous-batching request loop
  (``ContinuousBatchingLoop``) over a synthetic Poisson arrival trace —
  admission control and load shedding priced from the calibration report,
  per-request SLO timestamps written as JSON via ``--trace-out``.

  PYTHONPATH=src python -m repro.launch.serve --arch mixtral-8x22b --smoke \
      --batch 4 --prompt-len 64 --gen 32 --partitions 2
  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-7b --smoke \
      --serve-loop --requests 12 --load 1.0 --trace-out trace.json
"""

from __future__ import annotations

import argparse
import json

import numpy as np

from repro.data.pipeline import _rng
from repro.runtime.serving import (
    SLO,
    ContinuousBatchingLoop,
    ServeKernels,
    build_lm,
    calibrate_split,
    decode_batch,
    poisson_trace,
    warm_batch,
)


def run_oneshot(args) -> None:
    cfg, lm, params, mesh = build_lm(
        args.arch, smoke=args.smoke, mesh=args.mesh, seed=args.seed
    )
    g = _rng(args.seed, 0)
    prompts = g.integers(0, cfg.vocab_size, (args.batch, args.prompt_len), dtype=np.int32)
    kernels = ServeKernels(lm, mesh, max_len=args.prompt_len + args.gen + 8)

    P = max(1, min(args.partitions, args.batch))
    if P > 1:
        executor, report = calibrate_split(
            kernels, params, prompts, P,
            calib_gen=args.calib_gen, fused=args.fused_decode,
        )
        print("calibration report:")
        print(report.summary())
        print(f"calibrated split: counts={executor.counts.tolist()} "
              f"(round {executor.round}, predicted makespan "
              f"{executor.predicted_makespan() * 1e3:.1f}ms)")
    else:
        from repro.runtime.executor import NestedPartitionExecutor

        executor = NestedPartitionExecutor(args.batch, P, bucket=1, smoothing=1.0)

    # serving pass on the (re)calibrated splice; contiguous splice keeps the
    # original row order under concatenation.  Warm unconditionally (P=1
    # included) so the timed pass never measures prefill/scan compilation.
    offs = executor.offsets
    for p in range(P):
        warm_batch(kernels, params, prompts[offs[p]:offs[p + 1]], args.gen,
                   fused=args.fused_decode)
    parts, per_part = [], []
    t_prefill_all, t_decode_all = 0.0, 0.0
    for p in range(P):
        rows = prompts[offs[p]:offs[p + 1]]
        if len(rows) == 0:
            continue
        gen_p, tp, td = decode_batch(kernels, params, rows, args.gen,
                                     fused=args.fused_decode)
        parts.append(gen_p)
        per_part.append((p, int(len(rows)), tp + td))
        t_prefill_all += tp
        t_decode_all += td
    gen = np.concatenate(parts, axis=0)

    assert gen.shape == (args.batch, args.gen)
    assert (gen >= 0).all() and (gen < cfg.vocab_size).all()
    per_tok = t_decode_all / max(1, args.gen - 1)
    disp = 1 if args.fused_decode else args.gen - 1
    print(f"arch={cfg.arch_id} batch={args.batch} partitions={P} "
          f"prefill({args.prompt_len} tok)={t_prefill_all * 1e3:.1f}ms "
          f"decode={per_tok * 1e3:.2f} ms/step throughput={args.batch / per_tok:.1f} tok/s "
          f"decode-dispatches/sub-batch={disp} "
          f"({'fused scan' if args.fused_decode else 'python loop'})")
    for p, n, dt in per_part:
        print(f"  partition {p}: rows={n} wall={dt * 1e3:.1f}ms")
    print("sample:", gen[0, :16].tolist())
    if args.out:
        np.save(args.out, gen)
        print(f"wrote {args.out}")


def run_loop(args) -> None:
    cfg, lm, params, mesh = build_lm(
        args.arch, smoke=args.smoke, mesh=args.mesh, seed=args.seed
    )
    kernels = ServeKernels(lm, mesh, max_len=args.prompt_len + args.max_new)
    slo = None
    if args.slo_ttft is not None or args.slo_tok is not None:
        slo = SLO(ttft_s=args.slo_ttft or 1.0, tok_s=args.slo_tok or 0.05)
    rounds = None
    if args.rounds:
        # heterogeneous decode workers = simulated cluster node groups: the
        # loop re-aggregates each chunk's token shards across them through
        # the multi-round plan (one fused dispatch per worker per chunk)
        from repro.runtime.cluster import NodeProfile
        from repro.runtime.rounds import workers_from_profiles

        speeds = [float(s) for s in args.round_speeds.split(",") if s]
        rounds = workers_from_profiles(
            [NodeProfile(name=f"node{i}", speed=s) for i, s in enumerate(speeds)]
        )
    loop = ContinuousBatchingLoop(
        kernels, params,
        capacity=args.capacity, chunk=args.chunk,
        partitions=args.partitions, bucket=args.bucket,
        calib_gen=args.calib_gen, slo=slo, clock=args.clock,
        rounds=rounds, rounds_shrink=args.round_shrink,
    )
    if loop.rounds_plan is not None:
        print("round plan (pool rows):")
        print(loop.rounds_plan.summary())
    # the trace rate is expressed against the calibrated service rate, so
    # calibrate first (on a seed trace's prompts), then price the arrivals
    seed_trace = poisson_trace(
        max(args.capacity, 1), 1.0, prompt_len=args.prompt_len,
        vocab=cfg.vocab_size, max_new=args.max_new, seed=args.seed,
    )
    loop._ensure_calibrated(seed_trace)
    rate = args.rate if args.rate > 0 else args.load * loop.service_rate_rps(args.max_new)
    trace = poisson_trace(
        args.requests, rate, prompt_len=args.prompt_len,
        vocab=cfg.vocab_size, max_new=args.max_new, seed=args.seed,
    )
    summary = loop.run(trace)
    print(f"arch={cfg.arch_id} capacity={args.capacity} chunk={args.chunk} "
          f"clock={args.clock} offered={rate:.2f} req/s")
    for k, v in summary.to_dict().items():
        print(f"  {k}={v}")
    # one fused dispatch per decode chunk — per WORKER shard in rounds mode
    if summary.dispatches_per_chunk != float(loop.n_round_workers):
        raise SystemExit(
            f"decode chunk not fused: {summary.dispatches_per_chunk} "
            f"dispatches/chunk for {loop.n_round_workers} worker(s)"
        )
    if args.trace_out:
        loop.write_trace(args.trace_out)
        print(f"wrote {args.trace_out}")
    if args.bench_out:
        with open(args.bench_out, "w") as f:
            json.dump({"offered_rps": rate, **summary.to_dict()}, f, indent=2,
                      allow_nan=False)
        print(f"wrote {args.bench_out}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", help="model arch id (see --list-scenarios)")
    ap.add_argument("--list-scenarios", action="store_true",
                    help="print every registered arch/scenario and exit")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--partitions", type=int, default=1,
                    help="virtual partitions the request batch is spliced over")
    ap.add_argument("--calib-gen", type=int, default=4,
                    help="decode steps per partition in the calibration pass")
    ap.add_argument("--fused-decode", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="scan-compile the greedy decode loop into one "
                         "donated dispatch per sub-batch (default on)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=None,
                    help="write the generated (batch, gen) token matrix as "
                         ".npy — lets the determinism tests diff two runs "
                         "(and fused vs unfused decode) bitwise")
    # -- continuous-batching loop mode --------------------------------------
    ap.add_argument("--serve-loop", action="store_true",
                    help="run the continuous-batching request loop over a "
                         "synthetic Poisson arrival trace")
    ap.add_argument("--capacity", type=int, default=4,
                    help="serve-loop row pool size (max concurrent requests)")
    ap.add_argument("--chunk", type=int, default=4,
                    help="decode steps per fused dispatch (splice granularity)")
    ap.add_argument("--bucket", type=int, default=1,
                    help="admission groups padded to this multiple")
    ap.add_argument("--requests", type=int, default=12,
                    help="number of requests in the synthetic trace")
    ap.add_argument("--max-new", type=int, default=8,
                    help="tokens generated per request in the loop")
    ap.add_argument("--rate", type=float, default=0.0,
                    help="offered load, requests/s (0 = --load x the "
                         "calibrated service rate)")
    ap.add_argument("--load", type=float, default=1.0,
                    help="offered load as a fraction of the calibrated "
                         "service rate (used when --rate is 0)")
    ap.add_argument("--clock", default="virtual", choices=["virtual", "wall"],
                    help="virtual = deterministic report-priced clock")
    ap.add_argument("--rounds", action="store_true",
                    help="multi-round re-aggregation: shard the row pool "
                         "across heterogeneous simulated node groups "
                         "(--round-speeds), one fused decode dispatch per "
                         "worker per chunk, token shards merged through the "
                         "shrinking round tree (bitwise the single-"
                         "aggregator rows)")
    ap.add_argument("--round-speeds", default="2,1",
                    help="comma-separated relative node speeds for --rounds")
    ap.add_argument("--round-shrink", type=float, default=1.6,
                    help="per-round worker-count divisor (default 1.6, "
                         "the paper's K_MIC/K_CPU echo)")
    ap.add_argument("--slo-ttft", type=float, default=None,
                    help="time-to-first-token budget, seconds")
    ap.add_argument("--slo-tok", type=float, default=None,
                    help="per-decode-step budget, seconds")
    ap.add_argument("--trace-out", default=None,
                    help="write the per-request SLO trace as JSON")
    ap.add_argument("--bench-out", default=None,
                    help="write the run summary as JSON")
    args = ap.parse_args()

    if args.list_scenarios:
        from repro.configs.registry import format_listing

        print(format_listing())
        return
    if not args.arch:
        ap.error("--arch is required (or --list-scenarios to enumerate)")
    if args.serve_loop:
        run_loop(args)
    else:
        run_oneshot(args)


if __name__ == "__main__":
    main()
