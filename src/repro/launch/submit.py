"""Batch-system front-end: fan a ``RoundPlan`` out to slurm or sge.

partiscontainer's launcher shape (SNIPPETS §1): the cluster plan becomes one
job script per (round, worker slot), submitted with cross-round dependencies
so each re-aggregation round starts only when the previous-round outputs it
merges exist.  Conventions follow the snippet:

* ``--batch-system {slurm,sge}`` picks the dialect (``sbatch`` +
  ``#SBATCH`` headers + ``--dependency=afterok``, or ``qsub`` + ``#$``
  headers + named ``-hold_jid`` holds);
* per-job stdout/stderr paths are **auto-assigned** under
  ``<workdir>/logs/`` so round outputs can be located and parsed — do NOT
  pass ``-e``/``-o`` (or ``--output``/``--error``) through
  ``--batch-options``, the front-end rejects them;
* ``--batch-options "..."`` appends extra scheduler directives verbatim
  (queues, accounts, memory);
* ``--workdir`` should be on a filesystem every node mounts (NFS) — the
  plan JSON, scripts, and logs all live under it;
* ``--dry-run`` prints every script and the submission commands without
  invoking the batch system (what the CI golden check runs).

Each script's payload is ``python -m repro.runtime.rounds --plan
<workdir>/plan.json --worker-step R:J`` — the job re-reads the shared plan
and prints its own assignment, so generated scripts run anywhere the repo
is importable.

  PYTHONPATH=src python -m repro.launch.submit --batch-system slurm \
      --workdir /nfs/scratch/rounds --items 4096 --speeds 4,2,1,1 --dry-run
  PYTHONPATH=src python -m repro.launch.submit --batch-system sge \
      --workdir /nfs/scratch/rounds --plan-json plan.json \
      --batch-options "-q long.q -l mem=4G"
"""

from __future__ import annotations

import argparse
import json
import os
import shlex
import shutil
import subprocess
import sys
from typing import Any, Dict, List, Optional, Sequence, Tuple

from repro.runtime.rounds import RoundPlan, RoundWorker, plan_rounds

__all__ = [
    "render_script",
    "submit_command",
    "materialize",
    "main",
]

BATCH_SYSTEMS = ("slurm", "sge")

# stdout/stderr are OURS to assign (the snippet's rule — sge output paths
# must be predictable for the merge rounds to find); reject user overrides
_RESERVED = {
    "slurm": ("-o", "--output", "-e", "--error"),
    "sge": ("-o", "-e"),
}


def _check_batch_options(system: str, options: Sequence[str]) -> None:
    reserved = _RESERVED[system]
    for opt in options:
        bare = opt.split("=", 1)[0]
        if bare in reserved:
            raise ValueError(
                f"do not set stdout/stderr locations ({bare}) in "
                f"--batch-options for {system}: per-job paths are "
                "auto-assigned under <workdir>/logs/"
            )


def _option_lines(options: Sequence[str]) -> List[str]:
    """Regroup shlex-split extras into one header line per flag
    (``["-q", "long.q", "-l", "mem=4G"]`` -> ``["-q long.q", "-l mem=4G"]``)."""
    lines: List[str] = []
    for opt in options:
        if opt.startswith("-") or not lines:
            lines.append(opt)
        else:
            lines[-1] += f" {opt}"
    return lines


def _payload(job: Dict[str, Any], workdir: str) -> str:
    plan_json = os.path.join(workdir, "plan.json")
    return (
        f"{shlex.quote(sys.executable)} -m repro.runtime.rounds "
        f"--plan {shlex.quote(plan_json)} "
        f"--worker-step {job['round']}:{job['slot']}"
    )


def render_script(
    system: str,
    job: Dict[str, Any],
    workdir: str,
    batch_options: Sequence[str] = (),
) -> str:
    """One job's script: dialect headers (name, auto stdout/stderr, chdir,
    extras, sge name-holds) + the worker-step payload."""
    name = job["name"]
    out = os.path.join(workdir, "logs", f"{name}.out")
    err = os.path.join(workdir, "logs", f"{name}.err")
    lines = ["#!/bin/bash"]
    if system == "slurm":
        lines += [
            f"#SBATCH --job-name={name}",
            f"#SBATCH --output={out}",
            f"#SBATCH --error={err}",
            f"#SBATCH --chdir={workdir}",
        ]
        lines += [f"#SBATCH {opt}" for opt in _option_lines(batch_options)]
    elif system == "sge":
        lines += [
            f"#$ -N {name}",
            f"#$ -o {out}",
            f"#$ -e {err}",
            f"#$ -wd {workdir}",
            "#$ -S /bin/bash",
        ]
        if job["depends"]:
            # sge holds by job NAME: names are unique per plan, and the
            # submit order (round-major) guarantees they exist when queued
            lines.append(f"#$ -hold_jid {','.join(job['depends'])}")
        lines += [f"#$ {opt}" for opt in _option_lines(batch_options)]
    else:
        raise ValueError(f"unknown batch system {system!r} (choose from {BATCH_SYSTEMS})")
    lines += [
        "",
        f"# round {job['round']} slot {job['slot']}: worker {job['worker']} "
        f"(rate {job['rate']:g}/s), {job['count']} items, "
        f"modeled {job['modeled_s']:.4g}s",
        _payload(job, workdir),
        "",
    ]
    return "\n".join(lines)


def submit_command(
    system: str,
    job: Dict[str, Any],
    script_path: str,
    job_ids: Dict[str, str],
) -> List[str]:
    """The submission argv.  slurm dependencies ride the command line
    (``--dependency=afterok:<ids>``, resolved from previously submitted
    rounds — placeholders ``<jobid:name>`` in a dry run); sge holds are
    baked into the script headers by name."""
    if system == "slurm":
        cmd = ["sbatch"]
        if job["depends"]:
            ids = ":".join(job_ids.get(d, f"<jobid:{d}>") for d in job["depends"])
            cmd.append(f"--dependency=afterok:{ids}")
        return cmd + [script_path]
    return ["qsub", script_path]


def materialize(
    plan: RoundPlan,
    system: str,
    workdir: str,
    *,
    batch_options: Sequence[str] = (),
    dry_run: bool = True,
    runner=None,
) -> List[Tuple[Dict[str, Any], str, List[str]]]:
    """Write ``plan.json`` + every job script under ``workdir`` and submit
    (or, dry run, just print).  Returns ``(job, script_path, submit_argv)``
    per job in submission (round-major) order.

    ``runner`` is the submission hook (default: ``subprocess.run``); it
    must return an object whose ``stdout`` contains the scheduler's
    response — for slurm the new job id is parsed out of it to thread
    ``afterok`` dependencies.
    """
    if system not in BATCH_SYSTEMS:
        raise ValueError(f"unknown batch system {system!r} (choose from {BATCH_SYSTEMS})")
    _check_batch_options(system, batch_options)
    scripts_dir = os.path.join(workdir, "scripts")
    os.makedirs(scripts_dir, exist_ok=True)
    os.makedirs(os.path.join(workdir, "logs"), exist_ok=True)
    with open(os.path.join(workdir, "plan.json"), "w") as f:
        json.dump(plan.to_json(), f, indent=1)

    if not dry_run and runner is None:
        binary = "sbatch" if system == "slurm" else "qsub"
        if shutil.which(binary) is None:
            raise RuntimeError(
                f"{binary} not found on PATH — use --dry-run to inspect the "
                "scripts without a batch system"
            )
        runner = lambda argv: subprocess.run(  # noqa: E731
            argv, check=True, capture_output=True, text=True
        )

    job_ids: Dict[str, str] = {}
    out: List[Tuple[Dict[str, Any], str, List[str]]] = []
    for job in plan.job_specs():
        script = render_script(system, job, workdir, batch_options)
        path = os.path.join(scripts_dir, f"{job['name']}.sh")
        with open(path, "w") as f:
            f.write(script)
        os.chmod(path, 0o755)
        argv = submit_command(system, job, path, job_ids)
        if not dry_run:
            proc = runner(argv)
            if system == "slurm":
                # "Submitted batch job 12345"
                tokens = [t for t in str(proc.stdout).split() if t.isdigit()]
                job_ids[job["name"]] = tokens[-1] if tokens else job["name"]
        out.append((job, path, argv))
    return out


def main(argv: Optional[Sequence[str]] = None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--batch-system", required=True, choices=BATCH_SYSTEMS)
    ap.add_argument("--workdir", required=True,
                    help="plan/scripts/logs root — every node must mount it "
                         "(NFS) so the merge rounds see each other's output")
    ap.add_argument("--batch-options", default="",
                    help="extra scheduler directives, appended verbatim, "
                         'e.g. "--partition=batch --mem=4G" or "-q long.q" '
                         "(do NOT set -o/-e: stdout/stderr paths are "
                         "auto-assigned per job)")
    ap.add_argument("--dry-run", action="store_true",
                    help="print every script + submit command, submit nothing")
    ap.add_argument("--plan-json", default=None,
                    help="a serialized RoundPlan (repro.runtime.rounds "
                         "--plan-out) to materialize")
    ap.add_argument("--items", type=int, default=None,
                    help="solve a fresh plan: work-set size")
    ap.add_argument("--speeds", default=None,
                    help="solve a fresh plan: comma-separated worker rates")
    ap.add_argument("--names", default=None,
                    help="worker names for --speeds (default n0,n1,...)")
    ap.add_argument("--shrink", type=float, default=1.6,
                    help="per-round worker-count divisor (default 1.6)")
    args = ap.parse_args(argv)

    if args.plan_json:
        with open(args.plan_json) as f:
            plan = RoundPlan.from_json(json.load(f))
    elif args.items is not None and args.speeds:
        speeds = [float(s) for s in args.speeds.split(",") if s]
        names = (args.names.split(",") if args.names
                 else [f"n{i}" for i in range(len(speeds))])
        plan = plan_rounds(args.items,
                           [RoundWorker(n, s) for n, s in zip(names, speeds)],
                           shrink=args.shrink)
    else:
        ap.error("need --plan-json, or --items with --speeds")

    try:
        jobs = materialize(
            plan, args.batch_system, args.workdir,
            batch_options=shlex.split(args.batch_options),
            dry_run=args.dry_run,
        )
    except (ValueError, RuntimeError) as e:
        ap.error(str(e))

    print(plan.summary())
    print(f"{len(jobs)} jobs -> {os.path.join(args.workdir, 'scripts')}")
    for job, path, argv_ in jobs:
        print(f"\n# {' '.join(argv_)}")
        if args.dry_run:
            with open(path) as f:
                print(f.read(), end="")
    if args.dry_run:
        print("\n(dry run: nothing submitted)")


if __name__ == "__main__":
    main()
