"""Production meshes.

Defined as FUNCTIONS so importing this module never touches jax device
state.  The dry-run sets XLA_FLAGS=--xla_force_host_platform_device_count=512
before any jax import; smoke tests and benchmarks see the real single CPU
device and use ``debug_mesh``.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def debug_mesh(data: int = 1, model: int = 1, pod: int = 0):
    """Small mesh over however many (possibly fake) local devices exist."""
    if pod:
        return jax.make_mesh((pod, data, model), ("pod", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))
