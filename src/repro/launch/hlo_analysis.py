"""Static analysis of compiled (SPMD-partitioned, scheduled) HLO text.

XLA's ``compiled.cost_analysis()`` counts ``while`` bodies once (verified on
this backend: a 10-iteration scan reports 0.1x the true FLOPs), so the
roofline terms are derived from the HLO text instead.  Every loop in this
framework has a static trip count, and XLA records it on the while op
(``backend_config={"known_trip_count":{"n":...}}``), which makes exact
accounting possible:

  1. split the module into computations; build a per-computation symbol
     table (instruction name -> shape) so operand shapes can be resolved;
  2. build the call graph (while body/condition, fusion ``calls``,
     ``to_apply``, conditional branches), tagging each callee's role;
  3. propagate multiplicities from ENTRY, multiplying while bodies by their
     known_trip_count (fallback: the constant in the condition);
  4. FLOPs: 2 * prod(result dims) * prod(contracting dims) per dot (+conv),
     x multiplicity;
  5. memory bytes: operand+result bytes of HBM-visible ops — i.e. op lines
     in non-fusion-internal computations (fusion internals live in
     registers/VMEM; the fusion op itself is charged);
  6. collective bytes: per-device *operand* bytes of each all-gather /
     all-reduce / reduce-scatter / all-to-all / collective-permute,
     x multiplicity (the assignment's convention).

All numbers are per-device (the partitioned module is one device's
program); roofline terms divide by per-chip peaks directly.
"""

from __future__ import annotations

import dataclasses
import json
import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"^([a-z0-9]+)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(\(?)([a-z0-9]+\[[\d,]*\])?")
_OPNAME_RE = re.compile(r"=\s*(?:\([^=]*?\)|[a-z0-9]+\[[\d,]*\](?:\{[\d,]*\})?)\s+([\w\-]+)\(")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CONST_RE = re.compile(r"constant\((\d+)\)")
_WHILE_ATTR_RE = re.compile(r"condition=%?([\w\.\-]+),\s*body=%?([\w\.\-]+)")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all", "collective-permute")
_FREE_OPS = {
    "get-tuple-element", "bitcast", "tuple", "parameter", "constant",
    "after-all", "partition-id", "replica-id", "iota", "broadcast",
    "reshape", "copy-start", "copy-done",
    # control ops: the carried tuple is not HBM traffic; their bodies are
    # charged via the call graph
    "while", "conditional", "call", "optimization-barrier",
}

# Ops a TPU compiler fuses into neighbouring producers/consumers.  The CPU
# backend leaves many of these at top level, so charging them all gives an
# UPPER bound on HBM traffic; excluding them approximates a well-fused TPU
# schedule (LOWER bound).  Both are reported; the roofline memory term uses
# the fused estimate (the deployment target's behaviour).
_ELEMENTWISE_OPS = {
    "add", "subtract", "multiply", "divide", "exponential", "tanh",
    "maximum", "minimum", "select", "compare", "convert", "negate", "abs",
    "log", "power", "rsqrt", "sqrt", "and", "or", "not", "xor", "clamp",
    "floor", "ceil", "sign", "cosine", "sine", "logistic", "atan2",
    "exponential-minus-one", "log-plus-one", "round-nearest-afz",
    "round-nearest-even", "shift-left", "shift-right-logical",
    "shift-right-arithmetic", "reduce-precision", "bitcast-convert",
    "is-finite", "remainder", "copy", "transpose", "rev", "map",
}


def _parse_shapes(type_str: str) -> List[Tuple[str, List[int]]]:
    """All dtype[dims] shapes inside a (possibly tuple) type string."""
    out = []
    for m in re.finditer(r"([a-z0-9]+)\[([\d,]*)\]", type_str):
        dims = [int(d) for d in m.group(2).split(",") if d.strip()]
        out.append((m.group(1), dims))
    return out


def _shape_bytes(dtype: str, dims: List[int]) -> int:
    b = _DTYPE_BYTES.get(dtype)
    if b is None:
        return 0
    n = 1
    for d in dims:
        n *= d
    return n * b


@dataclasses.dataclass
class Instr:
    name: str
    op: str
    result_types: List[Tuple[str, List[int]]]
    operands: List[str]
    line: str


@dataclasses.dataclass
class Computation:
    name: str
    is_entry: bool
    instrs: List[Instr]
    symbols: Dict[str, List[Tuple[str, List[int]]]]


_COMP_HDR = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\(")


def _operand_names(line: str) -> List[str]:
    """Names inside the op's argument parens."""
    m = re.search(r"\w\(([^)]*(?:\([^)]*\)[^)]*)*)\)", line)
    if not m:
        return []
    return re.findall(r"%([\w\.\-]+)", m.group(1))


_COMMENT_RE = re.compile(r"/\*.*?\*/")


def split_computations(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for raw in text.splitlines():
        # strip /*index=N*/ comments — they contain '=' and break parsing
        line = _COMMENT_RE.sub("", raw).rstrip()
        stripped = line.strip()
        if cur is None:
            if stripped.endswith("{") and "->" in stripped:
                m = _COMP_HDR.match(stripped)
                if m:
                    cur = Computation(m.group(2), bool(m.group(1)), [], {})
            continue
        if stripped == "}":
            comps[cur.name] = cur
            cur = None
            continue
        dm = _DEF_RE.match(line)
        if not dm:
            continue
        name = dm.group(1)
        # result type: everything between '=' and the op name
        after_eq = line.split("=", 1)[1].strip()
        om = re.match(r"((?:\([^=]*?\)|[a-z0-9]+\[[\d,]*\](?:\{[\d,:T()]*\})?))\s+([\w\-]+)", after_eq)
        if om:
            rtypes = _parse_shapes(om.group(1))
            op = om.group(2)
        else:
            rtypes, op = [], "unknown"
        # operand names: inside the eventual parens after op
        paren = after_eq.find("(")
        ops_names: List[str] = []
        if paren >= 0:
            depth = 0
            j = paren
            for j in range(paren, len(after_eq)):
                if after_eq[j] == "(":
                    depth += 1
                elif after_eq[j] == ")":
                    depth -= 1
                    if depth == 0:
                        break
            args = after_eq[paren + 1 : j]
            ops_names = re.findall(r"%([\w\.\-]+)", args)
        inst = Instr(name, op, rtypes, ops_names, line)
        cur.instrs.append(inst)
        cur.symbols[name] = rtypes
    return comps


def _resolve(comps: Dict[str, Computation], comp: Computation, name: str):
    if name in comp.symbols:
        return comp.symbols[name]
    for c in comps.values():
        if name in c.symbols:
            return c.symbols[name]
    return []


def computation_multiplicities(comps: Dict[str, Computation], dynamic_trips: Optional[float] = None) -> Dict[str, float]:
    mult: Dict[str, float] = defaultdict(float)
    entries = [c for c in comps.values() if c.is_entry]
    if not entries:
        return {k: 1.0 for k in comps}
    roles: Dict[str, str] = {}
    edges: Dict[str, List[Tuple[str, float, str]]] = defaultdict(list)
    for comp in comps.values():
        for inst in comp.instrs:
            if inst.op == "while":
                wm = _WHILE_ATTR_RE.search(inst.line)
                if not wm:
                    continue
                cond, body = wm.group(1), wm.group(2)
                tm = _TRIP_RE.search(inst.line)
                if tm:
                    trips = float(tm.group(1))
                else:
                    consts = [int(c) for c in _CONST_RE.findall(" ".join(i.line for i in comps[cond].instrs))] if cond in comps else []
                    if consts:
                        trips = float(max(consts))
                    elif dynamic_trips is not None:
                        # data-dependent bound (e.g. causal-skip fori): use
                        # the caller-provided expected trip count
                        trips = float(dynamic_trips)
                    else:
                        trips = 1.0
                edges[comp.name].append((body, trips, "while-body"))
                edges[comp.name].append((cond, trips + 1, "while-cond"))
            else:
                for attr, role in (("calls", "fusion-internal"), ("to_apply", "applied")):
                    m = re.search(attr + r"=%?([\w\.\-]+)", inst.line)
                    if m:
                        edges[comp.name].append((m.group(1), 1.0, role))
                m = re.search(r"branch_computations=\{([^}]*)\}", inst.line)
                if m:
                    for nm in m.group(1).split(","):
                        edges[comp.name].append((nm.strip().lstrip("%"), 1.0, "branch"))
    # propagate multiplicities in topological order (HLO call graph is a DAG)
    indeg: Dict[str, int] = {name: 0 for name in comps}
    for src, lst in edges.items():
        for callee, _, _ in lst:
            if callee in indeg:
                indeg[callee] += 1
    for e in entries:
        mult[e.name] = 1.0
    queue = [n for n, d in indeg.items() if d == 0]
    topo: List[str] = []
    while queue:
        n = queue.pop()
        topo.append(n)
        for callee, _, _ in edges.get(n, []):
            if callee in indeg:
                indeg[callee] -= 1
                if indeg[callee] == 0:
                    queue.append(callee)
    for name in topo:
        m = mult.get(name, 0.0)
        if m <= 0:
            continue
        for callee, k, _ in edges.get(name, []):
            if callee in comps:
                mult[callee] += m * k
    # roles for memory accounting
    role_map: Dict[str, str] = {}
    for src, lst in edges.items():
        for callee, _, role in lst:
            if role == "fusion-internal" or role_map.get(callee) == "fusion-internal":
                role_map[callee] = "fusion-internal"
            else:
                role_map.setdefault(callee, role)
    mult = dict(mult)
    mult["__roles__"] = role_map  # type: ignore[assignment]
    return mult


def _types_bytes(types: List[Tuple[str, List[int]]]) -> int:
    return sum(_shape_bytes(dt, dims) for dt, dims in types)


def _op_mem_bytes(comps, comp, inst: Instr) -> float:
    """HBM traffic estimate for one op, slice/update-aware.

    dynamic-slice reads only the slice (= result); dynamic-update-slice
    writes only the update region (aliased in place).  Fusions are charged
    at their boundary, with parameters that feed only dynamic-slices inside
    charged at the slice size, and DUS-rooted fusions charged at the update
    size — this is what makes scan xs-slicing, ys-updates, and KV-cache
    writes cost what they actually move.
    """
    if inst.op == "dynamic-slice":
        return 2.0 * _types_bytes(inst.result_types)
    if inst.op == "dynamic-update-slice":
        upd = _resolve(comps, comp, inst.operands[1]) if len(inst.operands) > 1 else []
        return 2.0 * _types_bytes(upd)
    if inst.op == "fusion":
        m = re.search(r"calls=%?([\w\.\-]+)", inst.line)
        callee = comps.get(m.group(1)) if m else None
        if callee is None:
            b = _types_bytes(inst.result_types)
            for on in inst.operands:
                b += _types_bytes(_resolve(comps, comp, on))
            return float(b)
        # writes: root op (DUS root -> update size)
        root = callee.instrs[-1] if callee.instrs else None
        roots = [i for i in callee.instrs if i.line.strip().startswith("ROOT")]
        if roots:
            root = roots[0]
        if root is not None and root.op == "dynamic-update-slice" and len(root.operands) > 1:
            wbytes = 2.0 * _types_bytes(_resolve(comps, callee, root.operands[1]))
        else:
            wbytes = float(_types_bytes(inst.result_types))
        # reads: per fusion parameter.  A param whose value only ever reaches
        # dynamic-slice/gather ops (possibly through bitcast/reshape/copy
        # chains — scan xs slicing compiles to exactly that) is charged at
        # the slice size, not the full (e.g. layer-stacked) array.
        _PASS = {"bitcast", "reshape", "copy", "transpose"}
        users: Dict[str, List[Instr]] = defaultdict(list)
        for i in callee.instrs:
            for on in i.operands:
                users[on].append(i)

        def _sliced_read_bytes(name, depth=0) -> Optional[float]:
            """Bytes read if all terminal uses are slices; None otherwise."""
            if depth > 6:
                return None
            total = 0.0
            us = users.get(name, [])
            if not us:
                return None
            for u in us:
                if u.op in ("dynamic-slice", "gather"):
                    total += _types_bytes(u.result_types)
                elif u.op in _PASS:
                    sub = _sliced_read_bytes(u.name, depth + 1)
                    if sub is None:
                        return None
                    total += sub
                else:
                    return None
            return total

        params = [i for i in callee.instrs if i.op == "parameter"]
        rbytes = 0.0
        for pi in params:
            sliced = _sliced_read_bytes(pi.name)
            rbytes += sliced if sliced is not None else _types_bytes(pi.result_types)
        return wbytes + rbytes
    b = _types_bytes(inst.result_types)
    for on in inst.operands:
        b += _types_bytes(_resolve(comps, comp, on))
    return float(b)


def analyze(text: str, dynamic_trips: Optional[float] = None) -> Dict[str, float]:
    comps = split_computations(text)
    mult = computation_multiplicities(comps, dynamic_trips=dynamic_trips)
    roles: Dict[str, str] = mult.pop("__roles__", {})  # type: ignore[arg-type]

    flops = 0.0
    mem_bytes = 0.0
    mem_bytes_fused = 0.0
    coll = defaultdict(float)
    coll_sites = defaultdict(int)

    for name, comp in comps.items():
        m = mult.get(name, 1.0 if comp.is_entry else 0.0)
        if m <= 0:
            continue
        internal = roles.get(name) == "fusion-internal"
        for inst in comp.instrs:
            # ---- flops
            if inst.op in ("dot", "dot-general"):
                out_elems = 1
                for _, dims in inst.result_types:
                    for d in dims:
                        out_elems *= d
                cm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", inst.line)
                contract = 1
                if cm and inst.operands:
                    lhs = _resolve(comps, comp, inst.operands[0])
                    if lhs:
                        _, ldims = lhs[0]
                        for i in cm.group(1).split(","):
                            if i.strip() and int(i) < len(ldims):
                                contract *= ldims[int(i)]
                flops += m * 2.0 * out_elems * contract
            elif inst.op == "convolution":
                out_elems = 1
                for _, dims in inst.result_types:
                    for d in dims:
                        out_elems *= d
                kern = 1
                if len(inst.operands) > 1:
                    rhs = _resolve(comps, comp, inst.operands[1])
                    if rhs:
                        _, rdims = rhs[0]
                        kern = 1
                        for d in rdims[:-1]:  # all but output-feature dim
                            kern *= d
                gm = re.search(r"feature_group_count=(\d+)", inst.line)
                groups = int(gm.group(1)) if gm else 1
                flops += m * 2.0 * out_elems * kern / max(groups, 1)

            # ---- collective bytes (operand convention)
            base_op = inst.op[:-6] if inst.op.endswith("-start") else inst.op
            if base_op in COLLECTIVES:
                b = 0
                for on in inst.operands:
                    for dt, dims in _resolve(comps, comp, on):
                        b += _shape_bytes(dt, dims)
                coll[base_op] += m * b
                coll_sites[base_op] += 1

            # ---- memory traffic (HBM-visible ops only)
            if not internal and inst.op not in _FREE_OPS and not inst.op.endswith("-done"):
                b = m * _op_mem_bytes(comps, comp, inst)
                mem_bytes += b
                if inst.op not in _ELEMENTWISE_OPS:
                    mem_bytes_fused += b

    out = {
        "flops": flops,
        "mem_bytes": mem_bytes,  # upper bound (unfused CPU schedule)
        "mem_bytes_fused": mem_bytes_fused,  # lower bound (TPU-fused estimate)
        "collective_bytes_total": sum(coll.values()),
    }
    for k in COLLECTIVES:
        out[f"collective_bytes_{k}"] = coll.get(k, 0.0)
        out[f"collective_sites_{k}"] = float(coll_sites.get(k, 0))
    return out


def top_multiplicities(text: str, n: int = 10):
    comps = split_computations(text)
    mult = computation_multiplicities(comps)
    mult.pop("__roles__", None)
    return sorted(mult.items(), key=lambda kv: -kv[1])[:n]
