"""Build EXPERIMENTS.md tables from the dry-run JSON records.

Roofline terms (per assignment; v5e constants):
    compute   = HLO_FLOPs(per-device) / 197e12
    memory    = HLO_bytes(per-device) / 819e9
    collective= collective_bytes(per-device) / 50e9
HLO_FLOPs/HLO_bytes/collective_bytes come from the loop-aware HLO analysis
(launch/hlo_analysis.py) — XLA's cost_analysis() counts while bodies once
(verified; recorded in the table for comparison).

MODEL_FLOPS = 6*N*D (train) / 2*N*D (prefill/decode), N = non-embedding
params (active share for MoE), D = tokens processed per step.  The ratio
MODEL_FLOPS / (HLO_FLOPs * chips) is the useful-compute fraction.
"""

from __future__ import annotations

import glob
import json
import os
from typing import Any, Dict, List, Optional

import numpy as np

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9


def param_counts(arch: str) -> Dict[str, float]:
    """(total, non_embed, active_non_embed) parameter counts."""
    import jax

    from repro.models.zoo import LM, get_config

    cfg = get_config(arch)
    lm = LM(cfg, ep_size=16 if cfg.n_experts else 1)
    sds = jax.eval_shape(lm.init, jax.random.PRNGKey(0))
    total = emb = moe = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(sds)[0]:
        n = int(np.prod(leaf.shape))
        total += n
        keys = "/".join(str(getattr(p, "key", "")) for p in path)
        if "embed" in keys or "lm_head" in keys:
            emb += n
        if "/moe/" in "/" + keys + "/" and "router" not in keys:
            moe += n
    non_embed = total - emb
    if cfg.n_experts:
        active = non_embed - moe + moe * cfg.experts_per_token / cfg.n_experts
    else:
        active = non_embed
    return {"total": total, "non_embed": non_embed, "active": active}


def model_flops(arch: str, shape_name: str) -> float:
    from repro.configs.shapes import SHAPES

    s = SHAPES[shape_name]
    pc = param_counts(arch)
    if s.kind == "train":
        return 6.0 * pc["active"] * s.global_batch * s.seq_len
    if s.kind == "prefill":
        return 2.0 * pc["active"] * s.global_batch * s.seq_len
    return 2.0 * pc["active"] * s.global_batch  # decode: one token per seq


def load_records(dirpath: str) -> List[Dict[str, Any]]:
    recs = []
    for fn in sorted(glob.glob(os.path.join(dirpath, "*.json"))):
        with open(fn) as f:
            recs.append(json.load(f))
    return recs


def fmt_bytes(b: Optional[float]) -> str:
    if b is None:
        return "-"
    return f"{b/2**30:.2f}"


def dryrun_table(recs: List[Dict[str, Any]]) -> str:
    lines = [
        "| arch | shape | mesh | status | lower+compile (s) | args GiB/dev | temp GiB/dev | HLO coll. bytes/dev | coll. ops |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        name = f"| {r['arch']} | {r['shape']} | {r['mesh']} "
        if r.get("skipped"):
            lines.append(name + f"| SKIP: {r['skipped']} | | | | | |")
            continue
        if r.get("error"):
            lines.append(name + f"| FAIL: {r['error'][:60]} | | | | | |")
            continue
        coll = r.get("collective_bytes_total", 0)
        ops = []
        for k in ("all-gather", "all-reduce", "reduce-scatter", "all-to-all", "collective-permute"):
            n = r.get(f"collective_sites_{k}", 0)
            if n:
                ops.append(f"{k.replace('collective-','c')}:{int(n)}")
        lines.append(
            name
            + f"| ok | {r['lower_s']}+{r['compile_s']} | {fmt_bytes(r.get('argument_size_in_bytes'))} "
            f"| {fmt_bytes(r.get('temp_size_in_bytes'))} | {coll/2**20:.1f} MiB | {' '.join(ops)} |"
        )
    return "\n".join(lines)


def roofline_table(recs: List[Dict[str, Any]], mesh: str = "16x16") -> str:
    lines = [
        "| arch | shape | t_compute (ms) | t_memory (ms) | t_coll (ms) | dominant | MODEL_FLOPS/HLO | note |",
        "|---|---|---|---|---|---|---|---|",
    ]
    notes = {
        "compute": "raise MXU occupancy: larger microbatch / fused kernels / causal-skipping attention",
        "memory": "cut HBM traffic: fuse elementwise chains, wider remat policy, bf16 accumulators",
        "collective": "cut wire bytes: overlap ring collectives with interior compute; compress the slow hop",
    }
    for r in recs:
        if r.get("mesh") != mesh or r.get("skipped") or r.get("error"):
            continue
        if "t_compute_s" not in r:
            continue
        chips = r.get("chips", 256)
        mf = model_flops(r["arch"], r["shape"])
        ratio = mf / (r["flops"] * chips) if r.get("flops") else float("nan")
        dom = r["dominant"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_s']*1e3:.2f} | {r['t_memory_s']*1e3:.2f} "
            f"| {r['t_collective_s']*1e3:.2f} | **{dom}** | {ratio:.2f} | {notes[dom]} |"
        )
    return "\n".join(lines)


def main():
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--which", default="both", choices=["dryrun", "roofline", "both"])
    args = ap.parse_args()
    recs = load_records(args.dir)
    if args.which in ("dryrun", "both"):
        print("## Dry-run\n")
        print(dryrun_table(recs))
    if args.which in ("roofline", "both"):
        print("\n## Roofline (single-pod 16x16)\n")
        print(roofline_table(recs))


if __name__ == "__main__":
    main()
