"""ShapeDtypeStruct input stand-ins for every (arch x shape) cell.

Pattern: weak-type-correct, shardable, zero allocation — everything the
dry-run lowers against is an ``eval_shape`` artifact.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.shapes import ShapeSpec
from repro.models.common import ModelConfig
from repro.models.zoo import LM, VIS_EMBED_DIM

SDS = jax.ShapeDtypeStruct


def train_input_specs(cfg: ModelConfig, shape: ShapeSpec, accum: int, micro: int) -> Dict[str, Any]:
    S, adt = shape.seq_len, jnp.dtype(cfg.dtype)
    assert accum * micro == shape.global_batch
    lead = (accum, micro)
    if cfg.family == "audio":
        return {
            "features": SDS(lead + (S, cfg.d_model), adt),
            "labels": SDS(lead + (S,), jnp.int32),
        }
    if cfg.family == "vlm":
        ni = cfg.frontend_tokens
        return {
            "tokens": SDS(lead + (S - ni,), jnp.int32),
            "patches": SDS(lead + (ni, VIS_EMBED_DIM), adt),
            "labels": SDS(lead + (S - ni,), jnp.int32),
        }
    return {
        "tokens": SDS(lead + (S,), jnp.int32),
        "labels": SDS(lead + (S,), jnp.int32),
    }


def prefill_input_specs(cfg: ModelConfig, shape: ShapeSpec) -> Dict[str, Any]:
    B, S, adt = shape.global_batch, shape.seq_len, jnp.dtype(cfg.dtype)
    if cfg.family == "audio":
        return {"features": SDS((B, S, cfg.d_model), adt)}
    if cfg.family == "vlm":
        ni = cfg.frontend_tokens
        return {
            "tokens": SDS((B, S - ni), jnp.int32),
            "patches": SDS((B, ni, VIS_EMBED_DIM), adt),
        }
    return {"tokens": SDS((B, S), jnp.int32)}


def decode_input_specs(lm: LM, shape: ShapeSpec) -> Tuple[Dict[str, Any], Any]:
    """(token specs, cache specs): 'one new token with a KV cache of
    seq_len' — capacity seq_len, len = seq_len - 1, so the written slot is
    in bounds and attention spans the full context."""
    B, S = shape.global_batch, shape.seq_len
    cache = jax.eval_shape(lambda: lm.init_cache(B, S))
    tokens = SDS((B,), jnp.int32)
    return {"tokens": tokens}, cache
