"""End-to-end training driver.

Runs any zoo architecture (full or smoke-reduced config) with the real
substrate: sharded jit step (grad accumulation + AdamW), deterministic data
pipeline, async sharded checkpoints, and the fault-tolerant supervisor
(retry / restore / straggler EWMA).  On this CPU container use ``--smoke``
(reduced config, 1 device); on a pod the same file drives the production
mesh.

An online ``repro.runtime.executor.NestedPartitionExecutor`` rides along
through the supervisor (the paper's section-5.6 equalizer run at runtime):
wall times feed it each step and the re-solved data-parallel row counts are
reported at the end (``--rebalance-every`` cadence, ``--plan-cache``
persistence).  On this synchronous single-process path the attribution is
uniform, so the split is advisory until per-device step times exist; the
asymmetric execution lives in ``BlockedDGEngine`` / ``launch.serve``.

  PYTHONPATH=src python -m repro.launch.train --arch qwen2-7b --smoke --steps 20
  PYTHONPATH=src python -m repro.launch.train --arch olmoe-1b-7b --smoke \
      --steps 30 --fail-at 12 --ckpt-every 5      # exercises restart
"""

from __future__ import annotations

import argparse
import json
import time
from typing import Any, Dict

import jax
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs.shapes import SHAPES, ShapeSpec, smoke_config
from repro.data import make_batch
from repro.launch.mesh import debug_mesh, make_production_mesh
from repro.models.zoo import LM, get_config
from repro.optim import OptConfig, init_opt_state
from repro.parallel.steps import accum_layout, make_shardings, make_train_step
from repro.runtime import FailureInjector, NestedPartitionExecutor, TrainSupervisor


def build(args):
    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_config(cfg)
        shape = ShapeSpec("smoke", seq_len=args.seq_len, global_batch=args.batch, kind="train")
        mesh = debug_mesh()
        dp = 1
    else:
        shape = SHAPES[args.shape]
        mesh = make_production_mesh(multi_pod=(args.mesh == "multi"))
        dp = mesh.shape["data"] * mesh.shape.get("pod", 1)
    ep = max(1, min(cfg.n_experts, mesh.shape["data"])) if cfg.n_experts else 1
    lm = LM(cfg, ep_size=ep)
    accum, micro = accum_layout(shape.global_batch, dp)
    sh = make_shardings(lm, mesh, kind="train", accum=True, batch_shardable=(micro % dp == 0))
    opt_cfg = OptConfig(peak_lr=args.lr, warmup_steps=args.warmup, total_steps=args.steps)
    step_fn = make_train_step(lm, opt_cfg, sh, grad_sync=args.grad_sync)
    jitted = jax.jit(
        step_fn,
        in_shardings=(sh.params, sh.opt, sh.batch),
        out_shardings=(sh.params, sh.opt, None),
        donate_argnums=(0, 1),
    )
    return cfg, shape, lm, jitted, accum, micro, dp


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--smoke", action="store_true", help="reduced config on local devices")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--warmup", type=int, default=5)
    ap.add_argument("--grad-sync", default="auto", choices=["auto", "podwise", "podwise_int8"])
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--fail-at", type=int, default=None, help="inject a failure at step N")
    ap.add_argument("--rebalance-every", type=int, default=10,
                    help="online-executor rebalance cadence (steps)")
    ap.add_argument("--plan-cache", default=None,
                    help="persist solved batch splits under this directory")
    ap.add_argument("--metrics-out", default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg, shape, lm, jitted, accum, micro, dp = build(args)
    key = jax.random.PRNGKey(args.seed)
    params = lm.init(key)
    opt_state = init_opt_state(params)
    n_params = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params))
    print(f"arch={cfg.arch_id} params={n_params/1e6:.1f}M accum={accum} micro={micro}", flush=True)

    ckpt = CheckpointManager(args.ckpt_dir, keep=3) if args.ckpt_dir else None
    start_step = 0
    if ckpt is not None:
        from repro.checkpoint import latest_step

        ls = latest_step(args.ckpt_dir)
        if ls is not None:
            (params, opt_state), manifest = ckpt.restore_latest((params, opt_state))
            start_step = manifest["step"]
            print(f"restored step {start_step}", flush=True)

    metrics_log = []

    def batch_fn(step: int) -> Dict[str, Any]:
        return make_batch(cfg, shape, step, seed=args.seed, accum=accum, micro=micro)

    def step_fn(state, step, batch):
        params, opt_state = state
        params, opt_state, metrics = jitted(params, opt_state, batch)
        return (params, opt_state), metrics

    def save_fn(step, state):
        if ckpt is not None:
            ckpt.save(step, state, extra_meta={"arch": cfg.arch_id})

    def restore_fn():
        if ckpt is None:
            raise RuntimeError("failure without checkpointing enabled")
        (p, o), manifest = ckpt.restore_latest((params, opt_state))
        return manifest["step"], (p, o)

    def on_metrics(step, metrics, dt, stragglers):
        rec = {"step": step, "loss": float(metrics["loss"]), "lr": float(metrics["lr"]),
               "grad_norm": float(metrics["grad_norm"]), "sec": round(dt, 4)}
        metrics_log.append(rec)
        if step % max(1, args.steps // 10) == 0 or step < 3:
            print(json.dumps(rec), flush=True)

    # online equalizer riding along via the supervisor: uniform wall-time
    # attribution here (advisory split); per-device times would make it real
    executor = NestedPartitionExecutor(
        shape.global_batch,
        dp,
        bucket=1,
        rebalance_every=args.rebalance_every,
        plan_cache_dir=args.plan_cache,
    )
    sup = TrainSupervisor(
        step_fn, batch_fn, save_fn, restore_fn,
        ckpt_every=args.ckpt_every,
        injector=FailureInjector({args.fail_at: "node-loss"}) if args.fail_at else None,
        on_metrics=on_metrics,
        executor=executor,
    )
    t0 = time.time()
    final_step, (params, opt_state) = sup.run((params, opt_state), start_step, args.steps)
    wall = time.time() - t0
    if ckpt is not None:
        ckpt.save(final_step, (params, opt_state))
        ckpt.wait()
    losses = [m["loss"] for m in metrics_log]
    print(f"done: steps={final_step} wall={wall:.1f}s loss {losses[0]:.4f} -> {losses[-1]:.4f} "
          f"restarts={sup.restarts} retries={sup.retries}", flush=True)
    print(f"executor: dp={executor.n_partitions} rounds={executor.round} "
          f"counts={executor.counts.tolist()} "
          f"predicted_makespan={executor.predicted_makespan():.4f}s", flush=True)
    if args.metrics_out:
        with open(args.metrics_out, "w") as f:
            for m in metrics_log:
                f.write(json.dumps(m) + "\n")
    assert all(np.isfinite(l) for l in losses), "non-finite loss"
    if args.steps >= 20:  # short runs are too noisy for a hard progress gate
        assert min(losses[-5:]) < losses[0], "loss did not decrease"


if __name__ == "__main__":
    main()
