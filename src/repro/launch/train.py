"""End-to-end training driver.

Runs any zoo architecture (full or smoke-reduced config) with the real
substrate: sharded jit step (grad accumulation + AdamW), deterministic data
pipeline, async sharded checkpoints, and the fault-tolerant supervisor
(retry / restore / straggler EWMA).  On this CPU container use ``--smoke``
(reduced config, 1 device); on a pod the same file drives the production
mesh.

An online ``repro.runtime.executor.NestedPartitionExecutor`` rides along
through the supervisor (the paper's section-5.6 equalizer run at runtime):
wall times feed it each step and the re-solved data-parallel row counts are
reported at the end (``--rebalance-every`` cadence, ``--plan-cache``
persistence).  On this synchronous single-process path the attribution is
uniform, so the split is advisory until per-device step times exist; the
asymmetric execution lives in ``BlockedDGEngine`` / ``launch.serve``.

``--fused-steps N`` scan-compiles N optimizer steps into ONE donated device
dispatch (batches for the chunk are stacked and scanned over — the training
twin of the blocked engine's ``FusedStepPipeline``); the supervisor then
drives chunks, so retries and rebalances happen at chunk granularity.
``--steps`` must be divisible by N.  Step-indexed fault tolerance
(``--fail-at`` / ``--ckpt-dir`` / ``--ckpt-every``) composes with fusion by
unit conversion: those flags stay optimizer-step indexed (``--fail-at``
must land on a chunk boundary), checkpoints store optimizer step numbers,
and the supervisor's chunk counter is translated at the boundary.

  PYTHONPATH=src python -m repro.launch.train --arch qwen2-7b --smoke --steps 20
  PYTHONPATH=src python -m repro.launch.train --arch qwen2-7b --smoke \
      --steps 20 --fused-steps 5                  # 4 dispatches total
  PYTHONPATH=src python -m repro.launch.train --arch olmoe-1b-7b --smoke \
      --steps 30 --fail-at 12 --ckpt-every 5      # exercises restart
  PYTHONPATH=src python -m repro.launch.train --arch olmoe-1b-7b --smoke \
      --steps 30 --fused-steps 5 --fail-at 10 --ckpt-every 5 \
      --ckpt-dir /tmp/ck                          # fused restart, same units
"""

from __future__ import annotations

import argparse
import json
import time
from typing import Any, Dict

import jax
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs.registry import format_listing, resolve_arch
from repro.configs.shapes import SHAPES, ShapeSpec, smoke_config
from repro.data import make_batch
from repro.launch.mesh import debug_mesh, make_production_mesh
from repro.models.zoo import LM
from repro.optim import OptConfig, init_opt_state
from repro.parallel.steps import accum_layout, make_shardings, make_train_step
from repro.runtime import FailureInjector, NestedPartitionExecutor, TrainSupervisor


def build(args):
    cfg = resolve_arch(args.arch)
    if args.smoke:
        cfg = smoke_config(cfg)
        shape = ShapeSpec("smoke", seq_len=args.seq_len, global_batch=args.batch, kind="train")
        mesh = debug_mesh()
        dp = 1
    else:
        shape = SHAPES[args.shape]
        mesh = make_production_mesh(multi_pod=(args.mesh == "multi"))
        dp = mesh.shape["data"] * mesh.shape.get("pod", 1)
    ep = max(1, min(cfg.n_experts, mesh.shape["data"])) if cfg.n_experts else 1
    lm = LM(cfg, ep_size=ep)
    accum, micro = accum_layout(shape.global_batch, dp)
    sh = make_shardings(lm, mesh, kind="train", accum=True, batch_shardable=(micro % dp == 0))
    opt_cfg = OptConfig(peak_lr=args.lr, warmup_steps=args.warmup, total_steps=args.steps)
    step_fn = make_train_step(lm, opt_cfg, sh, grad_sync=args.grad_sync)
    jitted = jax.jit(
        step_fn,
        in_shardings=(sh.params, sh.opt, sh.batch),
        out_shardings=(sh.params, sh.opt, None),
        donate_argnums=(0, 1),
    )
    jitted_chunk = None
    if getattr(args, "fused_steps", 1) > 1:
        # N optimizer steps as ONE donated program: lax.scan over a stacked
        # batch chunk with the (params, opt) carry donated — per-step
        # metrics come back stacked along the scan axis
        from jax.sharding import NamedSharding, PartitionSpec

        batch_sh = jax.tree.map(
            lambda s: NamedSharding(s.mesh, PartitionSpec(None, *s.spec)),
            sh.batch,
            is_leaf=lambda x: isinstance(x, NamedSharding),
        )

        def chunk_fn(params, opt_state, batches):
            def body(carry, batch):
                p, o = carry
                p, o, metrics = step_fn(p, o, batch)
                return (p, o), metrics

            (params, opt_state), ms = jax.lax.scan(body, (params, opt_state), batches)
            return params, opt_state, ms

        jitted_chunk = jax.jit(
            chunk_fn,
            in_shardings=(sh.params, sh.opt, batch_sh),
            out_shardings=(sh.params, sh.opt, None),
            donate_argnums=(0, 1),
        )
    return cfg, shape, lm, jitted, jitted_chunk, accum, micro, dp


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", help="model arch id (see --list-scenarios)")
    ap.add_argument("--list-scenarios", action="store_true",
                    help="print every registered arch/scenario and exit")
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--smoke", action="store_true", help="reduced config on local devices")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--warmup", type=int, default=5)
    ap.add_argument("--grad-sync", default="auto", choices=["auto", "podwise", "podwise_int8"])
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--fail-at", type=int, default=None, help="inject a failure at step N")
    ap.add_argument("--fused-steps", type=int, default=1,
                    help="optimizer steps fused into one scan-compiled donated "
                         "dispatch (supervisor retries/ckpts act per chunk)")
    ap.add_argument("--rebalance-every", type=int, default=10,
                    help="online-executor rebalance cadence (steps)")
    ap.add_argument("--plan-cache", default=None,
                    help="persist solved batch splits under this directory")
    ap.add_argument("--metrics-out", default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    if args.list_scenarios:
        print(format_listing())
        return
    if not args.arch:
        ap.error("--arch is required (or --list-scenarios to enumerate)")

    N = max(1, args.fused_steps)
    if args.steps % N:
        raise SystemExit(f"--steps {args.steps} not divisible by --fused-steps {N}")
    if N > 1 and args.fail_at is not None and args.fail_at % N:
        # the supervisor counts chunks when steps are fused; a failure can
        # only be injected between dispatches, i.e. on a chunk boundary
        raise SystemExit(f"--fail-at {args.fail_at} must be a multiple of "
                         f"--fused-steps {N} (failures fire between fused chunks)")
    cfg, shape, lm, jitted, jitted_chunk, accum, micro, dp = build(args)
    key = jax.random.PRNGKey(args.seed)
    params = lm.init(key)
    opt_state = init_opt_state(params)
    n_params = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params))
    print(f"arch={cfg.arch_id} params={n_params/1e6:.1f}M accum={accum} micro={micro}", flush=True)

    ckpt = CheckpointManager(args.ckpt_dir, keep=3) if args.ckpt_dir else None
    start_step = 0
    if ckpt is not None:
        from repro.checkpoint import latest_step

        ls = latest_step(args.ckpt_dir)
        if ls is not None:
            (params, opt_state), manifest = ckpt.restore_latest((params, opt_state))
            # checkpoints store OPTIMIZER step numbers; the supervisor loop
            # counts chunks, so convert at the boundary (ckpts are only
            # written on chunk boundaries, so this divides exactly)
            start_step = manifest["step"] // N
            print(f"restored step {manifest['step']}", flush=True)

    metrics_log = []

    def batch_fn(step: int) -> Dict[str, Any]:
        if N == 1:
            return make_batch(cfg, shape, step, seed=args.seed, accum=accum, micro=micro)
        # fused chunk: stack the next N deterministic batches along the scan axis
        bs = [
            make_batch(cfg, shape, step * N + i, seed=args.seed, accum=accum, micro=micro)
            for i in range(N)
        ]
        return jax.tree.map(lambda *xs: np.stack(xs), *bs)

    def step_fn(state, step, batch):
        params, opt_state = state
        if N == 1:
            params, opt_state, metrics = jitted(params, opt_state, batch)
        else:
            params, opt_state, ms = jitted_chunk(params, opt_state, batch)
            metrics = jax.tree.map(lambda v: v[-1], ms)  # the chunk's last step
        return (params, opt_state), metrics

    def save_fn(step, state):
        # supervisor steps are chunks; persist the optimizer step number so
        # checkpoints mean the same thing whatever --fused-steps produced them
        if ckpt is not None:
            ckpt.save(step * N, state, extra_meta={"arch": cfg.arch_id})

    def restore_fn():
        if ckpt is None:
            raise RuntimeError("failure without checkpointing enabled")
        (p, o), manifest = ckpt.restore_latest((params, opt_state))
        return manifest["step"] // N, (p, o)

    def on_metrics(step, metrics, dt, stragglers):
        # under fusion the supervisor step is a chunk: report the optimizer
        # step the (last-of-chunk) metrics belong to, and per-step seconds
        rec = {"step": step * N + (N - 1), "loss": float(metrics["loss"]),
               "lr": float(metrics["lr"]),
               "grad_norm": float(metrics["grad_norm"]), "sec": round(dt / N, 4)}
        metrics_log.append(rec)
        if step % max(1, (args.steps // N) // 10) == 0 or step < 3:
            print(json.dumps(rec), flush=True)

    # online equalizer riding along via the supervisor: uniform wall-time
    # attribution here (advisory split); per-device times would make it real
    executor = NestedPartitionExecutor(
        shape.global_batch,
        dp,
        bucket=1,
        # the executor advances once per supervisor step (= N optimizer
        # steps under fusion): scale the cadence so --rebalance-every keeps
        # meaning optimizer steps
        rebalance_every=max(1, args.rebalance_every // N) if args.rebalance_every > 0
        else args.rebalance_every,
        plan_cache_dir=args.plan_cache,
    )
    sup = TrainSupervisor(
        step_fn, batch_fn, save_fn, restore_fn,
        ckpt_every=max(1, args.ckpt_every // N),
        injector=FailureInjector({args.fail_at // N: "node-loss"}) if args.fail_at else None,
        on_metrics=on_metrics,
        executor=executor,
    )
    # perf_counter: wall deltas must survive NTP clock steps (same
    # non-monotonic-clock bug class as the serving decode timer)
    t0 = time.perf_counter()
    final_step, (params, opt_state) = sup.run((params, opt_state), start_step, args.steps // N)
    wall = time.perf_counter() - t0
    if ckpt is not None:
        ckpt.save(final_step * N, (params, opt_state))
        ckpt.wait()
    losses = [m["loss"] for m in metrics_log]
    print(f"done: steps={final_step * N} dispatches={final_step} wall={wall:.1f}s "
          f"loss {losses[0]:.4f} -> {losses[-1]:.4f} "
          f"restarts={sup.restarts} retries={sup.retries}", flush=True)
    # full-precision final loss on its own line: the determinism tests diff
    # this (and --metrics-out) bitwise across seeds and fused/unfused drivers
    print(f"final_loss={losses[-1]!r}", flush=True)
    print(f"executor: dp={executor.n_partitions} rounds={executor.round} "
          f"counts={executor.counts.tolist()} "
          f"predicted_makespan={executor.predicted_makespan():.4f}s", flush=True)
    if args.metrics_out:
        with open(args.metrics_out, "w") as f:
            for m in metrics_log:
                f.write(json.dumps(m) + "\n")
    assert all(np.isfinite(l) for l in losses), "non-finite loss"
    # short runs are too noisy for a hard progress gate; under fusion the
    # log holds one record per CHUNK, so also require >=2 samples
    if args.steps >= 20 and len(losses) >= 2:
        assert min(losses[-5:]) < losses[0], "loss did not decrease"


if __name__ == "__main__":
    main()
