"""Version tolerance for the jax APIs this repo relies on.

The codebase targets the modern spellings — ``jax.shard_map``,
``jax.make_mesh(..., axis_types=...)``, ``jax.sharding.AxisType`` — which do
not exist on older jax (0.4.x) where the same machinery lives under
``jax.experimental.shard_map`` with ``check_rep`` / ``auto`` parameters.
Importing the helpers from here instead of guessing the installed version is
what lets the tier-1 suite and CI run on any jax the container ships.
"""

from __future__ import annotations

import jax

try:  # modern jax
    AxisType = jax.sharding.AxisType
    HAS_AXIS_TYPES = True
except AttributeError:  # jax <= 0.4.x: meshes have no axis types
    class AxisType:  # noqa: D401 - sentinel mirroring jax.sharding.AxisType
        """Placeholder so call sites can name axis types unconditionally."""

        Auto = "auto"
        Explicit = "explicit"
        Manual = "manual"

    HAS_AXIS_TYPES = False


def axis_size(axis_name) -> int:
    """Static mesh-axis size inside ``shard_map`` on any jax version.

    Old jax has no ``lax.axis_size``; ``psum`` of a Python constant is its
    long-standing implementation (constant-folded at trace time)."""
    from jax import lax

    if hasattr(lax, "axis_size"):
        return lax.axis_size(axis_name)
    return lax.psum(1, axis_name)


def make_mesh(axis_shapes, axis_names, *, axis_types=None, devices=None):
    """``jax.make_mesh`` accepting ``axis_types`` on any jax version.

    On old jax the argument is dropped (meshes are implicitly Auto — the
    same semantics the modern default provides)."""
    kwargs = {}
    if devices is not None:
        kwargs["devices"] = devices
    if hasattr(jax, "make_mesh"):
        if axis_types is not None and HAS_AXIS_TYPES:
            try:
                return jax.make_mesh(axis_shapes, axis_names, axis_types=axis_types, **kwargs)
            except TypeError:  # make_mesh exists but predates axis_types
                pass
        return jax.make_mesh(axis_shapes, axis_names, **kwargs)
    # jax without make_mesh at all: build the Mesh by hand
    from jax.experimental import mesh_utils
    from jax.sharding import Mesh

    devs = mesh_utils.create_device_mesh(tuple(axis_shapes), devices=devices)
    return Mesh(devs, tuple(axis_names))


def shard_map(f, *, mesh, in_specs, out_specs, check_vma=None, axis_names=None, **kwargs):
    """``jax.shard_map`` on any jax version.

    Maps the modern ``check_vma`` to the legacy ``check_rep`` (both disable
    replication checking) and ``axis_names`` (manual axes) to the legacy
    complement ``auto`` (every mesh axis NOT named stays automatic)."""
    if hasattr(jax, "shard_map"):
        if check_vma is not None:
            kwargs["check_vma"] = check_vma
        if axis_names is not None:
            kwargs["axis_names"] = axis_names
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kwargs)

    from jax.experimental.shard_map import shard_map as _legacy_shard_map

    if check_vma is not None:
        kwargs["check_rep"] = check_vma
    if axis_names is not None:
        kwargs["auto"] = frozenset(mesh.axis_names) - set(axis_names)
    return _legacy_shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kwargs)
