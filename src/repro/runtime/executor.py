"""Online auto-rebalancing nested-partition executor — paper section 5.6
closed at *runtime*.

The paper's payoff is not a static split but a calibrated one: it solves

    T_acc(K_acc) = T_host(K - K_acc) + Transfer(K_acc)

from *measured* kernel times so that neither side idles.  This module wires
the repo's existing pieces (``core.load_balance``, ``core.partition``,
``runtime.schedule``) into the measure -> re-solve -> re-splice loop that
makes a heterogeneous run track hardware reality:

1. **calibrate** — a short phase that times the four ``StepSchedule``
   phases (boundary face flux / interior volume / halo transfer / halo
   fold) per partition.  ``BlockedDGEngine.calibrate`` resolves all four on
   the DG workload; injected ``time_models`` give whole-step totals for
   simulated fleets (``CalibrationReport.from_totals``);
2. **solve** — measured step times feed ``rebalance_from_measurements`` /
   ``solve_multiway``; a component-resolved report additionally enables the
   overlap-aware solve ``plan_from_report``, whose time model
   ``t_p(k) = boundary + max(interior, transfer) + correction`` credits a
   partition for transfer hidden under interior compute (paper Fig 5.1);
3. **resplice** — the ``NestedPartition`` index arrays are rebuilt and the
   device assignment re-spliced *without recompiling the interior kernels*:
   per-partition chunk sizes are padded to ``bucket`` multiples, so the jit
   cache is keyed on a small set of padded shapes that survive rebalances;
4. **drive** — a step-driver API (``drive`` / ``observe`` /
   ``maybe_rebalance``) adopted by ``repro.dg.partitioned``,
   ``repro.launch.train`` and ``repro.launch.serve``.

``BlockedDGEngine`` executes each partition's block as a thin instantiation
of the shared ``StepSchedule`` (the same object ``dg.partitioned`` builds
its SPMD rhs from): the exchange phase gathers the halo, the interior phase
runs the volume kernel on the block's own elements, and the correction
phase computes the face flux and folds it in.  Solved splits are cached
(hash of mesh/topology/weights -> counts) and persisted through
``repro.checkpoint``, so a restarted job starts from the last calibrated
split instead of the naive one.  A straggler-injection hook
(``inject_straggler``) multiplies observed times for one partition, which is
how tests exercise convergence: a 2x straggler must be rebalanced to within
10% of the common-finish-time optimum in a few rounds.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import time
from typing import Callable, List, Optional, Sequence

import numpy as np

from repro.core.load_balance import (
    rebalance_from_measurements,
    solve_multiway,
)
from repro.core.partition import NestedPartition, build_nested_partition, splice
from repro.runtime.schedule import CalibrationReport, StepSchedule

__all__ = [
    "Plan",
    "PlanCache",
    "CalibrationReport",
    "StepSchedule",
    "NestedPartitionExecutor",
    "BlockedDGEngine",
    "bucket_counts",
]


# ---------------------------------------------------------------------------
# Bucketed counts — jit-cache-friendly chunk sizes
# ---------------------------------------------------------------------------


def bucket_counts(counts: Sequence[int], bucket: int) -> np.ndarray:
    """Round per-partition counts to multiples of ``bucket`` while conserving
    the total (largest-remainder on bucket units).  The sub-bucket tail goes
    to the largest partition; its padded shape is unchanged, so the set of
    compiled chunk shapes stays small across rebalances."""
    counts = np.asarray(counts, dtype=np.int64)
    total = int(counts.sum())
    if bucket <= 1 or total == 0:
        return counts.copy()
    units = total // bucket
    if units == 0:
        out = np.zeros_like(counts)
        out[int(np.argmax(counts))] = total
        return out
    ideal = units * counts / total
    base = np.floor(ideal).astype(np.int64)
    rem = units - int(base.sum())
    order = np.argsort(-(ideal - base), kind="stable")
    base[order[:rem]] += 1
    out = base * bucket
    out[int(np.argmax(counts))] += total - int(out.sum())
    assert out.sum() == total and (out >= 0).all()
    return out


def pad_to_bucket(n: int, bucket: int) -> int:
    """Padded (compiled) size for a chunk of ``n`` items."""
    if bucket <= 1 or n == 0:
        return n
    return int(-(-n // bucket) * bucket)


# ---------------------------------------------------------------------------
# Plans and the persistent plan cache
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Plan:
    """A solved split: normalized work weights and bucketed counts."""

    key: str
    weights: np.ndarray  # (P,) normalized
    counts: np.ndarray  # (P,) integer, bucketed, sums to K
    predicted_times: np.ndarray  # (P,) seconds under the current belief
    round: int = 0

    @property
    def makespan(self) -> float:
        return float(self.predicted_times.max()) if len(self.predicted_times) else 0.0


def plan_key(
    grid_dims: Optional[tuple],
    n_items: int,
    n_partitions: int,
    bucket: int,
    accel_fraction: float,
    weights: Sequence[float],
) -> str:
    """Stable hash of mesh/topology/weights identifying a solved split."""
    w = np.asarray(weights, dtype=np.float64)
    w = w / w.sum()
    payload = json.dumps(
        {
            "grid": list(grid_dims) if grid_dims else None,
            "K": int(n_items),
            "P": int(n_partitions),
            "bucket": int(bucket),
            "accel_fraction": round(float(accel_fraction), 6),
            "weights": [round(float(x), 6) for x in w],
        },
        sort_keys=True,
    )
    return hashlib.sha256(payload.encode()).hexdigest()[:16]


class PlanCache:
    """hash(mesh/topology/weights) -> solved split, persisted atomically via
    ``repro.checkpoint`` (one checkpoint directory per key, pruned to
    ``keep``).  A ``plan_latest`` marker records the last applied key so a
    restarted executor resumes from the calibrated split, not the naive
    one."""

    def __init__(self, root: str, keep: int = 8):
        self.root = root
        self.keep = keep
        os.makedirs(root, exist_ok=True)
        self.hits = 0
        self.misses = 0

    def _dir(self, key: str) -> str:
        return os.path.join(self.root, f"plan_{key}")

    def _marker(self) -> str:
        return os.path.join(self.root, "plan_latest")

    def mark_latest(self, key: str) -> None:
        tmp = self._marker() + ".tmp"
        with open(tmp, "w") as f:
            f.write(key)
        os.replace(tmp, self._marker())

    def get_latest(self, n_partitions: int) -> Optional[Plan]:
        try:
            with open(self._marker()) as f:
                key = f.read().strip()
        except FileNotFoundError:
            return None
        return self.get(key, n_partitions) if key else None

    def _prune(self) -> None:
        dirs = [
            os.path.join(self.root, d)
            for d in os.listdir(self.root)
            if d.startswith("plan_") and os.path.isdir(os.path.join(self.root, d))
        ]
        if len(dirs) <= self.keep:
            return
        dirs.sort(key=os.path.getmtime)
        import shutil

        for d in dirs[: len(dirs) - self.keep]:
            shutil.rmtree(d, ignore_errors=True)

    def get(self, key: str, n_partitions: int) -> Optional[Plan]:
        from repro.checkpoint import latest_step, restore

        d = self._dir(key)
        if latest_step(d) is None:
            self.misses += 1
            return None
        template = {
            "weights": np.zeros(n_partitions),
            "counts": np.zeros(n_partitions, dtype=np.int64),
            "predicted_times": np.zeros(n_partitions),
        }
        tree, manifest = restore(d, template)
        self.hits += 1
        return Plan(
            key=key,
            weights=np.asarray(tree["weights"], dtype=np.float64),
            counts=np.asarray(tree["counts"], dtype=np.int64),
            predicted_times=np.asarray(tree["predicted_times"], dtype=np.float64),
            round=int(manifest["extra"].get("round", 0)),
        )

    def put(self, plan: Plan) -> None:
        from repro.checkpoint import save

        tree = {
            "weights": plan.weights,
            "counts": plan.counts,
            "predicted_times": plan.predicted_times,
        }
        save(self._dir(plan.key), 0, tree, extra_meta={"key": plan.key, "round": plan.round})
        self._prune()


# ---------------------------------------------------------------------------
# The executor
# ---------------------------------------------------------------------------


class NestedPartitionExecutor:
    """Closes the paper's calibration loop at runtime.

    Two operating modes share the same solve/resplice machinery:

    * **measured** — ``observe`` is fed real per-partition step seconds (from
      ``BlockedDGEngine`` timing, or a synchronous driver attributing wall
      time);
    * **modeled** — ``time_models[p]`` is a callable ``T_p(k) -> seconds``
      (e.g. from ``repro.core.cost_model``); ``simulated_times`` evaluates it
      on the current counts.  This is how virtual heterogeneous fleets and
      CI-sized convergence tests run on a homogeneous container.

    ``inject_straggler(p, factor)`` multiplies partition ``p``'s *observed*
    times — the test hook for convergence: the executor must re-splice work
    away from the straggler until the predicted makespan is within ``rtol``
    of the common-finish-time optimum.
    """

    def __init__(
        self,
        n_items: int,
        n_partitions: int,
        *,
        grid_dims: Optional[tuple] = None,
        bucket: int = 16,
        smoothing: float = 0.5,
        ewma_alpha: float = 1.0,
        rebalance_every: int = 10,
        time_models: Optional[Sequence[Callable[[float], float]]] = None,
        plan_cache_dir: Optional[str] = None,
        initial_weights: Optional[Sequence[float]] = None,
        accel_fraction: float = 0.0,
        neighbors: Optional[np.ndarray] = None,
    ):
        if grid_dims is not None:
            expected = int(np.prod(grid_dims))
            if n_items != expected:
                raise ValueError(f"n_items={n_items} != prod(grid_dims)={expected}")
        self.n_items = int(n_items)
        self.n_partitions = int(n_partitions)
        self.grid_dims = tuple(grid_dims) if grid_dims is not None else None
        self.bucket = int(bucket)
        self.smoothing = float(smoothing)
        self.ewma_alpha = float(ewma_alpha)
        self.rebalance_every = int(rebalance_every)
        self.time_models = list(time_models) if time_models is not None else None
        if self.time_models is not None and len(self.time_models) != n_partitions:
            raise ValueError("need one time model per partition")
        self.plan_cache = PlanCache(plan_cache_dir) if plan_cache_dir else None
        self.accel_fraction = float(accel_fraction)
        # per-partition accelerator element counts (level-2 solve output);
        # overrides accel_fraction when set — see set_accel_counts()
        self.accel_counts: Optional[np.ndarray] = None
        # face-neighbour table the nested partition is built from; engines
        # whose mesh topology differs from the default non-periodic grid
        # (periodic bricks) install their own via set_neighbors()
        self.neighbors = None if neighbors is None else np.asarray(neighbors, dtype=np.int64)

        self._factors = np.ones(self.n_partitions)
        # ejected partitions are pinned at zero weight by every solve until
        # readmitted — the fault-tolerance layer's weight->0 ejection
        self.ejected: set = set()
        self._ewma: Optional[np.ndarray] = None
        self._obs_counts: Optional[np.ndarray] = None
        self._n_obs = 0
        self._step = 0
        self.round = 0
        self.partition: Optional[NestedPartition] = None
        self.offsets: Optional[np.ndarray] = None
        self._resplice_hooks: List[Callable[[], None]] = []
        self.history: List[Plan] = []

        w0 = np.asarray(
            initial_weights if initial_weights is not None else np.ones(n_partitions),
            dtype=np.float64,
        )
        self.weights = w0 / w0.sum()
        self.counts = bucket_counts(np.diff(splice(self.n_items, self.weights)), self.bucket)
        if self.plan_cache is not None:
            if initial_weights is None:
                # restart path: resume the last calibrated split, not naive
                latest = self.plan_cache.get_latest(self.n_partitions)
                if latest is not None and int(latest.counts.sum()) == self.n_items:
                    self.weights = latest.weights
                    self.counts = latest.counts.copy()
            else:
                # elastic-membership path: a fleet the cache has seen (same
                # seed weights, same P) resumes its solved splice directly
                key = plan_key(self.grid_dims, self.n_items, self.n_partitions,
                               self.bucket, self.accel_fraction, self.weights)
                cached = self.plan_cache.get(key, self.n_partitions)
                if cached is not None and int(cached.counts.sum()) == self.n_items:
                    self.weights = cached.weights
                    self.counts = cached.counts.copy()
        self._resplice()

    # -- introspection ------------------------------------------------------

    @property
    def chunk_pads(self) -> tuple:
        """Padded (compiled) chunk sizes — the jit-cache key set."""
        return tuple(pad_to_bucket(int(c), self.bucket) for c in self.counts)

    def rates(self) -> np.ndarray:
        """items/s per partition under the current belief (measured EWMA if
        available, else the time models, else uniform)."""
        if self._ewma is not None:
            with np.errstate(divide="ignore", invalid="ignore"):
                r = self._obs_counts / self._ewma
            good = np.isfinite(r) & (r > 0)
            if not good.any():
                return np.ones(self.n_partitions)
            r = np.where(good, r, r[good].mean())
            return r
        if self.time_models is not None:
            k = max(1, self.n_items // self.n_partitions)
            t = np.array([max(f(k), 1e-30) for f in self.time_models])
            return k / t
        return np.ones(self.n_partitions)

    def predicted_makespan(self) -> float:
        """max_p T_p(counts_p) under the current belief."""
        with np.errstate(divide="ignore", invalid="ignore"):
            t = self.counts / self.rates()
        return float(np.nanmax(np.where(self.counts > 0, t, 0.0)))

    def optimal_makespan(self) -> float:
        """Common-finish-time optimum for the current belief (continuous
        relaxation of ``solve_multiway``)."""
        rates = self.rates()
        fns = [lambda k, r=r: k / r for r in rates]
        res = solve_multiway(fns, self.n_items, integer=False)
        return res.makespan

    # -- test / simulation hooks -------------------------------------------

    @property
    def straggler_factors(self) -> np.ndarray:
        """Current per-partition straggler multipliers (a copy; see
        ``inject_straggler``).  Consumers pricing decisions off a
        calibration report — e.g. the serving loop's admission control —
        read these so an injected straggler reprices immediately."""
        return self._factors.copy()

    def inject_straggler(self, partition: int, factor: float) -> None:
        """Multiply partition's observed times by ``factor`` (test hook)."""
        self._factors[partition] = float(factor)

    def clear_stragglers(self) -> None:
        self._factors[:] = 1.0

    def simulated_times(self, counts: Optional[Sequence[int]] = None) -> np.ndarray:
        """Evaluate the time models on ``counts`` (default: current split).
        Straggler factors are NOT applied here — ``observe`` applies them, so
        a simulated measure->observe round counts them exactly once."""
        if self.time_models is None:
            raise RuntimeError("no time models configured")
        counts = self.counts if counts is None else np.asarray(counts)
        return np.array([self.time_models[p](int(counts[p])) for p in range(self.n_partitions)])

    # -- calibration / measurement -----------------------------------------

    def calibrate(
        self,
        measure_fn: Optional[Callable[[], "np.ndarray | CalibrationReport"]] = None,
        n_steps: int = 3,
    ) -> CalibrationReport:
        """Short calibration phase: run ``n_steps`` measurements and seed the
        EWMA.  ``measure_fn`` returns either a ``CalibrationReport`` (phase-
        resolved — e.g. a bound ``BlockedDGEngine.calibrate``) or plain
        per-partition step seconds (``BlockedDGEngine.measure_block_times``,
        the whole-step ``time_models`` default), which are carried as an
        unresolved ``CalibrationReport.from_totals``."""
        reports = []
        for _ in range(max(1, n_steps)):
            before = self._n_obs
            r = measure_fn() if measure_fn is not None else self.simulated_times()
            if not isinstance(r, CalibrationReport):
                r = CalibrationReport.from_totals(np.asarray(r))
            if self._n_obs == before:
                # only observe if the measure_fn didn't already feed us
                # (a bound BlockedDGEngine.calibrate observes internally)
                self.observe(r.step_s)
            reports.append(r)
        return CalibrationReport.median(reports)

    def observe(self, times: Sequence[float]) -> None:
        """Record measured per-partition step seconds (straggler factors are
        applied here — the single injection point)."""
        t = np.asarray(times, dtype=np.float64) * self._factors
        self._n_obs += 1
        if self._ewma is None or self.ewma_alpha >= 1.0:
            self._ewma = t.copy()
        else:
            self._ewma = self.ewma_alpha * t + (1.0 - self.ewma_alpha) * self._ewma
        # throughput must be computed against the counts these times were
        # measured under, not the counts a later resplice installs
        self._obs_counts = self.counts.astype(np.float64)

    def observe_total(self, dt: float) -> None:
        """Synchronous-step attribution: under a barrier every partition's
        step time equals the wall time (SPMD semantics).  Gives no skew
        signal by itself — stragglers enter via injection or per-partition
        measurement."""
        self.observe(np.full(self.n_partitions, float(dt)))

    def observe_chunk(self, report: "CalibrationReport", n_steps: int):
        """In-scan observation entry point: record one fused chunk's
        per-partition step seconds (a ``run_observed`` report — straggler
        factors are applied here, inside ``observe``, exactly once) and
        advance the rebalance schedule by the chunk's steps.  Returns the
        applied ``Plan`` when the schedule fired, else ``None``."""
        self.observe(np.asarray(report.step_s))
        return self.advance(int(n_steps))

    # -- solve / resplice ---------------------------------------------------

    def solve(self, weights: Sequence[float]) -> Plan:
        """Weights -> bucketed counts (plan-cache aware).  Ejected
        partitions are pinned at zero weight — the equalizer can never
        hand work back to a node the fault-tolerance layer removed."""
        w = np.asarray(weights, dtype=np.float64).copy()
        if self.ejected:
            w[sorted(self.ejected)] = 0.0
        if w.sum() <= 0:
            raise RuntimeError("no live partitions left to solve over")
        w = w / w.sum()
        key = plan_key(
            self.grid_dims, self.n_items, self.n_partitions, self.bucket,
            self.accel_fraction, w,
        )
        if self.plan_cache is not None:
            cached = self.plan_cache.get(key, self.n_partitions)
            if cached is not None and int(cached.counts.sum()) == self.n_items:
                return cached
        counts = bucket_counts(np.diff(splice(self.n_items, w)), self.bucket)
        with np.errstate(divide="ignore", invalid="ignore"):
            predicted = np.where(counts > 0, counts / self.rates(), 0.0)
        plan = Plan(key=key, weights=w, counts=counts, predicted_times=predicted, round=self.round)
        if self.plan_cache is not None:
            self.plan_cache.put(plan)
        return plan

    def set_neighbors(self, neighbors: np.ndarray) -> None:
        """Install the true mesh topology (e.g. a periodic brick's wrapping
        neighbour table) and re-splice so boundary/halo sets match it."""
        self.neighbors = np.asarray(neighbors, dtype=np.int64)
        self._resplice()

    def set_accel_counts(self, accel_counts: Optional[Sequence[int]]) -> None:
        """Install per-partition accelerator element counts (the hierarchical
        level-2 solve output) and re-splice.  ``None`` reverts to the static
        ``accel_fraction``.  Counts are clamped per node to the available
        interior by the partition build, so a stale count after a level-1
        resplice shrinks gracefully instead of erroring."""
        if accel_counts is None:
            self.accel_counts = None
        else:
            ac = np.asarray(accel_counts, dtype=np.int64)
            if len(ac) != self.n_partitions:
                raise ValueError(f"need {self.n_partitions} accel counts, got {len(ac)}")
            if (ac < 0).any():
                raise ValueError(f"accel counts must be non-negative, got {ac}")
            self.accel_counts = ac
        self._resplice()

    def _resplice(self) -> None:
        """Rebuild index arrays for the current counts.  Interior kernels are
        NOT recompiled: consumers key their jit caches on ``chunk_pads``."""
        if self.grid_dims is not None:
            self.partition = build_nested_partition(
                self.grid_dims,
                self.n_partitions,
                accel_fraction=self.accel_fraction,
                node_weights=np.maximum(self.counts, 0) if self.counts.sum() else None,
                accel_counts=self.accel_counts,
                neighbors=self.neighbors,
            )
            self.offsets = self.partition.offsets
        else:
            self.offsets = splice(self.n_items, np.maximum(self.counts, 1e-9))
        for hook in self._resplice_hooks:
            hook()

    def apply(self, plan: Plan) -> None:
        self.weights = plan.weights
        self.counts = plan.counts.copy()
        self.history.append(plan)
        if self.plan_cache is not None:
            self.plan_cache.mark_latest(plan.key)
        self._resplice()

    def rebalance(self) -> Plan:
        """One calibration-loop round: measured EWMA -> equalizer -> new
        bucketed split -> resplice."""
        if self._ewma is None:
            raise RuntimeError("rebalance before any observation; run calibrate() first")
        w = rebalance_from_measurements(
            np.maximum(self._obs_counts, 0),
            np.maximum(self._ewma, 1e-30),
            smoothing=self.smoothing,
            prev_weights=self.weights,
        )
        self.round += 1
        plan = dataclasses.replace(self.solve(w), round=self.round)
        self.apply(plan)
        return plan

    def plan_from_report(
        self,
        report: CalibrationReport,
        overlap: bool = True,
        apply: bool = True,
    ) -> Plan:
        """Overlap-aware solve from a phase-resolved calibration.

        Feeds ``t_p(k) = boundary + max(interior, transfer) + correction``
        (``report.time_models``) into ``solve_multiway``, so the planner
        credits a partition for transfer time hidden under its interior
        compute — the paper's Fig 5.1 schedule entering the balance
        equation.  With ``overlap=False`` the phases are charged
        back-to-back (the sequential strawman)."""
        fns = report.time_models(self.counts, overlap=overlap)
        res = solve_multiway(fns, self.n_items)
        w = np.maximum(np.asarray(res.counts, dtype=np.float64), 1e-9)
        plan = self.solve(w / w.sum())
        if apply:
            # the round counter tracks APPLIED resplices; a what-if solve
            # (apply=False) must not inflate it
            self.round += 1
            plan = dataclasses.replace(plan, round=self.round)
            self.apply(plan)
        return plan

    # -- ejection / elastic state -------------------------------------------

    def eject(self, partition: int) -> Plan:
        """Weight -> 0 for ``partition`` and re-splice the survivors — the
        straggler-ejection primitive.  Every subsequent solve keeps the
        ejected partition at zero until :meth:`readmit`; the engine side is
        automatic (a zero-count block builds no tables and joins no
        launches, so the fused loop stays one dispatch per chunk)."""
        p = int(partition)
        if not (0 <= p < self.n_partitions):
            raise ValueError(f"partition {p} out of range")
        live = self.n_partitions - len(self.ejected)
        if p not in self.ejected and live <= 1:
            raise RuntimeError("cannot eject the last live partition")
        self.ejected.add(p)
        self.round += 1
        plan = dataclasses.replace(self.solve(self.weights), round=self.round)
        self.apply(plan)
        return plan

    def readmit(self, partition: int, weight: Optional[float] = None) -> Plan:
        """Re-splice an ejected partition back in at ``weight`` (default:
        the live fleet's mean weight) — ejection is not sticky."""
        p = int(partition)
        self.ejected.discard(p)
        w = self.weights.copy()
        live = w > 0
        w[p] = float(weight) if weight is not None else (
            float(w[live].mean()) if live.any() else 1.0
        )
        self.round += 1
        plan = dataclasses.replace(self.solve(w), round=self.round)
        self.apply(plan)
        return plan

    def snapshot_state(self) -> dict:
        """The plan/belief state a checkpointed resplice needs to resume:
        everything the fault-tolerance layer saves next to ``q``."""
        return {
            "weights": self.weights.copy(),
            "counts": self.counts.copy(),
            "round": int(self.round),
            "exec_step": int(self._step),
            "ejected": sorted(self.ejected),
            "ewma": None if self._ewma is None else self._ewma.copy(),
            "obs_counts": None if self._obs_counts is None else self._obs_counts.copy(),
            "factors": self._factors.copy(),
        }

    def restore_state(self, state: dict) -> None:
        """Reinstall a :meth:`snapshot_state` (or the JSON-roundtripped
        subset a checkpoint manifest carries) and re-splice to its counts."""
        self.weights = np.asarray(state["weights"], dtype=np.float64)
        self.counts = np.asarray(state["counts"], dtype=np.int64).copy()
        self.round = int(state.get("round", self.round))
        self._step = int(state.get("exec_step", self._step))
        self.ejected = set(int(p) for p in state.get("ejected", ()))
        if state.get("ewma") is not None:
            self._ewma = np.asarray(state["ewma"], dtype=np.float64)
            self._obs_counts = (
                np.asarray(state["obs_counts"], dtype=np.float64)
                if state.get("obs_counts") is not None
                else self.counts.astype(np.float64)
            )
        if state.get("factors") is not None:
            self._factors = np.asarray(state["factors"], dtype=np.float64)
        self._resplice()

    def maybe_rebalance(self, step: Optional[int] = None) -> Optional[Plan]:
        """Step-driver hook: rebalance every ``rebalance_every`` steps
        (``rebalance_every <= 0`` disables the schedule)."""
        step = self._step if step is None else step
        if self.rebalance_every <= 0 or self._ewma is None or step == 0:
            return None
        if step % self.rebalance_every:
            return None
        return self.rebalance()

    def advance(self, n_steps: int = 1) -> Optional[Plan]:
        """Advance the step counter by ``n_steps`` and rebalance if the
        schedule fires — the one protocol external step drivers use."""
        self._step += int(n_steps)
        return self.maybe_rebalance(self._step)

    def run_until_balanced(
        self,
        measure_fn: Optional[Callable[[], np.ndarray]] = None,
        rtol: float = 0.10,
        max_rounds: int = 8,
    ) -> int:
        """Measure -> rebalance until the predicted makespan is within
        ``rtol`` of the common-finish-time optimum; returns rounds used."""
        for r in range(1, max_rounds + 1):
            t = np.asarray(measure_fn() if measure_fn is not None else self.simulated_times())
            self.observe(t)
            self.rebalance()
            if self.predicted_makespan() <= (1.0 + rtol) * self.optimal_makespan():
                return r
        return max_rounds

    # -- step driver --------------------------------------------------------

    def drive(
        self,
        state,
        step_fn: Callable,
        n_steps: int,
        times_fn: Optional[Callable[["NestedPartitionExecutor", float], np.ndarray]] = None,
    ):
        """Run ``n_steps`` of ``step_fn(state) -> state``, observing wall time
        (or ``times_fn(self, dt)`` per-partition seconds) and rebalancing on
        schedule.  This is the API ``launch.train`` / ``launch.serve`` adopt."""
        for _ in range(n_steps):
            t0 = time.perf_counter()
            state = step_fn(state)
            dt = time.perf_counter() - t0
            if times_fn is not None:
                self.observe(np.asarray(times_fn(self, dt)))
            else:
                self.observe_total(dt)
            self.advance()
        return state


# ---------------------------------------------------------------------------
# Blocked DG engine — per-partition execution with halos
# ---------------------------------------------------------------------------


class BlockedDGEngine:
    """Executes a ``DGSolver`` rhs as per-partition element blocks with halo
    gathers — the executor's heterogeneous execution engine, a thin
    instantiation of the shared ``StepSchedule``.

    Per block, the four phases are: *boundary* packs the halo request (the
    index set that crosses the link), *exchange* gathers those remote
    elements, *interior* runs the volume kernel on the block's own elements
    (no halo dependence — the work that hides the transfer), and
    *correction* computes the face flux on the assembled block and folds it
    into the volume result.  ``calibrate`` times the phases separately
    (face-flux time is attributed to ``boundary_s`` — it is boundary-face
    work even though it executes inside the correction phase here).

    Each block's index tables are padded to ``bucket`` multiples, so after
    a resplice the per-block jit cache is hit whenever the padded sizes have
    been seen before; the full-field arrays never change shape.  The rhs is
    mathematically the flat solver's rhs restricted to each block (identical
    per-element arithmetic), so the partitioned run matches the flat run
    bitwise — the partition is a reordering, never an approximation.
    """

    def __init__(self, solver, executor: NestedPartitionExecutor,
                 only_blocks: Optional[Sequence[int]] = None):
        import jax

        if executor.grid_dims is None:
            raise ValueError("BlockedDGEngine needs a grid-backed executor")
        if tuple(executor.grid_dims) != tuple(solver.mesh.grid):
            raise ValueError(
                f"executor grid {executor.grid_dims} != solver grid {solver.mesh.grid}"
            )
        self.solver = solver
        self.executor = executor
        # chaos hook: a runtime.fault_tolerance.FailureInjector probed at
        # each observed chunk's dispatch (inside run_observed, before the
        # device program runs) — settable after construction
        self.injector = None
        # restrict this engine to a subset of partitions (a cluster node's
        # engine only ever executes its own block): other entries stay None,
        # so a resplice builds O(1) tables per engine instead of O(P)
        self.only_blocks = None if only_blocks is None else set(int(p) for p in only_blocks)
        self.pads_seen: set = set()
        self._blocks: list = []
        self._jax = jax
        self._build_jitted()
        self.schedule = self._make_schedule()
        # the partition's boundary/halo sets must reflect the SOLVER mesh's
        # topology (a periodic brick wraps; the default grid table does not)
        mesh_nbr = np.asarray(solver.mesh.neighbors, dtype=np.int64)
        current = executor.partition.neighbors if executor.partition is not None else executor.neighbors
        if current is None or not np.array_equal(current, mesh_nbr):
            executor.set_neighbors(mesh_nbr)
        else:
            executor.neighbors = mesh_nbr  # same table: no resplice needed
        self.rebuild()
        executor._resplice_hooks.append(self.rebuild)

    # -- jitted kernels (compiled once per padded block size) ---------------

    def _build_jitted(self):
        import jax
        import jax.numpy as jnp

        from repro.dg.operators import surface_rhs, volume_rhs_impl

        s = self.solver
        # one jitted bundle per solver, shared by every engine bound to it —
        # a SimulatedCluster's N engines would otherwise recompile the same
        # five kernels N times (jit caches live on the wrappers)
        bundle = getattr(s, "_blocked_jit_bundle", None)
        if bundle is None:
            D, metrics, lift = s.D, s.metrics, s.lift
            impl = s.kernel_impl  # Pallas volume AND flux kernels thread through

            def gather(q, idx):
                return q[idx]

            def assemble(q, own_idx, q_halo):
                # own gather is node-local; concatenated with the exchanged
                # halo this reproduces the extended block q[own ++ halo ++ pad]
                return jnp.concatenate([q[own_idx], q_halo], axis=0)

            def interior(q, own_idx, rho, lam, mu):
                return volume_rhs_impl(q[own_idx], D, metrics, rho, lam, mu,
                                       kernel_impl=impl)

            def boundary(qb, nbr_local, rho, lam, mu, cp, cs):
                return surface_rhs(qb, nbr_local, lift, rho, lam, mu, cp, cs,
                                   kernel_impl=impl)

            def fold(vol, sur):
                # rows past the block's own count are dump rows (scattered to
                # the sentinel); only the leading own rows must line up
                return vol + sur[: vol.shape[0]]

            bundle = tuple(jax.jit(f) for f in (gather, assemble, interior, boundary, fold))
            s._blocked_jit_bundle = bundle
        self._gather, self._assemble, self._interior, self._boundary, self._fold = bundle

    def _make_schedule(self) -> StepSchedule:
        """The block rhs as the shared four-phase schedule; ``state`` is
        ``(q, block)`` so one schedule (and one jit cache keyed on padded
        shapes) serves every block."""

        def boundary(state):
            _, b = state
            return b["halo"]  # the pack: which remote elements cross the link

        def exchange(send, state):
            q, _ = state
            return self._gather(q, send)

        def interior(state):
            q, b = state
            return self._interior(q, b["own_pad"], b["rho_o"], b["lam_o"], b["mu_o"])

        def correction(part, recv, state):
            q, b = state
            qb = self._assemble(q, b["own"], recv)
            sur = self._boundary(qb, b["nbr_local"], b["rho"], b["lam"],
                                 b["mu"], b["cp"], b["cs"])
            return self._fold(part, sur)

        return StepSchedule(boundary=boundary, exchange=exchange,
                            interior=interior, correction=correction, name="blocked-dg")

    # -- block tables -------------------------------------------------------

    def rebuild(self) -> None:
        """Re-splice: rebuild per-partition index tables from the executor's
        current ``NestedPartition`` (which carries each node's boundary/
        interior/halo index sets).  No kernel recompiles unless a brand-new
        padded size appears."""
        import jax.numpy as jnp

        s = self.solver
        part = self.executor.partition
        K = s.mesh.K
        nbr = s.mesh.neighbors
        bucket = self.executor.bucket
        dt = jnp.dtype(s.dtype)
        # the (K+1)-row scatter target (row K is the dump row for padded
        # block rows) is shape-invariant across resplices — hoisted here,
        # and shared per solver (a SimulatedCluster's N engines reuse one
        # buffer), so rhs() never allocates a fresh zeros per evaluation
        if getattr(s, "_scatter_base", None) is None:
            s._scatter_base = jnp.zeros((K + 1, 9, s.M, s.M, s.M), dt)
        self._scatter_base = s._scatter_base
        blocks = []
        for p, node in enumerate(part.nodes):
            own = np.asarray(node.elements, dtype=np.int64)
            if len(own) == 0 or (self.only_blocks is not None and p not in self.only_blocks):
                blocks.append(None)
                continue
            halo = np.asarray(node.halo, dtype=np.int64)
            ext = np.concatenate([own, halo])
            pad = pad_to_bucket(len(ext), bucket)
            pad_own = pad_to_bucket(len(own), bucket)
            self.pads_seen.update((pad, pad_own))
            ext_pad = np.concatenate([ext, np.zeros(pad - len(ext), dtype=np.int64)])
            own_pad = np.concatenate([own, np.zeros(pad_own - len(own), dtype=np.int64)])
            halo_pad = ext_pad[len(own):]  # halo ++ zero-pad: concat target
            lut = np.full(K, -1, dtype=np.int64)
            lut[ext] = np.arange(len(ext))
            nbr_ext = nbr[ext_pad]
            # own rows: every real neighbour is in ext by construction, so
            # lut resolves it; -1 (physical boundary) is preserved.  halo and
            # pad rows may point outside ext -> -1; their output is dumped.
            nbr_local = np.where(nbr_ext >= 0, lut[np.clip(nbr_ext, 0, None)], -1)
            scat = np.concatenate([own, np.full(pad_own - len(own), K, dtype=np.int64)])
            blocks.append(
                {
                    "own": jnp.asarray(own),
                    "own_pad": jnp.asarray(own_pad),
                    "halo": jnp.asarray(halo_pad),
                    "nbr_local": jnp.asarray(nbr_local),
                    "scat": jnp.asarray(scat),
                    "rho": jnp.asarray(s.rho[ext_pad], dt),
                    "lam": jnp.asarray(s.lam[ext_pad], dt),
                    "mu": jnp.asarray(s.mu[ext_pad], dt),
                    "cp": jnp.asarray(np.sqrt((s.lam + 2 * s.mu) / s.rho)[ext_pad], dt),
                    "cs": jnp.asarray(np.sqrt(s.mu / s.rho)[ext_pad], dt),
                    "rho_o": jnp.asarray(s.rho[own_pad], dt),
                    "lam_o": jnp.asarray(s.lam[own_pad], dt),
                    "mu_o": jnp.asarray(s.mu[own_pad], dt),
                    "n_own": len(own),
                }
            )
        self._blocks = blocks

    # -- execution ----------------------------------------------------------

    def block_rhs(self, q, b):
        """One partition's rhs rows via the four-phase schedule."""
        return self.schedule.rhs((q, b))

    def scatter_base(self, q):
        """The hoisted (K+1)-row scatter target (falls back to a fresh zeros
        only when the caller's field dtype/shape differs from the solver's)."""
        import jax.numpy as jnp

        base = self._scatter_base
        if base.dtype != q.dtype or base.shape[1:] != tuple(q.shape[1:]):
            K = self.solver.mesh.K
            base = jnp.zeros((K + 1,) + tuple(q.shape[1:]), q.dtype)
        return base

    def rhs(self, q):
        """Full rhs assembled from per-partition block evaluations.

        Composition is phase-major (``StepSchedule.rhs_many``): every halo
        gather is issued before any interior kernel, so an async backend
        overlaps all transfers with all interiors — the same issue order the
        fused pipeline compiles into one program."""
        K = self.solver.mesh.K
        blocks = [b for b in self._blocks if b is not None]
        outs = self.schedule.rhs_many([(q, b) for b in blocks])
        out = self.scatter_base(q)
        for b, r in zip(blocks, outs):
            out = out.at[b["scat"]].set(r)
        return out[:K]

    def pipeline(self, groups=None, layout: str = "envelope"):
        """The fused scan-compiled step pipeline bound to this engine
        (built lazily, invalidated and rebuilt across resplices).

        The default ``layout="envelope"`` pads every block to a common
        envelope so each rhs is exactly ONE volume + ONE surface kernel
        launch regardless of the bucket split; ``layout="grouped"`` keeps
        the per-bucket launch batching (the bitwise differential reference,
        and the layout under which ``groups`` separates launches).

        ``groups`` (optional partition -> bucket-group map) keeps blocks of
        different groups out of each other's batched launches under the
        grouped layout — how a ``SimulatedCluster`` fuses each same-profile
        node group separately; the envelope layout batches across groups by
        design (its in-scan pricing is launch-grouping independent).  One
        pipeline is cached per distinct (grouping, layout)."""
        key = (
            None if groups is None else tuple(int(g) for g in groups),
            str(layout),
        )
        cache = getattr(self, "_pipelines", None)
        if cache is None:
            cache = self._pipelines = {}
        if key not in cache:
            from repro.runtime.pipeline import FusedStepPipeline

            cache[key] = FusedStepPipeline(self, groups=groups, layout=layout)
        return cache[key]

    def resplice(self, plan) -> None:
        """Apply a solved plan: the executor installs the new counts and the
        resplice hooks rebuild this engine's block tables (jit caches are
        hit whenever the padded block sizes have been seen before)."""
        self.executor.apply(plan)

    def run(self, q, n_steps: int, dt: Optional[float] = None, observe: bool = False,
            fused: bool = True):
        """Step driver: LSRK4(5) on the blocked rhs.

        ``fused`` (default) drives the ``FusedStepPipeline``: the whole time
        loop — ``lax.scan`` over steps, scan over the five LSRK stages,
        same-bucket blocks batched into one kernel launch — runs as a single
        donated device program, so host dispatches drop from
        O(stages x blocks) to O(1) per run.  With ``observe`` the run is
        segmented on the executor's rebalance schedule and each chunk is
        ONE fused dispatch through ``FusedStepPipeline.run_observed``: the
        per-partition cost accumulator rides the scan carry, the host
        synchronizes once per chunk, and the wall-attributed
        ``CalibrationReport`` feeds ``executor.observe_chunk`` — so
        observation never un-fuses the hot path and q stays bitwise
        identical to the unobserved run (the priced and plain programs
        perform the same field arithmetic).  ``fused=False`` is the eager
        per-block reference path; with ``observe`` it wall-times each step
        (one sync per step) and attributes it by the current counts."""
        import jax
        import jax.numpy as jnp

        from repro.dg.rk import lsrk45_step
        from repro.runtime.schedule import CalibrationReport

        dt = dt or self.solver.cfl_dt()
        if fused and not observe:
            return self.pipeline().run(q, n_steps, dt=dt)
        if fused:
            done = 0
            while done < n_steps:
                chunk = n_steps - done
                if self.executor.rebalance_every > 0:
                    chunk = min(self.executor.rebalance_every, chunk)
                # after a resplice the pipeline rebuilds its tables; the
                # compiled program is reused while the bucket signature
                # (stable under bucketed counts) recurs
                q, report = self.pipeline().run_observed(
                    q, chunk, dt=dt,
                    injector=self.injector, step=self.executor._step,
                )
                self.executor.observe_chunk(report, chunk)
                done += chunk
            return q
        res = jnp.zeros_like(q)
        shares = np.maximum(self.executor.counts.astype(np.float64), 0.0)
        for _ in range(n_steps):
            if observe:
                t0 = time.perf_counter()
                q, res = lsrk45_step(q, res, self.rhs, dt)
                jax.block_until_ready(q)
                report = CalibrationReport.from_chunk(
                    time.perf_counter() - t0, shares, 1
                )
                self.executor.observe_chunk(report, 1)
                shares = np.maximum(self.executor.counts.astype(np.float64), 0.0)
            else:
                q, res = lsrk45_step(q, res, self.rhs, dt)
        return q

    # -- measurement --------------------------------------------------------

    def _time(self, fn, *args, reps: int = 1):
        """(median seconds, last result) — returning the result lets
        calibrate reuse each phase's output as the next phase's input
        instead of re-running kernels it already timed."""
        jax = self._jax
        out = fn(*args)
        jax.block_until_ready(out)  # warmup / compile
        ts = []
        for _ in range(reps):
            t0 = time.perf_counter()
            out = fn(*args)
            jax.block_until_ready(out)
            ts.append(time.perf_counter() - t0)
        ts.sort()
        return ts[len(ts) // 2], out

    def measure_block_times(self, q, reps: int = 1) -> np.ndarray:
        """Per-partition seconds for one rhs evaluation of each block
        (the full four-phase schedule, end to end)."""
        out = np.zeros(len(self._blocks))
        for p, b in enumerate(self._blocks):
            if b is None:
                continue
            out[p], _ = self._time(self.block_rhs, q, b, reps=reps)
        return out

    def calibrate(self, q, reps: int = 2, blocks: Optional[Sequence[int]] = None,
                  observe: Optional[bool] = None) -> CalibrationReport:
        """The executor's phase (1): time the four schedule phases per
        partition — boundary (face flux), interior (volume), transfer (halo
        gather) and correction (halo fold) — so the planner can run the
        overlap-aware solve (``NestedPartitionExecutor.plan_from_report``).

        ``blocks`` restricts the measurement to those partition indices (a
        cluster node calibrating only its own block); rows not measured stay
        zero.  ``observe`` defaults to full-fleet calibrations only: a
        partial report must NOT enter the executor's EWMA (the unmeasured
        partitions' 0.0s would read as infinitely fast and the equalizer
        would dump all work on them), so requesting observe=True together
        with a blocks subset is rejected — the caller (e.g.
        ``SimulatedCluster``) assembles a fleet report first and observes
        once."""
        if observe is None:
            observe = blocks is None
        elif observe and blocks is not None:
            raise ValueError(
                "cannot observe a partial calibration (blocks subset): "
                "unmeasured partitions would enter the EWMA as 0.0s"
            )
        P = len(self._blocks)
        boundary = np.zeros(P)
        interior = np.zeros(P)
        transfer = np.zeros(P)
        correction = np.zeros(P)
        picked = set(range(P)) if blocks is None else set(int(p) for p in blocks)
        for p, b in enumerate(self._blocks):
            if b is None or p not in picked:
                continue
            # each timed phase's output feeds the next phase, exactly like
            # the composed schedule — no kernel runs twice
            transfer[p], q_halo = self._time(self._gather, q, b["halo"], reps=reps)
            interior[p], vol = self._time(
                self._interior, q, b["own_pad"], b["rho_o"], b["lam_o"], b["mu_o"],
                reps=reps,
            )
            t_asm, qb = self._time(self._assemble, q, b["own"], q_halo, reps=reps)
            boundary[p], sur = self._time(
                self._boundary, qb, b["nbr_local"], b["rho"], b["lam"], b["mu"],
                b["cp"], b["cs"], reps=reps,
            )
            t_fold, _ = self._time(self._fold, vol, sur, reps=reps)
            # correction = everything the correction phase does besides the
            # face flux itself: assemble the block, fold the flux in
            correction[p] = t_asm + t_fold
        report = CalibrationReport(boundary_s=boundary, interior_s=interior,
                                   transfer_s=transfer, correction_s=correction)
        if observe:
            self.executor.observe(report.step_s)
        return report
