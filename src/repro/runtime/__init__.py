from repro.runtime.fault_tolerance import FailureInjector, StepTimer, TrainSupervisor
