"""Runtime package: execution engines, the online executor, serving.

``Engine`` is the one step-driver API every execution engine conforms to —
``DGSolver`` (flat reference), ``PartitionedDG`` (SPMD slabs),
``BlockedDGEngine`` (per-partition blocks) and ``SimulatedCluster``
(heterogeneous nodes) each grew their own ``run(...)`` spelling across
PRs 1–5; they now share this protocol (the last divergent spelling, the
``PartitionedDG.run(executor=)`` shim, expired and is gone).
"""

from typing import Any, Optional, Protocol, runtime_checkable

from repro.runtime.executor import (
    BlockedDGEngine,
    CalibrationReport,
    NestedPartitionExecutor,
    Plan,
    PlanCache,
    bucket_counts,
)
from repro.runtime.cluster import NodeProfile, SimulatedCluster, format_cluster_plan, stampede_profile
from repro.runtime.elastic import resume_engine, rescale_plan
from repro.runtime.fault_tolerance import (
    ChunkTimeout,
    FailureInjector,
    InjectedFailure,
    RunSupervisor,
    StepTimer,
    TrainSupervisor,
)
from repro.runtime.pipeline import FusedStepPipeline, ShardedStepPipeline
from repro.runtime.rounds import (
    RoundPlan,
    RoundWorker,
    plan_rounds,
    run_rounds,
    single_aggregator,
    workers_from_profiles,
    workers_from_report,
)
from repro.runtime.schedule import DispatchStats, StepSchedule
from repro.runtime.serving import (
    SLO,
    ContinuousBatchingLoop,
    ServeKernels,
    ServeRequest,
    ServeSummary,
    build_lm,
    calibrate_split,
    decode_batch,
    poisson_trace,
)


@runtime_checkable
class Engine(Protocol):
    """The unified step-driver API of the four execution engines.

    * ``run(q, n_steps, dt=None, *, observe=False, fused=True) -> q`` —
      advance the state.  ``fused`` drives the engine's single-dispatch
      compiled path (scan over steps); ``fused=False`` is the eager
      per-step reference.  ``observe`` feeds per-partition step seconds to
      the engine's executor so the calibrate→solve→resplice loop runs
      alongside the compute (engines without partition-resolved timing
      attribute the synchronous wall time; the flat solver ignores it).
    * ``calibrate(q, **kw) -> CalibrationReport`` — per-partition seconds
      for the schedule phases, the planner's input.
    * ``resplice(plan)`` — apply a solved :class:`Plan` (engines rebuild
      their index tables through the executor's resplice hooks; the flat
      solver treats it as a no-op).

    The protocol is structural (``isinstance`` checks methods exist);
    ``tests/test_serving.py`` runs the behavioural conformance suite.
    """

    def run(self, q: Any, n_steps: int, dt: Optional[float] = None, *,
            observe: bool = False, fused: bool = True) -> Any: ...

    def calibrate(self, q: Any, **kwargs) -> CalibrationReport: ...

    def resplice(self, plan: Optional[Plan]) -> None: ...


__all__ = [
    "Engine",
    "BlockedDGEngine",
    "CalibrationReport",
    "FusedStepPipeline",
    "ShardedStepPipeline",
    "DispatchStats",
    "StepSchedule",
    "NestedPartitionExecutor",
    "Plan",
    "PlanCache",
    "bucket_counts",
    "NodeProfile",
    "SimulatedCluster",
    "stampede_profile",
    "format_cluster_plan",
    "FailureInjector",
    "InjectedFailure",
    "ChunkTimeout",
    "RunSupervisor",
    "StepTimer",
    "TrainSupervisor",
    "resume_engine",
    "rescale_plan",
    "RoundPlan",
    "RoundWorker",
    "plan_rounds",
    "run_rounds",
    "single_aggregator",
    "workers_from_profiles",
    "workers_from_report",
    "SLO",
    "ContinuousBatchingLoop",
    "ServeKernels",
    "ServeRequest",
    "ServeSummary",
    "build_lm",
    "calibrate_split",
    "decode_batch",
    "poisson_trace",
]
