from repro.runtime.executor import (
    BlockedDGEngine,
    CalibrationReport,
    NestedPartitionExecutor,
    Plan,
    PlanCache,
    bucket_counts,
)
from repro.runtime.cluster import NodeProfile, SimulatedCluster, format_cluster_plan, stampede_profile
from repro.runtime.fault_tolerance import FailureInjector, StepTimer, TrainSupervisor
from repro.runtime.pipeline import FusedStepPipeline, ShardedStepPipeline
from repro.runtime.schedule import DispatchStats, StepSchedule

__all__ = [
    "BlockedDGEngine",
    "CalibrationReport",
    "FusedStepPipeline",
    "ShardedStepPipeline",
    "DispatchStats",
    "StepSchedule",
    "NestedPartitionExecutor",
    "Plan",
    "PlanCache",
    "bucket_counts",
    "NodeProfile",
    "SimulatedCluster",
    "stampede_profile",
    "format_cluster_plan",
    "FailureInjector",
    "StepTimer",
    "TrainSupervisor",
]
