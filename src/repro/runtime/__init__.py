from repro.runtime.executor import (
    BlockedDGEngine,
    CalibrationReport,
    NestedPartitionExecutor,
    Plan,
    PlanCache,
    bucket_counts,
)
from repro.runtime.fault_tolerance import FailureInjector, StepTimer, TrainSupervisor
from repro.runtime.schedule import StepSchedule

__all__ = [
    "BlockedDGEngine",
    "CalibrationReport",
    "StepSchedule",
    "NestedPartitionExecutor",
    "Plan",
    "PlanCache",
    "bucket_counts",
    "FailureInjector",
    "StepTimer",
    "TrainSupervisor",
]
