"""Continuous-batching serve loop on the nested-partition runtime.

``launch/serve.py`` used to be one-shot: splice a batch, decode, exit —
devices idle between batches and a late request waits for the next launch,
which is exactly the idling the paper's nested schedule exists to kill.
This module turns serving into a *loop* that keeps the fused-decode scan hot
under a stream of arrivals:

  * **One compiled decode program.**  The loop owns a fixed-capacity
    ``(B,)`` row pool; decode advances all rows together in chunks of
    ``chunk`` greedy steps, each chunk ONE ``lax.scan``-compiled, cache-
    donating dispatch (``ServeKernels.decode_chunk``).  Splice points only
    ever happen at chunk boundaries, and admission groups are padded to
    ``bucket`` multiples, so the jit signature set stays tiny and stable —
    the serving-side twin of the blocked engine's bucketed resplice.

  * **Continuous batching.**  Finished rows are freed at the next chunk
    boundary and refilled by splicing a newly admitted request's prefill
    cache over the dead row (``cache["len"]`` is a per-row vector, so rows
    at different sequence positions coexist in one batch).  Every batched
    decode op is row-independent, which makes a mid-loop splice produce the
    bitwise-identical token row the same request gets in a fresh one-shot
    batch — ``tests/test_serving.py`` asserts this exactly.

  * **Calibrated admission control.**  A calibration pass times prefill
    (boundary phase) and decode (interior phase) into the same
    ``CalibrationReport`` → ``plan_from_report`` path the DG engines use;
    the report's per-partition time models — scaled by the executor's
    straggler factors — price every scheduling decision.  The admissible
    row count is the largest ``m`` whose waterfilled (``solve_multiway``)
    makespan fits the chunk SLO budget.

  * **SLO accounting + load shedding.**  Each request carries
    arrival → admission → first-token → completion timestamps and deadline
    flags.  A request whose modeled time-to-first-token can no longer meet
    the SLO is shed; one whose completion no longer fits the latency budget
    is downgraded (its ``max_new`` trimmed) or shed if even the minimum
    would miss.

The loop runs on a wall clock or — default, and what CI uses — a
deterministic **virtual clock** priced entirely from the calibration
report, so SLO/shedding behaviour is reproducible and host-speed
independent.  The bitwise-splice guarantee assumes rows are computationally
independent, which holds for every dense arch in the zoo (capacity-dropping
MoE routing could in principle couple rows; serve smoke tests use dense
models).
"""

from __future__ import annotations

import dataclasses
import json
import time
from collections import deque
from functools import partial
from typing import Any, Callable, Dict, List, Optional, Sequence

import numpy as np

from repro.core.load_balance import solve_multiway
from repro.runtime.executor import NestedPartitionExecutor, pad_to_bucket
from repro.runtime.schedule import CalibrationReport, DispatchStats

__all__ = [
    "SLO",
    "ServeKernels",
    "ServeRequest",
    "ServeSummary",
    "ContinuousBatchingLoop",
    "build_lm",
    "calibrate_split",
    "decode_batch",
    "poisson_trace",
]


# ---------------------------------------------------------------------------
# Library extracted from the old launch/serve.py main() — the CLI is now
# argument parsing over these, and the loop + tests call them directly.
# ---------------------------------------------------------------------------


def build_lm(arch: str, *, smoke: bool = True, mesh: str = "single", seed: int = 0):
    """Resolve an arch (through the scenario registry), build + init the LM.

    Returns ``(cfg, lm, params, mesh)``.  Encoder-only archs are rejected —
    there is nothing to decode.
    """
    import jax

    from repro.configs.registry import resolve_arch
    from repro.configs.shapes import smoke_config
    from repro.launch.mesh import debug_mesh, make_production_mesh
    from repro.models.zoo import LM

    cfg = resolve_arch(arch)
    if cfg.is_encoder_only:
        raise ValueError(f"{cfg.arch_id} is encoder-only: no decode serving")
    if smoke:
        cfg = smoke_config(cfg)
        mesh_obj = debug_mesh()
    else:
        mesh_obj = make_production_mesh(multi_pod=(mesh == "multi"))
    ep = max(1, min(cfg.n_experts, mesh_obj.shape["data"])) if cfg.n_experts else 1
    lm = LM(cfg, ep_size=ep)
    params = lm.init(jax.random.PRNGKey(seed))
    return cfg, lm, params, mesh_obj


class ServeKernels:
    """The compiled serving programs for one ``(lm, mesh, max_len)``:

      * ``prefill_rows`` — jitted prefill + greedy first token;
      * ``decode_scan``  — the one-shot fused generation (n steps, ONE
        donated dispatch), as the old serve CLI compiled it;
      * ``decode_chunk`` — the masked continuous-batching variant the loop
        drives (inactive rows hold token + per-row cache position frozen);
      * ``splice_rows``  — overwrite freed rows with a freshly prefilled
        request's cache (one fused dispatch per admission group).

    ``max_len`` is the cache capacity every program is built against; the
    loop and the one-shot reference must share it for the bitwise-splice
    guarantee (cache capacity is part of the jit signature, not the math,
    but sharing it removes any doubt).
    """

    def __init__(self, lm, mesh, max_len: int):
        import jax
        import jax.numpy as jnp

        from repro.parallel.steps import make_serve_step, make_shardings

        self.lm = lm
        self.cfg = lm.cfg
        self.mesh = mesh
        self.max_len = int(max_len)
        self.stats = DispatchStats()  # fused decode dispatches (scan + chunk)
        self.warmed: set = set()

        sh = make_shardings(lm, mesh, kind="decode", batch_shardable=False)
        raw_step = make_serve_step(lm, sh)
        raw_masked = make_serve_step(lm, sh, masked=True)
        vocab = self.cfg.vocab_size

        self.serve_step = jax.jit(raw_step, donate_argnums=(1,))
        self._prefill = jax.jit(lambda p, b: lm.prefill(p, b, max_len=self.max_len))

        def first_token(logits):
            logits = jnp.where(jnp.arange(logits.shape[-1]) < vocab, logits, -jnp.inf)
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)

        self._first_token = jax.jit(first_token)

        @partial(jax.jit, static_argnums=(2,), donate_argnums=(1,))
        def decode_scan(p, carry, n):
            """n greedy steps as ONE program: lax.scan with the (cache, tok)
            carry donated.  The final cache is returned so every donated
            leaf aliases an output."""

            def body(carry, _):
                cache, tok = carry
                tok, cache = raw_step(p, cache, tok)
                return (cache, tok), tok

            (cache, tok), toks = jax.lax.scan(body, carry, None, length=n)
            return toks, tok, cache

        self.decode_scan = decode_scan

        @partial(jax.jit, static_argnums=(3,), donate_argnums=(1,))
        def decode_chunk(p, carry, active, n):
            """The loop's hot program: n masked greedy steps, one dispatch."""

            def body(carry, _):
                cache, tok = carry
                tok, cache = raw_masked(p, cache, tok, active)
                return (cache, tok), tok

            (cache, tok), toks = jax.lax.scan(body, carry, None, length=n)
            return toks, tok, cache

        self.decode_chunk = decode_chunk

        @partial(jax.jit, donate_argnums=(0, 1, 2))
        def splice_rows(cache, tok, active, new_cache, new_tok, idx):
            """Overwrite rows ``idx`` of the loop state with the freshly
            prefilled ``new_cache``/``new_tok``.  ``idx`` may repeat its
            last entry (bucket padding) — duplicate writes carry identical
            values, so the scatter is deterministic.  Segment cache leaves
            are layer-major ``(Lseg, B, ...)`` — batch is axis 1; ``len``
            is the per-row ``(B,)`` position vector."""
            new_len = jnp.broadcast_to(
                jnp.asarray(new_cache["len"], jnp.int32), idx.shape
            )
            out = {"len": cache["len"].at[idx].set(new_len)}
            for key in cache:
                if key == "len":
                    continue
                out[key] = jax.tree.map(
                    lambda a, b: a.at[:, idx].set(b.astype(a.dtype)),
                    cache[key],
                    new_cache[key],
                )
            tok = tok.at[idx].set(new_tok)
            active = active.at[idx].set(True)
            return out, tok, active

        self.splice_rows = splice_rows

        @partial(jax.jit, static_argnums=(3, 4))
        def gather_rows(cache, tok, active, start, size):
            """Slice a contiguous row shard [start, start+size) out of the
            pool state — the per-worker view a rounds-mode decode chunk
            advances.  Static bounds keep the jit signature set one entry
            per distinct shard width (the round plan is static)."""
            sub = {"len": cache["len"][start : start + size]}
            for key in cache:
                if key == "len":
                    continue
                sub[key] = jax.tree.map(
                    lambda a: a[:, start : start + size], cache[key]
                )
            return sub, tok[start : start + size], active[start : start + size]

        self.gather_rows = gather_rows

        @partial(jax.jit, static_argnums=(4,), donate_argnums=(0, 1))
        def scatter_rows(cache, tok, sub_cache, sub_tok, start):
            """Write a worker's advanced shard back over its pool rows
            (the donated inverse of ``gather_rows``)."""
            out = {
                "len": jax.lax.dynamic_update_slice_in_dim(
                    cache["len"], sub_cache["len"].astype(cache["len"].dtype), start, 0
                )
            }
            for key in cache:
                if key == "len":
                    continue
                out[key] = jax.tree.map(
                    lambda a, b: jax.lax.dynamic_update_slice_in_dim(
                        a, b.astype(a.dtype), start, 1
                    ),
                    cache[key],
                    sub_cache[key],
                )
            tok = jax.lax.dynamic_update_slice_in_dim(tok, sub_tok, start, 0)
            return out, tok

        self.scatter_rows = scatter_rows

    def prefill_rows(self, params, rows: np.ndarray):
        """Prefill a (b, S) int32 prompt block; returns (first_tok, cache)."""
        import jax.numpy as jnp

        logits, cache = self._prefill(params, {"tokens": jnp.asarray(rows)})
        return self._first_token(logits), cache

    def empty_state(self, params, capacity: int, prompt_len: int):
        """Zero loop state (cache, tok, active) for ``capacity`` rows,
        shaped via ``eval_shape`` (no throwaway prefill execution).  The
        per-row ``len`` vector starts at 0; rows are refilled by splice
        before they are ever read."""
        import jax
        import jax.numpy as jnp

        spec = jax.ShapeDtypeStruct((capacity, prompt_len), jnp.int32)
        _, cache_shape = jax.eval_shape(
            lambda p, b: self.lm.prefill(p, b, max_len=self.max_len),
            params,
            {"tokens": spec},
        )
        cache = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), cache_shape)
        cache["len"] = jnp.zeros((capacity,), jnp.int32)
        tok = jnp.zeros((capacity,), jnp.int32)
        active = jnp.zeros((capacity,), bool)
        return cache, tok, active


def decode_batch(
    kernels: ServeKernels,
    params,
    rows: np.ndarray,
    n_gen: int,
    *,
    fused: bool = True,
):
    """One-shot serve of a (b, S) prompt block: prefill + ``n_gen`` greedy
    tokens.  Returns ``(gen (b, n_gen) np.int32, prefill_s, decode_s)``.

    This is the old CLI's inner loop as a library function — and the
    reference the continuous-batching bitwise test compares against.
    """
    import jax

    # perf_counter, NOT time.time(): the wall clock is non-monotonic (NTP
    # slew / step adjustments), so time.time() deltas can go negative or
    # skew — and these two numbers price TTFT/latency downstream
    t0 = time.perf_counter()
    tok, cache = kernels.prefill_rows(params, rows)
    jax.block_until_ready(tok)
    t_prefill = time.perf_counter() - t0
    out = [np.asarray(tok)]
    t1 = time.perf_counter()
    if fused and n_gen > 1:
        toks, tok, _ = kernels.decode_scan(params, (cache, tok), n_gen - 1)
        jax.block_until_ready(toks)
        kernels.stats.record(1, n_gen - 1)
        out.extend(np.asarray(toks))
    else:
        for _ in range(n_gen - 1):
            tok, cache = kernels.serve_step(params, cache, tok)
            kernels.stats.record(1, 1)
            out.append(np.asarray(tok))
        jax.block_until_ready(tok)
    return np.stack(out, axis=1), t_prefill, time.perf_counter() - t1


def warm_batch(kernels: ServeKernels, params, rows: np.ndarray, n_gen: int, *, fused: bool = True):
    """Compile (and warm jit's dispatch cache for) one sub-batch shape.
    Fused scans bake the length into the program, so warming executes one
    throwaway generation per distinct (rows, n) shape — the timed pass
    stays compile-free."""
    key = (len(rows), n_gen if fused else 3, fused)
    if len(rows) and key not in kernels.warmed:
        decode_batch(kernels, params, rows, n_gen if fused else 3, fused=fused)
        kernels.warmed.add(key)


def calibrate_split(
    kernels: ServeKernels,
    params,
    prompts: np.ndarray,
    partitions: int,
    *,
    calib_gen: int = 4,
    executor: Optional[NestedPartitionExecutor] = None,
    fused: bool = True,
):
    """Calibration pass over ``partitions`` virtual partitions of a prompt
    batch: time each partition's prefill (boundary phase — per-request
    setup) and decode (interior phase), build the ``CalibrationReport``,
    and re-solve the row split through the executor's ``plan_from_report``
    — the same report→plan path the DG engines run online.

    Returns ``(executor, report)`` with the calibrated counts applied.
    """
    P = max(1, min(int(partitions), len(prompts)))
    if executor is None:
        executor = NestedPartitionExecutor(len(prompts), P, bucket=1, smoothing=1.0)
    n = max(2, int(calib_gen))
    offs = executor.offsets
    t_prefill = np.zeros(P)
    t_decode = np.zeros(P)
    for p in range(P):
        rows = prompts[offs[p] : offs[p + 1]]
        if len(rows) == 0:
            continue
        warm_batch(kernels, params, rows, n, fused=fused)
        _, tp, td = decode_batch(kernels, params, rows, n, fused=fused)
        t_prefill[p], t_decode[p] = tp, td
    report = CalibrationReport(
        boundary_s=t_prefill, interior_s=t_decode, transfer_s=np.zeros(P)
    )
    executor.observe(report.step_s)
    executor.plan_from_report(report)
    return executor, report


# ---------------------------------------------------------------------------
# Requests, SLOs, clocks
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ServeRequest:
    """One request and its full SLO ledger (all timestamps in loop seconds,
    wall or virtual depending on the clock the loop runs)."""

    rid: int
    prompt: np.ndarray  # (S,) int32
    max_new: int
    arrival_s: float = 0.0

    # -- lifecycle, filled in by the loop ----------------------------------
    state: str = "queued"  # queued | active | done | shed
    admitted_s: Optional[float] = None
    first_token_s: Optional[float] = None
    done_s: Optional[float] = None
    shed_s: Optional[float] = None
    max_new_eff: Optional[int] = None  # post-downgrade generation budget
    tokens: List[int] = dataclasses.field(default_factory=list)

    @property
    def ttft_s(self) -> Optional[float]:
        if self.first_token_s is None:
            return None
        return self.first_token_s - self.arrival_s

    @property
    def latency_s(self) -> Optional[float]:
        if self.done_s is None:
            return None
        return self.done_s - self.arrival_s

    @property
    def downgraded(self) -> bool:
        return self.max_new_eff is not None and self.max_new_eff < self.max_new

    def record(self, slo: "SLO") -> Dict[str, Any]:
        """JSON-able trace row, deadline flags evaluated against ``slo``."""
        ttft, lat = self.ttft_s, self.latency_s
        return {
            "rid": self.rid,
            "state": self.state,
            "arrival_s": self.arrival_s,
            "admitted_s": self.admitted_s,
            "first_token_s": self.first_token_s,
            "done_s": self.done_s,
            "shed_s": self.shed_s,
            "ttft_s": ttft,
            "latency_s": lat,
            "n_tokens": len(self.tokens),
            "max_new": self.max_new,
            "max_new_eff": self.max_new_eff,
            "downgraded": self.downgraded,
            "ttft_miss": bool(ttft is not None and ttft > slo.ttft_s),
            "deadline_miss": bool(
                lat is not None
                and np.isfinite(slo.latency_s)
                and lat > slo.latency_s
            ),
        }


@dataclasses.dataclass(frozen=True)
class SLO:
    """Service-level objectives the admission/shedding policy enforces.

    ``ttft_s``    — arrival→first-token budget; a request whose *modeled*
                    TTFT already exceeds it is shed at admission time.
    ``tok_s``     — per-decode-step budget; the admissible row count is the
                    largest m whose waterfilled chunk makespan fits
                    ``chunk * tok_s``.
    ``latency_s`` — arrival→completion budget (inf disables downgrades): a
                    request whose full generation no longer fits is trimmed
                    to what does.
    ``min_new``   — floor below which a downgrade becomes a shed.
    """

    ttft_s: float = 1.0
    tok_s: float = 0.05
    latency_s: float = float("inf")
    min_new: int = 1


class VirtualClock:
    """Deterministic loop clock priced from the calibration report: decode
    chunks and prefills advance it by their *modeled* seconds, so SLO and
    shedding behaviour is reproducible and host-speed independent."""

    def __init__(self):
        self._now = 0.0

    def now(self) -> float:
        return self._now

    def advance(self, dt: float) -> None:
        self._now += max(0.0, float(dt))

    def wait_until(self, t: float) -> None:
        self._now = max(self._now, float(t))


class WallClock:
    """Real time.  ``advance`` is a no-op (work itself consumes time);
    idle waits sleep until the next arrival."""

    def __init__(self):
        self._t0 = time.perf_counter()

    def now(self) -> float:
        return time.perf_counter() - self._t0

    def advance(self, dt: float) -> None:  # work already took the time
        pass

    def wait_until(self, t: float) -> None:
        dt = t - self.now()
        if dt > 0:
            time.sleep(dt)


def poisson_trace(
    n_requests: int,
    rate_rps: float,
    *,
    prompt_len: int,
    vocab: int,
    max_new: int,
    seed: int = 0,
) -> List[ServeRequest]:
    """Synthetic Poisson arrival trace.  A fixed seed draws one set of
    exponential gaps that the rate only rescales, so raising the offered
    load strictly compresses the same arrival pattern — which is what makes
    the shed-rate-vs-load curve monotone and testable."""
    g = np.random.default_rng(seed)
    gaps = g.exponential(1.0, n_requests) / float(rate_rps)
    arrivals = np.cumsum(gaps)
    prompts = g.integers(0, vocab, (n_requests, prompt_len), dtype=np.int32)
    return [
        ServeRequest(
            rid=i,
            prompt=prompts[i],
            max_new=int(max_new),
            arrival_s=float(arrivals[i]),
        )
        for i in range(n_requests)
    ]


# ---------------------------------------------------------------------------
# The loop
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ServeSummary:
    n_requests: int
    n_done: int
    n_shed: int
    n_downgraded: int
    shed_rate: float
    throughput_tok_s: float
    ttft_p50_s: float
    ttft_p99_s: float
    latency_p50_s: float
    ttft_miss_rate: float
    elapsed_s: float
    n_chunks: int
    dispatches_per_chunk: float
    total_tokens: int
    n_round_workers: int = 1  # rounds mode: decode workers per chunk

    def to_dict(self) -> Dict[str, Any]:
        # strict-JSON safe: a fully-shed trace has no percentile samples, and
        # json.dump would otherwise write its NaNs as the literal ``NaN``
        # (invalid JSON) — non-finite floats serialize as null instead
        return {
            k: (None if isinstance(v, float) and not np.isfinite(v) else v)
            for k, v in dataclasses.asdict(self).items()
        }


class ContinuousBatchingLoop:
    """Request-queue serving loop over a fixed row pool (see module doc).

    Parameters
    ----------
    kernels, params : the compiled serving programs and model weights.
    capacity        : row-pool size B (max concurrent requests).
    chunk           : decode steps per fused dispatch; splice points and
                      admissions happen only at chunk boundaries.
    partitions      : virtual partitions for calibration/pricing (the
                      admission solver waterfills rows over them).
    bucket          : admission groups are padded to multiples of this, so
                      prefill jit signatures stay a small fixed set.
    slo             : admission/shedding budgets; ``None`` derives a
                      generous default (3x the calibrated full-pool cost)
                      after calibration.
    report/executor : inject a pre-built calibration (tests do, for full
                      determinism); otherwise ``run`` calibrates on the
                      first ``capacity`` trace prompts.
    clock           : "virtual" (deterministic, report-priced — default)
                      or "wall".
    rounds          : optional heterogeneous decode workers
                      (``runtime.rounds.RoundWorker``, e.g. from a
                      ``SimulatedCluster``'s node profiles): the pool's rows
                      are statically sharded across them in proportion to
                      calibrated rates (the round plan's round-1
                      apportionment), every chunk runs ONE fused decode
                      dispatch per worker shard, and the workers' token
                      shards are re-aggregated through the plan's multi-round
                      merge tree — bitwise the single-aggregator rows, with
                      the virtual clock priced by the plan's modeled
                      makespan.  ``rounds_shrink`` is the per-round
                      worker-count divisor (1.6, the paper's echo).
    """

    def __init__(
        self,
        kernels: ServeKernels,
        params,
        *,
        capacity: int = 4,
        chunk: int = 8,
        partitions: int = 1,
        bucket: int = 1,
        calib_gen: int = 4,
        slo: Optional[SLO] = None,
        report: Optional[CalibrationReport] = None,
        executor: Optional[NestedPartitionExecutor] = None,
        clock: str = "virtual",
        injector=None,
        max_retries: int = 1,
        rounds: Optional[Sequence] = None,
        rounds_shrink: float = 1.6,
    ):
        self.kernels = kernels
        self.params = params
        self.capacity = int(capacity)
        self.chunk = max(1, int(chunk))
        self.partitions = max(1, min(int(partitions), self.capacity))
        self.bucket = max(1, int(bucket))
        self.calib_gen = max(2, int(calib_gen))
        self.slo = slo
        self.report = report
        self.executor = executor
        self.clock_kind = clock
        # chaos hook: a runtime.fault_tolerance.FailureInjector probed at
        # each decode chunk's dispatch boundary (keyed by chunk index);
        # transient failures are retried in place up to max_retries — the
        # chunk has not dispatched yet, so the retry is exact and the loop
        # stays one dispatch per chunk
        self.injector = injector
        self.max_retries = int(max_retries)
        self.chunk_retries = 0
        self.stats = DispatchStats()  # decode-chunk dispatches only
        self.n_chunks = 0
        self.aux_dispatches = 0  # prefill + splice dispatches (not the scan)
        # the most recent per-chunk observation fed to the executor (the
        # serving loop's arm of the continuous calibrate→solve→resplice)
        self.last_chunk_report: Optional[CalibrationReport] = None
        self.requests: List[ServeRequest] = []
        self._calib_counts: Optional[np.ndarray] = None
        self._calib_steps = 1

        # -- multi-round re-aggregation mode -------------------------------
        self.rounds_workers = list(rounds) if rounds else None
        self.rounds_shrink = float(rounds_shrink)
        self.rounds_plan = None
        self.n_round_workers = 1
        self._round_slices: List = []
        if self.rounds_workers:
            from repro.runtime.rounds import plan_rounds

            # static row shards: the plan's round-1 apportionment of the
            # pool across workers, contiguous in worker-rank order (so the
            # merged token shards reassemble in pool-row order)
            self.rounds_plan = plan_rounds(
                self.capacity, self.rounds_workers, shrink=self.rounds_shrink
            )
            offs = np.concatenate(
                [[0], np.cumsum(self.rounds_plan.rounds[0].counts)]
            ).astype(int)
            self._round_slices = [
                (int(offs[j]), int(offs[j + 1]))
                for j in range(self.rounds_plan.rounds[0].n_workers)
            ]
            self.n_round_workers = sum(1 for s, e in self._round_slices if e > s)

        if self.report is not None:
            # injected report: observe + plan exactly like the measured
            # path so pricing and counts line up
            if self.executor is None:
                self.executor = NestedPartitionExecutor(
                    self.capacity, self.partitions, bucket=1, smoothing=1.0
                )
            self._adopt_report(self.report)

    # -- calibration / pricing ---------------------------------------------

    def _adopt_report(self, report: CalibrationReport) -> None:
        self._calib_counts = np.maximum(self.executor.counts.astype(np.float64), 1.0)
        self._calib_steps = max(1, self.calib_gen - 1)
        self.executor.observe(report.step_s)
        self.executor.plan_from_report(report)
        self.report = report
        if self.slo is None:
            full_chunk = self.modeled_chunk_seconds(self.capacity)
            self.slo = SLO(
                tok_s=3.0 * full_chunk / self.chunk,
                ttft_s=3.0 * (self.modeled_prefill_seconds(self.capacity) + full_chunk),
            )

    def _ensure_calibrated(self, trace: Sequence[ServeRequest]) -> None:
        if self.report is not None and self._calib_counts is not None:
            return
        prompts = np.stack(
            [trace[i % len(trace)].prompt for i in range(self.capacity)]
        )
        self.executor = NestedPartitionExecutor(
            self.capacity, self.partitions, bucket=1, smoothing=1.0
        )
        self._calib_counts = np.maximum(self.executor.counts.astype(np.float64), 1.0)
        self._calib_steps = max(1, self.calib_gen - 1)
        offs = self.executor.offsets
        P = self.partitions
        t_prefill, t_decode = np.zeros(P), np.zeros(P)
        for p in range(P):
            rows = prompts[offs[p] : offs[p + 1]]
            if len(rows) == 0:
                continue
            warm_batch(self.kernels, self.params, rows, self.calib_gen)
            _, tp, td = decode_batch(self.kernels, self.params, rows, self.calib_gen)
            t_prefill[p], t_decode[p] = tp, td
        self._adopt_report(
            CalibrationReport(
                boundary_s=t_prefill, interior_s=t_decode, transfer_s=np.zeros(P)
            )
        )

    def _decode_models(self) -> List[Callable[[float], float]]:
        """Per-partition t_p(k): modeled seconds for ONE decode step of k
        rows, linear in the calibrated per-row rate, scaled by the
        executor's live straggler factors (so an injected straggler
        immediately reprices admission)."""
        interior = np.asarray(self.report.interior_s, dtype=np.float64)
        factors = self.executor.straggler_factors
        steps, counts = self._calib_steps, self._calib_counts
        return [
            lambda k, p=p: float(
                interior[p] / steps * (k / counts[p]) * factors[p]
            )
            for p in range(len(counts))
        ]

    def modeled_chunk_seconds(self, m: int) -> float:
        """Waterfilled makespan of one ``chunk`` with m admitted rows."""
        if m <= 0:
            return 0.0
        fns = self._decode_models()
        if len(fns) == 1:
            return fns[0](m) * self.chunk
        return solve_multiway(fns, int(m)).makespan * self.chunk

    def rounds_chunk_seconds(self, m: int) -> float:
        """Modeled makespan of one chunk under the multi-round plan: the
        calibrated per-row chunk price (at this occupancy) spread across the
        heterogeneous workers' relative rates, sized by the same equal-cost
        ``solve_rounds`` the plan uses — every re-aggregation round is on
        the clock, not just the parallel round 1."""
        from repro.core.load_balance import solve_rounds

        if m <= 0:
            return 0.0
        per_row = self.modeled_chunk_seconds(m) / m  # speed-1.0 reference
        fns = [
            (lambda k, r=w.rate: per_row * float(k) / r)
            for w in self.rounds_workers
        ]
        return solve_rounds(fns, int(m), shrink=self.rounds_shrink).makespan

    def modeled_prefill_seconds(self, nb: int) -> float:
        boundary = np.asarray(self.report.boundary_s, dtype=np.float64)
        factors = self.executor.straggler_factors
        per_row = float(np.mean(boundary / self._calib_counts * factors))
        return per_row * max(0, int(nb))

    def admissible_rows(self) -> int:
        """Largest m (≤ capacity) whose modeled chunk makespan fits the
        chunk SLO budget — floored at 1 so the loop always progresses."""
        budget = self.chunk * self.slo.tok_s
        m = self.capacity
        while m > 1 and self.modeled_chunk_seconds(m) > budget:
            m -= 1
        return m

    def service_rate_rps(self, max_new: int) -> float:
        """Modeled steady-state request throughput at a full pool — the
        reference point offered-load sweeps are expressed against."""
        per_req = (
            self.modeled_prefill_seconds(self.capacity) / self.capacity
            + max_new * self.modeled_chunk_seconds(self.capacity) / self.chunk / self.capacity
        )
        return 1.0 / max(per_req, 1e-12)

    # -- the loop ----------------------------------------------------------

    def run(self, trace: Sequence[ServeRequest], max_iters: int = 100_000) -> ServeSummary:
        import jax
        import jax.numpy as jnp

        trace = sorted(trace, key=lambda r: (r.arrival_s, r.rid))
        self.requests = list(trace)
        if not trace:
            return self._summarize(0.0)
        S = len(trace[0].prompt)
        if any(len(r.prompt) != S for r in trace):
            raise ValueError("continuous batching expects equal prompt lengths")
        if max(r.max_new for r in trace) + S > self.kernels.max_len:
            raise ValueError(
                f"max_len={self.kernels.max_len} < prompt_len+max_new; "
                "rows would overflow their cache slots"
            )
        self._ensure_calibrated(trace)
        clock = VirtualClock() if self.clock_kind == "virtual" else WallClock()

        cache, tok, active = self.kernels.empty_state(self.params, self.capacity, S)
        rows: List[Optional[ServeRequest]] = [None] * self.capacity
        pending: deque = deque()
        upcoming = deque(trace)
        total_tokens = 0

        for _ in range(max_iters):
            now = clock.now()
            while upcoming and upcoming[0].arrival_s <= now:
                pending.append(upcoming.popleft())

            n_active = sum(r is not None for r in rows)
            if n_active == 0 and not pending:
                if not upcoming:
                    break
                clock.wait_until(upcoming[0].arrival_s)
                continue

            # ---- admission: shed the hopeless, admit what fits ----------
            m_star = self.admissible_rows()
            free = [j for j in range(self.capacity) if rows[j] is None]
            room = max(0, m_star - n_active)
            if n_active == 0 and room == 0:
                room = 1  # progress floor: an empty pool always serves
            admit: List[ServeRequest] = []
            still: deque = deque()
            while pending:
                req = pending.popleft()
                wait = now - req.arrival_s
                nb_next = pad_to_bucket(len(admit) + 1, self.bucket)
                pred_ttft = wait + self.modeled_prefill_seconds(nb_next)
                if pred_ttft > self.slo.ttft_s:
                    req.state = "shed"
                    req.shed_s = now
                    continue
                if len(admit) >= min(len(free), room):
                    still.append(req)
                    continue
                # downgrade: trim the generation to what the latency
                # budget still fits at the modeled per-step rate
                req.max_new_eff = req.max_new
                if np.isfinite(self.slo.latency_s):
                    per_step = self.modeled_chunk_seconds(
                        min(self.capacity, n_active + len(admit) + 1)
                    ) / self.chunk
                    left = (req.arrival_s + self.slo.latency_s) - (now + pred_ttft - wait)
                    fit = 1 + int(max(0.0, left) / max(per_step, 1e-12))
                    if fit < self.slo.min_new:
                        req.state = "shed"
                        req.shed_s = now
                        continue
                    req.max_new_eff = min(req.max_new, fit)
                admit.append(req)
            pending = still

            # ---- prefill + splice the admitted group --------------------
            if admit:
                nb = len(admit)
                pb = pad_to_bucket(nb, self.bucket)
                block = np.stack(
                    [admit[min(i, nb - 1)].prompt for i in range(pb)]
                )
                slots = [free[min(i, nb - 1)] for i in range(pb)]
                tok_new, cache_new = self.kernels.prefill_rows(self.params, block)
                self.aux_dispatches += 2  # prefill + splice
                clock.advance(self.modeled_prefill_seconds(pb))
                jax.block_until_ready(tok_new)
                t_first = clock.now()
                cache, tok, active = self.kernels.splice_rows(
                    cache, tok, active, cache_new, tok_new,
                    jnp.asarray(slots, jnp.int32),
                )
                tok_np = np.asarray(tok[jnp.asarray(slots[:nb], jnp.int32)])
                for i, req in enumerate(admit):
                    req.state = "active"
                    req.admitted_s = now
                    req.first_token_s = t_first
                    req.tokens = [int(tok_np[i])]
                    total_tokens += 1
                    rows[free[i]] = req
                    if req.max_new_eff is None:
                        req.max_new_eff = req.max_new
                    if len(req.tokens) >= req.max_new_eff:
                        req.state = "done"
                        req.done_s = t_first
                        rows[free[i]] = None
                        active = active.at[free[i]].set(False)

            # ---- one fused decode chunk ---------------------------------
            if any(r is not None for r in rows):
                n_live = sum(r is not None for r in rows)
                if self.injector is not None:
                    attempts = 0
                    while True:
                        try:
                            self.injector.maybe_fail(self.n_chunks)
                            break
                        except Exception:  # noqa: BLE001 — transient chunk fault
                            attempts += 1
                            self.chunk_retries += 1
                            if attempts > self.max_retries:
                                raise
                t0_chunk = time.perf_counter()
                if self.rounds_plan is not None:
                    # multi-round re-aggregation: ONE fused decode dispatch
                    # per worker shard (every op is row-independent, so each
                    # shard's rows are bitwise the full-pool rows), then the
                    # workers' token shards merge through the plan's
                    # shrinking round tree — associative column concat,
                    # bitwise the single-aggregator fold
                    from repro.runtime.rounds import run_rounds

                    shards, advanced = [], []
                    for s, e in self._round_slices:
                        if e <= s:  # worker apportioned zero pool rows
                            shards.append(np.zeros((self.chunk, 0), np.int32))
                            continue
                        sub_cache, sub_tok, sub_active = self.kernels.gather_rows(
                            cache, tok, active, s, e - s
                        )
                        toks_w, tok_w, cache_w = self.kernels.decode_chunk(
                            self.params, (sub_cache, sub_tok), sub_active, self.chunk
                        )
                        self.stats.record(1, self.chunk)
                        self.kernels.stats.record(1, self.chunk)
                        shards.append(toks_w)
                        advanced.append((s, cache_w, tok_w))
                    for s, cache_w, tok_w in advanced:
                        cache, tok = self.kernels.scatter_rows(
                            cache, tok, cache_w, tok_w, s
                        )
                    self.n_chunks += 1
                    jax.block_until_ready(tok)
                    shards = [np.asarray(t) for t in shards]
                    toks = run_rounds(
                        self.rounds_plan,
                        shards,
                        lambda a, b: np.concatenate([a, b], axis=1),
                    )
                    wall_chunk = time.perf_counter() - t0_chunk
                    modeled_chunk = self.rounds_chunk_seconds(n_live)
                else:
                    toks, tok, cache = self.kernels.decode_chunk(
                        self.params, (cache, tok), active, self.chunk
                    )
                    self.stats.record(1, self.chunk)
                    self.kernels.stats.record(1, self.chunk)
                    self.n_chunks += 1
                    jax.block_until_ready(toks)
                    wall_chunk = time.perf_counter() - t0_chunk
                    modeled_chunk = self.modeled_chunk_seconds(n_live)
                clock.advance(modeled_chunk)
                t_end = clock.now()
                # continuous in-loop observation: each decode chunk's
                # seconds (measured wall under the wall clock, modeled —
                # hence deterministic — under the virtual clock) are
                # attributed across the calibration partitions by their
                # current row shares and fed to the executor, so the
                # calibrate→solve→resplice loop keeps running at chunk
                # granularity under serving load with zero extra
                # dispatches (straggler factors enter once, in observe)
                chunk_s = (
                    modeled_chunk if self.clock_kind == "virtual" else wall_chunk
                )
                shares = np.maximum(
                    self.executor.counts.astype(np.float64), 0.0
                )
                self.last_chunk_report = CalibrationReport.from_chunk(
                    chunk_s, shares, self.chunk
                )
                self.executor.observe_chunk(self.last_chunk_report, self.chunk)
                self.stats.record_chunk()
                toks_np = np.asarray(toks)  # (chunk, B)
                dead = []
                for j, req in enumerate(rows):
                    if req is None:
                        continue
                    need = req.max_new_eff - len(req.tokens)
                    take = min(need, self.chunk)
                    req.tokens.extend(int(t) for t in toks_np[:take, j])
                    total_tokens += take
                    if len(req.tokens) >= req.max_new_eff:
                        req.state = "done"
                        req.done_s = t_end
                        rows[j] = None
                        dead.append(j)
                if dead:
                    active = active.at[jnp.asarray(dead, jnp.int32)].set(False)
            elif not pending and not upcoming:
                break
        else:
            raise RuntimeError(f"serving loop did not drain in {max_iters} iterations")

        return self._summarize(clock.now(), total_tokens)

    # -- reporting ---------------------------------------------------------

    def _summarize(self, elapsed: float, total_tokens: int = 0) -> ServeSummary:
        reqs = self.requests
        done = [r for r in reqs if r.state == "done"]
        shed = [r for r in reqs if r.state == "shed"]
        ttfts = sorted(r.ttft_s for r in done if r.ttft_s is not None)
        lats = sorted(r.latency_s for r in done if r.latency_s is not None)
        pct = lambda xs, q: float(np.percentile(xs, q)) if xs else float("nan")
        slo = self.slo or SLO()
        return ServeSummary(
            n_requests=len(reqs),
            n_done=len(done),
            n_shed=len(shed),
            n_downgraded=sum(1 for r in reqs if r.downgraded),
            shed_rate=len(shed) / max(1, len(reqs)),
            throughput_tok_s=total_tokens / max(elapsed, 1e-12),
            ttft_p50_s=pct(ttfts, 50),
            ttft_p99_s=pct(ttfts, 99),
            latency_p50_s=pct(lats, 50),
            ttft_miss_rate=(
                sum(1 for r in done if r.ttft_s is not None and r.ttft_s > slo.ttft_s)
                / max(1, len(done))
            ),
            elapsed_s=elapsed,
            n_chunks=self.n_chunks,
            dispatches_per_chunk=self.stats.dispatches / max(1, self.n_chunks),
            total_tokens=total_tokens,
            n_round_workers=self.n_round_workers,
        )

    def trace_records(self) -> List[Dict[str, Any]]:
        slo = self.slo or SLO()
        return [r.record(slo) for r in self.requests]

    def write_trace(self, path: str) -> None:
        # allow_nan=False gates the strict-JSON guarantee: a non-finite
        # float reaching a writer is a bug, not a serialization choice
        with open(path, "w") as f:
            json.dump(self.trace_records(), f, indent=1, allow_nan=False)
