"""Elastic rescale: resume training on a different device count.

The pieces are already in place — checkpoints store full logical arrays per
shard index (checkpoint/), shardings are recomputed from logical axis rules
for whatever mesh exists (parallel/steps.py), and the deterministic pipeline
replays batches exactly.  ``rescale_plan`` packages them: given a checkpoint
and a new mesh, it returns re-sharded (params, opt_state) plus the step to
resume from.  Tested end-to-end in tests/test_elastic.py: a run trained on
a (2,2) mesh continues on (4,) and on a single device with a loss trajectory
equal to an uninterrupted run.
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax

from repro.checkpoint import restore
from repro.models.zoo import LM
from repro.parallel.steps import StepShardings, make_shardings


def rescale_plan(
    ckpt_dir: str,
    lm: LM,
    new_mesh,
    *,
    kind: str = "train",
    accum: bool = True,
    batch_shardable: bool = True,
) -> Tuple[Any, Any, int, StepShardings]:
    """Load the latest checkpoint and place it on ``new_mesh``.

    Returns (params, opt_state, step, shardings) ready for a jit step built
    against the new mesh.
    """
    sh = make_shardings(lm, new_mesh, kind=kind, accum=accum, batch_shardable=batch_shardable)
    params_t = jax.eval_shape(lm.init, jax.random.PRNGKey(0))
    import repro.optim as optim

    opt_t = jax.eval_shape(optim.init_opt_state, params_t)
    (params, opt_state), manifest = restore(
        ckpt_dir, (params_t, opt_t), shardings=(sh.params, sh.opt)
    )
    return params, opt_state, manifest["step"], sh
