"""Elastic rescale: resume a run on a different device count / node fleet.

Two resume paths share the ``repro.checkpoint`` substrate:

* ``rescale_plan`` — the LM-training path.  Checkpoints store full logical
  arrays per shard index (checkpoint/), shardings are recomputed from
  logical axis rules for whatever mesh exists (parallel/steps.py), and the
  deterministic pipeline replays batches exactly: given a checkpoint and a
  new mesh it returns re-sharded (params, opt_state) plus the step to
  resume from.  Tested end-to-end in tests/test_elastic.py: a run trained
  on a (2,2) mesh continues on (4,) and on a single device with a loss
  trajectory equal to an uninterrupted run.

* ``resume_engine`` — the DG-engine twin.  A ``RunSupervisor`` snapshot is
  ``(q, step, plan)``; the field update is split-independent (a nested
  partition is a reordering, never an approximation), so the resuming
  engine may carry a DIFFERENT partition count or node fleet than the one
  that saved — the mesh-rescale property lifted from the train loop to the
  fused engines.  The plan metadata rides along for fleets whose partition
  count still matches (``NestedPartitionExecutor.restore_state``).
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax

from repro.checkpoint import restore
from repro.models.zoo import LM
from repro.parallel.steps import StepShardings, make_shardings


def resume_engine(ckpt_dir: str, executor=None) -> Tuple[Any, int, Dict[str, Any]]:
    """Load the latest ``RunSupervisor`` snapshot: ``(q, step, plan_meta)``.

    ``q`` is partition-layout independent, so the engine resuming it may
    have a different node count than the saver (a shrunk or grown fleet).
    Pass the resuming engine's ``executor`` to also reinstall the plan
    state when the partition counts line up (a same-shape restart resumes
    the calibrated split); on a count mismatch only ``q`` is restored and
    the new fleet keeps its own seed splice.
    """
    import jax.numpy as jnp

    tree, manifest = restore(ckpt_dir, {"q": 0})
    meta = manifest.get("extra", {})
    if executor is not None and len(meta.get("counts", [])) == executor.n_partitions:
        executor.restore_state(meta)
    return jnp.asarray(tree["q"]), int(manifest["step"]), meta


def rescale_plan(
    ckpt_dir: str,
    lm: LM,
    new_mesh,
    *,
    kind: str = "train",
    accum: bool = True,
    batch_shardable: bool = True,
) -> Tuple[Any, Any, int, StepShardings]:
    """Load the latest checkpoint and place it on ``new_mesh``.

    Returns (params, opt_state, step, shardings) ready for a jit step built
    against the new mesh.
    """
    sh = make_shardings(lm, new_mesh, kind=kind, accum=accum, batch_shardable=batch_shardable)
    params_t = jax.eval_shape(lm.init, jax.random.PRNGKey(0))
    import repro.optim as optim

    opt_t = jax.eval_shape(optim.init_opt_state, params_t)
    (params, opt_state), manifest = restore(
        ckpt_dir, (params_t, opt_t), shardings=(sh.params, sh.opt)
    )
    return params, opt_state, manifest["step"], sh
