"""Multi-round re-aggregation across heterogeneous workers (SNIPPETS §1).

partiscontainer's parallel scheduler splits the work set over every worker
in round 1, then *merges* the partial results and reapportions them among a
smaller set of workers — about ``1/1.6x`` as many each round — until a
single final aggregator holds everything.  Because later rounds mostly
re-merge results earlier rounds already compared, each round can be sized
to cost about the same wall time; the 1.6 shrink is an uncanny echo of the
paper's K_MIC/K_CPU = 1.6 intra-node optimum.

This module is the deterministic planning + merge-execution side of that
shape on top of ``core.load_balance.solve_rounds``:

* ``RoundWorker`` — one worker with a calibrated throughput (items/s),
  built from ``NodeProfile`` speeds (``workers_from_profiles``) or from a
  measured ``CalibrationReport`` (``workers_from_report``);
* ``plan_rounds`` — emits a ``RoundPlan``: per-round worker subsets,
  per-worker counts proportional to calibrated rates (equal modeled finish
  time within a round, equal modeled cost across rounds), plus the
  single-round-aggregation baseline it is benchmarked against;
* ``run_rounds`` / ``single_aggregator`` — execute the merge tree over
  actual per-worker partial results.  The merge callable must be
  associative (disjoint row/key unions, concatenations): then the
  multi-round tree is *bitwise* identical to one worker folding every
  shard, which is what lets the serving loop re-aggregate decode batches
  through a plan without perturbing a single token.

The plan serializes to JSON (``to_json``/``from_json``) and enumerates
per-(round, worker) jobs with cross-round dependencies (``job_specs``) —
the unit ``launch/submit.py`` materializes as slurm/sge scripts.  The
module is also a tiny CLI: ``python -m repro.runtime.rounds --items 4096
--speeds 4,2,1,1`` prints a plan (optionally ``--plan-out plan.json``),
and ``--plan plan.json --worker-step R:J`` prints one job's assignment —
the payload the generated batch scripts run.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Any, Callable, Dict, List, Optional, Sequence

import numpy as np

from repro.core.load_balance import RoundSpec, RoundsResult, solve_rounds

__all__ = [
    "RoundWorker",
    "RoundPlan",
    "plan_rounds",
    "workers_from_profiles",
    "workers_from_report",
    "run_rounds",
    "single_aggregator",
]


@dataclasses.dataclass(frozen=True)
class RoundWorker:
    """One heterogeneous worker: a name and a calibrated rate (items/s)."""

    name: str
    rate: float

    def __post_init__(self):
        if not (self.rate > 0):
            raise ValueError(f"worker rate must be positive, got {self.rate}")


def workers_from_profiles(profiles: Sequence, unit_rate: float = 1.0) -> List[RoundWorker]:
    """Workers from ``runtime.cluster.NodeProfile``s: rate = speed x
    ``unit_rate`` (items/s at speed 1.0) — the simulated-cluster knob reused
    as a round-scheduling throughput."""
    return [
        RoundWorker(name=f"{p.name}{i}" if p.name == "node" else p.name,
                    rate=float(p.speed) * float(unit_rate))
        for i, p in enumerate(profiles)
    ]


def workers_from_report(report, counts: Sequence[int],
                        names: Optional[Sequence[str]] = None) -> List[RoundWorker]:
    """Workers from a measured ``CalibrationReport``: each partition's rate
    is its calibrated items/s (count / step seconds) — sizing rounds by
    measured per-class throughput rather than worker count."""
    step_s = np.asarray(report.step_s, dtype=np.float64)
    counts = np.asarray(counts, dtype=np.float64)
    if len(step_s) != len(counts):
        raise ValueError(f"{len(counts)} counts for {len(step_s)} partitions")
    alive = step_s > 0
    rates = np.where(alive, np.maximum(counts, 1.0) / np.where(alive, step_s, 1.0), 0.0)
    if not alive.all():  # unmeasured partition: fleet-mean prior
        rates = np.where(alive, rates, rates[alive].mean() if alive.any() else 1.0)
    return [
        RoundWorker(name=names[p] if names else f"p{p}", rate=float(rates[p]))
        for p in range(len(rates))
    ]


@dataclasses.dataclass(frozen=True)
class RoundPlan:
    """A deterministic multi-round re-aggregation schedule (see module doc).

    ``rounds[0]`` apportions all ``n_items`` across every worker in
    proportion to calibrated rates; each later round re-aggregates the
    merged results over the fastest ``~1/shrink`` of the previous fleet at
    the cost discount that equalizes its makespan with round 1's.
    ``single_round_makespan`` is the naive baseline: round 1 plus ONE
    aggregator folding every shard at full first-pass cost.
    """

    workers: tuple  # RoundWorker, caller's order
    n_items: int
    shrink: float
    rounds: tuple  # core.load_balance.RoundSpec, round 1 first
    single_round_makespan: float

    # -- shape ---------------------------------------------------------------

    @property
    def n_rounds(self) -> int:
        return len(self.rounds)

    @property
    def worker_counts(self) -> tuple:
        return tuple(r.n_workers for r in self.rounds)

    @property
    def round_makespans(self) -> tuple:
        return tuple(r.makespan for r in self.rounds)

    @property
    def makespan(self) -> float:
        return float(sum(r.makespan for r in self.rounds))

    @property
    def speedup_vs_single_round(self) -> float:
        return self.single_round_makespan / self.makespan if self.makespan > 0 else 1.0

    def counts_by_worker(self, r: int = 0) -> np.ndarray:
        """Round ``r`` item counts indexed by the caller's worker order
        (non-participants 0) — round 0's is the work apportionment."""
        out = np.zeros(len(self.workers), dtype=np.int64)
        spec = self.rounds[r]
        for w, c in zip(spec.workers, spec.counts):
            out[w] = int(c)
        return out

    # -- merge topology ------------------------------------------------------

    def merge_groups(self, r: int) -> List[List[int]]:
        """Which round-``r-1`` output slots each round-``r`` worker merges.

        Slots are assigned contiguously (preserving worker-rank order, so an
        associative merge reduces in a fixed global order) and proportionally
        to the round's counts, with every worker guaranteed at least one
        slot — the fleet only ever shrinks, so there are always enough.
        """
        if r <= 0 or r >= self.n_rounds:
            raise ValueError(f"merge round must be in [1, {self.n_rounds - 1}], got {r}")
        n_prev = self.rounds[r - 1].n_workers
        counts = np.asarray(self.rounds[r].counts, dtype=np.float64)
        total = counts.sum()
        shares = counts / total if total > 0 else np.full(len(counts), 1.0 / len(counts))
        bounds = np.round(np.cumsum(shares) * n_prev).astype(int)
        bounds[-1] = n_prev
        # strictly increasing: every merger gets >= 1 source
        for j in range(len(bounds)):
            lo = (bounds[j - 1] if j > 0 else 0) + 1
            hi = n_prev - (len(bounds) - 1 - j)
            bounds[j] = min(max(bounds[j], lo), hi)
        groups, lo = [], 0
        for b in bounds:
            groups.append(list(range(lo, b)))
            lo = b
        return groups

    # -- batch-system jobs ---------------------------------------------------

    def job_specs(self) -> List[Dict[str, Any]]:
        """One job per (round, worker slot), with cross-round dependencies:
        a merge job depends on exactly the previous-round jobs whose outputs
        it folds.  ``name`` is unique and batch-system safe — the unit
        ``launch/submit.py`` renders as a script."""
        jobs: List[Dict[str, Any]] = []
        for r, spec in enumerate(self.rounds):
            groups = self.merge_groups(r) if r > 0 else [[] for _ in spec.workers]
            for j, w in enumerate(spec.workers):
                jobs.append({
                    "name": f"round{r}_worker{j}",
                    "round": r,
                    "slot": j,
                    "worker": self.workers[w].name,
                    "rate": self.workers[w].rate,
                    "count": int(spec.counts[j]),
                    "modeled_s": float(spec.times[j]),
                    "depends": [f"round{r - 1}_worker{s}" for s in groups[j]],
                })
        return jobs

    # -- (de)serialization ---------------------------------------------------

    def to_json(self) -> Dict[str, Any]:
        return {
            "version": 1,
            "n_items": int(self.n_items),
            "shrink": float(self.shrink),
            "workers": [{"name": w.name, "rate": float(w.rate)} for w in self.workers],
            "rounds": [
                {
                    "workers": list(r.workers),
                    "counts": [int(c) for c in r.counts],
                    "times": [float(t) for t in r.times],
                    "discount": float(r.discount),
                }
                for r in self.rounds
            ],
            "single_round_makespan": float(self.single_round_makespan),
        }

    @staticmethod
    def from_json(doc: Dict[str, Any]) -> "RoundPlan":
        return RoundPlan(
            workers=tuple(RoundWorker(w["name"], float(w["rate"])) for w in doc["workers"]),
            n_items=int(doc["n_items"]),
            shrink=float(doc["shrink"]),
            rounds=tuple(
                RoundSpec(
                    workers=tuple(int(w) for w in r["workers"]),
                    counts=tuple(int(c) for c in r["counts"]),
                    times=tuple(float(t) for t in r["times"]),
                    discount=float(r["discount"]),
                )
                for r in doc["rounds"]
            ),
            single_round_makespan=float(doc["single_round_makespan"]),
        )

    def summary(self) -> str:
        lines = [
            f"{self.n_items} items over {len(self.workers)} workers, "
            f"shrink x{self.shrink:g}: {self.n_rounds} rounds, "
            f"makespan {self.makespan:.4g}s "
            f"(single-round {self.single_round_makespan:.4g}s, "
            f"x{self.speedup_vs_single_round:.2f})"
        ]
        for r, spec in enumerate(self.rounds):
            who = ", ".join(
                f"{self.workers[w].name}={c}" for w, c in zip(spec.workers, spec.counts)
            )
            lines.append(
                f"  round {r}: {spec.n_workers} workers, "
                f"discount {spec.discount:.3f}, "
                f"makespan {spec.makespan:.4g}s [{who}]"
            )
        return "\n".join(lines)


def plan_rounds(n_items: int, workers: Sequence[RoundWorker],
                shrink: float = 1.6) -> RoundPlan:
    """Emit the deterministic ``RoundPlan`` for ``n_items`` across
    ``workers`` (see module doc).  Linear rate models ``t_w(k) = k/rate_w``
    feed the same waterfilling ``solve_rounds``/``solve_multiway`` path the
    DG planners use; callers with richer roofline models can run
    ``solve_rounds`` directly."""
    workers = list(workers)
    if not workers:
        raise ValueError("need at least one worker")
    n_items = int(n_items)
    if n_items <= 0:
        raise ValueError(f"need a positive work set, got {n_items}")
    fns: List[Callable[[float], float]] = [
        (lambda k, r=w.rate: float(k) / r) for w in workers
    ]
    result: RoundsResult = solve_rounds(fns, n_items, shrink=shrink)
    # naive baseline: the same round 1, then ONE aggregator folds all
    # n_items merged results at full first-pass cost (no cached rounds)
    best = max(w.rate for w in workers)
    single = result.rounds[0].makespan + n_items / best
    return RoundPlan(
        workers=tuple(workers),
        n_items=n_items,
        shrink=float(shrink),
        rounds=result.rounds,
        single_round_makespan=float(single),
    )


# ---------------------------------------------------------------------------
# merge execution
# ---------------------------------------------------------------------------


def _fold(merge: Callable[[Any, Any], Any], parts: Sequence[Any]):
    acc = parts[0]
    for p in parts[1:]:
        acc = merge(acc, p)
    return acc


def run_rounds(plan: RoundPlan, shards: Sequence[Any],
               merge: Callable[[Any, Any], Any]):
    """Execute the plan's merge tree over round-1 partial results.

    ``shards`` must be ordered by round-1 worker *slot* (``rounds[0]``
    order); ``merge`` must be associative — contiguous grouping then makes
    every round's fold a re-bracketing of the same left-to-right reduction,
    so the result is bitwise what ``single_aggregator`` produces.
    """
    if len(shards) != plan.rounds[0].n_workers:
        raise ValueError(
            f"{len(shards)} shards for {plan.rounds[0].n_workers} round-1 workers"
        )
    parts = list(shards)
    for r in range(1, plan.n_rounds):
        parts = [_fold(merge, [parts[s] for s in g]) for g in plan.merge_groups(r)]
    return _fold(merge, parts)  # no-op fold once the final aggregator holds all


def single_aggregator(shards: Sequence[Any], merge: Callable[[Any, Any], Any]):
    """The baseline: one worker folds every shard left to right."""
    return _fold(merge, list(shards))


# ---------------------------------------------------------------------------
# CLI — plan printing + the per-job payload the batch scripts run
# ---------------------------------------------------------------------------


def _main(argv: Optional[Sequence[str]] = None) -> None:
    import argparse

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--items", type=int, default=None, help="work-set size")
    ap.add_argument("--speeds", default=None,
                    help="comma-separated relative worker rates, e.g. 4,2,1,1")
    ap.add_argument("--names", default=None,
                    help="comma-separated worker names (default n0,n1,...)")
    ap.add_argument("--shrink", type=float, default=1.6,
                    help="per-round worker-count divisor (default 1.6)")
    ap.add_argument("--plan-out", default=None, help="write the plan as JSON")
    ap.add_argument("--plan", default=None, help="load a plan JSON instead of solving")
    ap.add_argument("--worker-step", default=None, metavar="R:J",
                    help="print one job's assignment (round R, slot J) — the "
                         "payload the generated batch scripts execute")
    args = ap.parse_args(argv)

    if args.plan:
        with open(args.plan) as f:
            plan = RoundPlan.from_json(json.load(f))
    else:
        if args.items is None or args.speeds is None:
            ap.error("need --plan, or --items with --speeds")
        speeds = [float(s) for s in args.speeds.split(",") if s]
        names = (args.names.split(",") if args.names
                 else [f"n{i}" for i in range(len(speeds))])
        if len(names) != len(speeds):
            ap.error(f"{len(names)} names for {len(speeds)} speeds")
        plan = plan_rounds(args.items,
                           [RoundWorker(n, s) for n, s in zip(names, speeds)],
                           shrink=args.shrink)

    if args.worker_step:
        r, j = (int(x) for x in args.worker_step.split(":"))
        spec = plan.rounds[r]
        w = plan.workers[spec.workers[j]]
        srcs = plan.merge_groups(r)[j] if r > 0 else []
        kind = f"merge outputs of round {r - 1} slots {srcs}" if r else "first-pass work"
        print(f"round={r} slot={j} worker={w.name} rate={w.rate:g} "
              f"count={spec.counts[j]} modeled_s={spec.times[j]:.6g} [{kind}]")
        return

    print(plan.summary())
    if args.plan_out:
        with open(args.plan_out, "w") as f:
            json.dump(plan.to_json(), f, indent=1)
        print(f"wrote {args.plan_out}")


if __name__ == "__main__":
    _main()
