"""Fused, donated, scan-compiled time stepping for the blocked DG engine.

The paper's overlap schedule only pays off once each partition's step is a
single resident device program (cf. the fused propagate/collide kernels of
Calore et al. and the per-device kernel specialization of Borrell et al.).
``BlockedDGEngine`` historically drove LSRK4(5) from Python — 5 stages x P
blocks x ~6 separate jit calls per RHS evaluation, a fresh ``(K+1, ...)``
scatter target allocated per call, no buffer donation — so the blocked path
burned its budget on host dispatch.  ``FusedStepPipeline`` compiles the
entire blocked time loop into ONE donated program:

* **compiled step loop** — ``lax.fori_loop`` with a *traced* trip count and
  the ``(q, res)`` low-storage carry donated (``donate_argnums``), so the
  whole run is one host dispatch, the carry is updated in place, and ONE
  compiled program per bucket signature serves every horizon;
* **scan over stages** — the five LSRK4(5) stages are the inner
  ``lax.scan`` of ``repro.dg.rk.lsrk45_step``, traced once;
* **bucket batching** — blocks sharing a padded ``(ext, own)`` size are
  stacked and the block RHS is batched over the stacked element axis, so P
  same-bucket partitions become ONE volume launch and ONE surface launch
  instead of P of each.  The element axis is the batch axis the kernels
  (XLA einsum and the Pallas ``dg_volume_pallas`` / ``dg_flux_pallas``
  grids alike) already tile over, so stacking into it is both the fastest
  layout and arithmetically identical per element;
* **hoisted scatter target** — the ``(K+1, ...)`` dump-row target is built
  once per resplice (``BlockedDGEngine.rebuild``) and threaded through the
  program as an operand instead of being allocated per evaluation;
* **kernel_impl threading** — the engine's ``kernel_impl`` selects the
  Pallas volume AND flux kernels inside the fused program, exactly as on
  the flat solver path.

Correctness invariant (tested in ``tests/test_pipeline.py``): the fused
program is bitwise identical to the unfused four-phase per-block schedule —
the per-bucket gather ``q[own ++ halo ++ pad]`` reproduces the engine's
assemble concatenation row for row, the batched kernels perform the same
per-element arithmetic, and the scatter rows are disjoint across buckets.
The per-block ``StepSchedule`` path survives solely for calibration
(``BlockedDGEngine.calibrate`` / ``measure_block_times``), which needs the
four phases separable to time them.

The pipeline registers itself as a resplice hook: a rebalance invalidates
the stacked tables, and the next call rebuilds them.  Compiled programs are
cached on the *bucket signature* — the tuple of ``(pad, pad_own, B)`` per
bucket — which ``bucket_counts`` keeps stable across rebalances, so a
resplice that moves work between partitions without changing the padded
shape set reuses the compiled program with new index tables.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import numpy as np

__all__ = ["FusedStepPipeline"]


class FusedStepPipeline:
    """One engine's time loop as a single donated, scan-compiled program."""

    def __init__(self, engine):
        import jax

        self.engine = engine
        self.executor = engine.executor
        self.solver = engine.solver
        self.kernel_impl = engine.solver.kernel_impl
        self._jax = jax
        self._tables: Optional[List[dict]] = None
        self._sig: Optional[Tuple] = None
        self._rhs_fns: Dict[Tuple, object] = {}
        self._step_fns: Dict[Tuple, object] = {}
        self._run_fns: Dict[Tuple, object] = {}
        # introspection for benchmarks: host dispatches vs steps advanced
        self.dispatches = 0
        self.steps_run = 0
        self.executor._resplice_hooks.append(self.invalidate)

    # -- tables -------------------------------------------------------------

    def invalidate(self) -> None:
        """Resplice hook: drop the stacked tables (compiled programs stay
        cached on the bucket signature and are reused when it recurs)."""
        self._tables = None
        self._sig = None

    def _build_tables(self) -> None:
        """Stack same-bucket blocks: one table set per (pad, pad_own) bucket.

        Per bucket of B blocks the tables are the engine's per-block index /
        material arrays concatenated along the element axis, with block b's
        local neighbour ids offset by ``b * pad`` (sentinels -1/-2 kept), so
        one flat surface evaluation reproduces B block evaluations row for
        row."""
        import jax.numpy as jnp

        groups: Dict[Tuple[int, int], List[dict]] = {}
        for b in self.engine._blocks:
            if b is None:
                continue
            pad = int(b["nbr_local"].shape[0])
            pad_own = int(b["own_pad"].shape[0])
            groups.setdefault((pad, pad_own), []).append(b)

        sig = []
        tables = []
        for (pad, pad_own), blks in sorted(groups.items()):
            B = len(blks)
            nbr = np.concatenate(
                [
                    np.where(
                        np.asarray(blk["nbr_local"]) >= 0,
                        np.asarray(blk["nbr_local"]) + i * pad,
                        np.asarray(blk["nbr_local"]),
                    )
                    for i, blk in enumerate(blks)
                ]
            )
            cat = lambda key: jnp.concatenate([blk[key] for blk in blks])
            tables.append(
                {
                    # q[own ++ halo ++ pad]: the engine's assemble concat as
                    # one gather (own is unpadded; halo carries the zero pad)
                    "ext": jnp.concatenate(
                        [jnp.concatenate([blk["own"], blk["halo"]]) for blk in blks]
                    ),
                    "own_pad": cat("own_pad"),
                    "scat": cat("scat"),
                    "nbr": jnp.asarray(nbr),
                    "rho": cat("rho"),
                    "lam": cat("lam"),
                    "mu": cat("mu"),
                    "cp": cat("cp"),
                    "cs": cat("cs"),
                    "rho_o": cat("rho_o"),
                    "lam_o": cat("lam_o"),
                    "mu_o": cat("mu_o"),
                }
            )
            sig.append((pad, pad_own, B))
        self._tables = tables
        self._sig = tuple(sig)

    def _ensure(self) -> None:
        if self._tables is None:
            self._build_tables()

    @property
    def bucket_signature(self) -> Tuple:
        """((pad, pad_own, n_blocks), ...) — the compile-cache key."""
        self._ensure()
        return self._sig

    # -- program construction ----------------------------------------------

    def _make_rhs(self, sig):
        """The fused full-field rhs: per bucket one gather + one volume
        launch + one surface launch + one scatter."""
        from repro.dg.operators import surface_rhs, volume_rhs_impl

        s = self.solver
        D, metrics, lift = s.D, s.metrics, s.lift
        K = s.mesh.K
        impl = self.kernel_impl

        def rhs(q, tables, base):
            out = base
            for (pad, pad_own, B), T in zip(sig, tables):
                vol = volume_rhs_impl(
                    q[T["own_pad"]], D, metrics,
                    T["rho_o"], T["lam_o"], T["mu_o"], kernel_impl=impl,
                )
                sur = surface_rhs(
                    q[T["ext"]], T["nbr"], lift,
                    T["rho"], T["lam"], T["mu"], T["cp"], T["cs"],
                    kernel_impl=impl,
                )
                # rows past each block's own count are dump rows; fold the
                # leading pad_own surface rows of every block into its volume
                sur_own = sur.reshape((B, pad) + sur.shape[1:])[:, :pad_own]
                sur_own = sur_own.reshape((B * pad_own,) + sur.shape[1:])
                out = out.at[T["scat"]].set(vol + sur_own)
            return out[:K]

        return rhs

    def _rhs_fn(self, sig):
        import jax

        fn = self._rhs_fns.get(sig)
        if fn is None:
            fn = jax.jit(self._make_rhs(sig))
            self._rhs_fns[sig] = fn
        return fn

    def _step_fn(self, sig):
        import jax

        fn = self._step_fns.get(sig)
        if fn is None:
            from repro.dg.rk import lsrk45_step

            rhs = self._make_rhs(sig)

            def step(q, res, dt, tables, base):
                return lsrk45_step(q, res, lambda x: rhs(x, tables, base), dt)

            fn = jax.jit(step, donate_argnums=(0, 1))
            self._step_fns[sig] = fn
        return fn

    def _run_fn(self, sig):
        import jax

        fn = self._run_fns.get(sig)
        if fn is None:
            from repro.dg.rk import lsrk45_step

            rhs = self._make_rhs(sig)

            def run(q, res, dt, n, tables, base):
                # fori_loop with a TRACED trip count: one compiled program
                # per bucket signature serves every horizon (a per-n cache
                # would recompile and retain a program per distinct n)
                def body(_, carry):
                    q, res = carry
                    return lsrk45_step(q, res, lambda x: rhs(x, tables, base), dt)

                q, res = jax.lax.fori_loop(0, n, body, (q, res))
                return q, res

            fn = jax.jit(run, donate_argnums=(0, 1))
            self._run_fns[sig] = fn
        return fn

    # -- execution ----------------------------------------------------------

    def rhs(self, q):
        """One fused full-field rhs evaluation (the unfused-equality probe)."""
        self._ensure()
        self.dispatches += 1
        return self._rhs_fn(self._sig)(q, self._tables, self.engine.scatter_base(q))

    def step(self, q, res, dt):
        """One fused LSRK4(5) step; (q, res) are DONATED — callers must pass
        buffers they own (``run`` handles the copy)."""
        self._ensure()
        self.dispatches += 1
        self.steps_run += 1
        return self._step_fn(self._sig)(
            q, res, dt, self._tables, self.engine.scatter_base(q)
        )

    def run(self, q, n_steps: int, dt: Optional[float] = None, res=None):
        """Advance ``n_steps`` as ONE host dispatch (step loop with a traced
        trip count, scan over stages, donated carry).  The caller's ``q`` is
        copied once so donation never consumes a buffer the caller still
        holds."""
        import jax.numpy as jnp

        dt = dt if dt is not None else self.solver.cfl_dt()
        self._ensure()
        q = jnp.copy(q)
        res = jnp.zeros_like(q) if res is None else jnp.copy(res)
        fn = self._run_fn(self._sig)
        self.dispatches += 1
        self.steps_run += int(n_steps)
        q, _ = fn(q, res, dt, int(n_steps), self._tables, self.engine.scatter_base(q))
        return q
