"""Fused, donated, scan-compiled time stepping for the DG step drivers.

The paper's overlap schedule only pays off once each partition's step is a
single resident device program (cf. the fused propagate/collide kernels of
Calore et al. and the per-device kernel specialization of Borrell et al.).
``BlockedDGEngine`` historically drove LSRK4(5) from Python — 5 stages x P
blocks x ~6 separate jit calls per RHS evaluation, a fresh ``(K+1, ...)``
scatter target allocated per call, no buffer donation — so the blocked path
burned its budget on host dispatch.  ``FusedStepPipeline`` compiles the
entire blocked time loop into ONE donated program:

* **compiled step loop** — ``lax.fori_loop`` with a *traced* trip count and
  the ``(q, res)`` low-storage carry donated (``donate_argnums``), so the
  whole run is one host dispatch, the carry is updated in place, and ONE
  compiled program per bucket signature serves every horizon;
* **scan over stages** — the five LSRK4(5) stages are the inner
  ``lax.scan`` of ``repro.dg.rk.lsrk45_step``, traced once;
* **envelope batching** (default ``layout="envelope"``) — ALL blocks are
  padded to a common envelope ``(env, env_own)`` = (max ext pad, max own
  pad) and stacked, so the whole heterogeneous split becomes exactly ONE
  volume launch and ONE surface launch per rhs no matter how many bucket
  sizes or profile groups the partitioner produced.  Pad rows gather
  ``q[0]`` with unit materials, carry ``nbr = -1`` sentinels (no real row
  ever references them) and scatter to the dump row ``K``, so the masked
  tail is arithmetically inert and the result stays bitwise identical to
  the per-bucket path: the kernels are block-diagonal / per-row over the
  element axis, so real rows see the exact same operands either way.  The
  ledgered ``stats.kernel_launches`` counter (recorded at trace time)
  asserts the one-launch property;
* **bucket batching** (``layout="grouped"``, the differential reference) —
  blocks sharing a padded ``(ext, own)`` size (and profile group, see
  below) are stacked and the block RHS is batched over the stacked element
  axis, so P same-bucket partitions become ONE volume launch and ONE
  surface launch per *bucket*.  The element axis is the batch axis the
  kernels (XLA einsum and the Pallas ``dg_volume_pallas`` /
  ``dg_flux_pallas`` grids alike) already tile over, so stacking into it
  is both the fastest layout and arithmetically identical per element;
* **hoisted scatter target** — the ``(K+1, ...)`` dump-row target is built
  once per resplice (``BlockedDGEngine.rebuild``) and threaded through the
  program as an operand instead of being allocated per evaluation;
* **kernel_impl threading** — the engine's ``kernel_impl`` selects the
  Pallas volume AND flux kernels inside the fused program, exactly as on
  the flat solver path;
* **profile groups** — an optional partition -> group map keeps blocks of
  different (simulated) node classes in separate buckets, so a
  ``SimulatedCluster`` batches each same-profile node group through its own
  launches inside the one compiled program;
* **in-scan pricing / observation** — ``run(..., price=...)`` threads a
  per-partition per-step cost vector through the step loop's carry, so a
  simulated cluster's link+compute seconds accumulate inside the compiled
  scan instead of in host Python.  ``run_observed`` generalizes the same
  carry-riding accumulator into the runtime's measurement channel: one
  fused dispatch per rebalance chunk, ``block_until_ready`` ONCE at the
  chunk boundary, and the chunk's host wall time attributed across
  partitions by the accumulator shares
  (``CalibrationReport.from_chunk``) — so the online
  calibrate→solve→resplice loop runs at full fused speed and observation
  never leaves the compiled program.

``ShardedStepPipeline`` is the multi-device incarnation of the same idea
for the SPMD slab path (``repro.dg.partitioned.PartitionedDG``): the whole
time loop is ONE donated ``shard_map`` program spanning all devices — the
ring ``lax.ppermute`` face exchange of the slab ``StepSchedule`` runs
*inside* the compiled ``fori_loop``/stage-scan, so the halo DMA overlaps
the interior volume kernel across ranks with zero host involvement.  Host
dispatches per ``run()`` are O(1) independent of device count, slab count
and step horizon (asserted by ``tests/test_multidevice.py``).

Correctness invariant (tested in ``tests/test_pipeline.py`` /
``tests/test_multidevice.py``): both fused programs are bitwise identical
to their unfused reference paths and to the flat solver — the per-bucket
gather ``q[own ++ halo ++ pad]`` (or the slab's ``q[own ++ halo_lo ++
halo_hi]`` extension) reproduces the engine's assemble concatenation row
for row, the batched kernels perform the same per-element arithmetic, and
the scatter rows are disjoint across buckets.  The per-block
``StepSchedule`` path survives solely for calibration
(``BlockedDGEngine.calibrate`` / ``measure_block_times``), which needs the
four phases separable to time them.

The blocked pipeline registers itself as a resplice hook: a rebalance
invalidates the stacked tables, and the next call rebuilds them.  Compiled
programs are cached on the *bucket signature* — the tuple of
``(pad, pad_own, B, group)`` per bucket — which ``bucket_counts`` keeps
stable across rebalances, so a resplice that moves work between partitions
without changing the padded shape set reuses the compiled program with new
index tables.
"""

from __future__ import annotations

import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.runtime.schedule import CalibrationReport, DispatchStats

__all__ = ["FusedStepPipeline", "ShardedStepPipeline"]


class FusedStepPipeline:
    """One engine's time loop as a single donated, scan-compiled program."""

    def __init__(self, engine, groups=None, layout: str = "envelope"):
        import jax

        if layout not in ("envelope", "grouped"):
            raise ValueError(
                f"layout must be 'envelope' or 'grouped', got {layout!r}"
            )
        self.engine = engine
        self.executor = engine.executor
        self.solver = engine.solver
        self.kernel_impl = engine.solver.kernel_impl
        # partition -> bucket group.  Under layout="grouped" blocks in
        # different groups are never stacked into one launch (a
        # SimulatedCluster keeps each profile class in its own batched
        # launches); the envelope layout deliberately IGNORES groups — its
        # whole point is one launch over everything, and the in-scan price
        # vector (the only per-group observable) rides the carry
        # independently of launch grouping.
        self.groups = None if groups is None else np.asarray(groups, dtype=np.int64)
        self.layout = layout
        self._jax = jax
        self._tables: Optional[List[dict]] = None
        self._sig: Optional[Tuple] = None
        # sig -> {"volume": n, "surface": n}: launch sites counted while the
        # rhs traced (feeds stats.kernel_launches after every execution)
        self._launch_sites: Dict[Tuple, Dict[str, int]] = {}
        self._rhs_fns: Dict[Tuple, object] = {}
        self._step_fns: Dict[Tuple, object] = {}
        self._run_fns: Dict[Tuple, object] = {}
        self._priced_run_fns: Dict[Tuple, object] = {}
        # introspection for benchmarks and the dispatch-count regression
        # tests: host dispatches vs steps advanced
        self.stats = DispatchStats()
        self.executor._resplice_hooks.append(self.invalidate)

    @property
    def dispatches(self) -> int:
        return self.stats.dispatches

    @property
    def steps_run(self) -> int:
        return self.stats.steps_run

    # -- tables -------------------------------------------------------------

    def invalidate(self) -> None:
        """Resplice hook: drop the stacked tables (compiled programs stay
        cached on the bucket signature and are reused when it recurs)."""
        self._tables = None
        self._sig = None

    def _build_tables(self) -> None:
        if self.layout == "envelope":
            self._build_tables_envelope()
        else:
            self._build_tables_grouped()

    def _build_tables_envelope(self) -> None:
        """Pad EVERY block to the common envelope ``(env, env_own)`` = (max
        ext pad, max own pad) and stack: one table set, one volume launch,
        one surface launch per rhs regardless of the bucket split.

        The masked tail of each block is arithmetically inert by
        construction:

        * padded ext rows gather ``q[0]`` with unit materials — finite
          operands, and no real row references them because their neighbour
          sentinel is -1 and every real row's neighbour id resolves inside
          its own block's first ``pad`` rows (offsets move from ``i * pad``
          to ``i * env`` without touching the intra-block layout);
        * padded own rows gather ``q[0]`` with unit ``rho_o`` (divided by in
          the volume kernel, hence nonzero) and scatter to the dump row
          ``K``, which ``out[:K]`` discards;
        * real rows see byte-for-byte the operands of the per-bucket path —
          the kernels are block-diagonal / per-row over the element axis, so
          the trajectory stays bitwise identical (asserted by the
          envelope-vs-grouped differential tests)."""
        import jax.numpy as jnp

        blks = [b for b in self.engine._blocks if b is not None]
        if not blks:
            self._tables = []
            self._sig = ()
            return
        K = self.solver.mesh.K
        env = max(int(b["nbr_local"].shape[0]) for b in blks)
        env_own = max(int(b["own_pad"].shape[0]) for b in blks)

        def pad_idx(a, n, fill):
            a = np.asarray(a)
            if a.shape[0] < n:
                tail = np.full((n - a.shape[0],) + a.shape[1:], fill, a.dtype)
                a = np.concatenate([a, tail])
            return a

        def pad_mat(key, n):
            cols = []
            for blk in blks:
                a = np.asarray(blk[key])
                if a.shape[0] < n:
                    a = np.concatenate(
                        [a, np.ones((n - a.shape[0],) + a.shape[1:], a.dtype)]
                    )
                cols.append(a)
            return jnp.asarray(np.concatenate(cols))

        ext = np.concatenate(
            [
                pad_idx(
                    np.concatenate(
                        [np.asarray(blk["own"]), np.asarray(blk["halo"])]
                    ),
                    env,
                    0,
                )
                for blk in blks
            ]
        )
        nbr = np.concatenate(
            [
                pad_idx(
                    np.where(
                        np.asarray(blk["nbr_local"]) >= 0,
                        np.asarray(blk["nbr_local"]) + i * env,
                        np.asarray(blk["nbr_local"]),
                    ),
                    env,
                    -1,
                )
                for i, blk in enumerate(blks)
            ]
        )
        own_pad = np.concatenate(
            [pad_idx(np.asarray(blk["own_pad"]), env_own, 0) for blk in blks]
        )
        scat = np.concatenate(
            [pad_idx(np.asarray(blk["scat"]), env_own, K) for blk in blks]
        )
        self._tables = [
            {
                "ext": jnp.asarray(ext),
                "own_pad": jnp.asarray(own_pad),
                "scat": jnp.asarray(scat),
                "nbr": jnp.asarray(nbr),
                "rho": pad_mat("rho", env),
                "lam": pad_mat("lam", env),
                "mu": pad_mat("mu", env),
                "cp": pad_mat("cp", env),
                "cs": pad_mat("cs", env),
                "rho_o": pad_mat("rho_o", env_own),
                "lam_o": pad_mat("lam_o", env_own),
                "mu_o": pad_mat("mu_o", env_own),
            }
        ]
        self._sig = ((env, env_own, len(blks), 0),)

    def _build_tables_grouped(self) -> None:
        """Stack same-bucket blocks: one table set per (pad, pad_own, group)
        bucket.

        Per bucket of B blocks the tables are the engine's per-block index /
        material arrays concatenated along the element axis, with block b's
        local neighbour ids offset by ``b * pad`` (sentinels -1/-2 kept), so
        one flat surface evaluation reproduces B block evaluations row for
        row."""
        import jax.numpy as jnp

        groups: Dict[Tuple[int, int, int], List[dict]] = {}
        for p, b in enumerate(self.engine._blocks):
            if b is None:
                continue
            pad = int(b["nbr_local"].shape[0])
            pad_own = int(b["own_pad"].shape[0])
            gid = 0 if self.groups is None else int(self.groups[p])
            groups.setdefault((pad, pad_own, gid), []).append(b)

        sig = []
        tables = []
        for (pad, pad_own, gid), blks in sorted(groups.items()):
            B = len(blks)
            nbr = np.concatenate(
                [
                    np.where(
                        np.asarray(blk["nbr_local"]) >= 0,
                        np.asarray(blk["nbr_local"]) + i * pad,
                        np.asarray(blk["nbr_local"]),
                    )
                    for i, blk in enumerate(blks)
                ]
            )
            cat = lambda key: jnp.concatenate([blk[key] for blk in blks])
            tables.append(
                {
                    # q[own ++ halo ++ pad]: the engine's assemble concat as
                    # one gather (own is unpadded; halo carries the zero pad)
                    "ext": jnp.concatenate(
                        [jnp.concatenate([blk["own"], blk["halo"]]) for blk in blks]
                    ),
                    "own_pad": cat("own_pad"),
                    "scat": cat("scat"),
                    "nbr": jnp.asarray(nbr),
                    "rho": cat("rho"),
                    "lam": cat("lam"),
                    "mu": cat("mu"),
                    "cp": cat("cp"),
                    "cs": cat("cs"),
                    "rho_o": cat("rho_o"),
                    "lam_o": cat("lam_o"),
                    "mu_o": cat("mu_o"),
                }
            )
            sig.append((pad, pad_own, B, gid))
        self._tables = tables
        self._sig = tuple(sig)

    def _ensure(self) -> None:
        if self._tables is None:
            self._build_tables()

    @property
    def bucket_signature(self) -> Tuple:
        """((pad, pad_own, n_blocks, group), ...) — the compile-cache key."""
        self._ensure()
        return self._sig

    # -- program construction ----------------------------------------------

    def _make_rhs(self, sig):
        """The fused full-field rhs: per bucket one gather + one volume
        launch + one surface launch + one scatter (ONE of each total under
        the envelope layout, where sig is a single bucket).

        The ``counts`` side effects run at TRACE time only — the stage scan
        and step loop trace this body once, so the recorded numbers are the
        per-kernel launch sites baked into the compiled program per rhs
        evaluation (the quantity the dispatch-count regression tests pin)."""
        from repro.dg.operators import surface_rhs, volume_rhs_impl

        s = self.solver
        D, metrics, lift = s.D, s.metrics, s.lift
        K = s.mesh.K
        impl = self.kernel_impl
        launch_sites = self._launch_sites

        def rhs(q, tables, base):
            counts = {"volume": 0, "surface": 0}
            out = base
            for (pad, pad_own, B, _gid), T in zip(sig, tables):
                counts["volume"] += 1
                vol = volume_rhs_impl(
                    q[T["own_pad"]], D, metrics,
                    T["rho_o"], T["lam_o"], T["mu_o"], kernel_impl=impl,
                )
                counts["surface"] += 1
                sur = surface_rhs(
                    q[T["ext"]], T["nbr"], lift,
                    T["rho"], T["lam"], T["mu"], T["cp"], T["cs"],
                    kernel_impl=impl,
                )
                # rows past each block's own count are dump rows; fold the
                # leading pad_own surface rows of every block into its volume
                sur_own = sur.reshape((B, pad) + sur.shape[1:])[:, :pad_own]
                sur_own = sur_own.reshape((B * pad_own,) + sur.shape[1:])
                out = out.at[T["scat"]].set(vol + sur_own)
            launch_sites[sig] = counts
            return out[:K]

        return rhs

    def _record_launches(self) -> None:
        """Feed the trace-time launch-site counts of the active signature
        into the stats ledger (each bucket issues exactly one volume + one
        surface launch, so the sig-derived fallback covers the impossible
        not-yet-traced case)."""
        n = len(self._sig or ())
        self.stats.record_launches(
            self._launch_sites.get(self._sig) or {"volume": n, "surface": n}
        )

    def _rhs_fn(self, sig):
        import jax

        fn = self._rhs_fns.get(sig)
        if fn is None:
            fn = jax.jit(self._make_rhs(sig))
            self._rhs_fns[sig] = fn
        return fn

    def _step_fn(self, sig):
        import jax

        fn = self._step_fns.get(sig)
        if fn is None:
            from repro.dg.rk import lsrk45_step

            rhs = self._make_rhs(sig)

            def step(q, res, dt, tables, base):
                return lsrk45_step(q, res, lambda x: rhs(x, tables, base), dt)

            fn = jax.jit(step, donate_argnums=(0, 1))
            self._step_fns[sig] = fn
        return fn

    def _run_fn(self, sig):
        import jax

        fn = self._run_fns.get(sig)
        if fn is None:
            from repro.dg.rk import lsrk45_step

            rhs = self._make_rhs(sig)

            def run(q, res, dt, n, tables, base):
                # fori_loop with a TRACED trip count: one compiled program
                # per bucket signature serves every horizon (a per-n cache
                # would recompile and retain a program per distinct n)
                def body(_, carry):
                    q, res = carry
                    return lsrk45_step(q, res, lambda x: rhs(x, tables, base), dt)

                q, res = jax.lax.fori_loop(0, n, body, (q, res))
                return q, res

            fn = jax.jit(run, donate_argnums=(0, 1))
            self._run_fns[sig] = fn
        return fn

    def _priced_run_fn(self, sig):
        import jax

        fn = self._priced_run_fns.get(sig)
        if fn is None:
            from repro.dg.rk import lsrk45_step

            rhs = self._make_rhs(sig)

            def run(q, res, acc, dt, n, tables, base, price):
                # same fused step loop, with a per-partition simulated-cost
                # accumulator riding the carry: the (link + compute) price
                # of every step is charged inside the compiled scan.  With
                # today's loop-invariant price the result equals price * n;
                # the in-carry accumulator is the hook the roadmap's
                # on-device per-step observation slots into, and a cluster
                # pipeline only ever compiles THIS family (run(price=...)
                # every call), so no program is compiled twice in practice.
                def body(_, carry):
                    q, res, acc = carry
                    q, res = lsrk45_step(q, res, lambda x: rhs(x, tables, base), dt)
                    return q, res, acc + price

                return jax.lax.fori_loop(0, n, body, (q, res, acc))

            fn = jax.jit(run, donate_argnums=(0, 1, 2))
            self._priced_run_fns[sig] = fn
        return fn

    # -- execution ----------------------------------------------------------

    def rhs(self, q):
        """One fused full-field rhs evaluation (the unfused-equality probe)."""
        self._ensure()
        self.stats.record(1, 0)
        out = self._rhs_fn(self._sig)(q, self._tables, self.engine.scatter_base(q))
        self._record_launches()
        return out

    def step(self, q, res, dt):
        """One fused LSRK4(5) step; (q, res) are DONATED — callers must pass
        buffers they own (``run`` handles the copy)."""
        self._ensure()
        self.stats.record(1, 1)
        out = self._step_fn(self._sig)(
            q, res, dt, self._tables, self.engine.scatter_base(q)
        )
        self._record_launches()
        return out

    def run(self, q, n_steps: int, dt: Optional[float] = None, res=None,
            price=None):
        """Advance ``n_steps`` as ONE host dispatch (step loop with a traced
        trip count, scan over stages, donated carry).  The caller's ``q`` is
        copied once so donation never consumes a buffer the caller still
        holds.

        With ``price`` (a per-partition per-step seconds vector) the
        compiled loop also accumulates the simulated cost of every step and
        the call returns ``(q, accumulated_seconds)`` — how
        ``runtime.cluster.SimulatedCluster`` prices its virtual link inside
        the scan."""
        import jax.numpy as jnp

        dt = dt if dt is not None else self.solver.cfl_dt()
        self._ensure()
        q = jnp.copy(q)
        res = jnp.zeros_like(q) if res is None else jnp.copy(res)
        base = self.engine.scatter_base(q)
        self.stats.record(1, int(n_steps))
        if price is None:
            fn = self._run_fn(self._sig)
            q, _ = fn(q, res, dt, int(n_steps), self._tables, base)
            self._record_launches()
            return q
        price = jnp.asarray(price, dtype=jnp.float64 if q.dtype == jnp.float64
                            else jnp.float32)
        fn = self._priced_run_fn(self._sig)
        q, _, acc = fn(q, res, jnp.zeros_like(price), dt, int(n_steps),
                       self._tables, base, price)
        self._record_launches()
        return q, acc

    def run_observed(self, q, n_steps: int, dt: Optional[float] = None,
                     price=None, attribute_wall: bool = True,
                     injector=None, step: int = 0):
        """Advance ``n_steps`` as ONE fused dispatch AND observe it: the
        in-scan measurement channel of the calibrate→solve→resplice loop.

        The per-partition cost accumulator rides the scan carry (the
        ``_priced_run_fn`` family), so the relative shares of work never
        leave the compiled program; the host synchronizes exactly once per
        chunk (``block_until_ready``) and attributes the chunk's wall
        seconds across partitions by those shares
        (``CalibrationReport.from_chunk``).  ``price`` defaults to the
        executor's current element counts — the work proxy of a fused
        single-arena program, where each partition's slice of the launch
        scales with its element count.  With ``attribute_wall=False`` the
        report carries the accumulated price itself (``acc / n_steps``, no
        wall measurement) — the deterministic mode ``SimulatedCluster``
        uses for its virtual link+compute pricing.

        Returns ``(q, CalibrationReport)``; straggler factors are NOT in
        the report — ``NestedPartitionExecutor.observe`` applies them, the
        single injection point.

        ``injector`` (a ``runtime.fault_tolerance.FailureInjector``) is
        probed at ``step`` BEFORE the dispatch — the chaos hook: a raised
        failure leaves ``q``, the ledger and the executor schedule
        untouched, so a supervised retry replays the chunk exactly."""
        import jax

        if injector is not None:
            injector.maybe_fail(step)
        if price is None:
            price = np.maximum(
                self.executor.counts.astype(np.float64), 0.0
            )
        t0 = time.perf_counter()
        q, acc = self.run(q, n_steps, dt=dt, price=price)
        jax.block_until_ready(q)
        wall = time.perf_counter() - t0
        self.stats.record_chunk()
        acc = np.asarray(acc, dtype=np.float64)
        if attribute_wall:
            report = CalibrationReport.from_chunk(wall, acc, n_steps)
        else:
            report = CalibrationReport.from_totals(acc / max(1, int(n_steps)))
        return q, report


class ShardedStepPipeline:
    """The SPMD slab time loop as ONE donated shard_map program spanning all
    devices (see module docstring).

    Bound to a ``repro.dg.partitioned.PartitionedDG``: the slab
    ``StepSchedule`` — pack edge layers, ring ``ppermute``, overlapped
    volume interior, extended surface fold — is traced INTO the compiled
    ``fori_loop`` over steps (traced trip count) and ``lax.scan`` over the
    five LSRK stages, with the ``(q, res)`` carry donated.  One compiled
    program serves every horizon and every ``dt``; host dispatches per run
    are O(1) regardless of device count."""

    def __init__(self, pdg):
        import jax

        self.pdg = pdg
        self.solver = pdg.solver
        self._jax = jax
        self._rhs_c = None
        self._step_c = None
        self._run_c = None
        self._priced_run_c = None
        self.stats = DispatchStats()

    @property
    def dispatches(self) -> int:
        return self.stats.dispatches

    @property
    def steps_run(self) -> int:
        return self.stats.steps_run

    # -- program construction ----------------------------------------------

    def _local_rhs(self):
        p = self.pdg

        def rhs(q, nbr, rho, lam, mu, cp, cs):
            return p._rhs_local(q, nbr, rho, lam, mu, cp, cs)

        return rhs

    def _shard(self, f, n_carry_out: int):
        from repro.jax_compat import shard_map

        p = self.pdg
        qs = p.spec_q
        out = qs if n_carry_out == 1 else (qs,) * n_carry_out
        return shard_map(
            f,
            mesh=p.mesh_axes,
            in_specs=(qs,) * n_carry_out
            + (self._scalar_spec(),) * (2 if n_carry_out > 1 else 0)
            + p._operand_specs(),
            out_specs=out,
            check_vma=False,
        )

    @staticmethod
    def _scalar_spec():
        from jax.sharding import PartitionSpec

        return PartitionSpec()

    def _rhs_fn(self):
        if self._rhs_c is None:
            import jax

            self._rhs_c = jax.jit(self._shard(self._local_rhs(), 1))
        return self._rhs_c

    def _step_fn(self):
        if self._step_c is None:
            import jax

            from repro.dg.rk import lsrk45_step

            local_rhs = self._local_rhs()

            def local_step(q, res, dt, n, nbr, rho, lam, mu, cp, cs):
                del n
                return lsrk45_step(
                    q, res, lambda x: local_rhs(x, nbr, rho, lam, mu, cp, cs), dt
                )

            self._step_c = jax.jit(self._shard(local_step, 2), donate_argnums=(0, 1))
        return self._step_c

    def _run_fn(self):
        if self._run_c is None:
            import jax

            from repro.dg.rk import lsrk45_step

            local_rhs = self._local_rhs()

            def local_run(q, res, dt, n, nbr, rho, lam, mu, cp, cs):
                # fori_loop with a TRACED trip count; the ring ppermute of
                # the schedule's exchange phase is traced into the loop body,
                # so the whole multi-device run is one resident program
                def body(_, carry):
                    q, res = carry
                    return lsrk45_step(
                        q, res, lambda x: local_rhs(x, nbr, rho, lam, mu, cp, cs), dt
                    )

                return jax.lax.fori_loop(0, n, body, (q, res))

            self._run_c = jax.jit(self._shard(local_run, 2), donate_argnums=(0, 1))
        return self._run_c

    def _priced_run_fn(self):
        if self._priced_run_c is None:
            import jax
            import jax.numpy as jnp
            from jax.sharding import PartitionSpec

            from repro.dg.rk import lsrk45_step
            from repro.jax_compat import shard_map

            p = self.pdg
            local_rhs = self._local_rhs()
            axis, n_shards = p.axis, p.P

            def local_run(q, res, acc, dt, n, price, nbr, rho, lam, mu, cp, cs):
                # the blocked pipeline's carry-riding accumulator, per
                # shard: each rank charges its own per-step price inside
                # the compiled loop (the ring ppermute of the exchange
                # phase is traced into the same body)
                def body(_, carry):
                    q, res, acc = carry
                    q, res = lsrk45_step(
                        q, res,
                        lambda x: local_rhs(x, nbr, rho, lam, mu, cp, cs), dt,
                    )
                    return q, res, acc + price

                q, res, acc = jax.lax.fori_loop(0, n, body, (q, res, acc))
                # collect every shard's scalar accumulator into ONE
                # replicated (P,) vector inside the compiled program —
                # one-hot placement + psum over the mesh axis — so the
                # host reads all per-shard totals from a single output
                full = (
                    jnp.zeros((n_shards,), acc.dtype)
                    .at[jax.lax.axis_index(axis)]
                    .set(acc[0])
                )
                return q, res, jax.lax.psum(full, axis)

            qs = p.spec_q
            scalar = PartitionSpec()
            vec = PartitionSpec(p.axis)
            f = shard_map(
                local_run,
                mesh=p.mesh_axes,
                in_specs=(qs, qs, vec, scalar, scalar, vec) + p._operand_specs(),
                out_specs=(qs, qs, scalar),
                check_vma=False,
            )
            self._priced_run_c = jax.jit(f, donate_argnums=(0, 1, 2))
        return self._priced_run_c

    # -- execution ----------------------------------------------------------

    def _sharded_copy(self, x):
        """A fresh buffer with the pipeline's q-sharding — what the donated
        carry consumes, so the caller's array survives every call."""
        import jax
        import jax.numpy as jnp
        from jax.sharding import NamedSharding

        p = self.pdg
        return jax.device_put(jnp.copy(x), NamedSharding(p.mesh_axes, p.spec_q))

    def rhs(self, q):
        """One fused sharded rhs evaluation (the differential-test probe)."""
        self.stats.record(1, 0)
        return self._rhs_fn()(q, *self.pdg._operands())

    def step(self, q, res, dt):
        """One fused sharded LSRK4(5) step; (q, res) are DONATED."""
        import jax.numpy as jnp

        self.stats.record(1, 1)
        dt = jnp.asarray(dt, q.dtype)
        n = jnp.asarray(1, jnp.int32)
        return self._step_fn()(q, res, dt, n, *self.pdg._operands())

    def run(self, q, n_steps: int, dt: Optional[float] = None, res=None):
        """Advance ``n_steps`` as ONE host dispatch across all devices."""
        import jax.numpy as jnp

        dt = dt if dt is not None else self.solver.cfl_dt()
        q = self._sharded_copy(q)
        res = self._sharded_copy(jnp.zeros_like(q) if res is None else res)
        fn = self._run_fn()
        self.stats.record(1, int(n_steps))
        q, _ = fn(q, res, jnp.asarray(dt, q.dtype),
                  jnp.asarray(int(n_steps), jnp.int32), *self.pdg._operands())
        return q

    def run_observed(self, q, n_steps: int, dt: Optional[float] = None,
                     price=None, attribute_wall: bool = True):
        """Advance ``n_steps`` as ONE fused multi-device dispatch AND
        observe it (the sharded twin of
        ``FusedStepPipeline.run_observed``): per-shard cost accumulators
        ride the donated carry and are reduced to one replicated vector
        with ``psum`` INSIDE the compiled program, then the chunk's host
        wall time (one ``block_until_ready``) is attributed across shards
        by those shares.  ``price`` defaults to the (equal) per-slab
        element counts; returns ``(q, CalibrationReport)``."""
        import jax
        import jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec

        p = self.pdg
        dt = dt if dt is not None else self.solver.cfl_dt()
        if price is None:
            price = np.full(p.P, float(p.K_loc))
        dtype = jnp.float64 if q.dtype == jnp.float64 else jnp.float32
        sh = NamedSharding(p.mesh_axes, PartitionSpec(p.axis))
        price = jax.device_put(jnp.asarray(price, dtype), sh)
        acc = jax.device_put(jnp.zeros((p.P,), dtype), sh)
        q = self._sharded_copy(q)
        res = self._sharded_copy(jnp.zeros_like(q))
        fn = self._priced_run_fn()
        self.stats.record(1, int(n_steps))
        t0 = time.perf_counter()
        q, _, acc = fn(q, res, acc, jnp.asarray(dt, q.dtype),
                       jnp.asarray(int(n_steps), jnp.int32), price,
                       *p._operands())
        jax.block_until_ready(q)
        wall = time.perf_counter() - t0
        self.stats.record_chunk()
        acc = np.asarray(acc, dtype=np.float64)
        if attribute_wall:
            report = CalibrationReport.from_chunk(wall, acc, n_steps)
        else:
            report = CalibrationReport.from_totals(acc / max(1, int(n_steps)))
        return q, report
