"""Simulated heterogeneous cluster — the paper's *outer* partition level,
closed end to end on one machine.

The repo's executor (`runtime.executor`) closes the paper's calibration loop
for a single node's partitions.  This module lifts the same loop to the
cluster: a ``SimulatedCluster`` owns one shared ``NestedPartitionExecutor``
(the control plane: Morton splice + online re-solve) and drives one
``BlockedDGEngine`` per virtual node (the data plane: each node executes its
own Morton-contiguous block with halo gathers, bitwise-identical to the flat
solver).  Heterogeneity and the network are *simulated* on top of real
kernel timings:

* a ``NodeProfile`` per node scales measured seconds by ``1/speed`` (a node
  twice as fast observes half the time) and optionally carries calibrated
  ``t_host`` / ``t_accel`` / PCI models for the intra-node level-2 solve;
* inter-node halo exchange is priced by an alpha–beta ``LinkClass`` model on
  the partition's *exact* cross-node face cuts (``ClusterPartition``):
  ``latency * peers + bytes / bandwidth`` per node per step.

The step driver is fused by default (``run(fused=True)``): every node's
block executes inside ONE donated scan-compiled ``FusedStepPipeline``
program per rebalance chunk — same-profile node groups are batched into
their own launches (``profile_groups``), and the simulated per-node step
price (compute/speed plus the link model) is accumulated *inside* the
compiled scan (``FusedStepPipeline.run(price=...)``), so observation no
longer forces one host dispatch per step.  The eager per-step path
(``fused=False``) survives for calibration-style per-step measurement.

``resolve`` re-solves **both** levels from a per-node ``CalibrationReport``:
level 1 feeds the overlap-aware fleet report into the executor's
waterfilling solve (new node counts -> resplice), level 2 re-runs the
asymmetric two-way solve inside each node (new accelerator block sizes ->
``set_accel_counts``).  The straggler hook is the executor's own
(``inject_straggler``), so a slow node is rebalanced by exactly the paper's
equalizer.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Sequence

import numpy as np

from repro.core.cost_model import inter_node_transfer_fn, stampede_node_models
from repro.core.load_balance import NodeModel, solve_hierarchical
from repro.core.partition import ClusterPartition
from repro.core.topology import STAMPEDE_IB, LinkClass
from repro.runtime.executor import BlockedDGEngine, NestedPartitionExecutor
from repro.runtime.schedule import CalibrationReport

__all__ = [
    "NodeProfile",
    "stampede_profile",
    "SimulatedCluster",
    "format_cluster_plan",
]


@dataclasses.dataclass(frozen=True)
class NodeProfile:
    """One virtual node: a relative speed plus optional calibrated models.

    ``speed`` is a throughput multiplier applied to *measured* seconds (the
    simulation knob: speed 2.0 halves observed times, speed 0.5 is a slow
    node).  ``t_host`` / ``t_accel`` / ``transfer`` are the paper's
    T_CPU / T_MIC / PCI models for the intra-node solve; a profile without
    them is a homogeneous node (no level-2 accelerator split).
    """

    name: str = "node"
    speed: float = 1.0
    t_host: Optional[Callable[[float], float]] = None
    t_accel: Optional[Callable[[float], float]] = None
    transfer: Optional[Callable[[float], float]] = None

    def __post_init__(self):
        if self.speed <= 0:
            raise ValueError(f"node speed must be positive, got {self.speed}")

    @property
    def has_models(self) -> bool:
        return self.t_host is not None


def stampede_profile(order: int = 7, speed: float = 1.0, name: str = "stampede") -> NodeProfile:
    """The paper's node (SNB socket + MIC over PCI) as a cluster profile."""
    t_cpu, t_mic, xfer = stampede_node_models(order)
    return NodeProfile(name=name, speed=speed, t_host=t_cpu, t_accel=t_mic, transfer=xfer)


class SimulatedCluster:
    """N virtual heterogeneous nodes over one DG solver (see module docstring).

    The field execution is exact: node ``i`` evaluates block ``i`` of the
    shared nested partition through its own ``BlockedDGEngine``, and the
    assembled rhs is bitwise-identical to the flat solver's.  Only *time* is
    simulated (speed scaling + link model), which is what lets CI exercise
    cluster-level rebalancing on a single container.
    """

    def __init__(
        self,
        solver,
        profiles: Sequence[NodeProfile],
        *,
        link: LinkClass = STAMPEDE_IB,
        bucket: int = 8,
        accel_fraction: float = 0.0,
        rebalance_every: int = 0,
        plan_cache_dir: Optional[str] = None,
        sim_unit_cost: float = 50e-6,
        injector=None,
    ):
        if len(profiles) == 0:
            raise ValueError("need at least one node profile")
        self.solver = solver
        self.profiles = tuple(profiles)
        self.link = link
        # chaos hook: a runtime.fault_tolerance.FailureInjector probed once
        # per node at each fused chunk's dispatch boundary (BEFORE the
        # device program runs, so a raised failure leaves q and the
        # executor's schedule untouched and a supervised retry is exact)
        self.injector = injector
        # seconds per element (at speed 1) for the field-free deterministic
        # simulation — on the same scale as the link model, so the wire
        # genuinely enters the simulated balance
        self.sim_unit_cost = float(sim_unit_cost)
        K = solver.mesh.K
        speeds = np.array([p.speed for p in self.profiles], dtype=np.float64)
        # level-1 seed: splice the curve proportionally to nominal speeds
        self.executor = NestedPartitionExecutor(
            K,
            len(self.profiles),
            grid_dims=tuple(solver.mesh.grid),
            bucket=bucket,
            accel_fraction=accel_fraction,
            rebalance_every=rebalance_every,
            initial_weights=speeds,
            plan_cache_dir=plan_cache_dir,
        )
        # one engine per node, all bound to the shared executor/partition;
        # node i only executes block i, so its engine only builds block i's
        # tables (a resplice costs O(N) total, not O(N^2))
        self.engines: List[BlockedDGEngine] = [
            BlockedDGEngine(solver, self.executor, only_blocks=[i])
            for i in range(len(self.profiles))
        ]
        # the fused data plane (built lazily by fused_pipeline()): one full
        # engine whose FusedStepPipeline batches each same-profile node
        # group through its own launches inside ONE compiled program
        self._fused_engine: Optional[BlockedDGEngine] = None
        self.last_sim_times: Optional[np.ndarray] = None

    # -- introspection -------------------------------------------------------

    @property
    def n_nodes(self) -> int:
        return len(self.profiles)

    @property
    def counts(self) -> np.ndarray:
        return self.executor.counts

    def cluster_partition(self) -> ClusterPartition:
        """The current two-level partition with cluster-level metadata."""
        counts = np.maximum(self.executor.counts.astype(np.float64), 0.0)
        total = counts.sum()
        weights = counts / total if total > 0 else np.full(self.n_nodes, 1.0 / self.n_nodes)
        return ClusterPartition(node_weights=weights, nested=self.executor.partition)

    # -- the simulated network ----------------------------------------------

    def comm_times(self) -> np.ndarray:
        """Per-node inter-node halo exchange seconds under the alpha-beta
        link model, priced on the partition's exact cross-node face cuts."""
        part = self.cluster_partition()
        dtype_bytes = int(np.dtype(self.solver.dtype).itemsize)
        nbytes = part.halo_bytes(self.solver.order, n_fields=9, dtype_bytes=dtype_bytes)
        peers = part.halo_peers()
        return np.array(
            [self.link.time(float(nbytes[i]), n_messages=int(peers[i]))
             for i in range(self.n_nodes)]
        )

    def inter_transfer_fn(self) -> Callable[[float], float]:
        """Plan-time surface model of the same exchange: a Morton-compact
        chunk of k elements exposes ~6*k^(2/3) faces (paper section 5.5)."""
        return inter_node_transfer_fn(
            self.solver.order, link=self.link,
            dtype_bytes=int(np.dtype(self.solver.dtype).itemsize),
        )

    # -- execution (exact) ---------------------------------------------------

    def rhs(self, q):
        """Global rhs assembled from per-node engine evaluations — the same
        arithmetic as one BlockedDGEngine, so it matches the flat solver
        bitwise."""
        K = self.solver.mesh.K
        # the hoisted (K+1)-row scatter target (engines share one solver)
        out = self.engines[0].scatter_base(q)
        for i, eng in enumerate(self.engines):
            b = eng._blocks[i]
            if b is None:
                continue
            out = out.at[b["scat"]].set(eng.block_rhs(q, b))
        return out[:K]

    def profile_groups(self) -> np.ndarray:
        """Node -> bucket-group ids: nodes sharing a profile class
        ``(name, speed)`` share a group, so the fused pipeline batches each
        same-profile group through its own launches."""
        keys: dict = {}
        out = np.zeros(self.n_nodes, dtype=np.int64)
        for i, p in enumerate(self.profiles):
            out[i] = keys.setdefault((p.name, p.speed), len(keys))
        return out

    def fused_pipeline(self, layout: str = "envelope"):
        """The cluster's fused step driver: ONE donated scan-compiled
        program covering every node's block, rebuilt across resplices via
        the usual hooks.  The default envelope layout collapses ALL profile
        groups into one volume + one surface launch per rhs (the per-node
        simulated price rides the scan carry, independent of launch
        grouping); ``layout="grouped"`` keeps one launch pair per profile
        class (the differential reference)."""
        if self._fused_engine is None:
            self._fused_engine = BlockedDGEngine(self.solver, self.executor)
        return self._fused_engine.pipeline(groups=self.profile_groups(),
                                           layout=layout)

    def resplice(self, plan) -> None:
        """Apply a solved plan: every node engine rebuilds its own block
        through the executor's resplice hooks."""
        self.executor.apply(plan)

    def run(self, q, n_steps: int, dt: Optional[float] = None, observe: bool = False,
            fused: bool = True):
        """LSRK4(5) on the cluster rhs.

        ``fused`` (default) drives the grouped ``FusedStepPipeline``: the
        whole horizon is one donated device program per rebalance chunk,
        with the simulated per-node step price (compute/speed + the
        alpha-beta link on the exact face cuts) accumulated INSIDE the
        compiled scan via the in-scan observation channel
        (``run_observed(..., attribute_wall=False)`` — the report carries
        the virtual price itself, keeping the simulation deterministic);
        with ``observe`` each chunk's report feeds
        ``executor.observe_chunk`` and it rebalances on its schedule.
        ``fused=False`` is the eager per-step reference path (kept for
        calibration-style per-step observation)."""
        from repro.dg.rk import lsrk45_step

        import jax.numpy as jnp

        dt = dt or self.solver.cfl_dt()
        if fused:
            done = 0
            while done < n_steps:
                chunk = n_steps - done
                if observe and self.executor.rebalance_every > 0:
                    chunk = min(self.executor.rebalance_every, chunk)
                if self.injector is not None:
                    # probe every node's dispatch at the global step this
                    # chunk starts from (the executor's step counter —
                    # monotone across supervised per-chunk calls)
                    base = self.executor._step
                    for node in range(self.n_nodes):
                        self.injector.maybe_fail(base, node=node)
                pipe = self.fused_pipeline()  # after a resplice: new tables
                q, report = pipe.run_observed(
                    q, chunk, dt=dt,
                    price=self.step_times(),  # deterministic: counts + link
                    attribute_wall=False,
                )
                self.last_sim_times = np.asarray(report.step_s)
                if observe:
                    self.executor.observe_chunk(report, chunk)
                done += chunk
            return q
        res = jnp.zeros_like(q)
        for _ in range(n_steps):
            if observe:
                self.executor.observe(self.step_times(q))
                self.executor.advance()
            q, res = lsrk45_step(q, res, self.rhs, dt)
        return q

    # -- measurement (simulated time on real kernels) ------------------------

    def step_times(self, q=None, reps: int = 1) -> np.ndarray:
        """Per-node simulated step seconds: measured block time (or, without
        a field, a deterministic counts/speed model) scaled by ``1/speed``,
        plus the modeled inter-node exchange.  Straggler factors are NOT
        applied here — ``executor.observe`` applies them, the single
        injection point."""
        comm = self.comm_times()
        speeds = np.array([p.speed for p in self.profiles])
        if q is None:
            # deterministic simulation: sim_unit_cost seconds per element —
            # real-seconds scale, so the link term is commensurate and a
            # comm-heavy node genuinely reads as slower
            compute = self.executor.counts.astype(np.float64) * self.sim_unit_cost / speeds
        else:
            measured = np.zeros(self.n_nodes)
            for i, eng in enumerate(self.engines):
                b = eng._blocks[i]
                if b is None:
                    continue
                measured[i], _ = eng._time(eng.block_rhs, q, b, reps=reps)
            compute = measured / speeds
        return compute + comm

    def calibrate(self, q, reps: int = 1) -> CalibrationReport:
        """Per-node phase-resolved calibration: each node's engine times its
        OWN block, compute phases are scaled by the node's speed, and the
        transfer phase gains the modeled inter-node wire time on top of the
        measured local pack/gather.  Observes the executor once."""
        P = self.n_nodes
        boundary = np.zeros(P)
        interior = np.zeros(P)
        transfer = np.zeros(P)
        correction = np.zeros(P)
        comm = self.comm_times()
        for i, (prof, eng) in enumerate(zip(self.profiles, self.engines)):
            rep = eng.calibrate(q, reps=reps, blocks=[i], observe=False)
            boundary[i] = rep.boundary_s[i] / prof.speed
            interior[i] = rep.interior_s[i] / prof.speed
            correction[i] = rep.correction_s[i] / prof.speed
            transfer[i] = rep.transfer_s[i] / prof.speed + comm[i]
        report = CalibrationReport(boundary_s=boundary, interior_s=interior,
                                   transfer_s=transfer, correction_s=correction)
        self.executor.observe(report.step_s)
        return report

    # -- the two-level re-solve ----------------------------------------------

    @staticmethod
    def _node_model(profile: NodeProfile, inter=None) -> NodeModel:
        """The single speed-scaling convention profile -> NodeModel (both the
        offline hierarchical solve and the online level-2 re-solve use it)."""
        if not profile.has_models:
            raise RuntimeError(
                f"profile {profile.name!r} has no t_host model; "
                "model-based solves need calibrated profiles"
            )
        s = profile.speed
        return NodeModel(
            t_host=lambda k, f=profile.t_host, s=s: f(k) / s,
            t_accel=None if profile.t_accel is None
            else (lambda k, f=profile.t_accel, s=s: f(k) / s),
            transfer=profile.transfer,
            inter_transfer=inter,
        )

    def node_models(self) -> List[NodeModel]:
        """Per-node ``NodeModel``s from the profiles (speed-scaled), with the
        cluster link's surface model as each node's inter-node transfer."""
        inter = self.inter_transfer_fn()
        return [self._node_model(p, inter=inter) for p in self.profiles]

    def solve_hierarchical(self, overlap: bool = False):
        """The offline two-level solve on the profiles' calibrated models
        (level 1 waterfilling over best-achievable node times, level 2
        two-way splits) — the plan the online loop should converge to."""
        return solve_hierarchical(self.node_models(), self.solver.mesh.K, overlap=overlap)

    def resolve(self, report: Optional[CalibrationReport] = None, overlap: bool = True):
        """Re-solve both levels and resplice.

        Level 1: the fleet ``CalibrationReport`` (pass one from
        ``calibrate``, or the executor's last observation is used) feeds the
        overlap-aware waterfilling solve — new node counts.  Level 2: each
        node with intra-node models re-runs the asymmetric two-way solve at
        its new count — new accelerator block sizes via
        ``set_accel_counts``.  Returns the applied level-1 plan.
        """
        if report is not None:
            plan = self.executor.plan_from_report(report, overlap=overlap)
        else:
            plan = self.executor.rebalance()
        if any(p.has_models and p.t_accel is not None for p in self.profiles):
            accel = []
            for i, p in enumerate(self.profiles):
                k = int(self.executor.counts[i])
                if p.has_models and p.t_accel is not None:
                    res = self._node_model(p).solve(k, overlap=overlap)
                    accel.append(int(res.counts[1]))
                else:
                    accel.append(0)
            self.executor.set_accel_counts(accel)
        return plan

    # -- hooks ----------------------------------------------------------------

    def inject_straggler(self, node: int, factor: float) -> None:
        """The existing straggler hook, at cluster level: multiply node's
        observed times by ``factor``."""
        self.executor.inject_straggler(node, factor)

    def clear_stragglers(self) -> None:
        self.executor.clear_stragglers()

    # -- elastic membership ---------------------------------------------------

    def _rebuild_membership(self, profiles: Sequence[NodeProfile],
                            weights: np.ndarray) -> None:
        """Swap the control plane for a new fleet: a fresh executor seeded
        from ``weights`` (spliced through the shared plan cache, so a
        membership the cache has seen resumes its calibrated split), one
        ``only_blocks`` engine per node, and a lazily rebuilt fused data
        plane.  The solver — and with it the jitted kernel bundle and every
        compiled program keyed on a recurring bucket signature — is shared,
        so joins/leaves recompile nothing at the kernel level."""
        old = self.executor
        cache_root = old.plan_cache.root if old.plan_cache is not None else None
        self.profiles = tuple(profiles)
        self.executor = NestedPartitionExecutor(
            self.solver.mesh.K,
            len(self.profiles),
            grid_dims=tuple(self.solver.mesh.grid),
            bucket=old.bucket,
            accel_fraction=old.accel_fraction,
            rebalance_every=old.rebalance_every,
            initial_weights=np.asarray(weights, dtype=np.float64),
            plan_cache_dir=cache_root,
        )
        self.engines = [
            BlockedDGEngine(self.solver, self.executor, only_blocks=[i])
            for i in range(len(self.profiles))
        ]
        self._fused_engine = None
        self.last_sim_times = None

    def add_node(self, profile: NodeProfile, weight: Optional[float] = None) -> int:
        """A node joins between chunks: re-splice the mesh over N+1 nodes.
        The joiner's seed weight defaults to its nominal speed on the same
        scale as the survivors' current counts (so the splice hands it a
        proportional share immediately; the observe loop refines from
        there).  Returns the new node's index."""
        counts = self.executor.counts.astype(np.float64)
        speeds = np.array([p.speed for p in self.profiles], dtype=np.float64)
        survivors = np.maximum(counts, 1e-9) if counts.sum() else speeds
        per_speed = survivors.sum() / max(speeds.sum(), 1e-30)
        w_new = float(weight) if weight is not None else profile.speed * per_speed
        self._rebuild_membership(
            self.profiles + (profile,), np.concatenate([survivors, [w_new]])
        )
        return self.n_nodes - 1

    def remove_node(self, index: int) -> None:
        """A node leaves (preemption, decommission) between chunks: its
        elements are re-spliced across the survivors, who keep their
        relative calibrated shares."""
        index = int(index)
        if not (0 <= index < self.n_nodes):
            raise ValueError(f"node {index} out of range")
        if self.n_nodes == 1:
            raise RuntimeError("cannot remove the last node")
        counts = self.executor.counts.astype(np.float64)
        speeds = np.array([p.speed for p in self.profiles], dtype=np.float64)
        survivors = np.maximum(counts, 1e-9) if counts.sum() else speeds
        keep = [i for i in range(self.n_nodes) if i != index]
        self._rebuild_membership(
            tuple(self.profiles[i] for i in keep), survivors[keep]
        )

    def run_until_balanced(self, rtol: float = 0.10, max_rounds: int = 8) -> int:
        """Deterministic convergence driver: observe simulated step times
        (speed model + link) and rebalance until within ``rtol`` of the
        common-finish-time optimum."""
        return self.executor.run_until_balanced(
            measure_fn=lambda: self.step_times(), rtol=rtol, max_rounds=max_rounds
        )

    def summary(self) -> str:
        part = self.cluster_partition()
        lines = [
            f"cluster: {self.n_nodes} nodes, K={self.solver.mesh.K}, "
            f"link={self.link.name} ({self.link.bandwidth / 1e9:.1f} GB/s, "
            f"{self.link.latency * 1e6:.1f} us)"
        ]
        comm = self.comm_times()
        for i, p in enumerate(self.profiles):
            npart = part.nodes[i]
            lines.append(
                f"  {p.name}[{i}]: speed={p.speed:g} elements={npart.n_elements} "
                f"boundary={len(npart.boundary)} accel={len(npart.accel)} "
                f"halo={0 if npart.halo is None else len(npart.halo)} "
                f"comm={comm[i] * 1e6:.1f}us"
            )
        return "\n".join(lines)


# ---------------------------------------------------------------------------
# The two-level plan, printable (launch.dryrun --cluster-plan)
# ---------------------------------------------------------------------------


def format_cluster_plan(
    grid: tuple,
    n_nodes: int,
    order: int = 7,
    speeds: Optional[Sequence[float]] = None,
    link: LinkClass = STAMPEDE_IB,
    overlap: bool = True,
) -> str:
    """Solve and render the two-level plan for ``n_nodes`` Stampede-profile
    nodes over a ``grid`` mesh: the level-1 Morton splice (counts, cut
    faces, link time) and each node's level-2 boundary/interior/accelerator
    split with predicted times.  ``speeds`` introduces heterogeneity."""
    from repro.core.partition import build_cluster_partition

    K = int(np.prod(grid))
    speeds = np.ones(n_nodes) if speeds is None else np.asarray(speeds, dtype=np.float64)
    if len(speeds) != n_nodes:
        raise ValueError(f"need {n_nodes} speeds, got {len(speeds)}")
    t_cpu, t_mic, xfer = stampede_node_models(order)
    inter = inter_node_transfer_fn(order, link=link)
    models = [
        NodeModel(
            t_host=lambda k, s=s: t_cpu(k) / s,
            t_accel=lambda k, s=s: t_mic(k) / s,
            transfer=xfer,
            inter_transfer=inter if n_nodes > 1 else None,
        )
        for s in speeds
    ]
    split = solve_hierarchical(models, K, overlap=overlap)
    part = build_cluster_partition(
        grid,
        node_weights=np.maximum(split.node_counts, 0)
        if sum(split.node_counts) else None,
        n_nodes=n_nodes,
        accel_counts=split.accel_counts,
    )
    part.validate()
    cuts = part.face_cuts()
    lines = [
        f"two-level plan: grid={grid} K={K} nodes={n_nodes} order={order} "
        f"link={link.name} overlap={'on' if overlap else 'off'}",
        f"level 0 (Morton inter-node splice): counts={list(split.node_counts)} "
        f"cut_faces={int(cuts.sum())} makespan={split.makespan * 1e3:.2f}ms "
        f"imbalance={split.imbalance:.3f}",
    ]
    for i, npart in enumerate(part.nodes):
        res = split.node_splits[i]
        lines.append(
            f"  node{i} (speed {speeds[i]:g}): {npart.n_elements} elements -> "
            f"boundary={len(npart.boundary)} host_interior={len(npart.host_interior)} "
            f"accel={len(npart.accel)} (K_acc/K_host={res.ratio:.2f}) "
            f"halo={0 if npart.halo is None else len(npart.halo)} "
            f"t={split.times[i] * 1e3:.2f}ms"
        )
    return "\n".join(lines)
