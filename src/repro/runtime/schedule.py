"""The boundary/interior step schedule — paper Fig 5.1 as a four-phase object.

The paper's level-2 idea is a *schedule*, not just an element split: compute
the boundary faces first, launch the (slow-link) halo exchange, compute the
interior volume work while the exchange is in flight, and fold the received
halo back in once per step.  ``StepSchedule`` makes that four-phase
decomposition of one RHS evaluation explicit:

    1. **boundary**   — boundary-face compute / pack: produce the payload
                        that must cross a link (packed faces on the SPMD
                        slab path; the halo index set on the blocked engine);
    2. **exchange**   — the async halo exchange, *issued before* interior
                        work so the scheduler can overlap the two;
    3. **interior**   — volume compute with no halo dependence (this is
                        what hides the transfer);
    4. **correction** — fold the received halo into the partial result.

``rhs`` composes the phases in that order; because phase 3 has no data
dependence on phase 2's output, XLA's latency-hiding scheduler (or an async
backend) overlaps them — the dataflow form of the paper's CPU/MIC timeline.

Both DG execution engines are thin instantiations of this object:
``repro.dg.partitioned.PartitionedDG`` (SPMD slabs, ring ppermute exchange)
and ``repro.runtime.executor.BlockedDGEngine`` (per-partition blocks, halo
gather exchange).  ``CalibrationReport`` is the measurement side of the same
decomposition: per-partition seconds for each phase, plus the overlap-aware
step model ``t = boundary + max(interior, transfer) + correction`` that the
load-balance planner consumes (so a partition that hides its transfer under
interior compute is credited for it, paper section 5.6).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, List, Optional, Sequence

import numpy as np

__all__ = ["StepSchedule", "CalibrationReport", "DispatchStats"]


@dataclasses.dataclass
class DispatchStats:
    """Host dispatches vs device steps advanced — the shared introspection
    ledger of every fused step driver (``runtime.pipeline.FusedStepPipeline``
    and ``ShardedStepPipeline`` both embed one).

    The fused drivers' whole point is O(1) dispatches per ``run()``
    regardless of step horizon, slab count and device count; the
    dispatch-count regression tests (``tests/test_pipeline.py``,
    ``tests/test_multidevice.py``) assert on this ledger so a future edit
    cannot silently re-Python-loop the hot path."""

    dispatches: int = 0
    steps_run: int = 0
    # fused chunks observed in-scan (run_observed calls): together with
    # ``dispatches`` this pins the observe-path invariant — observe=True
    # costs exactly ONE dispatch per rebalance chunk, never one per step
    observe_chunks: int = 0
    # per-kernel launch sites per RHS evaluation inside the most recently
    # used compiled program, recorded at TRACE time (the stage scan traces
    # its body once, so launch sites per rhs = launches per stage = launches
    # per step up to the constant 5 LSRK stages).  The envelope-layout fused
    # pipeline must read {"volume": 1, "surface": 1} here regardless of the
    # bucket split — the per-kernel half of the dispatch-count regression.
    kernel_launches: dict = dataclasses.field(default_factory=dict)

    def record(self, dispatches: int, steps: int) -> None:
        self.dispatches += int(dispatches)
        self.steps_run += int(steps)

    def record_chunk(self, n: int = 1) -> None:
        """Ledger one observed fused chunk (an in-scan ``run_observed``)."""
        self.observe_chunks += int(n)

    def record_launches(self, counts: dict) -> None:
        """Install the per-kernel launch-site counts of the program that
        just ran (replaces, not accumulates: the counts describe ONE
        compiled program, not a running total)."""
        self.kernel_launches = {str(k): int(v) for k, v in counts.items()}

    @property
    def dispatches_per_step(self) -> float:
        return self.dispatches / max(1, self.steps_run)


@dataclasses.dataclass
class StepSchedule:
    """One RHS evaluation as four named phases (see module docstring).

    The callables share an opaque ``state`` (whatever the instantiating
    engine carries — field arrays, index tables):

      * ``boundary(state) -> send``              (phase 1: compute + pack)
      * ``exchange(send, state) -> recv``        (phase 2: async halo exchange)
      * ``interior(state) -> partial``           (phase 3: overlapped compute)
      * ``correction(partial, recv, state) -> out``   (phase 4: fold halo in)
    """

    boundary: Callable[[Any], Any]
    exchange: Callable[[Any, Any], Any]
    interior: Callable[[Any], Any]
    correction: Callable[[Any, Any, Any], Any]
    name: str = "step"

    PHASES = ("boundary", "exchange", "interior", "correction")

    def rhs(self, state):
        """Composed evaluation, exchange issued before interior.

        Trace order is the overlap order: the exchange enters the program
        before the (independent) interior compute, which is exactly what
        lets the scheduler run the two concurrently.
        """
        send = self.boundary(state)
        recv = self.exchange(send, state)
        part = self.interior(state)
        return self.correction(part, recv, state)

    def rhs_many(self, states):
        """Phase-major composition over many independent per-block states:
        every boundary pack and exchange is issued before any interior
        compute, so an async backend can overlap ALL of a step's transfers
        with ALL of its interior work instead of only block-local pairs.

        The blocks are independent (their phases never read each other's
        results), so the returned list is element-wise identical to mapping
        :meth:`rhs` over ``states`` — only the issue order changes.  This is
        the dispatch order the fused pipeline (``runtime.pipeline``) bakes
        into its single compiled program; here it is available to the
        eager per-block engine as well.
        """
        sends = [self.boundary(st) for st in states]
        recvs = [self.exchange(send, st) for send, st in zip(sends, states)]
        parts = [self.interior(st) for st in states]
        return [
            self.correction(part, recv, st)
            for part, recv, st in zip(parts, recvs, states)
        ]


def _zeros_like(a: np.ndarray) -> np.ndarray:
    return np.zeros_like(np.asarray(a, dtype=np.float64))


@dataclasses.dataclass(frozen=True)
class CalibrationReport:
    """Per-partition seconds for the four schedule phases (paper sec. 5.6).

    ``boundary_s`` is face-flux work wherever it executes (on the blocked
    engine the face flux runs inside the correction phase, but it is still
    boundary-face work and is attributed here); ``correction_s`` is the
    residual fold/assemble cost.  ``transfer_s`` is the slow-link halo
    exchange — the component the overlap schedule can hide.
    """

    boundary_s: np.ndarray  # face-flux work (the host keeps the network)
    interior_s: np.ndarray  # volume work (what the accelerator absorbs)
    transfer_s: np.ndarray  # slow-link exchange of the halo / shared faces
    correction_s: Optional[np.ndarray] = None  # halo fold-in (defaults to 0)

    def __post_init__(self):
        if self.correction_s is None:
            object.__setattr__(self, "correction_s", _zeros_like(self.boundary_s))

    # -- derived step models ------------------------------------------------

    @property
    def step_s(self) -> np.ndarray:
        """Sequential step: every phase back-to-back (no overlap)."""
        return self.boundary_s + self.interior_s + self.transfer_s + self.correction_s

    @property
    def overlapped_s(self) -> np.ndarray:
        """Overlap-aware step: interior hides the transfer (Fig 5.1)."""
        return (
            self.boundary_s
            + np.maximum(self.interior_s, self.transfer_s)
            + self.correction_s
        )

    @property
    def hidden_s(self) -> np.ndarray:
        """Transfer seconds hidden under interior compute per step."""
        return np.minimum(self.interior_s, self.transfer_s)

    @property
    def overlap_efficiency(self) -> np.ndarray:
        """hidden transfer / total transfer in [0, 1] (1.0 = fully hidden;
        defined as 1.0 where there is no transfer at all)."""
        t = np.asarray(self.transfer_s, dtype=np.float64)
        with np.errstate(divide="ignore", invalid="ignore"):
            eff = np.where(t > 0, self.hidden_s / np.where(t > 0, t, 1.0), 1.0)
        return eff

    # -- constructors -------------------------------------------------------

    @staticmethod
    def from_totals(step_s: Sequence[float]) -> "CalibrationReport":
        """A report from component-UNresolved per-partition step seconds
        (wall-clock attribution, whole-step time models).  The total lands
        in ``interior_s`` purely as a carrier; such a report makes no claim
        about phase composition and its ``overlap_efficiency`` is trivially
        1.0 everywhere."""
        t = np.asarray(step_s, dtype=np.float64)
        z = np.zeros_like(t)
        return CalibrationReport(boundary_s=z, interior_s=t, transfer_s=z.copy(),
                                 correction_s=z.copy())

    @staticmethod
    def from_chunk(
        wall_s: float, shares: Sequence[float], n_steps: int
    ) -> "CalibrationReport":
        """A report from ONE fused chunk: total host wall seconds for the
        chunk (``block_until_ready`` once per chunk), attributed across
        partitions by the in-scan accumulator ``shares`` — the carry-riding
        per-partition cost totals the fused scan accumulated on device.

        The chunk's per-step wall time is split proportionally to the
        shares (degenerate all-zero shares fall back to uniform), so the
        sum of the per-partition step seconds equals ``wall_s / n_steps``
        and the executor's throughput model sees real elapsed time at
        chunk granularity without a single extra dispatch.  Like
        ``from_totals`` the result is component-unresolved."""
        s = np.asarray(shares, dtype=np.float64)
        if s.ndim != 1 or len(s) == 0:
            raise ValueError(f"shares must be a non-empty vector, got shape {s.shape}")
        s = np.maximum(s, 0.0)
        tot = s.sum()
        s = s / tot if tot > 0 else np.full(len(s), 1.0 / len(s))
        per_step = float(wall_s) / max(1, int(n_steps))
        return CalibrationReport.from_totals(per_step * s)

    @staticmethod
    def median(reports: Sequence["CalibrationReport"]) -> "CalibrationReport":
        """Component-wise median over repeated calibration steps."""
        # materialize first: a lazily-consumed iterable (generator) would
        # slip past the emptiness check and surface as numpy's opaque
        # "need at least one array to stack" from np.stack below
        reports = list(reports)
        if not reports:
            raise ValueError(
                "CalibrationReport.median needs at least one report "
                "(got an empty sequence — did calibration run zero steps?)"
            )
        return CalibrationReport(
            boundary_s=np.median(np.stack([r.boundary_s for r in reports]), axis=0),
            interior_s=np.median(np.stack([r.interior_s for r in reports]), axis=0),
            transfer_s=np.median(np.stack([r.transfer_s for r in reports]), axis=0),
            correction_s=np.median(np.stack([r.correction_s for r in reports]), axis=0),
        )

    # -- planner interface --------------------------------------------------

    def time_models(
        self,
        counts: Sequence[int],
        overlap: bool = True,
        transfer_exponent: float = 2.0 / 3.0,
    ) -> List[Callable[[float], float]]:
        """Per-partition ``t_p(k)`` callables for the load-balance solvers.

        Compute phases scale linearly from the calibrated element counts;
        transfer scales with ``k**(2/3)`` (Morton-compact surface area,
        paper section 5.5).  With ``overlap=True`` the model is the paper's
        ``t = boundary + max(interior, transfer) + correction``, so the
        planner credits a partition for transfer hidden under interior work.

        A partition with no calibrated work at all (every phase 0.0 — e.g.
        its count was 0 when the engine measured) gets the fleet-mean phase
        times as a prior, mirroring ``rebalance_from_measurements``:
        otherwise its model would be identically zero and the waterfilling
        solve would dump the whole workload on it.
        """
        counts = np.asarray(counts, dtype=np.float64)
        P = len(counts)
        phases = np.stack([np.asarray(self.boundary_s, dtype=np.float64),
                           np.asarray(self.interior_s, dtype=np.float64),
                           np.asarray(self.transfer_s, dtype=np.float64),
                           np.asarray(self.correction_s, dtype=np.float64)])
        alive = phases.sum(axis=0) > 0
        if alive.any() and not alive.all():
            prior = phases[:, alive].mean(axis=1)
            c_prior = max(1.0, float(counts[alive].mean()))
            phases = phases.copy()
            phases[:, ~alive] = prior[:, None]
            counts = np.where(alive, counts, c_prior)
        fns: List[Callable[[float], float]] = []
        for p in range(P):
            c = max(1.0, float(counts[p]))
            b, i = float(phases[0, p]), float(phases[1, p])
            x, co = float(phases[2, p]), float(phases[3, p])

            def t(k: float, b=b, i=i, x=x, co=co, c=c) -> float:
                k = float(k)
                if k <= 0:
                    return 0.0
                scale = k / c
                xfer = x * scale**transfer_exponent
                compute = i * scale
                hot = max(compute, xfer) if overlap else compute + xfer
                return b * scale + hot + co * scale

            fns.append(t)
        return fns

    # -- reporting ----------------------------------------------------------

    def summary(self) -> str:
        rows = []
        eff = self.overlap_efficiency
        for p in range(len(self.boundary_s)):
            rows.append(
                f"p{p}: boundary={self.boundary_s[p] * 1e3:.2f}ms "
                f"interior={self.interior_s[p] * 1e3:.2f}ms "
                f"transfer={self.transfer_s[p] * 1e3:.2f}ms "
                f"correction={self.correction_s[p] * 1e3:.2f}ms "
                f"overlapped={self.overlapped_s[p] * 1e3:.2f}ms "
                f"overlap-eff={eff[p] * 100:.0f}%"
            )
        return "\n".join(rows)
