"""Fault tolerance and elasticity for the fused nested-partition runtime.

Two supervisors share one failure machinery, all timed with
``time.perf_counter`` (monotonic — an NTP step never reads as a straggler):

* :class:`RunSupervisor` — the fused-engine layer.  Wraps any
  ``Engine.run(observe=True)`` chunk loop (``BlockedDGEngine``,
  ``SimulatedCluster``) with

  1. **checkpoint/replay** — ``(q, step, plan)`` snapshots through
     ``repro.checkpoint`` every K rebalance chunks; on an unrecoverable
     chunk the latest snapshot is restored and replayed.  The field update
     is split-independent (the partition is a reordering, never an
     approximation), so the replayed run lands on a final ``q`` bitwise
     identical to an uninterrupted one even when the replayed plans
     diverge;
  2. **retry / timeout / backoff** — transient chunk failures (a
     :class:`FailureInjector` raising inside the engine's node dispatches,
     or a chunk overrunning ``chunk_timeout_s``) are retried with
     exponential backoff before escalating to restore;
  3. **straggler ejection** — the per-partition EWMA the observe channel
     already feeds the executor is mirrored into a :class:`StepTimer`; a
     partition flagged for ``eject_after`` consecutive chunks is ejected
     (weight -> 0, survivors re-spliced) through
     ``NestedPartitionExecutor.eject``.  Ejection is not sticky: the timer
     clears its flag when the EWMA recovers, and ``readmit`` re-splices
     the node back in;
  4. **elastic membership** — ``at_step`` schedules arbitrary
     between-chunk actions (``SimulatedCluster.add_node`` /
     ``remove_node``), so a node can join or leave mid-run without
     breaking the fused loop: every chunk stays ONE dispatch, verified by
     the supervisor's :meth:`ledger` over the pipelines it has driven.

* :class:`TrainSupervisor` — the optimizer-step layer (``launch.train``):
  retry -> restore -> replay over a deterministic batch pipeline, with an
  optional online executor riding along.

:class:`FailureInjector` drives both, plus ``SimulatedCluster`` node
dispatches and ``ContinuousBatchingLoop`` decode chunks: a deterministic
``{step: kind}`` schedule and/or a seeded per-step Bernoulli draw
(``seed`` + ``p_fail``) — the probabilistic form is keyed on
``(seed, step)`` so a given step's verdict is reproducible regardless of
how many times other steps were probed, and each step fires at most once
(a retried step succeeds, modelling a transient fault).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Set, Tuple

import numpy as np

from repro.core.load_balance import rebalance_from_measurements

__all__ = [
    "InjectedFailure",
    "ChunkTimeout",
    "FailureInjector",
    "StepTimer",
    "RunSupervisor",
    "TrainSupervisor",
]


class InjectedFailure(RuntimeError):
    """A failure raised by :class:`FailureInjector` (carries its class)."""

    def __init__(self, step: int, kind: str, node: Optional[int] = None):
        self.step = int(step)
        self.kind = str(kind)
        self.node = node
        where = f" on node {node}" if node is not None else ""
        super().__init__(f"injected failure at step {step}{where}: {kind}")


class ChunkTimeout(RuntimeError):
    """A chunk exceeded the supervisor's ``chunk_timeout_s`` budget."""


class FailureInjector:
    """Deterministic failure source for chaos tests and benchmarks.

    Two composable schedules, both firing at most once per step:

    * **dict form** (the original API): ``{step: kind}`` raises
      :class:`InjectedFailure` the first time ``maybe_fail(step)`` is
      called for that step.  A value may also be ``(kind, node)`` to
      target one node's dispatch (callers that probe per node pass
      ``node=``; untargeted entries fire for any node).
    * **probabilistic form**: ``seed`` + ``p_fail`` draw a Bernoulli
      verdict per step from ``default_rng((seed, step))`` — deterministic
      in ``(seed, step)`` alone, so two runs with the same seed inject the
      identical failure sequence no matter how retries interleave.
      ``max_failures`` caps the total injected.
    """

    def __init__(
        self,
        schedule: Optional[Dict[int, Any]] = None,
        *,
        seed: Optional[int] = None,
        p_fail: float = 0.0,
        kind: str = "transient",
        max_failures: Optional[int] = None,
    ):
        self.schedule = dict(schedule or {})
        self.fired: Set[int] = set()
        self.seed = seed
        self.p_fail = float(p_fail)
        self.kind = str(kind)
        self.max_failures = max_failures
        self.injected = 0

    def _raise(self, step: int, kind: str, node: Optional[int]) -> None:
        self.fired.add(step)
        self.injected += 1
        raise InjectedFailure(step, kind, node)

    def maybe_fail(self, step: int, node: Optional[int] = None) -> None:
        step = int(step)
        if step in self.schedule and step not in self.fired:
            entry = self.schedule[step]
            kind, target = entry if isinstance(entry, tuple) else (entry, None)
            if target is None or node is None or int(target) == int(node):
                self._raise(step, kind, node)
        if (
            self.seed is not None
            and self.p_fail > 0.0
            and step not in self.fired
            and (self.max_failures is None or self.injected < self.max_failures)
        ):
            # keyed on (seed, step): the verdict for a step never depends on
            # how many other steps were probed (or re-probed on retry)
            draw = np.random.default_rng((int(self.seed), step)).random()
            if draw < self.p_fail:
                self._raise(step, self.kind, node)


@dataclasses.dataclass
class StepTimer:
    """EWMA step timing with hysteretic straggler flags over named keys.

    A key flags when its EWMA exceeds ``straggler_factor`` x the fleet
    median and *clears* when it drops back under ``recovery_factor`` x the
    median (default: the same threshold) — flags are not sticky, so an
    ejected node whose times recover can be readmitted.  ``streak`` counts
    consecutive flagged updates per key; :meth:`persistent` filters for
    stragglers that have stayed flagged long enough to act on.
    """

    alpha: float = 0.2
    straggler_factor: float = 1.5
    recovery_factor: Optional[float] = None
    ewma: Dict[str, float] = dataclasses.field(default_factory=dict)
    flagged: Set[str] = dataclasses.field(default_factory=set)
    streak: Dict[str, int] = dataclasses.field(default_factory=dict)

    def update(self, times: Dict[str, float]) -> List[str]:
        """Fold in one round of per-key seconds; returns the keys currently
        flagged (hysteresis applied)."""
        for k, t in times.items():
            prev = self.ewma.get(k)
            self.ewma[k] = t if prev is None else (1 - self.alpha) * prev + self.alpha * t
        med = float(np.median(list(self.ewma.values())))
        recover = self.straggler_factor if self.recovery_factor is None else self.recovery_factor
        for k, v in self.ewma.items():
            if med > 0 and v > self.straggler_factor * med:
                self.flagged.add(k)
                self.streak[k] = self.streak.get(k, 0) + 1
            elif k in self.flagged:
                if med <= 0 or v <= recover * med:
                    self.flagged.discard(k)
                    self.streak[k] = 0
                else:
                    self.streak[k] = self.streak.get(k, 0) + 1
            else:
                self.streak[k] = 0
        return [k for k in self.ewma if k in self.flagged]

    def persistent(self, patience: int) -> List[str]:
        """Keys flagged for at least ``patience`` consecutive updates."""
        return [k for k in self.ewma if self.streak.get(k, 0) >= int(patience)]

    def rebalance(self, counts: Sequence[int], order: Sequence[str]) -> np.ndarray:
        times = [self.ewma[k] for k in order]
        return rebalance_from_measurements(counts, times)


# ---------------------------------------------------------------------------
# RunSupervisor — the fused-engine fault-tolerance layer
# ---------------------------------------------------------------------------


class RunSupervisor:
    """Drives an ``Engine``'s fused ``run(observe=True)`` loop chunk by
    chunk with checkpoint/replay, retry/backoff, straggler ejection and
    between-chunk elasticity (see module docstring).

    The engine must carry a ``NestedPartitionExecutor`` on ``.executor``
    (``BlockedDGEngine`` and ``SimulatedCluster`` both do; the attribute is
    re-read every chunk, so engines that rebuild their executor on a
    membership change keep working).  Chunks are sized by the executor's
    ``rebalance_every`` — the same boundaries the engine's own observe loop
    uses, so a supervised run performs the identical per-chunk dispatches
    as an unsupervised one.

    ``ckpt_dir=None`` keeps snapshots in host memory (tests, benchmarks);
    a directory persists them through ``repro.checkpoint`` so a *new*
    process — possibly with a different partition count — can
    :meth:`resume` (``q`` is split-independent; the plan state is restored
    only when the partition counts still line up).
    """

    def __init__(
        self,
        engine,
        *,
        ckpt_dir: Optional[str] = None,
        ckpt_every_chunks: int = 1,
        keep: int = 3,
        max_retries: int = 1,
        backoff_s: float = 0.0,
        backoff_factor: float = 2.0,
        chunk_timeout_s: Optional[float] = None,
        injector: Optional[FailureInjector] = None,
        timer: Optional[StepTimer] = None,
        eject_after: int = 0,
        on_chunk: Optional[Callable[[int, Any], None]] = None,
    ):
        self.engine = engine
        self.ckpt_dir = ckpt_dir
        self.ckpt_every_chunks = int(ckpt_every_chunks)
        self.keep = int(keep)
        self.max_retries = int(max_retries)
        self.backoff_s = float(backoff_s)
        self.backoff_factor = float(backoff_factor)
        self.chunk_timeout_s = chunk_timeout_s
        self.injector = injector
        self.timer = timer if timer is not None else StepTimer(alpha=0.5)
        self.eject_after = int(eject_after)  # 0 disables auto-ejection
        self.on_chunk = on_chunk

        self.retries = 0
        self.restarts = 0
        self.timeouts = 0
        self.replayed_steps = 0
        self.chunks_run = 0  # every dispatched chunk, replays included
        self.recovery_s = 0.0  # wall spent in backoff sleeps + restores
        self.ejected: List[int] = []
        self._snapshots: List[Tuple[int, np.ndarray, dict]] = []
        self._scheduled: List[Tuple[int, Callable[[], None]]] = []
        self._ledgers: List[Any] = []

    # -- elasticity hooks ---------------------------------------------------

    @property
    def executor(self):
        return self.engine.executor

    def at_step(self, step: int, fn: Callable[[], None]) -> None:
        """Schedule ``fn`` to run at the first chunk boundary at or after
        ``step`` — the elastic-membership hook (``add_node`` /
        ``remove_node``, straggler injection, SLO changes...)."""
        self._scheduled.append((int(step), fn))
        self._scheduled.sort(key=lambda e: e[0])

    def readmit(self, partition: int, weight: Optional[float] = None) -> None:
        """Undo an ejection: re-splice the node back in (its timer streak
        restarts from zero)."""
        self.executor.readmit(partition, weight=weight)
        if partition in self.ejected:
            self.ejected.remove(partition)
        self.timer.streak[str(partition)] = 0

    # -- checkpoint / restore -----------------------------------------------

    def _plan_state(self) -> dict:
        ex = self.executor
        return {
            "counts": [int(c) for c in ex.counts],
            "weights": [float(w) for w in ex.weights],
            "round": int(ex.round),
            "exec_step": int(ex._step),
            "ejected": sorted(int(p) for p in ex.ejected),
        }

    def _save(self, step: int, q) -> None:
        import jax

        q_np = np.asarray(jax.device_get(q))
        meta = self._plan_state()
        if self.ckpt_dir is None:
            self._snapshots.append((int(step), q_np.copy(), meta))
            del self._snapshots[: -self.keep]
            return
        from repro.checkpoint import prune, save

        save(self.ckpt_dir, int(step), {"q": q_np}, extra_meta=meta)
        prune(self.ckpt_dir, keep=self.keep)

    def _restore(self):
        """Latest snapshot -> (q, step); re-installs the plan state when the
        partition count still matches (after a membership change only ``q``
        is restored — the new fleet keeps its own plan)."""
        import jax.numpy as jnp

        if self.ckpt_dir is None:
            if not self._snapshots:
                raise RuntimeError("restore before any snapshot")
            step, q_np, meta = self._snapshots[-1]
        else:
            from repro.checkpoint import restore

            tree, manifest = restore(self.ckpt_dir, {"q": 0})
            step, q_np, meta = manifest["step"], np.asarray(tree["q"]), manifest["extra"]
        ex = self.executor
        if len(meta.get("counts", [])) == ex.n_partitions:
            ex.restore_state(meta)
        return jnp.asarray(q_np), int(step)

    def resume(self) -> Tuple[Any, int]:
        """Entry point for a NEW process (or a rebuilt engine with a
        different mesh/node count): load the latest persisted snapshot and
        return ``(q, step)`` to pass to :meth:`run` as the remaining
        horizon's start state."""
        if self.ckpt_dir is None:
            raise RuntimeError("resume needs a persistent ckpt_dir")
        return self._restore()

    # -- the supervised chunk loop ------------------------------------------

    def _chunk_size(self, remaining: int, step: int) -> int:
        """Chunk = the executor's rebalance cadence, clamped so the next
        ``at_step`` action lands exactly on a chunk boundary (splitting a
        chunk is bitwise-free: the stage residual resets every step)."""
        every = int(getattr(self.executor, "rebalance_every", 0) or 0)
        chunk = min(remaining, every) if every > 0 else remaining
        if self._scheduled:
            nxt = int(self._scheduled[0][0])
            if step < nxt < step + chunk:
                chunk = nxt - step
        return chunk

    def _run_scheduled(self, step: int) -> None:
        while self._scheduled and self._scheduled[0][0] <= step:
            _, fn = self._scheduled.pop(0)
            fn()

    def _track_ledger(self) -> None:
        pipe_fn = getattr(self.engine, "fused_pipeline", None) or getattr(
            self.engine, "pipeline", None
        )
        if pipe_fn is None:
            return
        stats = pipe_fn().stats
        if not any(s is stats for s in self._ledgers):
            self._ledgers.append(stats)

    def ledger(self) -> dict:
        """The dispatch ledger across every pipeline this run drove (a
        membership change swaps pipelines; their stats are summed): recovery
        must never un-fuse the loop, i.e. ``dispatches == chunks_run`` and
        ``observe_chunks == chunks_run``."""
        out = {"chunks_run": self.chunks_run, "dispatches": 0, "observe_chunks": 0,
               "kernel_launches": {}}
        for s in self._ledgers:
            out["dispatches"] += s.dispatches
            out["observe_chunks"] += s.observe_chunks
            for k, v in s.kernel_launches.items():
                out["kernel_launches"][k] = max(out["kernel_launches"].get(k, 0), v)
        return out

    def _feed_timer(self) -> None:
        ex = self.executor
        if ex._ewma is None:
            return
        flags = self.timer.update({str(p): float(t) for p, t in enumerate(ex._ewma)})
        if self.eject_after <= 0 or not flags:
            return
        for key in self.timer.persistent(self.eject_after):
            p = int(key)
            if p not in ex.ejected and ex.n_partitions - len(ex.ejected) > 1:
                ex.eject(p)
                self.ejected.append(p)

    def run(self, q, n_steps: int, dt: Optional[float] = None, *, start_step: int = 0):
        """Advance ``n_steps`` with full fault tolerance; returns the final
        ``q`` (bitwise identical to an uninterrupted fused run)."""
        step = int(start_step)
        end = step + int(n_steps)
        chunk_idx = 0
        self._save(step, q)
        while step < end:
            self._run_scheduled(step)
            chunk = self._chunk_size(end - step, step)
            attempts = 0
            delay = self.backoff_s
            while True:
                try:
                    if self.injector is not None:
                        self.injector.maybe_fail(step)
                    t0 = time.perf_counter()
                    q_next = self.engine.run(q, chunk, dt=dt, observe=True, fused=True)
                    wall = time.perf_counter() - t0
                    self.chunks_run += 1
                    self._track_ledger()
                    if self.chunk_timeout_s is not None and wall > self.chunk_timeout_s:
                        self.timeouts += 1
                        raise ChunkTimeout(
                            f"chunk at step {step} took {wall:.3f}s "
                            f"(budget {self.chunk_timeout_s:.3f}s)"
                        )
                    break
                except Exception:  # noqa: BLE001 — retry, then restore+replay
                    t_rec = time.perf_counter()
                    attempts += 1
                    if attempts <= self.max_retries:
                        self.retries += 1
                        if delay > 0:
                            time.sleep(delay)
                            delay *= self.backoff_factor
                        self.recovery_s += time.perf_counter() - t_rec
                        continue
                    self.restarts += 1
                    q, restored = self._restore()
                    self.replayed_steps += step - restored
                    step = restored
                    chunk = self._chunk_size(end - step, step)
                    attempts = 0
                    delay = self.backoff_s
                    self.recovery_s += time.perf_counter() - t_rec
            q = q_next
            step += chunk
            chunk_idx += 1
            self._feed_timer()
            if self.on_chunk is not None:
                self.on_chunk(step, q)
            if self.ckpt_every_chunks > 0 and chunk_idx % self.ckpt_every_chunks == 0:
                self._save(step, q)
        if self.ckpt_every_chunks > 0:
            self._save(step, q)  # final state, whatever the cadence
        return q


# ---------------------------------------------------------------------------
# TrainSupervisor — the optimizer-step layer (launch.train)
# ---------------------------------------------------------------------------


class TrainSupervisor:
    """Runs (step_fn, state) with retry + checkpoint-restart.

    step_fn: (state, step, batch) -> (state, metrics)
    save_fn: (step, state) -> None        (checkpoint)
    restore_fn: () -> (step, state)       (latest checkpoint)
    batch_fn: (step) -> batch             (deterministic pipeline)
    """

    def __init__(
        self,
        step_fn: Callable,
        batch_fn: Callable,
        save_fn: Callable,
        restore_fn: Callable,
        *,
        ckpt_every: int = 50,
        max_retries: int = 1,
        injector: Optional[FailureInjector] = None,
        on_metrics: Optional[Callable] = None,
        executor=None,
    ):
        """``executor`` — an optional
        ``repro.runtime.executor.NestedPartitionExecutor``: each step's wall
        time is observed and the work split re-solved on its schedule (the
        paper's section-5.6 equalizer run online; supersedes the ad-hoc
        StepTimer-only straggler EWMA)."""
        self.step_fn = step_fn
        self.batch_fn = batch_fn
        self.save_fn = save_fn
        self.restore_fn = restore_fn
        self.ckpt_every = ckpt_every
        self.max_retries = max_retries
        self.injector = injector
        self.on_metrics = on_metrics
        self.executor = executor
        self.timer = StepTimer()
        self.restarts = 0
        self.retries = 0

    def run(self, state, start_step: int, n_steps: int):
        step = start_step
        end = start_step + n_steps
        while step < end:
            batch = self.batch_fn(step)
            attempts = 0
            while True:
                try:
                    if self.injector is not None:
                        self.injector.maybe_fail(step)
                    t0 = time.perf_counter()
                    state, metrics = self.step_fn(state, step, batch)
                    dt = time.perf_counter() - t0
                    break
                except Exception:  # noqa: BLE001 — retry then restore
                    attempts += 1
                    if attempts <= self.max_retries:
                        self.retries += 1
                        continue
                    # unrecoverable for this incarnation: restore + replay
                    self.restarts += 1
                    step, state = self.restore_fn()
                    batch = self.batch_fn(step)
                    attempts = 0
            stragglers = self.timer.update({"global": dt})
            if self.executor is not None:
                self.executor.observe_total(dt)
                self.executor.maybe_rebalance(step + 1)
            if self.on_metrics is not None:
                self.on_metrics(step, metrics, dt, stragglers)
            step += 1
            if step % self.ckpt_every == 0:
                self.save_fn(step, state)
        return step, state
