"""Fault tolerance and straggler mitigation for the training loop.

Three mechanisms, composable and individually tested:

* **checkpoint/restart** — the supervisor owns a CheckpointManager; on any
  step exception it restores the latest complete checkpoint (possibly onto
  a *different* mesh — elastic) and replays from there.  The deterministic
  data pipeline guarantees replayed batches are identical.

* **straggler detection** — per-step wall times per partition feed an EWMA;
  a partition slower than ``straggler_factor`` x median is flagged and the
  paper's equalizer (``rebalance_from_measurements``) computes new work
  weights.  This is literally section 5.6 run online: a straggler is a
  device class whose calibrated throughput just dropped.

* **step retry** — transient failures (preemption signals, network blips —
  simulated via FailureInjector) retry the same step up to ``max_retries``
  before escalating to restore.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

from repro.core.load_balance import rebalance_from_measurements


class FailureInjector:
    """Deterministic failure schedule for tests: fail step N with exc E."""

    def __init__(self, schedule: Optional[Dict[int, str]] = None):
        self.schedule = dict(schedule or {})
        self.fired = set()

    def maybe_fail(self, step: int):
        if step in self.schedule and step not in self.fired:
            self.fired.add(step)
            kind = self.schedule[step]
            raise RuntimeError(f"injected failure at step {step}: {kind}")


@dataclasses.dataclass
class StepTimer:
    """EWMA step timing + straggler flags over named partitions."""

    alpha: float = 0.2
    straggler_factor: float = 1.5
    ewma: Dict[str, float] = dataclasses.field(default_factory=dict)

    def update(self, times: Dict[str, float]) -> List[str]:
        for k, t in times.items():
            prev = self.ewma.get(k)
            self.ewma[k] = t if prev is None else (1 - self.alpha) * prev + self.alpha * t
        med = float(np.median(list(self.ewma.values())))
        return [k for k, v in self.ewma.items() if med > 0 and v > self.straggler_factor * med]

    def rebalance(self, counts: Sequence[int], order: Sequence[str]) -> np.ndarray:
        times = [self.ewma[k] for k in order]
        return rebalance_from_measurements(counts, times)


class TrainSupervisor:
    """Runs (step_fn, state) with retry + checkpoint-restart.

    step_fn: (state, step, batch) -> (state, metrics)
    save_fn: (step, state) -> None        (checkpoint)
    restore_fn: () -> (step, state)       (latest checkpoint)
    batch_fn: (step) -> batch             (deterministic pipeline)
    """

    def __init__(
        self,
        step_fn: Callable,
        batch_fn: Callable,
        save_fn: Callable,
        restore_fn: Callable,
        *,
        ckpt_every: int = 50,
        max_retries: int = 1,
        injector: Optional[FailureInjector] = None,
        on_metrics: Optional[Callable] = None,
        executor=None,
    ):
        """``executor`` — an optional
        ``repro.runtime.executor.NestedPartitionExecutor``: each step's wall
        time is observed and the work split re-solved on its schedule (the
        paper's section-5.6 equalizer run online; supersedes the ad-hoc
        StepTimer-only straggler EWMA)."""
        self.step_fn = step_fn
        self.batch_fn = batch_fn
        self.save_fn = save_fn
        self.restore_fn = restore_fn
        self.ckpt_every = ckpt_every
        self.max_retries = max_retries
        self.injector = injector
        self.on_metrics = on_metrics
        self.executor = executor
        self.timer = StepTimer()
        self.restarts = 0
        self.retries = 0

    def run(self, state, start_step: int, n_steps: int):
        step = start_step
        end = start_step + n_steps
        while step < end:
            batch = self.batch_fn(step)
            attempts = 0
            while True:
                try:
                    if self.injector is not None:
                        self.injector.maybe_fail(step)
                    t0 = time.perf_counter()
                    state, metrics = self.step_fn(state, step, batch)
                    dt = time.perf_counter() - t0
                    break
                except Exception:  # noqa: BLE001 — retry then restore
                    attempts += 1
                    if attempts <= self.max_retries:
                        self.retries += 1
                        continue
                    # unrecoverable for this incarnation: restore + replay
                    self.restarts += 1
                    step, state = self.restore_fn()
                    batch = self.batch_fn(step)
                    attempts = 0
            stragglers = self.timer.update({"global": dt})
            if self.executor is not None:
                self.executor.observe_total(dt)
                self.executor.maybe_rebalance(step + 1)
            if self.on_metrics is not None:
                self.on_metrics(step, metrics, dt, stragglers)
            step += 1
            if step % self.ckpt_every == 0:
                self.save_fn(step, state)
        return step, state
