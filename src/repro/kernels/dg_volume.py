"""Pallas TPU kernel for the paper's ``volume_loop`` hot-spot.

The paper hand-vectorizes the elemental tensor-product applications
(IIAX/IAIX/AIIX) with AVX/MIC intrinsics.  The TPU adaptation rethinks the
layout for the MXU instead of porting the vector code:

  * an (M x M) derivative matrix (M = order+1 = 8) used alone occupies
    8/128 of the MXU's contraction dim (~6% utilization);
  * we therefore process BE = 16 elements per grid step and apply the
    BLOCK-DIAGONAL operator D16 = kron(I_16, D) (128 x 128) — the r1
    derivative of 16 elements becomes ONE full-width MXU pass, with the
    9 fields x M^2 = 576 trailing lanes amortizing weight loads;
  * the r2/r3 derivatives contract the right factor (X @ D16^T) with the
    same blocking after an in-VMEM transpose;
  * flux assembly (stress, sym-grad combinations, 1/rho scaling) is fused
    into the same kernel (VPU elementwise) so the block's rhs leaves VMEM
    exactly once.

VMEM footprint per grid step: q block (16, 9, 512) f32 = 288 KiB + two
derivative temporaries of the same size + D16 (64 KiB) ~= 0.9 MiB << 16 MiB.

Validated against ``ref.dg_volume_ref`` in interpret mode (CPU) across
orders/dtypes; the TPU (Mosaic) path is the deployment target.

BE = 16 is the hand-derived default; ``repro.kernels.autotune`` sweeps it
per device class and installs the measured winner via ``set_block_elems``
(or per call via ``dg_volume_pallas(..., be=...)``).  The kernel is
block-diagonal per element, so results are bitwise-invariant in BE.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

BE = 16  # default elements per grid step -> 16*M = 128 MXU rows at M=8

# autotuned override (repro.kernels.autotune.activate): None = use BE.
# Baked into programs at trace time — activate BEFORE building pipelines.
_ACTIVE_BE: Optional[int] = None


def set_block_elems(be: Optional[int]) -> None:
    """Install an autotuned elements-per-grid-step block size (None resets
    to the default ``BE``).  Affects subsequent traces only."""
    global _ACTIVE_BE
    _ACTIVE_BE = None if be is None else int(be)


def block_elems() -> int:
    """The BE the next ``dg_volume_pallas`` trace will use."""
    return BE if _ACTIVE_BE is None else _ACTIVE_BE


def _volume_kernel(q_ref, d16_ref, mat_ref, out_ref, *, M: int, metrics, BE: int):
    """q_ref: (BE, 9, M, M, M); d16_ref: (BE*M, BE*M); mat_ref: (BE, 3) =
    (rho, lam, mu); out_ref: (BE, 9, M, M, M)."""
    cdt = jnp.result_type(q_ref.dtype, jnp.float32)
    q = q_ref[...].astype(cdt)
    D16 = d16_ref[...].astype(cdt)
    rho = mat_ref[:, 0][:, None, None, None]
    lam = mat_ref[:, 1][:, None, None, None]
    mu = mat_ref[:, 2][:, None, None, None]

    v = q[:, 6:9]  # (BE, 3, M, M, M)
    tr = q[:, 0] + q[:, 1] + q[:, 2]
    S = jnp.stack(
        [
            lam * tr + 2 * mu * q[:, 0],
            lam * tr + 2 * mu * q[:, 1],
            lam * tr + 2 * mu * q[:, 2],
            2 * mu * q[:, 3],
            2 * mu * q[:, 4],
            2 * mu * q[:, 5],
        ],
        axis=1,
    )  # (BE, 6, M, M, M)

    def dax(u, axis):
        """Derivative along element axis via the block-diagonal D16.
        u: (BE, F, M, M, M)."""
        F = u.shape[1]
        if axis == 0:
            # rows: (BE*M); lanes: F*M^2 — one full-width MXU pass
            x = u.transpose(0, 2, 1, 3, 4).reshape(BE * M, F * M * M)
            y = jax.lax.dot_general(D16, x, (((1,), (0,)), ((), ())),
                                    preferred_element_type=cdt)
            return y.reshape(BE, M, F, M, M).transpose(0, 2, 1, 3, 4) * metrics[0]
        if axis == 1:
            x = u.transpose(0, 3, 1, 2, 4).reshape(BE * M, F * M * M)
            y = jax.lax.dot_general(D16, x, (((1,), (0,)), ((), ())),
                                    preferred_element_type=cdt)
            return y.reshape(BE, M, F, M, M).transpose(0, 2, 3, 1, 4) * metrics[1]
        x = u.transpose(0, 4, 1, 2, 3).reshape(BE * M, F * M * M)
        y = jax.lax.dot_general(D16, x, (((1,), (0,)), ((), ())),
                                preferred_element_type=cdt)
        return y.reshape(BE, M, F, M, M).transpose(0, 2, 3, 4, 1) * metrics[2]

    dv0 = dax(v, 0)
    dv1 = dax(v, 1)
    dv2 = dax(v, 2)
    dS0 = dax(S, 0)
    dS1 = dax(S, 1)
    dS2 = dax(S, 2)

    # SYM index: (a,b) -> 6-component slot
    out = jnp.stack(
        [
            dv0[:, 0],
            dv1[:, 1],
            dv2[:, 2],
            0.5 * (dv2[:, 1] + dv1[:, 2]),
            0.5 * (dv2[:, 0] + dv0[:, 2]),
            0.5 * (dv1[:, 0] + dv0[:, 1]),
            (dS0[:, 0] + dS1[:, 5] + dS2[:, 4]) / rho,
            (dS0[:, 5] + dS1[:, 1] + dS2[:, 3]) / rho,
            (dS0[:, 4] + dS1[:, 3] + dS2[:, 2]) / rho,
        ],
        axis=1,
    )
    out_ref[...] = out.astype(out_ref.dtype)


def dg_volume_pallas(
    q: jnp.ndarray,  # (K, 9, M, M, M)
    D: jnp.ndarray,  # (M, M)
    metrics: Tuple[float, float, float],
    rho: jnp.ndarray,
    lam: jnp.ndarray,
    mu: jnp.ndarray,
    *,
    interpret: bool = True,
    be: Optional[int] = None,
) -> jnp.ndarray:
    BE = block_elems() if be is None else int(be)
    K, F, M = q.shape[0], q.shape[1], q.shape[2]
    if K % BE:
        pad = BE - K % BE
        q = jnp.concatenate([q, jnp.zeros((pad,) + q.shape[1:], q.dtype)])
        rho = jnp.concatenate([rho, jnp.ones(pad, rho.dtype)])
        lam = jnp.concatenate([lam, jnp.ones(pad, lam.dtype)])
        mu = jnp.concatenate([mu, jnp.ones(pad, mu.dtype)])
    else:
        pad = 0
    Kp = q.shape[0]
    d16 = jnp.asarray(np.kron(np.eye(BE), np.asarray(D, np.float64)), q.dtype)
    mats = jnp.stack([rho, lam, mu], axis=1).astype(q.dtype)

    out = pl.pallas_call(
        functools.partial(_volume_kernel, M=M, metrics=tuple(float(m) for m in metrics), BE=BE),
        grid=(Kp // BE,),
        in_specs=[
            pl.BlockSpec((BE, F, M, M, M), lambda i: (i, 0, 0, 0, 0)),
            pl.BlockSpec((BE * M, BE * M), lambda i: (0, 0)),
            pl.BlockSpec((BE, 3), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((BE, F, M, M, M), lambda i: (i, 0, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((Kp, F, M, M, M), q.dtype),
        interpret=interpret,
    )(q, d16, mats)
    return out[:K] if pad else out
