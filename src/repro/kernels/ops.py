"""jit'd wrappers with implementation selection for every kernel.

impl:
  * "xla"       — pure-jnp reference path (CPU, and the 512-device dry-run:
                  Mosaic does not lower on the CPU backend);
  * "interpret" — the Pallas kernel body executed by the interpreter
                  (correctness tests on CPU);
  * "pallas"    — the Mosaic-compiled TPU kernel (deployment target).
"""

from __future__ import annotations

from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.dg_flux import dg_flux_pallas
from repro.kernels.dg_volume import dg_volume_pallas
from repro.kernels.flash_attention import flash_attention_pallas


def dg_volume(q, D, metrics, rho, lam, mu, impl: str = "xla"):
    if impl == "xla":
        return ref.dg_volume_ref(q, D, metrics, rho, lam, mu)
    return dg_volume_pallas(q, D, metrics, rho, lam, mu, interpret=(impl == "interpret"))


def dg_flux(Sm, vm, Sp, vp, mats, axis, sign, impl: str = "xla"):
    if impl == "xla":
        return ref.dg_flux_ref(Sm, vm, Sp, vp, mats, axis, sign)
    return dg_flux_pallas(Sm, vm, Sp, vp, mats, axis, sign, interpret=(impl == "interpret"))


def flash_attention_op(
    q, k, v, *, causal: bool = True, window: Optional[int] = None,
    scale: Optional[float] = None, impl: str = "xla",
):
    if impl == "xla":
        from repro.models.attention import flash_attention

        return flash_attention(q, k, v, causal=causal, window=window, scale=scale)
    return flash_attention_pallas(
        q, k, v, causal=causal, window=window, scale=scale,
        interpret=(impl == "interpret"),
    )
