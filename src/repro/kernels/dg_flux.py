"""Pallas TPU kernel for the paper's ``int_flux`` / ``godonov_flux`` hot-spot.

The exact Riemann correction is embarrassingly parallel over face nodes
(paper section 4) — pure VPU work.  The TPU layout flattens each face's
(M x M) nodes into lanes and blocks BF faces into sublanes, so one grid
step processes a (BF, M*M) tile per field with the 8 material scalars held
alongside.  Axis/sign are compile-time grid parameters (one kernel
instantiation per face direction, as in the solver's face loop).

VMEM per step: (BF=128 faces) x (2x9 fields + out) x 64 lanes x 4 B ~= 0.9 MiB.

Validated against ``ref.dg_flux_ref`` in interpret mode across orders,
dtypes, and acoustic/elastic/coupled material draws.

Reached from the solver via the ``kernel_impl`` switch
(``dg.operators.surface_rhs(kernel_impl="pallas"|"interpret")``): one
instantiation per face direction inside the solver's face loop, on the flat
rhs, the SPMD slab interior, the blocked engine's correction phase, and the
fused step pipeline (``runtime.pipeline``) alike.

BF = 128 is the hand-derived default; ``repro.kernels.autotune`` sweeps it
per device class and installs the measured winner via ``set_block_faces``
(or per call via ``dg_flux_pallas(..., bf=...)``).  The kernel is pure
per-face VPU work, so results are bitwise-invariant in BF.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

BF = 128  # default faces per grid step

# autotuned override (repro.kernels.autotune.activate): None = use BF.
# Baked into programs at trace time — activate BEFORE building pipelines.
_ACTIVE_BF: Optional[int] = None


def set_block_faces(bf: Optional[int]) -> None:
    """Install an autotuned faces-per-grid-step block size (None resets to
    the default ``BF``).  Affects subsequent traces only."""
    global _ACTIVE_BF
    _ACTIVE_BF = None if bf is None else int(bf)


def block_faces() -> int:
    """The BF the next ``dg_flux_pallas`` trace will use."""
    return BF if _ACTIVE_BF is None else _ACTIVE_BF

# SYM[a][b]: 6-component slot of the symmetric (a,b) entry
SYM = ((0, 5, 4), (5, 1, 3), (4, 3, 2))


def _flux_kernel(Sm_ref, vm_ref, Sp_ref, vp_ref, mat_ref, FE_ref, Fv_ref, *, axis: int, sign: float):
    # compute in >= f32 (bf16 inputs upcast; f64 kept when x64 is on)
    cdt = jnp.result_type(Sm_ref.dtype, jnp.float32)
    Sm = Sm_ref[...].astype(cdt)  # (BF, 6, MM)
    vm = vm_ref[...].astype(cdt)  # (BF, 3, MM)
    Sp = Sp_ref[...].astype(cdt)
    vp = vp_ref[...].astype(cdt)
    mat = mat_ref[...].astype(cdt)  # (BF, 8)

    e = lambda c: mat[:, c][:, None]
    rcp_m, rcs_m = e(0) * e(1), e(0) * e(2)
    rcp_p, rcs_p = e(4) * e(5), e(4) * e(6)
    mu_m = e(3)
    k0 = 1.0 / (rcp_m + rcp_p)
    denom = rcs_m + rcs_p
    k1 = jnp.where(mu_m > 0, 1.0 / jnp.maximum(denom, 1e-30), 0.0)

    S_j = Sm - Sp
    v_j = vm - vp
    a0, a1, a2 = axis, (axis + 1) % 3, (axis + 2) % 3
    S_aa = S_j[:, SYM[a0][a0]]
    S_a1 = S_j[:, SYM[a0][a1]]
    S_a2 = S_j[:, SYM[a0][a2]]

    a = k0 * (S_aa + rcp_p * sign * v_j[:, a0])
    FE = jnp.zeros_like(S_j)
    FE = FE.at[:, SYM[a0][a0]].set(a)
    FE = FE.at[:, SYM[a0][a1]].set(0.5 * k1 * (S_a1 + rcs_p * sign * v_j[:, a1]))
    FE = FE.at[:, SYM[a0][a2]].set(0.5 * k1 * (S_a2 + rcs_p * sign * v_j[:, a2]))

    Fv = jnp.zeros_like(v_j)
    Fv = Fv.at[:, a0].set(a * rcp_m * sign)
    Fv = Fv.at[:, a1].set(k1 * rcs_m * (sign * S_a1 + rcs_p * v_j[:, a1]))
    Fv = Fv.at[:, a2].set(k1 * rcs_m * (sign * S_a2 + rcs_p * v_j[:, a2]))

    FE_ref[...] = FE.astype(FE_ref.dtype)
    Fv_ref[...] = Fv.astype(Fv_ref.dtype)


def dg_flux_pallas(
    Sm: jnp.ndarray,  # (F, 6, M, M)
    vm: jnp.ndarray,  # (F, 3, M, M)
    Sp: jnp.ndarray,
    vp: jnp.ndarray,
    mats: jnp.ndarray,  # (F, 8): rho-,cp-,cs-,mu-,rho+,cp+,cs+,mu+
    axis: int,
    sign: float,
    *,
    interpret: bool = True,
    bf: Optional[int] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    BF = block_faces() if bf is None else int(bf)
    F, _, M, _ = Sm.shape
    MM = M * M
    pad = (-F) % BF
    def p(x, fill=0.0):
        if pad:
            return jnp.concatenate([x, jnp.full((pad,) + x.shape[1:], fill, x.dtype)])
        return x
    Smf = p(Sm).reshape(F + pad, 6, MM)
    vmf = p(vm).reshape(F + pad, 3, MM)
    Spf = p(Sp).reshape(F + pad, 6, MM)
    vpf = p(vp).reshape(F + pad, 3, MM)
    matf = p(mats, fill=1.0)
    Fp = F + pad

    FE, Fv = pl.pallas_call(
        functools.partial(_flux_kernel, axis=axis, sign=float(sign)),
        grid=(Fp // BF,),
        in_specs=[
            pl.BlockSpec((BF, 6, MM), lambda i: (i, 0, 0)),
            pl.BlockSpec((BF, 3, MM), lambda i: (i, 0, 0)),
            pl.BlockSpec((BF, 6, MM), lambda i: (i, 0, 0)),
            pl.BlockSpec((BF, 3, MM), lambda i: (i, 0, 0)),
            pl.BlockSpec((BF, 8), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((BF, 6, MM), lambda i: (i, 0, 0)),
            pl.BlockSpec((BF, 3, MM), lambda i: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Fp, 6, MM), Sm.dtype),
            jax.ShapeDtypeStruct((Fp, 3, MM), Sm.dtype),
        ],
        interpret=interpret,
    )(Smf, vmf, Spf, vpf, matf)
    return FE[:F].reshape(F, 6, M, M), Fv[:F].reshape(F, 3, M, M)
