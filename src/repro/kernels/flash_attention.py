"""Pallas TPU flash attention (causal / sliding-window / encoder).

Standard TPU flash structure: grid (batch*heads, n_q_blocks, n_kv_blocks)
with the kv axis iterated minor-most (sequential on TPU), online-softmax
statistics (m, l, acc) living in VMEM scratch across kv steps, and the
output written once on the last visited kv block.

Unlike the lax fallback (models/attention.py), above-diagonal kv blocks are
SKIPPED via ``pl.when`` — causal attention costs the causal minimum here,
which is the kernel's main advantage besides fusion (the gap is visible in
the roofline useful-FLOPs ratio of the dry-run, which uses the lax path).

Blocks: q (Bq x D), k/v (Bk x D) — D = head_dim (80..160 for the zoo),
Bq = Bk = 128 by default: ~4 x 128 x 128 x 4 B ~= 0.26 MiB of VMEM scratch.

Validated against ``ref.flash_attention_ref`` in interpret mode across
shapes, dtypes, causal/SWA/encoder modes (tests/test_kernels.py).
"""

from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, causal: bool, window: Optional[int],
                  block_q: int, block_k: int, n_kv: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    qpos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    kpos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)

    # visit the block only if it can contribute
    visit = True
    if causal:
        visit = jnp.asarray(ki * block_k <= qi * block_q + block_q - 1)
    if window is not None:
        visit = jnp.logical_and(
            visit, jnp.asarray((ki + 1) * block_k - 1 > qi * block_q - window)
        )
    if isinstance(visit, bool):
        visit = jnp.asarray(visit)

    @pl.when(visit)
    def _body():
        q = q_ref[0].astype(jnp.float32)  # (block_q, D)
        k = k_ref[0].astype(jnp.float32)  # (block_k, D)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        mask = jnp.ones((block_q, block_k), jnp.bool_)
        if causal:
            mask = jnp.logical_and(mask, kpos <= qpos)
        if window is not None:
            mask = jnp.logical_and(mask, kpos > qpos - window)
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        l_prev = l_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_new = l_prev * alpha + p.sum(axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_ref[...] = m_new
        l_ref[...] = l_new

    @pl.when(ki == n_kv - 1)
    def _finish():
        l = l_ref[...]
        o_ref[0] = (acc_ref[...] / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


def flash_attention_pallas(
    q: jnp.ndarray,  # (B, H, S, D)
    k: jnp.ndarray,  # (B, H, S, D) — expand GQA before calling
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    scale: Optional[float] = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = True,
) -> jnp.ndarray:
    B, H, Sq, D = q.shape
    Skv = k.shape[2]
    scale = scale or 1.0 / math.sqrt(D)
    block_q = min(block_q, Sq)
    block_k = min(block_k, Skv)
    while Sq % block_q:
        block_q -= 1
    while Skv % block_k:
        block_k -= 1
    nq, nk = Sq // block_q, Skv // block_k

    qf = q.reshape(B * H, Sq, D)
    kf = k.reshape(B * H, Skv, D)
    vf = v.reshape(B * H, Skv, D)

    from jax.experimental.pallas import tpu as pltpu

    out = pl.pallas_call(
        functools.partial(
            _flash_kernel, scale=scale, causal=causal, window=window,
            block_q=block_q, block_k=block_k, n_kv=nk,
        ),
        grid=(B * H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, D), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, D), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, D), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),  # m
            pltpu.VMEM((block_q, 1), jnp.float32),  # l
            pltpu.VMEM((block_q, D), jnp.float32),  # acc
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, H, Sq, D)
