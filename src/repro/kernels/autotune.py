"""Block-size autotuner for the Pallas DG kernels — measured rooflines.

The hand-derived defaults (BE = 16 elements per volume grid step, BF = 128
faces per flux grid step) were sized for a TPU MXU/VPU on paper napkin math.
Calore et al. (PAPERS.md, lattice-Boltzmann on heterogeneous computers) show
the last ~2x of a stencil code lives in exactly this per-device-class block
tuning, and Tzovas & Predari's experimental study shows modeled costs must
be re-fit from measurements.  This module closes both loops:

1. **sweep** — time ``dg_volume_pallas`` over BE candidates and
   ``dg_flux_pallas`` over BF candidates on the *current* device (real
   TPU/GPU when present; interpret-mode fallback so CI exercises the full
   machinery on CPU).  Each candidate is timed at two problem sizes and fit
   as ``t(K) = overhead + K * sec_per_element``, so the winner is chosen on
   the marginal (roofline) cost and the intercept is a measured per-launch
   overhead;
2. **cache** — winners land in a JSON keyed by
   ``(device_kind, order, n_fields)`` (default
   ``~/.cache/repro-dg/autotune.json``, override with
   ``$REPRO_AUTOTUNE_CACHE`` or ``--cache``), uploaded as a CI artifact so
   the per-device roofline has a tracked trajectory;
3. **feed back** — ``activate(entry)`` installs the winning block sizes in
   the kernel modules (every later trace — flat solver, blocked engine,
   fused pipeline — picks them up), and
   ``repro.core.cost_model.CalibrationTable.from_autotune`` turns the
   measured seconds into the planner's calibration table, so
   ``solve_two_way`` / ``solve_hierarchical`` plan on observed rooflines
   instead of the analytic model.

CLI::

    PYTHONPATH=src python -m repro.kernels.autotune \
        --device-class cpu-interpret --order 2 --smoke \
        --cache autotune_kernels.json

Both kernels are arithmetically block-invariant (the volume kernel is
block-diagonal per element, the flux kernel pure per-face VPU work), so the
sweep only moves *time*, never results — the bitwise differential harnesses
hold under any activated winner.
"""

from __future__ import annotations

import argparse
import json
import os
import time
from typing import Dict, Optional, Sequence, Tuple

import numpy as np

__all__ = [
    "DEFAULT_BE_CANDIDATES",
    "DEFAULT_BF_CANDIDATES",
    "default_cache_path",
    "detect_device_kind",
    "entry_key",
    "load_cache",
    "save_entry",
    "lookup",
    "best_blocks",
    "sweep_volume",
    "sweep_flux",
    "autotune",
    "activate",
]

DEFAULT_BE_CANDIDATES = (8, 16, 32)
DEFAULT_BF_CANDIDATES = (64, 128, 256)
N_STAGES = 5  # LSRK4(5): rhs evaluations per timestep
FACES_PER_ELEMENT = 6  # our surface_rhs computes all 6 faces of every element


# ---------------------------------------------------------------------------
# Cache: JSON keyed by (device_kind, order, n_fields)
# ---------------------------------------------------------------------------


def default_cache_path() -> str:
    env = os.environ.get("REPRO_AUTOTUNE_CACHE")
    if env:
        return env
    return os.path.join(os.path.expanduser("~"), ".cache", "repro-dg", "autotune.json")


def detect_device_kind(interpret: Optional[bool] = None) -> str:
    """A stable label for the current accelerator class (``tpu-v4``,
    ``nvidia-a100``, ``cpu``), suffixed ``-interpret`` when the Pallas
    kernels would run in interpret mode (the CPU/CI fallback)."""
    import jax

    d = jax.devices()[0]
    kind = str(getattr(d, "device_kind", "") or d.platform).lower()
    kind = kind.replace(" ", "-").replace("_", "-")
    if interpret is None:
        interpret = d.platform == "cpu"
    return f"{kind}-interpret" if interpret else kind


def entry_key(device_kind: str, order: int, n_fields: int = 9) -> str:
    return f"{device_kind}|o{int(order)}|f{int(n_fields)}"


def load_cache(path: Optional[str] = None) -> Dict[str, dict]:
    path = path or default_cache_path()
    try:
        with open(path) as f:
            cache = json.load(f)
    except (FileNotFoundError, json.JSONDecodeError):
        return {}
    return cache if isinstance(cache, dict) else {}


def save_entry(entry: dict, path: Optional[str] = None) -> str:
    """Merge one sweep result into the cache JSON (atomic replace)."""
    path = path or default_cache_path()
    d = os.path.dirname(path)
    if d:
        os.makedirs(d, exist_ok=True)
    cache = load_cache(path)
    cache[entry_key(entry["device_kind"], entry["order"], entry["n_fields"])] = entry
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(cache, f, indent=2, sort_keys=True)
    os.replace(tmp, path)
    return path


def lookup(
    device_kind: Optional[str] = None,
    order: Optional[int] = None,
    n_fields: int = 9,
    path: Optional[str] = None,
) -> Optional[dict]:
    """The cached entry for ``(device_kind, order, n_fields)`` — device kind
    auto-detected when omitted; with ``order`` omitted, the entry for the
    current device at any order (closest key wins by insertion order)."""
    cache = load_cache(path)
    if not cache:
        return None
    if device_kind is None:
        device_kind = detect_device_kind()
    if order is not None:
        return cache.get(entry_key(device_kind, order, n_fields))
    for e in cache.values():
        if isinstance(e, dict) and e.get("device_kind") == device_kind:
            return e
    return None


def best_blocks(
    device_kind: Optional[str] = None,
    order: Optional[int] = None,
    n_fields: int = 9,
    path: Optional[str] = None,
) -> Tuple[Optional[int], Optional[int]]:
    """(be, bf) winners from the cache, (None, None) when unmeasured."""
    e = lookup(device_kind, order, n_fields, path)
    if e is None:
        return None, None
    return int(e["be"]), int(e["bf"])


def activate(entry: Optional[dict]) -> None:
    """Install an entry's winning block sizes in the kernel modules (every
    subsequent trace uses them); ``None`` resets both to the defaults."""
    from repro.kernels import dg_flux, dg_volume

    if entry is None:
        dg_volume.set_block_elems(None)
        dg_flux.set_block_faces(None)
    else:
        dg_volume.set_block_elems(int(entry["be"]))
        dg_flux.set_block_faces(int(entry["bf"]))


# ---------------------------------------------------------------------------
# The sweep
# ---------------------------------------------------------------------------


def _median_seconds(fn, reps: int) -> float:
    import jax

    jax.block_until_ready(fn())  # warmup / compile
    ts = []
    for _ in range(max(1, reps)):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2]


def _two_point_fit(t_small: float, n_small: int, t_big: float, n_big: int):
    """t(n) = overhead + n * slope, clamped non-negative."""
    slope = max(0.0, (t_big - t_small) / max(1, n_big - n_small))
    overhead = max(0.0, t_small - slope * n_small)
    return slope, overhead


def sweep_volume(
    order: int,
    n_fields: int = 9,
    dtype: str = "float32",
    candidates: Sequence[int] = DEFAULT_BE_CANDIDATES,
    interpret: Optional[bool] = None,
    reps: int = 3,
    size_factor: int = 8,
    seed: int = 0,
) -> Dict[str, dict]:
    """Per-candidate ``{sec_per_element, overhead_s}`` for ``dg_volume_pallas``."""
    import jax
    import jax.numpy as jnp

    from repro.dg.basis import diff_matrix, lgl_nodes_weights
    from repro.kernels.dg_volume import dg_volume_pallas

    if interpret is None:
        interpret = jax.devices()[0].platform == "cpu"
    M = order + 1
    x, _ = lgl_nodes_weights(order)
    D = jnp.asarray(diff_matrix(x), dtype)
    rng = np.random.default_rng(seed)
    metrics = (2.0, 2.0, 2.0)
    out: Dict[str, dict] = {}
    for be in candidates:
        be = int(be)
        results = {}
        for K in (be, size_factor * be):
            q = jnp.asarray(rng.standard_normal((K, n_fields, M, M, M)), dtype)
            rho = jnp.ones(K, dtype)
            lam = jnp.ones(K, dtype)
            mu = jnp.zeros(K, dtype)
            fn = jax.jit(
                lambda q, rho, lam, mu, be=be: dg_volume_pallas(
                    q, D, metrics, rho, lam, mu, interpret=interpret, be=be
                )
            )
            results[K] = _median_seconds(lambda: fn(q, rho, lam, mu), reps)
        (n_s, t_s), (n_b, t_b) = sorted(results.items())
        slope, ovh = _two_point_fit(t_s, n_s, t_b, n_b)
        out[str(be)] = {"sec_per_element": slope, "overhead_s": ovh,
                        "timed": {str(k): v for k, v in results.items()}}
    return out


def sweep_flux(
    order: int,
    dtype: str = "float32",
    candidates: Sequence[int] = DEFAULT_BF_CANDIDATES,
    interpret: Optional[bool] = None,
    reps: int = 3,
    size_factor: int = 8,
    seed: int = 0,
) -> Dict[str, dict]:
    """Per-candidate ``{sec_per_face, overhead_s}`` for ``dg_flux_pallas``."""
    import jax
    import jax.numpy as jnp

    from repro.kernels.dg_flux import dg_flux_pallas

    if interpret is None:
        interpret = jax.devices()[0].platform == "cpu"
    M = order + 1
    rng = np.random.default_rng(seed)
    out: Dict[str, dict] = {}
    for bf in candidates:
        bf = int(bf)
        results = {}
        for F in (bf, size_factor * bf):
            Sm = jnp.asarray(rng.standard_normal((F, 6, M, M)), dtype)
            vm = jnp.asarray(rng.standard_normal((F, 3, M, M)), dtype)
            Sp = jnp.asarray(rng.standard_normal((F, 6, M, M)), dtype)
            vp = jnp.asarray(rng.standard_normal((F, 3, M, M)), dtype)
            mats = jnp.asarray(np.abs(rng.standard_normal((F, 8))) + 0.5, dtype)
            fn = jax.jit(
                lambda Sm, vm, Sp, vp, mats, bf=bf: dg_flux_pallas(
                    Sm, vm, Sp, vp, mats, 0, 1.0, interpret=interpret, bf=bf
                )
            )
            results[F] = _median_seconds(lambda: fn(Sm, vm, Sp, vp, mats), reps)
        (n_s, t_s), (n_b, t_b) = sorted(results.items())
        slope, ovh = _two_point_fit(t_s, n_s, t_b, n_b)
        out[str(bf)] = {"sec_per_face": slope, "overhead_s": ovh,
                        "timed": {str(k): v for k, v in results.items()}}
    return out


def _winner(sweep: Dict[str, dict], cost_key: str) -> str:
    """Min marginal cost; per-launch overhead breaks ties."""
    return min(sweep, key=lambda k: (sweep[k][cost_key], sweep[k]["overhead_s"]))


def autotune(
    order: int,
    n_fields: int = 9,
    dtype: str = "float32",
    device_kind: Optional[str] = None,
    be_candidates: Sequence[int] = DEFAULT_BE_CANDIDATES,
    bf_candidates: Sequence[int] = DEFAULT_BF_CANDIDATES,
    interpret: Optional[bool] = None,
    reps: int = 3,
    size_factor: int = 8,
    cache_path: Optional[str] = None,
    save: bool = True,
) -> dict:
    """Run both sweeps, pick winners, and (by default) merge the entry into
    the cache JSON.  Returns the entry.

    ``sec_per_element`` in the entry is per element per *timestep* (the
    marginal per-evaluation cost times the 5 LSRK stages; int_flux times the
    6 faces our surface pass computes per element) — directly consumable by
    ``CalibrationTable.from_autotune``."""
    import jax

    if interpret is None:
        interpret = jax.devices()[0].platform == "cpu"
    if device_kind is None:
        device_kind = detect_device_kind(interpret)
    vol = sweep_volume(order, n_fields, dtype, be_candidates, interpret, reps, size_factor)
    flx = sweep_flux(order, dtype, bf_candidates, interpret, reps, size_factor)
    be = _winner(vol, "sec_per_element")
    bf = _winner(flx, "sec_per_face")
    entry = {
        "device_kind": device_kind,
        "order": int(order),
        "n_fields": int(n_fields),
        "dtype": dtype,
        "interpret": bool(interpret),
        "be": int(be),
        "bf": int(bf),
        "volume_sweep": vol,
        "flux_sweep": flx,
        "sec_per_element": {
            "volume_loop": vol[be]["sec_per_element"] * N_STAGES,
            "int_flux": flx[bf]["sec_per_face"] * FACES_PER_ELEMENT * N_STAGES,
        },
        # the measured per-launch intercept: what a fused step pays ONCE per
        # kernel now that the envelope layout is one launch per kernel
        "launch_overhead_s": 0.5 * (vol[be]["overhead_s"] + flx[bf]["overhead_s"]),
    }
    if save:
        save_entry(entry, cache_path)
    return entry


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------


def _int_list(s: str) -> Tuple[int, ...]:
    return tuple(int(x) for x in s.split(",") if x.strip())


def main(argv: Optional[Sequence[str]] = None) -> None:
    ap = argparse.ArgumentParser(
        description="Sweep Pallas DG kernel block sizes and cache the winners."
    )
    ap.add_argument("--device-class", default=None,
                    help="cache label override (default: auto-detected, e.g. "
                         "'cpu-interpret', 'tpu-v4')")
    ap.add_argument("--order", type=int, default=3)
    ap.add_argument("--n-fields", type=int, default=9)
    ap.add_argument("--dtype", default="float32")
    ap.add_argument("--be", type=_int_list, default=None,
                    help="comma-separated BE candidates (volume kernel)")
    ap.add_argument("--bf", type=_int_list, default=None,
                    help="comma-separated BF candidates (flux kernel)")
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--cache", default=None,
                    help=f"cache JSON path (default: {default_cache_path()})")
    ap.add_argument("--interpret", choices=["auto", "on", "off"], default="auto",
                    help="force interpret mode on/off (auto: on iff CPU backend)")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sweep (2 candidates, 1 rep, small sizes) — CI-safe")
    args = ap.parse_args(argv)

    interpret = {"auto": None, "on": True, "off": False}[args.interpret]
    be_cands = args.be or (DEFAULT_BE_CANDIDATES[:2] if args.smoke else DEFAULT_BE_CANDIDATES)
    bf_cands = args.bf or (DEFAULT_BF_CANDIDATES[:2] if args.smoke else DEFAULT_BF_CANDIDATES)
    entry = autotune(
        order=args.order,
        n_fields=args.n_fields,
        dtype=args.dtype,
        device_kind=args.device_class,
        be_candidates=be_cands,
        bf_candidates=bf_cands,
        interpret=interpret,
        reps=1 if args.smoke else args.reps,
        size_factor=4 if args.smoke else 8,
        cache_path=args.cache,
    )
    path = args.cache or default_cache_path()
    sec = entry["sec_per_element"]
    print(f"device_kind={entry['device_kind']} order={entry['order']} "
          f"n_fields={entry['n_fields']} dtype={entry['dtype']}")
    print(f"winners: BE={entry['be']} BF={entry['bf']}")
    print(f"volume_loop={sec['volume_loop']:.3e} s/elem/step  "
          f"int_flux={sec['int_flux']:.3e} s/elem/step  "
          f"launch_overhead={entry['launch_overhead_s']:.3e} s")
    print(f"cache: {path}")


if __name__ == "__main__":
    main()
