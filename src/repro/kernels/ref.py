"""Pure-jnp oracles for every Pallas kernel (the ``ref`` side of the
kernel == ref allclose sweeps in tests/test_kernels.py)."""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.dg.operators import riemann_correction, volume_rhs
from repro.models.attention import naive_attention


def dg_volume_ref(
    q: jnp.ndarray,  # (K, 9, M, M, M)
    D: jnp.ndarray,
    metrics: Tuple[float, float, float],
    rho: jnp.ndarray,
    lam: jnp.ndarray,
    mu: jnp.ndarray,
) -> jnp.ndarray:
    return volume_rhs(q, D, metrics, rho, lam, mu)


def dg_flux_ref(
    Sm: jnp.ndarray,  # (F, 6, M, M)
    vm: jnp.ndarray,  # (F, 3, M, M)
    Sp: jnp.ndarray,
    vp: jnp.ndarray,
    mats: jnp.ndarray,  # (F, 8): rho-,cp-,cs-,mu-,rho+,cp+,cs+,mu+
    axis: int,
    sign: float,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    mat_m = {"rho": mats[:, 0], "cp": mats[:, 1], "cs": mats[:, 2], "mu": mats[:, 3]}
    mat_p = {"rho": mats[:, 4], "cp": mats[:, 5], "cs": mats[:, 6], "mu": mats[:, 7]}
    return riemann_correction(Sm, vm, Sp, vp, axis, sign, mat_m, mat_p)


def flash_attention_ref(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    scale: Optional[float] = None,
) -> jnp.ndarray:
    return naive_attention(q, k, v, causal=causal, window=window, scale=scale)
