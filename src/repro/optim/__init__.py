from repro.optim.optimizer import OptConfig, adamw_update, init_opt_state, lr_at_step
