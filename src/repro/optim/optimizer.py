"""AdamW with cosine schedule, global-norm clipping, and (optional)
error-feedback state for compressed gradient exchange.

States are plain pytrees sharded exactly like the parameters (the sharding
specs tree is reused), i.e. ZeRO-style: with params 2-D sharded over
(data, model) the optimizer memory per device is params*12/(dp*tp) bytes.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    # error feedback buffer for compressed inter-pod gradient exchange
    error_feedback: bool = False


def lr_at_step(cfg: OptConfig, step: jnp.ndarray) -> jnp.ndarray:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip(
        (step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0
    )
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    scale = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos
    return cfg.peak_lr * warm * scale


def init_opt_state(params, cfg: Optional[OptConfig] = None) -> Dict[str, Any]:
    zeros = lambda t: jax.tree.map(lambda x: jnp.zeros_like(x, dtype=jnp.float32), t)
    state = {"m": zeros(params), "v": zeros(params), "step": jnp.zeros((), jnp.int32)}
    if cfg is not None and cfg.error_feedback:
        state["ef"] = zeros(params)
    return state


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree))
    )


def adamw_update(
    params,
    grads,
    state: Dict[str, Any],
    cfg: OptConfig,
) -> Tuple[Any, Dict[str, Any], Dict[str, jnp.ndarray]]:
    step = state["step"] + 1
    lr = lr_at_step(cfg, step)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-12))

    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mh = m / bc1
        vh = v / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    new_state = dict(state, m=new_m, v=new_v, step=step)
    return new_p, new_state, {"lr": lr, "grad_norm": gnorm}
