"""Heterogeneous load balancing — the paper's equalization solve (section 5.6).

Computation on the accelerator is asynchronous w.r.t. the host, so the
balance is optimal when both sides finish together:

    T_acc(K_acc) = T_host(K - K_acc) + Transfer(K_acc)
    K = K_acc + K_host

(the paper charges the PCI transfer to the CPU side).  We solve this by
integer bisection on the monotone residual, generalize it to n-way
heterogeneous partitions (common-finish-time waterfilling), and provide the
online re-solve used for straggler mitigation: the same equalizer re-fed
with *measured* per-partition step times.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Sequence

import numpy as np

__all__ = [
    "SplitResult",
    "NodeModel",
    "HierarchicalSplit",
    "RoundSpec",
    "RoundsResult",
    "solve_two_way",
    "solve_multiway",
    "solve_hierarchical",
    "solve_rounds",
    "rebalance_from_measurements",
]


def _imbalance(times: Sequence[float]) -> float:
    """makespan / mean — 1.0 is perfect."""
    mk = max(times)
    m = float(np.mean(times)) if mk > 0 else 1.0
    return mk / m if m > 0 else 1.0


@dataclasses.dataclass(frozen=True)
class SplitResult:
    counts: tuple  # work items per partition
    times: tuple  # predicted completion time per partition
    ratio: float  # counts[accel] / counts[host] for two-way splits

    @property
    def makespan(self) -> float:
        return max(self.times)

    @property
    def imbalance(self) -> float:
        return _imbalance(self.times)


def solve_two_way(
    t_host: Callable[[float], float],
    t_accel: Callable[[float], float],
    K: int,
    transfer: Optional[Callable[[float], float]] = None,
    K_accel_max: Optional[int] = None,
    overlap: bool = False,
) -> SplitResult:
    """Solve T_accel(Ka) = T_host(K-Ka) + Transfer(Ka) for integer Ka.

    ``K_accel_max`` caps the offload (the paper only offloads *interior*
    elements; pass the interior count).  Residual f(Ka) = T_acc - T_host_side
    is nondecreasing in Ka, so bisection applies.

    ``overlap=True`` models the boundary/interior step schedule (paper
    Fig 5.1): the host computes interior elements while the shared-face
    transfer is in flight, so the host side costs ``max(t_host, transfer)``
    instead of ``t_host + transfer`` — hidden transfer is credited to the
    offload.  The makespan ``max(t_accel, transfer, t_host)`` is the max of
    nondecreasing and nonincreasing pieces, so the same bisection applies
    on the residual ``max(t_accel, transfer) - t_host``.
    """
    transfer = transfer or (lambda k: 0.0)
    hi = K if K_accel_max is None else min(K, int(K_accel_max))
    lo = 0

    def host_side(ka: int) -> float:
        if overlap:
            return max(t_host(K - ka), transfer(ka))
        return t_host(K - ka) + transfer(ka)

    def resid(ka: int) -> float:
        if overlap:
            return max(t_accel(ka), transfer(ka)) - t_host(K - ka)
        return t_accel(ka) - host_side(ka)

    if resid(hi) <= 0:
        ka = hi  # accelerator never becomes the bottleneck: offload the cap
    elif resid(lo) >= 0:
        ka = lo
    else:
        while hi - lo > 1:
            mid = (lo + hi) // 2
            if resid(mid) <= 0:
                lo = mid
            else:
                hi = mid
        # pick the neighbour with the better makespan
        mk = lambda k: max(t_accel(k), host_side(k))
        ka = lo if mk(lo) <= mk(hi) else hi

    kh = K - ka
    times = (host_side(ka), t_accel(ka))
    ratio = float("inf") if kh == 0 else ka / kh
    return SplitResult(counts=(kh, ka), times=times, ratio=ratio)


def solve_multiway(
    time_fns: Sequence[Callable[[float], float]],
    K: int,
    integer: bool = True,
) -> SplitResult:
    """Equalize completion time across n partitions.

    Waterfilling: find common finish time T s.t. sum_i K_i(T) = K, where
    K_i(T) = max work partition i finishes within T (inverse of t_i, found
    by inner bisection since each t_i is nondecreasing).
    """
    n = len(time_fns)
    if n == 0:
        raise ValueError("need at least one partition")

    def k_of_t(t_fn: Callable[[float], float], T: float) -> float:
        if t_fn(0) > T:
            return 0.0
        lo, hi = 0.0, 1.0
        while t_fn(hi) <= T and hi < 1e15:
            hi *= 2
        for _ in range(80):
            mid = 0.5 * (lo + hi)
            if t_fn(mid) <= T:
                lo = mid
            else:
                hi = mid
        return lo

    # outer bisection on T
    T_hi = max(t_fn(K) for t_fn in time_fns) + 1e-12
    T_lo = 0.0
    for _ in range(80):
        T_mid = 0.5 * (T_lo + T_hi)
        total = sum(k_of_t(f, T_mid) for f in time_fns)
        if total >= K:
            T_hi = T_mid
        else:
            T_lo = T_mid
    ks = np.array([k_of_t(f, T_hi) for f in time_fns])
    if ks.sum() <= 0:
        ks = np.ones(n)
    if integer:
        ideal = K * ks / ks.sum()
        counts = np.floor(ideal).astype(int)
        rem = K - counts.sum()
        order = np.argsort(-(ideal - counts))
        counts[order[:rem]] += 1
    else:
        counts = K * ks / ks.sum()
    times = tuple(float(time_fns[i](counts[i])) for i in range(n))
    ratio = counts[1] / counts[0] if n == 2 and counts[0] > 0 else float("nan")
    return SplitResult(counts=tuple(int(c) if integer else float(c) for c in counts), times=times, ratio=ratio)


@dataclasses.dataclass(frozen=True)
class NodeModel:
    """Calibrated runtime models for one heterogeneous cluster node.

    ``t_host`` / ``t_accel`` are the paper's T_CPU / T_MIC (seconds for k
    elements, one timestep); ``transfer`` is the intra-node slow link (PCI),
    charged to the host side exactly as ``solve_two_way`` does;
    ``inter_transfer`` is the *cluster-level* halo exchange this node pays
    per step as a function of its chunk size (the IB/DCN alpha-beta model on
    the chunk's Morton-compact surface).  A host-only node (``t_accel``
    None) is a valid degenerate case — its inner solve is skipped.
    """

    t_host: Callable[[float], float]
    t_accel: Optional[Callable[[float], float]] = None
    transfer: Optional[Callable[[float], float]] = None
    inter_transfer: Optional[Callable[[float], float]] = None
    K_accel_max: Optional[int] = None

    @staticmethod
    def from_tables(
        host,
        accel=None,
        transfer: Optional[Callable[[float], float]] = None,
        inter_transfer: Optional[Callable[[float], float]] = None,
        K_accel_max: Optional[int] = None,
    ) -> "NodeModel":
        """A node model from measured ``CalibrationTable``s (e.g. the
        autotuner's ``CalibrationTable.from_autotune`` output): host and
        accel tables become the T_CPU / T_MIC callables via ``time_fn()``,
        so the level-1/level-2 solves plan on observed per-element seconds
        and launch overheads instead of the analytic roofline."""
        return NodeModel(
            t_host=host.time_fn(),
            t_accel=None if accel is None else accel.time_fn(),
            transfer=transfer,
            inter_transfer=inter_transfer,
            K_accel_max=K_accel_max,
        )

    def solve(self, k: int, overlap: bool = False) -> SplitResult:
        """Best intra-node split of ``k`` elements (the level-2 solve)."""
        k = int(k)
        if self.t_accel is None:
            t = self.t_host(k) + (self.transfer(0) if self.transfer else 0.0)
            return SplitResult(counts=(k, 0), times=(t, 0.0), ratio=0.0)
        return solve_two_way(
            self.t_host, self.t_accel, k,
            transfer=self.transfer, K_accel_max=self.K_accel_max, overlap=overlap,
        )

    def node_time(self, k: float, overlap: bool = False) -> float:
        """Seconds for this node to advance ``k`` elements at its *optimal*
        internal split, plus its inter-node halo exchange — the level-1
        waterfilling consumes this as the node's aggregate time model."""
        k = int(round(max(0.0, float(k))))
        if k == 0:
            return 0.0
        t = self.solve(k, overlap=overlap).makespan
        if self.inter_transfer is not None:
            t += self.inter_transfer(k)
        return t


@dataclasses.dataclass(frozen=True)
class HierarchicalSplit:
    """Result of the two-level solve: level-1 node counts plus the level-2
    host/accel split inside each node."""

    node_counts: tuple  # elements per node (level 1)
    node_splits: tuple  # SplitResult per node (level 2)
    times: tuple  # per-node makespan incl. inter-node transfer

    @property
    def makespan(self) -> float:
        return max(self.times)

    @property
    def imbalance(self) -> float:
        return _imbalance(self.times)

    @property
    def accel_counts(self) -> tuple:
        """Per-node accelerator element counts (what ``build_cluster_partition``
        takes as ``accel_counts``)."""
        return tuple(int(s.counts[1]) for s in self.node_splits)

    @property
    def ratios(self) -> tuple:
        """Per-node K_accel/K_host — the paper's published per-node optimum
        (1.6 on Stampede) should be invariant under the node count."""
        return tuple(float(s.ratio) for s in self.node_splits)


def solve_hierarchical(
    nodes: Sequence[NodeModel],
    K: int,
    overlap: bool = False,
) -> HierarchicalSplit:
    """The paper's scheme across a heterogeneous cluster, solved nested.

    Level 1 (inter-node): waterfill ``K`` elements across nodes where each
    node's time model is its *best-achievable* makespan — the inner two-way
    solve at that count plus the node's inter-node halo exchange.  Level 2
    (intra-node): re-run the overlap-aware ``solve_two_way`` at each node's
    solved count.  Nesting the solves this way means a node with a strong
    accelerator is credited at level 1 for the work its accelerator absorbs,
    not just for its host throughput.
    """
    if len(nodes) == 0:
        raise ValueError("need at least one node")
    # memoize on (node identity, integer count): the waterfilling bisections
    # re-evaluate nearby k values constantly and each evaluation is itself a
    # solve — and a uniform fleet built as [node] * n shares one entry per k
    # instead of redoing the same inner bisection once per position
    cache: dict = {}

    def fn_for(n: NodeModel) -> Callable[[float], float]:
        def T(k: float) -> float:
            key = (id(n), int(round(max(0.0, float(k)))))
            if key not in cache:
                cache[key] = n.node_time(key[1], overlap=overlap)
            return cache[key]

        return T

    fns = [fn_for(n) for n in nodes]
    level1 = solve_multiway(fns, int(K))
    splits = tuple(n.solve(int(k), overlap=overlap) for n, k in zip(nodes, level1.counts))
    times = tuple(fns[i](level1.counts[i]) for i in range(len(nodes)))
    return HierarchicalSplit(node_counts=tuple(int(c) for c in level1.counts),
                             node_splits=splits, times=times)


@dataclasses.dataclass(frozen=True)
class RoundSpec:
    """One round of a multi-round re-aggregation schedule.

    ``workers`` are indices into the caller's worker list (fastest first —
    the survivors of the geometric shrink); ``counts``/``times`` align with
    them.  ``discount`` is the per-item cost multiplier this round runs at:
    re-aggregating results that earlier rounds already merged is cheaper
    than first-pass work (partiscontainer's cached comparisons), and the
    equal-cost sizing *derives* the discount each later, narrower round
    needs so its makespan equals round 1's.
    """

    workers: tuple  # worker indices participating this round
    counts: tuple  # work items per listed worker
    times: tuple  # modeled seconds per listed worker (discount applied)
    discount: float  # per-item cost multiplier vs first-pass work

    @property
    def makespan(self) -> float:
        return max(self.times) if self.times else 0.0

    @property
    def n_workers(self) -> int:
        return len(self.workers)


@dataclasses.dataclass(frozen=True)
class RoundsResult:
    """The full multi-round schedule (see ``solve_rounds``)."""

    rounds: tuple  # RoundSpec per round, round 1 first
    shrink: float  # nominal per-round worker-count divisor

    @property
    def n_rounds(self) -> int:
        return len(self.rounds)

    @property
    def worker_counts(self) -> tuple:
        return tuple(r.n_workers for r in self.rounds)

    @property
    def round_makespans(self) -> tuple:
        return tuple(r.makespan for r in self.rounds)

    @property
    def makespan(self) -> float:
        """Total modeled wall time: the rounds run back to back."""
        return float(sum(r.makespan for r in self.rounds))


def solve_rounds(
    time_fns: Sequence[Callable[[float], float]],
    K: int,
    shrink: float = 1.6,
) -> RoundsResult:
    """Multi-round re-aggregation sizing (partiscontainer's scheduler shape).

    Round 1 waterfills all ``K`` items across every worker
    (``solve_multiway`` — counts proportional to calibrated rates, common
    finish time).  Each later round re-aggregates all ``K`` merged results
    across ~``1/shrink`` as many workers (the fastest survive) until a
    single final aggregator remains.  Every round is sized to cost the same
    modeled wall time as round 1: the narrower fleet is credited with the
    per-item ``discount`` that equalizes it — the modeled form of "later
    rounds mostly re-merge already-compared results".  The 1.6 default
    echoes the paper's K_MIC/K_CPU optimum.

    Like ``solve_hierarchical``, per-worker time models are memoized on
    ``(worker index, integer count)``: the nested waterfilling bisections
    re-evaluate nearby k constantly, across every round.
    """
    n = len(time_fns)
    if n == 0:
        raise ValueError("need at least one worker")
    if shrink <= 1.0:
        raise ValueError(f"shrink must be > 1, got {shrink}")
    K = int(K)

    cache: dict = {}

    def memo(i: int) -> Callable[[float], float]:
        def T(k: float) -> float:
            key = (i, int(round(max(0.0, float(k)))))
            if key not in cache:
                cache[key] = float(time_fns[i](key[1]))
            return cache[key]

        return T

    fns = [memo(i) for i in range(n)]
    # speed ranking (fastest first, index as tie-break) decides survival
    k_ref = max(1, int(round(K / n)))
    ranked = sorted(range(n), key=lambda i: (fns[i](k_ref), i))

    def solve_subset(idx: Sequence[int]) -> SplitResult:
        return solve_multiway([fns[i] for i in idx], K)

    first = solve_subset(ranked)
    rounds = [
        RoundSpec(
            workers=tuple(ranked),
            counts=tuple(first.counts),
            times=tuple(first.times),
            discount=1.0,
        )
    ]
    T1 = first.makespan
    active = list(ranked)
    while len(active) > 1:
        w_next = int(round(len(active) / shrink))
        w_next = max(1, min(len(active) - 1, w_next))
        active = active[:w_next]  # fastest survive
        raw = solve_subset(active)
        d = T1 / raw.makespan if raw.makespan > 0 else 1.0
        rounds.append(
            RoundSpec(
                workers=tuple(active),
                counts=tuple(raw.counts),
                times=tuple(t * d for t in raw.times),
                discount=d,
            )
        )
    return RoundsResult(rounds=tuple(rounds), shrink=float(shrink))


def rebalance_from_measurements(
    current_counts: Sequence[int],
    measured_times: Sequence[float],
    smoothing: float = 0.5,
    prev_weights: Optional[Sequence[float]] = None,
) -> np.ndarray:
    """Online re-balance (straggler mitigation).

    Estimate per-partition throughput from the *measured* last-step times and
    return new work weights that equalize predicted times.  ``smoothing``
    blends with previous weights (EWMA) so one noisy step cannot thrash the
    partition.  This is the paper's equalizer run online: a straggling node
    (slow device, contended network) simply looks like a device class with a
    lower calibrated throughput.
    """
    counts = np.asarray(current_counts, dtype=np.float64)
    times = np.asarray(measured_times, dtype=np.float64)
    if (times <= 0).any():
        raise ValueError("measured times must be positive")
    throughput = counts / times  # items / s
    if (throughput <= 0).any():
        pos = throughput[throughput > 0]
        if len(pos) == 0:
            # nothing measured anywhere (all partitions idle): keep prior /
            # uniform weights rather than dividing by an empty mean
            prior = np.ones_like(throughput)
            if prev_weights is not None:
                prior = np.asarray(prev_weights, dtype=np.float64)
            return prior / prior.sum()
        # a partition with zero work: give it the mean throughput as a prior
        throughput = np.where(throughput > 0, throughput, pos.mean())
    new_w = throughput / throughput.sum()
    if prev_weights is not None:
        prev = np.asarray(prev_weights, dtype=np.float64)
        prev = prev / prev.sum()
        new_w = smoothing * new_w + (1.0 - smoothing) * prev
    return new_w / new_w.sum()
