"""The paper's contribution: a nested, two-level, asymmetric partition.

Level 1 (inter-node): Morton-order the element array, splice it into
contiguous chunks — one per node — with sizes proportional to node weights
(equal for homogeneous nodes; from the load balancer for heterogeneous
fleets).

Level 2 (intra-node): split each node's chunk into
  * ``boundary`` elements — elements with at least one face neighbour on a
    different node.  These stay on the partition that owns the network
    (the CPU in the paper; the shard that issues inter-group collectives in
    the TPU mapping), so inter-node face exchange never touches the slow
    intra-node link.
  * ``interior`` elements — a Morton-contiguous block of these is assigned
    to the accelerator.  Its size comes from the calibrated load balancer
    (paper section 5.6), and Morton contiguity keeps the CPU↔accelerator
    interface area — i.e. PCI/slow-link bytes — near the 6*K^(2/3) minimum
    (paper section 5.5).

Everything here is plain numpy on element *indices*; the JAX solver consumes
the resulting index arrays.  The partition is a reordering, never an
approximation — a correctness invariant asserted in tests (nested and flat
partitions produce bitwise-identical fields).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np

from repro.core.morton import curve_rank, morton_order

__all__ = [
    "splice",
    "hierarchical_splice",
    "face_neighbors",
    "NodePartition",
    "NestedPartition",
    "ClusterPartition",
    "build_nested_partition",
    "build_cluster_partition",
    "node_weights_from_devices",
    "face_cut_matrix",
    "surface_faces",
]


def splice(n_items: int, weights: Optional[Sequence[float]] = None, n_parts: Optional[int] = None) -> np.ndarray:
    """Contiguous splice of ``n_items`` into parts proportional to ``weights``.

    Returns offsets of shape (P+1,).  Largest-remainder rounding so that
    sizes sum exactly to ``n_items`` and no part is negative.
    """
    if weights is None:
        if n_parts is None:
            raise ValueError("need weights or n_parts")
        weights = np.ones(n_parts)
    w = np.asarray(weights, dtype=np.float64)
    if (w < 0).any() or w.sum() <= 0:
        raise ValueError(f"invalid weights {w}")
    ideal = n_items * w / w.sum()
    base = np.floor(ideal).astype(np.int64)
    rem = n_items - base.sum()
    # distribute the remainder to the largest fractional parts
    frac = ideal - base
    order = np.argsort(-frac, kind="stable")
    base[order[:rem]] += 1
    offsets = np.zeros(len(w) + 1, dtype=np.int64)
    np.cumsum(base, out=offsets[1:])
    assert offsets[-1] == n_items
    return offsets


def hierarchical_splice(n_items: int, level_weights: Sequence[Sequence[float]]) -> list:
    """Nested splice: level_weights[0] splits the whole array, each chunk is
    then split by level_weights[1], etc.  Returns a list of offset arrays per
    level (level l has prod(parts[:l+1])+ ... flattened offsets).

    Used to place work grains on a (pod, device) hierarchy so that grains
    that are adjacent on the space-filling curve land on the same pod first,
    then on the same device — locality across the slow link before the fast
    link, exactly the paper's level ordering.
    """
    levels = []
    chunks = [(0, n_items)]
    for weights in level_weights:
        offsets_all = []
        new_chunks = []
        for (lo, hi) in chunks:
            offs = splice(hi - lo, weights) + lo
            offsets_all.append(offs)
            for i in range(len(offs) - 1):
                new_chunks.append((int(offs[i]), int(offs[i + 1])))
        levels.append(offsets_all)
        chunks = new_chunks
    return levels


def face_neighbors(grid_dims: tuple) -> np.ndarray:
    """Face-neighbour ids for a structured hex grid.

    Returns (K, 6) int array, entries -1 at physical boundaries.
    Face order: (-x, +x, -y, +y, -z, +z).  Element id is x-fastest.
    """
    nx, ny, nz = grid_dims
    ix, iy, iz = np.meshgrid(np.arange(nx), np.arange(ny), np.arange(nz), indexing="ij")
    ix, iy, iz = ix.ravel(), iy.ravel(), iz.ravel()
    eid = ix + nx * (iy + ny * iz)
    K = nx * ny * nz
    nbr = np.full((K, 6), -1, dtype=np.int64)

    def _id(jx, jy, jz):
        return jx + nx * (jy + ny * jz)

    m = ix > 0
    nbr[eid[m], 0] = _id(ix[m] - 1, iy[m], iz[m])
    m = ix < nx - 1
    nbr[eid[m], 1] = _id(ix[m] + 1, iy[m], iz[m])
    m = iy > 0
    nbr[eid[m], 2] = _id(ix[m], iy[m] - 1, iz[m])
    m = iy < ny - 1
    nbr[eid[m], 3] = _id(ix[m], iy[m] + 1, iz[m])
    m = iz > 0
    nbr[eid[m], 4] = _id(ix[m], iy[m], iz[m] - 1)
    m = iz < nz - 1
    nbr[eid[m], 5] = _id(ix[m], iy[m], iz[m] + 1)
    return nbr


def surface_faces(mask: np.ndarray, neighbors: np.ndarray) -> int:
    """Number of faces between elements inside ``mask`` and everything else
    (other elements or the physical boundary excluded)."""
    inside = mask[:, None]
    nbr = neighbors
    valid = nbr >= 0
    nbr_in = np.zeros_like(valid)
    nbr_in[valid] = mask[nbr[valid]]
    cut = inside & valid & (~nbr_in)
    return int(cut[mask].sum())


@dataclasses.dataclass(frozen=True)
class NodePartition:
    """Level-2 split of one node's Morton-contiguous element chunk.

    ``boundary`` and ``interior`` are a disjoint cover of ``elements``
    (validated): boundary elements own at least one halo face, so they are
    what the step schedule's boundary phase computes and packs; interior
    elements have no halo dependence, so their volume work overlaps the
    exchange.  ``halo`` is the remote side of the same cut — the elements
    other nodes own whose faces touch this chunk, i.e. exactly what the
    exchange phase must fetch."""

    node: int
    elements: np.ndarray  # global element ids, Morton order (this node's chunk)
    boundary: np.ndarray  # subset: shared-face elements (stay on host/CPU)
    host_interior: np.ndarray  # interior elements kept on the host
    accel: np.ndarray  # interior elements offloaded to the accelerator
    halo: Optional[np.ndarray] = None  # remote elements adjacent to the chunk

    @property
    def host(self) -> np.ndarray:
        return np.concatenate([self.boundary, self.host_interior])

    @property
    def interior(self) -> np.ndarray:
        """All interior elements (host-kept + offloaded)."""
        return np.concatenate([self.host_interior, self.accel])

    @property
    def n_elements(self) -> int:
        return len(self.elements)


@dataclasses.dataclass(frozen=True)
class NestedPartition:
    grid_dims: tuple
    n_nodes: int
    order: np.ndarray  # (K,) Morton permutation of global element ids
    offsets: np.ndarray  # (n_nodes+1,) splice points into ``order``
    node_of: np.ndarray  # (K,) node id per global element id
    boundary_mask: np.ndarray  # (K,) bool per global element id
    accel_mask: np.ndarray  # (K,) bool per global element id
    nodes: tuple  # tuple[NodePartition, ...]
    neighbors: Optional[np.ndarray] = None  # (K, 6) topology the split used

    @property
    def n_elements(self) -> int:
        return len(self.order)

    def accel_fraction(self, node: int) -> float:
        np_ = self.nodes[node]
        return len(np_.accel) / max(1, np_.n_elements)

    def validate(self) -> None:
        """Invariants (also exercised by hypothesis tests)."""
        K = self.n_elements
        assert sorted(self.order.tolist()) == list(range(K)), "order must be a permutation"
        counts = np.zeros(K, dtype=np.int64)
        neighbors = self.neighbors if self.neighbors is not None else face_neighbors(self.grid_dims)
        for npart in self.nodes:
            counts[npart.elements] += 1
            # host/accel split partitions the node's chunk exactly
            merged = np.sort(np.concatenate([npart.boundary, npart.host_interior, npart.accel]))
            assert np.array_equal(merged, np.sort(npart.elements))
            # only interior elements are offloaded (paper constraint #1)
            assert not self.boundary_mask[npart.accel].any(), "accel may only own interior elements"
            # boundary/interior is a disjoint cover of the chunk
            assert len(np.intersect1d(npart.boundary, npart.interior)) == 0
            cover = np.sort(np.concatenate([npart.boundary, npart.interior]))
            assert np.array_equal(cover, np.sort(npart.elements)), "boundary+interior must cover the chunk"
            # halo = exactly the remote elements face-adjacent to the chunk
            if npart.halo is not None:
                nn = neighbors[npart.elements].ravel()
                nn = nn[nn >= 0]
                expected = np.unique(nn[self.node_of[nn] != npart.node])
                assert np.array_equal(np.sort(npart.halo), expected), "halo mismatch"
                assert len(np.intersect1d(npart.halo, npart.elements)) == 0
        assert (counts == 1).all(), "every element assigned to exactly one node"


def _choose_accel_block(interior: np.ndarray, n_accel: int, neighbors: np.ndarray) -> tuple:
    """Pick a Morton-contiguous block of ``n_accel`` interior elements that
    (approximately) minimizes exposed surface.

    ``interior`` is already in Morton order; contiguous runs are compact, so
    we scan a handful of candidate windows and keep the one with the fewest
    cut faces.  This mirrors the paper's 'minimize the surface area of the
    partition offloaded to the MIC' rule without an exact (NP-hard) solve.
    """
    n = len(interior)
    if n_accel <= 0:
        return interior[:0], interior
    if n_accel >= n:
        return interior, interior[:0]
    K = neighbors.shape[0]
    best = None
    best_cut = None
    # candidate window starts: ends, middle, and quarter points
    starts = sorted({0, (n - n_accel) // 4, (n - n_accel) // 2, 3 * (n - n_accel) // 4, n - n_accel})
    for s in starts:
        sel = interior[s : s + n_accel]
        mask = np.zeros(K, dtype=bool)
        mask[sel] = True
        cut = surface_faces(mask, neighbors)
        if best_cut is None or cut < best_cut:
            best_cut, best = cut, s
    sel = interior[best : best + n_accel]
    rest = np.concatenate([interior[:best], interior[best + n_accel :]])
    return sel, rest


def build_nested_partition(
    grid_dims: tuple,
    n_nodes: int,
    accel_fraction: float = 0.0,
    node_weights: Optional[Sequence[float]] = None,
    accel_counts: Optional[Sequence[int]] = None,
    neighbors: Optional[np.ndarray] = None,
) -> NestedPartition:
    """Build the paper's two-level partition for a structured hex grid.

    ``accel_fraction`` — target fraction of each node's elements to offload
    (e.g. K_MIC/K = 1.6/2.6 for the paper's Stampede optimum).  Clamped per
    node to the available interior.  ``accel_counts`` overrides it per node
    (that is what the load balancer produces).  ``neighbors`` — (K, 6)
    face-neighbour table; pass the solver mesh's table when its topology
    differs from the default non-periodic grid (e.g. periodic bricks), so
    boundary/halo sets match what the step schedule actually exchanges.
    """
    nx, ny, nz = grid_dims
    K = nx * ny * nz
    if K < n_nodes:
        raise ValueError(f"{K} elements < {n_nodes} nodes")
    order = morton_order(grid_dims)
    offsets = splice(K, node_weights, n_parts=n_nodes)
    node_of = np.empty(K, dtype=np.int64)
    for p in range(n_nodes):
        node_of[order[offsets[p] : offsets[p + 1]]] = p

    if neighbors is None:
        neighbors = face_neighbors(grid_dims)
    else:
        neighbors = np.asarray(neighbors, dtype=np.int64)
        if neighbors.shape != (K, 6):
            raise ValueError(f"neighbors shape {neighbors.shape} != {(K, 6)}")
    # boundary = any face neighbour on another node (physical boundary does
    # NOT make an element 'boundary' — paper partitions on shared faces).
    nbr_node = np.where(neighbors >= 0, node_of[np.clip(neighbors, 0, None)], -2)
    boundary_mask = ((nbr_node >= 0) & (nbr_node != node_of[:, None])).any(axis=1)

    accel_mask = np.zeros(K, dtype=bool)
    nodes = []
    for p in range(n_nodes):
        chunk = order[offsets[p] : offsets[p + 1]]
        is_b = boundary_mask[chunk]
        boundary = chunk[is_b]
        interior = chunk[~is_b]
        if accel_counts is not None:
            n_accel = int(accel_counts[p])
        else:
            n_accel = int(round(accel_fraction * len(chunk)))
        n_accel = max(0, min(n_accel, len(interior)))
        accel, host_interior = _choose_accel_block(interior, n_accel, neighbors)
        accel_mask[accel] = True
        # halo: the remote elements the exchange phase must fetch (sorted,
        # so consumers get a deterministic extended-block layout)
        nn = neighbors[chunk].ravel()
        nn = nn[nn >= 0]
        halo = np.unique(nn[node_of[nn] != p])
        nodes.append(
            NodePartition(
                node=p,
                elements=chunk,
                boundary=boundary,
                host_interior=host_interior,
                accel=accel,
                halo=halo,
            )
        )

    part = NestedPartition(
        grid_dims=grid_dims,
        n_nodes=n_nodes,
        order=order,
        offsets=offsets,
        node_of=node_of,
        boundary_mask=boundary_mask,
        accel_mask=accel_mask,
        nodes=tuple(nodes),
        neighbors=neighbors,
    )
    return part


# ---------------------------------------------------------------------------
# Level 0: the cluster — Morton inter-node splice over weighted virtual nodes
# ---------------------------------------------------------------------------


def node_weights_from_devices(devices: Sequence) -> np.ndarray:
    """Normalized inter-node splice weights from per-node ``DeviceClass``
    throughput (sustained FLOP/s) — the paper's heterogeneous-fleet level-1
    weighting: a node twice as fast owns twice the curve."""
    w = np.array([float(d.sustained_flops) for d in devices], dtype=np.float64)
    if (w <= 0).any():
        raise ValueError(f"device throughputs must be positive, got {w}")
    return w / w.sum()


def face_cut_matrix(node_of: np.ndarray, neighbors: np.ndarray, n_nodes: int) -> np.ndarray:
    """Directed cross-node face counts: ``M[i, j]`` = faces whose owning
    element lives on node ``i`` and whose neighbour lives on node ``j``.

    This is the cluster-level exchange volume: node ``i`` fetches
    ``M[i, j]`` faces' worth of halo data from node ``j`` each step, so the
    alpha-beta inter-node link model prices ``sum_j M[i, j]`` bytes and
    ``#{j : M[i, j] > 0}`` messages."""
    valid = neighbors >= 0
    own = np.broadcast_to(node_of[:, None], neighbors.shape)[valid]
    other = node_of[neighbors[valid]]
    cross = own != other
    M = np.zeros((n_nodes, n_nodes), dtype=np.int64)
    np.add.at(M, (own[cross], other[cross]), 1)
    return M


@dataclasses.dataclass(frozen=True)
class ClusterPartition:
    """The paper's full nested scheme: level-0 Morton splice across cluster
    nodes, level-1 boundary/interior (+ accelerator block) inside each node.

    ``node_weights`` are the normalized level-0 splice weights (per-node
    throughput); ``nested`` carries the per-node splits built on top of the
    same splice.  The cluster partition adds the *inter-node* view: curve
    contiguity per node and the cross-node face-cut matrix the halo exchange
    is priced from.
    """

    node_weights: np.ndarray  # (N,) normalized level-0 splice weights
    nested: NestedPartition

    # -- delegation to the shared splice ------------------------------------

    @property
    def grid_dims(self) -> tuple:
        return self.nested.grid_dims

    @property
    def n_nodes(self) -> int:
        return self.nested.n_nodes

    @property
    def n_elements(self) -> int:
        return self.nested.n_elements

    @property
    def order(self) -> np.ndarray:
        return self.nested.order

    @property
    def offsets(self) -> np.ndarray:
        return self.nested.offsets

    @property
    def node_of(self) -> np.ndarray:
        return self.nested.node_of

    @property
    def nodes(self) -> tuple:
        return self.nested.nodes

    # -- the inter-node view -------------------------------------------------

    def face_cuts(self) -> np.ndarray:
        """Directed cross-node face counts (see ``face_cut_matrix``)."""
        neighbors = (
            self.nested.neighbors
            if self.nested.neighbors is not None
            else face_neighbors(self.grid_dims)
        )
        return face_cut_matrix(self.node_of, neighbors, self.n_nodes)

    def halo_bytes(self, order: int, n_fields: int = 9, dtype_bytes: int = 8) -> np.ndarray:
        """Per-node bytes crossing the inter-node link each step: fetched
        halo faces plus the mirrored send, each face carrying an
        ``(order+1)^2``-node payload per field."""
        cuts = self.face_cuts()
        per_face = (order + 1) ** 2 * n_fields * dtype_bytes
        return (cuts.sum(axis=1) + cuts.sum(axis=0)) * per_face

    def halo_peers(self) -> np.ndarray:
        """Number of distinct exchange partners per node (message count for
        the alpha term of the link model)."""
        cuts = self.face_cuts()
        return ((cuts + cuts.T) > 0).sum(axis=1)

    def validate(self) -> None:
        """Cluster-level invariants on top of the nested ones:

        * node element sets are a disjoint cover of the mesh (delegated);
        * each node's set is contiguous in Morton curve order (level-0 is a
          *splice* of the curve, the locality guarantee);
        * the level-0 splice sizes follow ``node_weights`` exactly
          (largest-remainder splice of the weights);
        * every node's boundary/interior/halo split remains a validated
          disjoint cover (delegated to ``NestedPartition.validate``).
        """
        self.nested.validate()
        w = np.asarray(self.node_weights, dtype=np.float64)
        assert len(w) == self.n_nodes, "one weight per node"
        assert np.isclose(w.sum(), 1.0), "weights must be normalized"
        expected = splice(self.n_elements, w)
        assert np.array_equal(expected, self.offsets), "splice must follow node_weights"
        rank = curve_rank(self.order)
        for npart in self.nodes:
            if len(npart.elements):
                # ranks spanning exactly [lo, hi) over hi-lo distinct elements
                # IS curve contiguity — one gap-free run of the splice
                ranks = rank[npart.elements]
                lo, hi = int(self.offsets[npart.node]), int(self.offsets[npart.node + 1])
                assert len(ranks) == hi - lo, "chunk size must match its splice"
                assert ranks.min() == lo and ranks.max() == hi - 1, (
                    f"node {npart.node} not contiguous on the curve"
                )

    def summary(self) -> str:
        rows = []
        cuts = self.face_cuts()
        for p, npart in enumerate(self.nodes):
            rows.append(
                f"node{p}: w={float(self.node_weights[p]):.3f} "
                f"elements={npart.n_elements} boundary={len(npart.boundary)} "
                f"interior={len(npart.interior)} accel={len(npart.accel)} "
                f"halo={0 if npart.halo is None else len(npart.halo)} "
                f"cut_faces={int(cuts[p].sum())}"
            )
        return "\n".join(rows)


def build_cluster_partition(
    grid_dims: tuple,
    n_nodes: Optional[int] = None,
    node_devices: Optional[Sequence] = None,
    node_weights: Optional[Sequence[float]] = None,
    accel_fraction: float = 0.0,
    accel_counts: Optional[Sequence[int]] = None,
    neighbors: Optional[np.ndarray] = None,
) -> ClusterPartition:
    """Build the cluster-level nested partition.

    Level 0 Morton-orders the mesh and splices it across ``n_nodes`` virtual
    nodes with sizes proportional to ``node_weights`` (or per-node
    ``DeviceClass`` throughput via ``node_devices``; uniform when neither is
    given).  Level 1 applies the existing boundary/interior split inside
    each node's chunk — ``accel_fraction`` / ``accel_counts`` size the
    per-node accelerator block exactly as in ``build_nested_partition``.
    """
    if node_devices is not None:
        if node_weights is not None:
            raise ValueError("pass node_devices or node_weights, not both")
        node_weights = node_weights_from_devices(node_devices)
        if n_nodes is not None and n_nodes != len(node_weights):
            raise ValueError(f"n_nodes={n_nodes} != len(node_devices)={len(node_weights)}")
        n_nodes = len(node_weights)
    if node_weights is not None:
        w = np.asarray(node_weights, dtype=np.float64)
        if n_nodes is not None and n_nodes != len(w):
            raise ValueError(f"n_nodes={n_nodes} != len(node_weights)={len(w)}")
        n_nodes = len(w)
        node_weights = w / w.sum()
    if n_nodes is None:
        raise ValueError("need n_nodes, node_weights or node_devices")
    nested = build_nested_partition(
        grid_dims,
        n_nodes,
        accel_fraction=accel_fraction,
        node_weights=node_weights,
        accel_counts=accel_counts,
        neighbors=neighbors,
    )
    if node_weights is None:
        node_weights = np.full(n_nodes, 1.0 / n_nodes)
    return ClusterPartition(node_weights=np.asarray(node_weights), nested=nested)
