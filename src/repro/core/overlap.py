"""Boundary/interior overlap primitives — the paper's level-2 idea on TPU.

The paper hides the slow intra-node link by computing *interior* elements
while *boundary* faces are in flight, synchronizing once per step.  On TPU
the same dependency structure is expressed by decomposing a collective +
matmul into a ring of (local matmul on the chunk you hold) || (ppermute of
the next chunk): XLA's latency-hiding scheduler overlaps the DMA with MXU
work because the two have no data dependence — exactly "interior compute
over boundary communication".

These run inside ``jax.shard_map``.  ``overlap_map`` is the shared
compute-over-communication pipeline: round ``i`` computes on the data in
hand while the communication for round ``i+1`` is issued.  The two matmul
collectives are thin instantiations of it — ``ring_allgather_matmul``
replaces ``all_gather -> matmul`` (activation gathering for column-parallel
layers); ``matmul_ring_reducescatter`` replaces ``matmul -> reduce_scatter``
(row-parallel layers).  Both are exact (tested against the fused forms).
``halo_exchange_1d`` is the one-round case consumed by the DG
``StepSchedule`` (``repro.runtime.schedule``): the exchange is issued, the
interior phase computes, the correction phase consumes the received halo.
"""

from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax import lax

from repro.jax_compat import axis_size as _axis_size


def _perm_shift(axis_size: int, shift: int = 1):
    return [(i, (i + shift) % axis_size) for i in range(axis_size)]


def overlap_map(
    n_rounds: int,
    compute: Callable[[int, Any], Any],
    communicate: Callable[[int, Any], Any],
    carry: Any,
) -> Any:
    """Generic interior-over-boundary pipeline (the paper's Fig 5.1 loop).

    Runs ``carry = communicate(i, compute(i, carry))`` for rounds
    ``0 .. n_rounds-2`` and a final ``compute(n_rounds-1, carry)`` with
    nothing left to send.  Each round's communication carries the data the
    NEXT round's compute needs, so the two have no data dependence and the
    scheduler overlaps the DMA with the compute.

    The loop is unrolled in Python (``n_rounds`` is the — always concrete —
    ring size), which lets the latency-hiding scheduler see the whole
    pipeline and keeps per-round ``compute`` free to use round-specific
    constants.
    """
    if n_rounds < 1:
        raise ValueError(f"need at least one round, got {n_rounds}")
    for i in range(n_rounds - 1):
        carry = communicate(i, compute(i, carry))
    return compute(n_rounds - 1, carry)


def ring_allgather_matmul(
    x_shard: jnp.ndarray,
    w: jnp.ndarray,
    axis_name: str,
    reverse: bool = False,
) -> jnp.ndarray:
    """Compute ``all_gather(x_shard, axis) @ w`` without materializing the
    gather ahead of the matmul.

    x_shard: (m_local, k) — this member's chunk of the gathered dimension.
    w:       (k, n)       — local (already sharded on n outside, if at all).
    Returns (m_local * P, n), identical to the fused form.

    Each ring step multiplies the chunk currently held (interior work) while
    the next chunk is in flight via ppermute (boundary exchange).
    """
    P = _axis_size(axis_name)
    idx = lax.axis_index(axis_name)
    m_local, _ = x_shard.shape
    n = w.shape[1]
    shift = -1 if reverse else 1
    perm = _perm_shift(P, shift)

    out0 = jnp.zeros((m_local * P, n), dtype=jnp.result_type(x_shard.dtype, w.dtype))

    def compute(i, carry):  # interior: multiply the chunk currently held
        out, chunk = carry
        src = (idx - i * shift) % P  # owner of the chunk we currently hold
        out = lax.dynamic_update_slice(out, (chunk @ w).astype(out.dtype), (src * m_local, 0))
        return out, chunk

    def communicate(i, carry):  # boundary: next chunk in flight
        out, chunk = carry
        return out, lax.ppermute(chunk, axis_name, perm)

    out, _ = overlap_map(P, compute, communicate, (out0, x_shard))
    return out


def matmul_ring_reducescatter(
    x: jnp.ndarray,
    w_shard: jnp.ndarray,
    axis_name: str,
) -> jnp.ndarray:
    """Compute ``reduce_scatter(x @ w, axis, dim=0)`` chunk-by-chunk.

    x:       (m, k_local)  — activations, sharded on the contraction dim.
    w_shard: (k_local, n)  — weights, sharded on the contraction dim.
    Returns (m / P, n): this member's scattered shard of the summed product.

    Ring accumulation: at each step, add your partial product for the chunk
    you are about to pass on (interior), then rotate the accumulator
    (boundary).  Requires m % P == 0.
    """
    P = _axis_size(axis_name)
    idx = lax.axis_index(axis_name)
    m = x.shape[0]
    if m % P:
        raise ValueError(f"rows {m} not divisible by axis size {P}")
    mc = m // P
    perm = _perm_shift(P, 1)

    def partial_for(slot: jnp.ndarray) -> jnp.ndarray:
        xs = lax.dynamic_slice(x, (slot * mc, 0), (mc, x.shape[1]))
        return xs @ w_shard

    def compute(i, acc):
        # chunk destined for member (idx + P - 1 - i): add the local partial
        # to the rotating accumulator (the final round lands on slot = idx).
        slot = (idx + (P - 1) - i) % P
        return acc + partial_for(slot)

    def communicate(i, acc):  # pass the accumulator along the ring
        return lax.ppermute(acc, axis_name, perm)

    acc0 = jnp.zeros((mc, w_shard.shape[1]), dtype=jnp.result_type(x.dtype, w_shard.dtype))
    return overlap_map(P, compute, communicate, acc0)


def halo_exchange_1d(
    edge_lo: jnp.ndarray,
    edge_hi: jnp.ndarray,
    axis_name: str,
    wrap: bool = False,
):
    """Exchange 1-D halos with ring neighbours (the DG face exchange and the
    SSM chunk-state handoff both reduce to this).

    Sends ``edge_hi`` to the next member and ``edge_lo`` to the previous one;
    returns (recv_from_prev, recv_from_next).  With ``wrap=False`` the ends
    receive zeros (physical boundary).
    """
    P = _axis_size(axis_name)
    idx = lax.axis_index(axis_name)
    fwd = _perm_shift(P, 1) if wrap else [(i, i + 1) for i in range(P - 1)]
    bwd = _perm_shift(P, -1) if wrap else [(i + 1, i) for i in range(P - 1)]
    from_prev = lax.ppermute(edge_hi, axis_name, fwd)
    from_next = lax.ppermute(edge_lo, axis_name, bwd)
    if not wrap:
        from_prev = jnp.where(idx == 0, jnp.zeros_like(from_prev), from_prev)
        from_next = jnp.where(idx == P - 1, jnp.zeros_like(from_next), from_next)
    return from_prev, from_next
