"""Boundary/interior overlap primitives — the paper's level-2 idea on TPU.

The paper hides the slow intra-node link by computing *interior* elements
while *boundary* faces are in flight, synchronizing once per step.  On TPU
the same dependency structure is expressed by decomposing a collective +
matmul into a ring of (local matmul on the chunk you hold) || (ppermute of
the next chunk): XLA's latency-hiding scheduler overlaps the DMA with MXU
work because the two have no data dependence — exactly "interior compute
over boundary communication".

These run inside ``jax.shard_map``.  ``ring_allgather_matmul`` replaces
``all_gather -> matmul`` (activation gathering for column-parallel layers);
``matmul_ring_reducescatter`` replaces ``matmul -> reduce_scatter``
(row-parallel layers).  Both are exact (tested against the fused forms).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.jax_compat import axis_size as _axis_size


def _perm_shift(axis_size: int, shift: int = 1):
    return [(i, (i + shift) % axis_size) for i in range(axis_size)]


def ring_allgather_matmul(
    x_shard: jnp.ndarray,
    w: jnp.ndarray,
    axis_name: str,
    reverse: bool = False,
) -> jnp.ndarray:
    """Compute ``all_gather(x_shard, axis) @ w`` without materializing the
    gather ahead of the matmul.

    x_shard: (m_local, k) — this member's chunk of the gathered dimension.
    w:       (k, n)       — local (already sharded on n outside, if at all).
    Returns (m_local * P, n), identical to the fused form.

    Each ring step multiplies the chunk currently held (interior work) while
    the next chunk is in flight via ppermute (boundary exchange).
    """
    P = _axis_size(axis_name)
    idx = lax.axis_index(axis_name)
    m_local, _ = x_shard.shape
    n = w.shape[1]
    shift = -1 if reverse else 1
    perm = _perm_shift(P, shift)

    out = jnp.zeros((m_local * P, n), dtype=jnp.result_type(x_shard.dtype, w.dtype))

    def body(i, carry):
        out, chunk = carry
        src = (idx - i * shift) % P  # owner of the chunk we currently hold
        part = chunk @ w  # interior compute
        out = lax.dynamic_update_slice(out, part.astype(out.dtype), (src * m_local, 0))
        chunk = lax.ppermute(chunk, axis_name, perm)  # boundary exchange
        return out, chunk

    out, last = lax.fori_loop(0, P - 1, body, (out, x_shard))
    # last chunk: no further permute needed
    src = (idx - (P - 1) * shift) % P
    out = lax.dynamic_update_slice(out, (last @ w).astype(out.dtype), (src * m_local, 0))
    return out


def matmul_ring_reducescatter(
    x: jnp.ndarray,
    w_shard: jnp.ndarray,
    axis_name: str,
) -> jnp.ndarray:
    """Compute ``reduce_scatter(x @ w, axis, dim=0)`` chunk-by-chunk.

    x:       (m, k_local)  — activations, sharded on the contraction dim.
    w_shard: (k_local, n)  — weights, sharded on the contraction dim.
    Returns (m / P, n): this member's scattered shard of the summed product.

    Ring accumulation: at each step, add your partial product for the chunk
    you are about to pass on (interior), then rotate the accumulator
    (boundary).  Requires m % P == 0.
    """
    P = _axis_size(axis_name)
    idx = lax.axis_index(axis_name)
    m = x.shape[0]
    if m % P:
        raise ValueError(f"rows {m} not divisible by axis size {P}")
    mc = m // P
    perm = _perm_shift(P, 1)

    def partial_for(slot: jnp.ndarray) -> jnp.ndarray:
        xs = lax.dynamic_slice(x, (slot * mc, 0), (mc, x.shape[1]))
        return xs @ w_shard

    def body(i, acc):
        # chunk destined for member (idx + P - 1 - i): compute local partial,
        # add to the rotating accumulator, pass it along the ring.
        slot = (idx + (P - 1) - i) % P
        acc = acc + partial_for(slot)
        acc = lax.ppermute(acc, axis_name, perm)
        return acc

    acc = jnp.zeros((mc, w_shard.shape[1]), dtype=jnp.result_type(x.dtype, w_shard.dtype))
    acc = lax.fori_loop(0, P - 1, body, acc)
    acc = acc + partial_for(idx)
    return acc


def halo_exchange_1d(
    edge_lo: jnp.ndarray,
    edge_hi: jnp.ndarray,
    axis_name: str,
    wrap: bool = False,
):
    """Exchange 1-D halos with ring neighbours (the DG face exchange and the
    SSM chunk-state handoff both reduce to this).

    Sends ``edge_hi`` to the next member and ``edge_lo`` to the previous one;
    returns (recv_from_prev, recv_from_next).  With ``wrap=False`` the ends
    receive zeros (physical boundary).
    """
    P = _axis_size(axis_name)
    idx = lax.axis_index(axis_name)
    fwd = _perm_shift(P, 1) if wrap else [(i, i + 1) for i in range(P - 1)]
    bwd = _perm_shift(P, -1) if wrap else [(i + 1, i) for i in range(P - 1)]
    from_prev = lax.ppermute(edge_hi, axis_name, fwd)
    from_next = lax.ppermute(edge_lo, axis_name, bwd)
    if not wrap:
        from_prev = jnp.where(idx == 0, jnp.zeros_like(from_prev), from_prev)
        from_next = jnp.where(idx == P - 1, jnp.zeros_like(from_next), from_next)
    return from_prev, from_next
