"""Morton (Z-order) space-filling-curve ordering.

The paper's level-1 partition Morton-orders the octree elements and splices
the resulting 1-D array into contiguous chunks (section 5.1, citing Sundar,
Sampath & Biros).  Contiguous Morton ranges are geometrically compact, which
is what keeps partition surface area — and therefore both MPI and CPU↔MIC
face traffic — near-minimal.

Vectorized numpy implementation; supports arbitrary (non-power-of-two,
anisotropic) structured grids by interleaving enough bits per axis.
"""

from __future__ import annotations

import numpy as np

__all__ = [
    "interleave_bits3",
    "morton_encode3",
    "morton_order",
    "morton_order_coords",
    "curve_rank",
    "is_curve_contiguous",
]


def _part1by2(x: np.ndarray, nbits: int) -> np.ndarray:
    """Spread the low ``nbits`` bits of x so consecutive bits are 3 apart."""
    x = x.astype(np.uint64)
    out = np.zeros_like(x)
    for b in range(nbits):
        out |= ((x >> np.uint64(b)) & np.uint64(1)) << np.uint64(3 * b)
    return out


def interleave_bits3(ix: np.ndarray, iy: np.ndarray, iz: np.ndarray, nbits: int) -> np.ndarray:
    """Interleave bits of three integer coordinate arrays (x lowest)."""
    return (
        _part1by2(ix, nbits)
        | (_part1by2(iy, nbits) << np.uint64(1))
        | (_part1by2(iz, nbits) << np.uint64(2))
    )


def morton_encode3(coords: np.ndarray) -> np.ndarray:
    """Morton codes for integer coordinates of shape (K, 3)."""
    coords = np.asarray(coords)
    if coords.ndim != 2 or coords.shape[1] != 3:
        raise ValueError(f"expected (K, 3) integer coords, got {coords.shape}")
    if coords.size and coords.min() < 0:
        raise ValueError("coordinates must be non-negative")
    maxc = int(coords.max()) if coords.size else 0
    nbits = max(1, int(maxc).bit_length())
    if 3 * nbits > 63:
        raise ValueError(f"grid too large for 64-bit Morton codes: max coord {maxc}")
    return interleave_bits3(coords[:, 0], coords[:, 1], coords[:, 2], nbits)


def morton_order(grid_dims: tuple) -> np.ndarray:
    """Permutation of element ids (x-fastest raveling) into Morton order.

    ``grid_dims = (nx, ny, nz)``; element id ``e = ix + nx*(iy + ny*iz)``.
    Returns ``perm`` such that ``elements[perm]`` is Morton-ordered.
    """
    nx, ny, nz = grid_dims
    ix, iy, iz = np.meshgrid(np.arange(nx), np.arange(ny), np.arange(nz), indexing="ij")
    # element id with x fastest:
    eid = (ix + nx * (iy + ny * iz)).ravel()
    codes = morton_encode3(np.stack([ix.ravel(), iy.ravel(), iz.ravel()], axis=1))
    order = np.argsort(codes, kind="stable")
    return eid[order]


def morton_order_coords(coords: np.ndarray) -> np.ndarray:
    """Argsort arbitrary integer (K,3) coordinates into Morton order."""
    return np.argsort(morton_encode3(coords), kind="stable")


def curve_rank(order: np.ndarray) -> np.ndarray:
    """Inverse permutation: position of each element id along the curve.

    ``rank[e]`` is where element ``e`` sits in ``order``; a set of elements
    is curve-contiguous iff its ranks form a gap-free integer range.  The
    cluster partition's level-1 invariant — each node owns a contiguous
    Morton range — is checked in terms of this.
    """
    order = np.asarray(order)
    rank = np.empty(len(order), dtype=np.int64)
    rank[order] = np.arange(len(order), dtype=np.int64)
    return rank


def is_curve_contiguous(order: np.ndarray, elements: np.ndarray) -> bool:
    """True iff ``elements`` occupy one gap-free run of the curve ``order``."""
    elements = np.asarray(elements)
    if len(elements) == 0:
        return True
    ranks = np.sort(curve_rank(order)[elements])
    return bool(ranks[-1] - ranks[0] == len(ranks) - 1 and len(np.unique(ranks)) == len(ranks))
