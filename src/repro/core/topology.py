"""Hardware topology descriptions and roofline constants.

The paper calibrates per-device-class runtime models for a heterogeneous
node (Sandy Bridge CPU socket + Xeon Phi coprocessor, joined by a PCI bus).
We keep the same abstraction — a ``DeviceClass`` with peak compute, memory
bandwidth and an attached ``LinkClass`` — and instantiate it both for the
paper's Stampede node (used to validate the load-balance solver against the
published ``K_MIC/K_CPU = 1.6`` optimum) and for the TPU v5e pod hierarchy
that this framework targets.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

# ---------------------------------------------------------------------------
# Generic device/link classes
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class LinkClass:
    """A communication link with a simple latency/bandwidth (alpha-beta) model."""

    name: str
    bandwidth: float  # bytes / second, per direction
    latency: float = 0.0  # seconds per message

    def time(self, nbytes: float, n_messages: int = 1) -> float:
        return self.latency * n_messages + nbytes / self.bandwidth


@dataclasses.dataclass(frozen=True)
class DeviceClass:
    """A compute device with roofline constants.

    ``efficiency`` scales peak FLOP/s to a *sustained* value for real kernels;
    the paper's T_CPU/T_MIC tables are measured, which is equivalent to
    carrying per-kernel efficiency factors.  ``mem_efficiency`` does the same
    for bandwidth.
    """

    name: str
    peak_flops: float  # FLOP/s (double for Stampede, bf16 for TPU)
    hbm_bandwidth: float  # bytes / s
    memory_bytes: float  # capacity
    efficiency: float = 1.0
    mem_efficiency: float = 1.0

    @property
    def sustained_flops(self) -> float:
        return self.peak_flops * self.efficiency

    @property
    def sustained_bandwidth(self) -> float:
        return self.hbm_bandwidth * self.mem_efficiency

    def time_roofline(self, flops: float, bytes_moved: float) -> float:
        """Roofline execution-time estimate: max of compute and memory terms."""
        t_compute = flops / self.sustained_flops
        t_memory = bytes_moved / self.sustained_bandwidth
        return max(t_compute, t_memory)


# ---------------------------------------------------------------------------
# The paper's machine: one Stampede compute node (section 5.2)
# ---------------------------------------------------------------------------
# Per the paper: one SNB socket = 8 cores * 2.7 GHz * 8 DP flops/cycle
# = 172.8 GFLOP/s, 51.2 GB/s (4 channels @ 1600 MT/s); the MIC = 61 cores
# @ 1.1 GHz * 16 DP flops/cycle ~= 1.0 TFLOP/s, 320 GB/s, 8 GB RAM.
#
# The published tables T_CPU / T_MIC are not in the paper; the *observed*
# optimum K_MIC/K_CPU = 1.6 implies a sustained-throughput ratio of ~1.6
# (MIC efficiency on this DG code was far below peak, as was typical).  We
# encode efficiencies consistent with the published optimum and the 6.3x
# single-node speedup, and validate the solver against them in tests; the
# sensitivity of the split to these factors is swept in
# benchmarks/fig5_2_load_fraction.py.

STAMPEDE_SNB_SOCKET = DeviceClass(
    name="snb-socket",
    peak_flops=172.8e9,
    hbm_bandwidth=51.2e9,
    memory_bytes=32e9,
    efficiency=0.65,
    mem_efficiency=0.80,
)

STAMPEDE_MIC = DeviceClass(
    name="xeon-phi",
    peak_flops=1.0e12,
    hbm_bandwidth=320e9,
    memory_bytes=8e9,
    efficiency=0.18,
    mem_efficiency=0.55,
)

# PCI bus between host and MIC; Fig 5.3 shows ~1-6 GB/s with high variance
# and a visible per-message latency floor.
STAMPEDE_PCI = LinkClass(name="pci", bandwidth=6.0e9, latency=15e-6)

# InfiniBand FDR between nodes.
STAMPEDE_IB = LinkClass(name="infiniband", bandwidth=6.8e9, latency=1.5e-6)


# ---------------------------------------------------------------------------
# Target machine: TPU v5e pods (roofline constants fixed by the assignment)
# ---------------------------------------------------------------------------

TPU_V5E = DeviceClass(
    name="tpu-v5e",
    peak_flops=197e12,  # bf16
    hbm_bandwidth=819e9,
    memory_bytes=16e9,
)

# Per-link ICI bandwidth (one direction).  A v5e chip in a 2D torus has
# multiple links; collective-bytes rooflines in this repo charge the
# per-chip aggregate as n_links * ICI_LINK.bandwidth where relevant, but the
# §Roofline collective term uses the assignment's convention:
# collective_bytes / (chips * link_bw).
ICI_LINK = LinkClass(name="ici", bandwidth=50e9, latency=1e-6)

# Data-centre network between pods: the slow link (the PCI-bus analogue in
# the nested-partition mapping).  ~25 GB/s per host (8 chips) is a
# representative planning number => ~3 GB/s per chip.
DCN_LINK = LinkClass(name="dcn", bandwidth=3.125e9, latency=10e-6)


@dataclasses.dataclass(frozen=True)
class ClusterTopology:
    """A nested cluster: groups of devices joined by a slow link.

    This generalizes the paper's node = (CPU + MIC over PCI) to
    fleet = (pods over DCN), pod = (chips over ICI).
    """

    name: str
    device: DeviceClass
    devices_per_group: int
    n_groups: int
    fast_link: LinkClass
    slow_link: LinkClass
    # Optional heterogeneity: per-group device class override.
    group_devices: Optional[tuple] = None

    @property
    def n_devices(self) -> int:
        return self.devices_per_group * self.n_groups

    def device_for_group(self, g: int) -> DeviceClass:
        if self.group_devices is not None:
            return self.group_devices[g]
        return self.device


def single_pod_v5e(n_chips: int = 256) -> ClusterTopology:
    return ClusterTopology(
        name=f"v5e-{n_chips}",
        device=TPU_V5E,
        devices_per_group=n_chips,
        n_groups=1,
        fast_link=ICI_LINK,
        slow_link=DCN_LINK,
    )


def multi_pod_v5e(n_pods: int = 2, chips_per_pod: int = 256) -> ClusterTopology:
    return ClusterTopology(
        name=f"v5e-{n_pods}x{chips_per_pod}",
        device=TPU_V5E,
        devices_per_group=chips_per_pod,
        n_groups=n_pods,
        fast_link=ICI_LINK,
        slow_link=DCN_LINK,
    )


def stampede_node() -> ClusterTopology:
    """The paper's heterogeneous node: CPU socket + MIC over PCI."""
    return ClusterTopology(
        name="stampede-node",
        device=STAMPEDE_SNB_SOCKET,
        devices_per_group=1,
        n_groups=2,
        fast_link=STAMPEDE_PCI,
        slow_link=STAMPEDE_IB,
        group_devices=(STAMPEDE_SNB_SOCKET, STAMPEDE_MIC),
    )
