"""Core library: the paper's nested partitioning scheme, generalized.

- morton:       space-filling-curve ordering (level-1 locality)
- partition:    two-level nested partition with boundary/interior split
- cost_model:   calibrated T(N, K) runtime models (paper section 5.6)
- load_balance: equalization solvers, offline and online (stragglers)
- topology:     device/link classes (Stampede node, TPU v5e pods)
- collectives:  hierarchy-aware (slow-link-minimizing, compressed) psums
- overlap:      boundary/interior overlapped collective-matmul primitives
"""

from repro.core.load_balance import SplitResult, rebalance_from_measurements, solve_multiway, solve_two_way
from repro.core.morton import morton_order, morton_order_coords
from repro.core.partition import (
    NestedPartition,
    NodePartition,
    build_nested_partition,
    face_neighbors,
    hierarchical_splice,
    splice,
    surface_faces,
)

__all__ = [
    "SplitResult",
    "solve_two_way",
    "solve_multiway",
    "rebalance_from_measurements",
    "morton_order",
    "morton_order_coords",
    "NestedPartition",
    "NodePartition",
    "build_nested_partition",
    "face_neighbors",
    "hierarchical_splice",
    "splice",
    "surface_faces",
]
