"""Core library: the paper's nested partitioning scheme, generalized.

- morton:       space-filling-curve ordering (level-1 locality)
- partition:    two-level nested partition with boundary/interior split
- cost_model:   calibrated T(N, K) runtime models (paper section 5.6)
- load_balance: equalization solvers, offline and online (stragglers)
- topology:     device/link classes (Stampede node, TPU v5e pods)
- collectives:  hierarchy-aware (slow-link-minimizing, compressed) psums
- overlap:      boundary/interior overlapped collective-matmul primitives
"""

from repro.core.load_balance import (
    HierarchicalSplit,
    NodeModel,
    SplitResult,
    rebalance_from_measurements,
    solve_hierarchical,
    solve_multiway,
    solve_two_way,
)
from repro.core.morton import curve_rank, is_curve_contiguous, morton_order, morton_order_coords
from repro.core.partition import (
    ClusterPartition,
    NestedPartition,
    NodePartition,
    build_cluster_partition,
    build_nested_partition,
    face_cut_matrix,
    face_neighbors,
    hierarchical_splice,
    node_weights_from_devices,
    splice,
    surface_faces,
)

__all__ = [
    "SplitResult",
    "NodeModel",
    "HierarchicalSplit",
    "solve_two_way",
    "solve_multiway",
    "solve_hierarchical",
    "rebalance_from_measurements",
    "morton_order",
    "morton_order_coords",
    "curve_rank",
    "is_curve_contiguous",
    "ClusterPartition",
    "NestedPartition",
    "NodePartition",
    "build_cluster_partition",
    "build_nested_partition",
    "face_cut_matrix",
    "face_neighbors",
    "hierarchical_splice",
    "node_weights_from_devices",
    "splice",
    "surface_faces",
]
