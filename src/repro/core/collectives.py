"""Hierarchy-aware collectives: the nested partition applied to communication.

The paper's rule — keep slow-link traffic at the surface-to-volume minimum
and synchronize once per step — becomes, on a multi-pod TPU mesh:

* gradients are reduce-scattered along the *fast* intra-pod axes, summed
  across pods over the *slow* DCN axis at 1/P of the bytes, then
  all-gathered back along the fast axes (`hierarchical_psum`);
* the slow hop can additionally be int8-compressed with per-chunk scales
  (`compressed_psum`); error feedback lives in the optimizer.

All functions are written for use *inside* ``jax.shard_map`` with the mesh
axes named as in ``launch/mesh.py`` (("pod",) "data", "model").
"""

from __future__ import annotations

from functools import partial
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax import lax


def _axis_size(axis_name) -> int:
    from repro.jax_compat import axis_size

    return axis_size(axis_name)


def _pad_to_multiple(x: jnp.ndarray, mult: int) -> Tuple[jnp.ndarray, int]:
    n = x.shape[0]
    pad = (-n) % mult
    if pad:
        x = jnp.concatenate([x, jnp.zeros((pad,) + x.shape[1:], x.dtype)], axis=0)
    return x, pad


def hierarchical_psum(x: jnp.ndarray, fast_axis, slow_axis: Optional[str] = None) -> jnp.ndarray:
    """psum over (fast_axis x slow_axis) that sends only 1/|fast| of the
    bytes over the slow link: RS(fast) -> psum(slow) -> AG(fast).

    ``fast_axis`` may be a tuple of axis names.  Works on any-shaped x
    (flattened internally, padded to the fast-axis multiple).
    """
    shape = x.shape
    flat = x.reshape(-1)
    fsize = _axis_size(fast_axis)
    flat, pad = _pad_to_multiple(flat, fsize)
    shard = lax.psum_scatter(flat, fast_axis, scatter_dimension=0, tiled=True)
    if slow_axis is not None:
        shard = lax.psum(shard, slow_axis)
    full = lax.all_gather(shard, fast_axis, axis=0, tiled=True)
    if pad:
        full = full[: flat.shape[0] - pad]
    return full.reshape(shape)


def quantize_int8(x: jnp.ndarray, block: int = 256) -> Tuple[jnp.ndarray, jnp.ndarray, int]:
    """Blockwise symmetric int8 quantization. Returns (q, scales, pad)."""
    flat = x.reshape(-1)
    flat, pad = _pad_to_multiple(flat, block)
    blocks = flat.reshape(-1, block)
    amax = jnp.max(jnp.abs(blocks), axis=1, keepdims=True)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0).astype(jnp.float32)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale, pad


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray, pad: int, shape, dtype) -> jnp.ndarray:
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    if pad:
        flat = flat[:-pad]
    return flat.reshape(shape).astype(dtype)


def compressed_psum(x: jnp.ndarray, slow_axis: str, block: int = 256) -> jnp.ndarray:
    """psum along the slow axis with int8 payloads (4x fewer slow-link bytes
    than a bf16 ring).  Each member quantizes its shard, all-gathers the int8
    blocks + fp32 scales, and sums the dequantized copies locally.  Exact for
    the scales; quantization error is handled by error feedback in the
    optimizer (optim/grad_compress.py).
    """
    q, scale, pad = quantize_int8(x, block)
    qg = lax.all_gather(q, slow_axis, axis=0)  # (P, nblk, block) int8
    sg = lax.all_gather(scale, slow_axis, axis=0)  # (P, nblk, 1) f32
    total = jnp.sum(qg.astype(jnp.float32) * sg, axis=0)
    flat = total.reshape(-1)
    if pad:
        flat = flat[:-pad]
    return flat.reshape(x.shape).astype(x.dtype)


def hierarchical_psum_compressed(
    x: jnp.ndarray, fast_axis, slow_axis: Optional[str], block: int = 256
) -> jnp.ndarray:
    """RS(fast) -> compressed psum(slow) -> AG(fast)."""
    shape = x.shape
    flat = x.reshape(-1)
    fsize = _axis_size(fast_axis)
    flat, pad = _pad_to_multiple(flat, fsize)
    shard = lax.psum_scatter(flat, fast_axis, scatter_dimension=0, tiled=True)
    if slow_axis is not None:
        shard = compressed_psum(shard, slow_axis, block=block)
    full = lax.all_gather(shard, fast_axis, axis=0, tiled=True)
    if pad:
        full = full[: flat.shape[0] - pad]
    return full.reshape(shape)


def collective_bytes_psum(n_elements: int, dtype_bytes: int, axis_sizes: Sequence[int]) -> float:
    """Napkin-math wire bytes for a ring all-reduce over the given axes."""
    total = 1
    for s in axis_sizes:
        total *= s
    return 2.0 * (total - 1) / total * n_elements * dtype_bytes
