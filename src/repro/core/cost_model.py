"""Calibrated per-kernel runtime models — the paper's T_CPU(N,K), T_MIC(N,K).

Section 5.6 of the paper builds, from measurement, two functions that
predict the time to process K order-N elements for one timestep on each
device class, plus a PCI transfer model, and solves
``T_MIC(N, K_MIC) = T_CPU(N, K - K_MIC)`` for the split.

We reproduce that machinery in two layers:

* an *analytic* roofline model (`DGWorkModel` + `roofline_time_fn`) that
  derives FLOPs and bytes per element per timestep for each DG kernel from
  the discretization (used for TPU planning and napkin math);
* a *calibration table* (`CalibrationTable`) of measured seconds/element —
  what the paper actually used.  `stampede_calibration()` encodes
  per-kernel times reconstructed from the paper's published data (Fig 4.1
  kernel shares; the K_MIC/K_CPU = 1.6 optimum; the 6.3x node speedup); the
  tables themselves were not published.  `calibrate()` builds a table from
  live measurements of this repo's JAX kernels.

The measured path now also closes the loop with the kernel autotuner:
`CalibrationTable.from_autotune` turns a `repro.kernels.autotune` cache
entry (measured sec/element for the Pallas volume/flux kernels plus the
fitted per-launch intercept) into a planner table, and
`roofline_time_fn`'s per-step `overhead` default resolves from the same
cache (`measured_launch_overhead`) when one is present, falling back to
the historical 20 µs constant otherwise — so `solve_two_way` /
`solve_hierarchical` plan on observed rooflines, not assumed ones.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, Optional

from repro.core.topology import (
    DeviceClass,
    LinkClass,
    STAMPEDE_IB,
    STAMPEDE_MIC,
    STAMPEDE_PCI,
    STAMPEDE_SNB_SOCKET,
)

DG_KERNELS = ("volume_loop", "interp_q", "int_flux", "lift", "rk", "bound_flux", "parallel_flux")


# ---------------------------------------------------------------------------
# Analytic work model for the DGSEM elastic-acoustic step
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class DGWorkModel:
    """FLOPs / bytes per element per *timestep* for each kernel.

    order: polynomial order N (M = N+1 nodes per direction).
    n_fields: 9 for strain(6-sym stored)+velocity(3) strain-velocity form.
    n_stages: RK stages per timestep (LSRK4(5) = 5).
    dtype_bytes: 8 (the paper is double precision).
    """

    order: int
    n_fields: int = 9
    n_stages: int = 5
    dtype_bytes: int = 8

    @property
    def M(self) -> int:
        return self.order + 1

    @property
    def nodes_per_elem(self) -> int:
        return self.M**3

    @property
    def face_nodes(self) -> int:
        return self.M**2

    def flops_per_element(self, kernel: str) -> float:
        M, F, V = self.M, self.n_fields, self.nodes_per_elem
        per_stage = {
            # 3 contravariant flux components x F fields x ~6 flops each,
            # then 3 tensor-product derivative applications (2*M flops per
            # node each) + divergence accumulate + inverse-mass scaling.
            "volume_loop": 3 * F * V * 6 + 3 * F * V * 2 * M + F * V * 2,
            # face extraction is data movement (LGL collocation: slices)
            "interp_q": 0.0,
            # exact Riemann flux: ~170 flops per face node per field-block,
            # 6 faces but each interior face shared by two elements => 3.
            "int_flux": 3 * self.face_nodes * 170,
            # lift: add scaled face flux into volume at face nodes
            "lift": 6 * self.face_nodes * F * 4,
            # LSRK update: res = a*res + dt*rhs ; q += b*res
            "rk": F * V * 4,
            "bound_flux": 0.5 * self.face_nodes * 170,  # amortized phys-boundary share
            "parallel_flux": 0.25 * self.face_nodes * 170,  # amortized halo share
        }[kernel]
        return per_stage * self.n_stages

    def bytes_per_element(self, kernel: str) -> float:
        M, F, V, B = self.M, self.n_fields, self.nodes_per_elem, self.dtype_bytes
        per_stage = {
            # read q + metrics, write rhs (+ flux temporaries)
            "volume_loop": V * F * B * 3 + V * 9 * B,
            "interp_q": 6 * self.face_nodes * F * B * 2,
            "int_flux": 3 * self.face_nodes * (2 * F) * B * 2,
            "lift": 6 * self.face_nodes * F * B * 2 + V * F * B,
            "rk": V * F * B * 4,
            "bound_flux": 0.5 * self.face_nodes * 2 * F * B * 2,
            "parallel_flux": 0.25 * self.face_nodes * 2 * F * B * 2,
        }[kernel]
        return per_stage * self.n_stages

    def total_flops_per_element(self) -> float:
        return sum(self.flops_per_element(k) for k in DG_KERNELS)

    def total_bytes_per_element(self) -> float:
        return sum(self.bytes_per_element(k) for k in DG_KERNELS)


def roofline_seconds(flops: float, bytes_moved: float, device: DeviceClass) -> float:
    return max(flops / device.sustained_flops, bytes_moved / device.sustained_bandwidth)


DEFAULT_LAUNCH_OVERHEAD = 20e-6  # per-step launch/sync overhead fallback


def measured_launch_overhead(
    device_name: Optional[str] = None,
    path: Optional[str] = None,
    default: float = DEFAULT_LAUNCH_OVERHEAD,
) -> float:
    """The per-launch overhead measured by ``repro.kernels.autotune`` (the
    intercept of its two-point t(K) fits), read from the autotune cache.

    Prefers entries whose ``device_kind`` matches ``device_name``; with no
    match (or no cache at all) falls back over all cached entries, then to
    ``default`` — the historical 20 µs constant, pinned by a unit test."""
    try:
        from repro.kernels.autotune import load_cache

        cache = load_cache(path)
    except Exception:
        return float(default)
    entries = [e for e in cache.values() if isinstance(e, dict)
               and "launch_overhead_s" in e]
    if device_name is not None:
        matched = [e for e in entries if e.get("device_kind") == device_name]
        entries = matched or entries
    vals = sorted(float(e["launch_overhead_s"]) for e in entries)
    if not vals:
        return float(default)
    return vals[len(vals) // 2]


def roofline_time_fn(
    work: DGWorkModel,
    device: DeviceClass,
    overhead: Optional[float] = None,
    autotune_path: Optional[str] = None,
) -> Callable[[float], float]:
    """T(K): seconds to advance K elements one timestep on ``device``.

    ``overhead=None`` (the default) resolves the per-step launch overhead
    from the autotune cache when one is present
    (:func:`measured_launch_overhead`), keeping the 20 µs constant as the
    no-cache fallback; pass an explicit float to bypass the lookup."""
    f = work.total_flops_per_element()
    b = work.total_bytes_per_element()
    if overhead is None:
        overhead = measured_launch_overhead(device.name, path=autotune_path)

    def T(K: float) -> float:
        K = max(0.0, float(K))
        if K == 0:
            return 0.0
        return roofline_seconds(K * f, K * b, device) + overhead

    return T


# ---------------------------------------------------------------------------
# Measured calibration tables
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class CalibrationTable:
    """seconds-per-element-per-timestep for each kernel on one device class."""

    device_name: str
    order: int
    sec_per_element: Dict[str, float]  # kernel -> s/elem/step
    overhead: float = 20e-6  # per-step launch/sync overhead

    def total_sec_per_element(self) -> float:
        return sum(self.sec_per_element.values())

    def time_fn(self) -> Callable[[float], float]:
        s = self.total_sec_per_element()

        def T(K: float) -> float:
            K = max(0.0, float(K))
            return 0.0 if K == 0 else K * s + self.overhead

        return T

    @staticmethod
    def from_autotune(entry: Dict, fill_shares: bool = True) -> "CalibrationTable":
        """A planner table from a ``repro.kernels.autotune`` cache entry.

        The autotuner measures the two Pallas hot-spots (``volume_loop``,
        ``int_flux``) and the per-launch intercept.  With ``fill_shares``
        (default) the unmeasured kernels are filled in from the paper's
        Fig 4.1 shares scaled so that ``volume_loop``'s share matches its
        *measured* seconds — the same reconstruction ``stampede_calibration``
        uses, but anchored to a measurement instead of the published wall
        time.  The result plugs straight into ``NodeModel.from_tables`` /
        ``solve_two_way``, which is how the measured roofline changes
        planner decisions."""
        measured = {k: float(v) for k, v in entry["sec_per_element"].items()}
        sec = dict(measured)
        if fill_shares and "volume_loop" in measured and measured["volume_loop"] > 0:
            scale = measured["volume_loop"] / _FIG41_SHARES["volume_loop"]
            for k, share in _FIG41_SHARES.items():
                if k not in sec:
                    sec[k] = share * scale
        return CalibrationTable(
            device_name=str(entry.get("device_kind", "autotuned")),
            order=int(entry.get("order", 0)),
            sec_per_element=sec,
            overhead=float(entry.get("launch_overhead_s", DEFAULT_LAUNCH_OVERHEAD)),
        )


def calibrate(
    measure_fn: Callable[[str, int], float],
    device_name: str,
    order: int,
    kernels=DG_KERNELS,
    K_sample: int = 256,
) -> CalibrationTable:
    """Build a table by timing ``measure_fn(kernel, K_sample)`` (seconds for
    K_sample elements, one timestep) for each kernel."""
    table = {}
    for k in kernels:
        t = measure_fn(k, K_sample)
        table[k] = max(0.0, t) / K_sample
    return CalibrationTable(device_name=device_name, order=order, sec_per_element=table)


# Reconstructed Stampede tables (see module docstring).  Kernel shares follow
# Fig 4.1 ("Average" bars); absolute scale follows the measured baseline
# wall time (408 s / 118 steps / 8192 elem with 8 ranks => ~53 us/elem/step
# serial => ~6.6 us/elem/step per 8-core socket aggregate...) and the
# published optimum split T_CPU/T_MIC throughput ratio of 1.6.
_FIG41_SHARES = {
    "volume_loop": 0.40,
    "int_flux": 0.25,
    "interp_q": 0.08,
    "lift": 0.08,
    "rk": 0.10,
    "bound_flux": 0.04,
    "parallel_flux": 0.05,
}


def stampede_calibration(order: int = 7) -> Dict[str, CalibrationTable]:
    # scale with (M/8)^4 like the dominant tensor kernel
    scale = ((order + 1) / 8.0) ** 4
    cpu_total = 22e-6 * scale  # s/elem/step, one vectorized+OMP SNB socket
    mic_total = cpu_total / 1.6  # the published optimum split ratio
    return {
        "snb-socket": CalibrationTable(
            "snb-socket", order, {k: cpu_total * s for k, s in _FIG41_SHARES.items()}
        ),
        "xeon-phi": CalibrationTable(
            "xeon-phi", order, {k: mic_total * s for k, s in _FIG41_SHARES.items()}, overhead=120e-6
        ),
    }


# ---------------------------------------------------------------------------
# Slow-link (PCI / DCN) transfer model — paper section 5.5 & Fig 5.3
# ---------------------------------------------------------------------------


def shared_face_bytes(K_accel: float, order: int, n_fields: int = 9, dtype_bytes: int = 8) -> float:
    """Bytes crossing the CPU<->accelerator link per timestep when K_accel
    Morton-compact elements live on the accelerator: ~6*K^(2/3) faces, each
    carrying (N+1)^2 nodes x n_fields, both directions."""
    if K_accel <= 0:
        return 0.0
    faces = 6.0 * K_accel ** (2.0 / 3.0)
    return faces * (order + 1) ** 2 * n_fields * dtype_bytes * 2


def offload_volume_bytes(K: float, order: int, n_fields: int = 9, dtype_bytes: int = 8) -> float:
    """Bytes for the *task-offload* strawman: whole volume fields each step."""
    return K * (order + 1) ** 3 * n_fields * dtype_bytes * 2


def transfer_time_fn(
    order: int,
    link: LinkClass = STAMPEDE_PCI,
    n_fields: int = 9,
    n_messages: int = 2,
    per_stage: bool = False,
    n_stages: int = 5,
) -> Callable[[float], float]:
    """PCI_time(K_accel) per timestep.

    Paper-faithful default: Fig 5.1 shows synchronization *once per
    timestep* ("when the CPU and coprocessor exchange their shared face
    data").  Set ``per_stage=True`` to model a halo exchange per RK stage
    instead (the conservative variant; swept in benchmarks/fig5_2)."""
    mult = n_stages if per_stage else 1

    def T(K_accel: float) -> float:
        if K_accel <= 0:
            return 0.0
        return mult * link.time(shared_face_bytes(K_accel, order, n_fields), n_messages)

    return T


def inter_node_transfer_fn(
    order: int,
    link: LinkClass = STAMPEDE_IB,
    n_fields: int = 9,
    dtype_bytes: int = 8,
    surface_fraction: float = 1.0,
    n_messages: int = 2,
) -> Callable[[float], float]:
    """Cluster-level halo time per step for a Morton-compact chunk of k
    elements: the alpha-beta ``link`` on ``surface_fraction`` of the chunk's
    ~6*k^(2/3)-face surface.  The single source for this closure — the
    simulated cluster, the printed plan and the weak-scaling benchmark all
    price the same exchange through here (with their own fraction/message
    parameters), so they cannot drift apart."""

    def T(k: float) -> float:
        if k <= 0 or surface_fraction <= 0:
            return 0.0
        nbytes = shared_face_bytes(k, order, n_fields, dtype_bytes) * surface_fraction
        return link.time(nbytes, n_messages)

    return T


# ---------------------------------------------------------------------------
# Paper-shaped convenience: the two sides of the Stampede node
# ---------------------------------------------------------------------------


def stampede_node_models(order: int = 7, calibrated: bool = True):
    """(T_cpu, T_mic, transfer) callables for the paper's node.

    T_cpu gets the PCI time added by the *solver* (the paper charges PCI to
    the CPU side, section 5.6) — here we return the raw kernel-time models.
    """
    if calibrated:
        tabs = stampede_calibration(order)
        t_cpu = tabs["snb-socket"].time_fn()
        t_mic = tabs["xeon-phi"].time_fn()
    else:
        work = DGWorkModel(order=order)
        t_cpu = roofline_time_fn(work, STAMPEDE_SNB_SOCKET)
        t_mic = roofline_time_fn(work, STAMPEDE_MIC, overhead=120e-6)
    return t_cpu, t_mic, transfer_time_fn(order)
