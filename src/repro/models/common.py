"""Model configuration and shared building blocks for the LM zoo.

Pure-JAX (no flax): parameters are nested-dict pytrees, every layer is a
function.  Layer stacks are scanned (params stacked on a leading ``layers``
axis) so HLO size is depth-independent — this keeps the 512-device dry-run
compiles tractable and is how production JAX LM frameworks are built.
"""

from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------------------
# Configuration
# ---------------------------------------------------------------------------

LANE = 128  # TPU lane width; vocab and head paddings align to this


def pad_to(n: int, mult: int) -> int:
    return ((n + mult - 1) // mult) * mult


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    arch_id: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int  # logical (published) q heads; 0 for attn-free
    n_kv_heads: int
    d_ff: int
    vocab_size: int  # logical (published)
    head_dim: int = 0  # 0 -> d_model // n_heads
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    rotary_pct: float = 1.0  # stablelm-2 uses partial rotary
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    causal: bool = True  # False for encoder-only (hubert)
    mlp_type: str = "gated_silu"  # gated_silu | gelu
    # sliding-window attention (None = full); hybrid models may mark a few
    # layers global via global_layers.
    sliding_window: Optional[int] = None
    global_layers: Tuple[int, ...] = ()
    # MoE
    n_experts: int = 0
    experts_per_token: int = 0
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    moe_impl: str = "ep"  # ep (shard_map all-to-all) | gspmd (scatter/gather)
    # SSM (mamba1)
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    dt_rank: int = 0  # 0 -> ceil(d_model / 16)
    # hybrid (hymba): number of learned meta tokens prepended to the sequence
    n_meta_tokens: int = 0
    # frontends (vlm / audio are backbone-only; the frontend is a stub that
    # provides precomputed patch/frame embeddings)
    frontend_tokens: int = 0  # e.g. image-patch positions in the sequence
    use_conv_pos: bool = False  # hubert-style convolutional positional embedding
    # numerics / impl
    flash_skip: bool = False  # causal KV-sweep skipping (inference paths)
    attn_block_q: int = 512
    attn_block_k: int = 512
    ssm_scan: str = "assoc"  # assoc | seq (selective-scan inner algorithm)
    ssm_chunk: int = 128
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    kernel_impl: str = "xla"  # xla | pallas | interpret
    remat: str = "full"  # full | none | dots
    # TP head-sharding plan inputs (see HeadShardingPlan)
    tp_size: int = 1  # padded head layout is computed for this TP degree

    # ------------------------------------------------------------------
    @property
    def head_dim_(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(1, self.n_heads)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def dt_rank_(self) -> int:
        return self.dt_rank or -(-self.d_model // 16)

    @property
    def padded_vocab(self) -> int:
        return pad_to(self.vocab_size, LANE)

    @property
    def is_encoder_only(self) -> bool:
        return not self.causal

    @property
    def has_attention(self) -> bool:
        return self.n_heads > 0

    @property
    def has_ssm(self) -> bool:
        return self.ssm_state > 0

    @property
    def activation_dtype(self):
        return jnp.dtype(self.dtype)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# GQA head sharding plan (DESIGN.md section 4)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class HeadShardingPlan:
    """Padded head layout making GQA shard on a fixed ``model`` axis.

    Cases (T = tp size, Q/G = logical q/kv heads):
      * G % T == 0: kv sharded directly; q padded to a multiple of T with
        group-aligned buckets.
      * T % G == 0: kv heads *duplicated* rep=T/G times (kv'[j]=kv[j//rep]),
        each duplicate serving a bucket of ceil(Q/G/rep) q heads; q padded to
        G' * bucket.  Every device then owns whole (padded) GQA groups.
      * otherwise (e.g. hymba 16 % 5 != 0): kv replicated across the model
        axis; q padded to a multiple of T; per-q-head kv index mapping.

    Padded q heads have zero-initialized projections and are sliced away by
    the output projection, so the padded model is *exactly* the logical
    model; the extra FLOPs are visible in the roofline useful-FLOPs ratio.
    """

    q_heads: int  # logical
    kv_heads: int  # logical
    tp: int
    padded_q: int
    padded_kv: int  # padded/duplicated kv head count (== tp when duplicated)
    kv_replicated: bool
    kv_dup: Tuple[int, ...]  # padded kv head -> logical kv head
    q_to_kv: Tuple[int, ...]  # padded q head -> *padded* kv head
    q_slot_of_logical: Tuple[int, ...]  # logical q head -> padded slot

    @property
    def q_per_kv(self) -> int:
        return self.padded_q // self.padded_kv


def make_head_plan(q_heads: int, kv_heads: int, tp: int) -> HeadShardingPlan:
    q_per_g = q_heads // kv_heads
    assert q_heads % kv_heads == 0, (q_heads, kv_heads)
    if kv_heads % tp == 0 or tp % kv_heads == 0:
        if kv_heads % tp == 0:
            rep = 1
            padded_kv = kv_heads
        else:
            rep = tp // kv_heads
            padded_kv = tp
        bucket = -(-q_per_g // rep)  # ceil
        padded_q = padded_kv * bucket
        kv_dup = tuple(j // rep for j in range(padded_kv))
        q_to_kv = tuple(h // bucket for h in range(padded_q))
        slot = []
        for h in range(q_heads):
            g, i = divmod(h, q_per_g)  # logical group, index in group
            r, k = divmod(i, bucket)  # bucket within the group's rep buckets
            slot.append((g * rep + r) * bucket + k)
        return HeadShardingPlan(
            q_heads, kv_heads, tp, padded_q, padded_kv, False, kv_dup, q_to_kv, tuple(slot)
        )
    # fallback: kv replicated
    padded_q = pad_to(q_heads, tp)
    kv_dup = tuple(range(kv_heads))
    # keep logical grouping; padded heads point at kv 0 (their weights are 0)
    q_to_kv = tuple((h // q_per_g) if h < q_heads else 0 for h in range(padded_q))
    slot = tuple(range(q_heads))
    return HeadShardingPlan(q_heads, kv_heads, tp, padded_q, kv_heads, True, kv_dup, q_to_kv, slot)


# ---------------------------------------------------------------------------
# Initializers / primitive layers
# ---------------------------------------------------------------------------


def _normal(key, shape, scale, dtype):
    return (jax.random.normal(key, shape) * scale).astype(dtype)


def dense_init(key, d_in: int, d_out: int, dtype, zero_rows: int = 0) -> jnp.ndarray:
    """Fan-in-scaled init; optionally zero the trailing ``zero_rows`` output
    columns (used for padded q heads so padding is exact)."""
    w = _normal(key, (d_in, d_out), 1.0 / math.sqrt(d_in), dtype)
    if zero_rows:
        w = w.at[:, d_out - zero_rows :].set(0.0)
    return w


def embed_init(key, vocab: int, d: int, dtype) -> jnp.ndarray:
    return _normal(key, (vocab, d), 1.0, dtype)


def rmsnorm(x: jnp.ndarray, scale: jnp.ndarray, eps: float) -> jnp.ndarray:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return ((x * jax.lax.rsqrt(var + eps)) * scale.astype(jnp.float32)).astype(dtype)


def rope_freqs(head_dim: int, theta: float, rotary_pct: float = 1.0) -> np.ndarray:
    rot = int(head_dim * rotary_pct) // 2 * 2
    inv = 1.0 / (theta ** (np.arange(0, rot, 2, dtype=np.float64) / rot))
    return inv.astype(np.float32)  # (rot/2,)


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, inv_freq: jnp.ndarray) -> jnp.ndarray:
    """x: (..., S, D); positions: (..., S) int32. Rotates the first
    2*len(inv_freq) channels (partial rotary supported), HF 'neox' layout."""
    rot = 2 * inv_freq.shape[0]
    ang = positions[..., None].astype(jnp.float32) * inv_freq  # (..., S, rot/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x_rot, x_pass = x[..., :rot], x[..., rot:]
    x1, x2 = jnp.split(x_rot, 2, axis=-1)
    cos = cos.astype(x.dtype)
    sin = sin.astype(x.dtype)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    if x_pass.shape[-1]:
        out = jnp.concatenate([out, x_pass], axis=-1)
    return out


def gated_mlp_apply(params: Dict[str, Any], x: jnp.ndarray, mlp_type: str) -> jnp.ndarray:
    c = lambda w: w.astype(x.dtype)  # f32 master -> activation dtype compute
    if mlp_type == "gated_silu":
        g = x @ c(params["w_gate"])
        u = x @ c(params["w_up"])
        return (jax.nn.silu(g) * u) @ c(params["w_down"])
    elif mlp_type == "gelu":
        h = jax.nn.gelu(x @ c(params["w_up"]) + c(params["b_up"]))
        return h @ c(params["w_down"]) + c(params["b_down"])
    raise ValueError(mlp_type)


def gated_mlp_init(key, cfg: ModelConfig, d_ff: Optional[int] = None) -> Dict[str, Any]:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 3)
    if cfg.mlp_type == "gated_silu":
        return {
            "w_gate": dense_init(ks[0], d, f, dt),
            "w_up": dense_init(ks[1], d, f, dt),
            "w_down": dense_init(ks[2], f, d, dt),
        }
    return {
        "w_up": dense_init(ks[0], d, f, dt),
        "b_up": jnp.zeros((f,), dt),
        "w_down": dense_init(ks[1], f, d, dt),
        "b_down": jnp.zeros((d,), dt),
    }


def cross_entropy_terms(
    logits: jnp.ndarray, labels: jnp.ndarray, vocab_size: int, z_coef: float = 1e-4
):
    """(nll+z sum, token count) with padded-vocab masking and label==-1 mask."""
    logits = logits.astype(jnp.float32)
    pv = logits.shape[-1]
    if pv > vocab_size:
        neg = jnp.full((pv - vocab_size,), -1e9, jnp.float32)
        logits = logits.at[..., vocab_size:].set(neg)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, jnp.maximum(labels, 0)[..., None], axis=-1)[..., 0]
    mask = (labels >= 0).astype(jnp.float32)
    nll = (lse - gold) * mask
    z = jnp.square(lse) * mask * z_coef
    return nll.sum() + z.sum(), mask.sum()


def cross_entropy_loss(
    logits: jnp.ndarray, labels: jnp.ndarray, vocab_size: int, z_coef: float = 1e-4
) -> jnp.ndarray:
    """Mean CE over tokens, masking padded vocab entries and label==-1."""
    s, n = cross_entropy_terms(logits, labels, vocab_size, z_coef)
    return s / jnp.maximum(n, 1.0)


def chunked_ce_loss(
    hidden: jnp.ndarray,  # (B, S, d) final-norm'd hidden states
    head: jnp.ndarray,  # (d, padded_vocab)
    labels: jnp.ndarray,  # (B, S)
    vocab_size: int,
    chunk: int = 1024,
    z_coef: float = 1e-4,
) -> jnp.ndarray:
    """Streaming CE: the (B, S, V) f32 logits tensor is never materialized —
    per-chunk logits are computed, reduced, and rematerialized in backward.
    At 150k vocabs this saves multiple GB/device of the train-step footprint.
    """
    import jax as _jax
    from jax import lax as _lax

    B, S, d = hidden.shape
    while S % chunk:
        chunk -= 1
    nc = S // chunk
    hs = hidden.reshape(B, nc, chunk, d).transpose(1, 0, 2, 3)
    ls = labels.reshape(B, nc, chunk).transpose(1, 0, 2)

    @partial(_jax.checkpoint, prevent_cse=False)
    def body(carry, xs):
        h_c, l_c = xs
        logits = h_c @ head.astype(h_c.dtype)
        s, n = cross_entropy_terms(logits, l_c, vocab_size, z_coef)
        return (carry[0] + s, carry[1] + n), None

    (s, n), _ = _lax.scan(body, (jnp.zeros(()), jnp.zeros(())), (hs, ls))
    return s / jnp.maximum(n, 1.0)
