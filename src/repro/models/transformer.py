"""Unified LM assembly for all 10 assigned architectures.

One code path builds dense / MoE / SSM / hybrid / VLM / audio-encoder
backbones from a ModelConfig:

  * layers are stacked on a leading axis and scanned (HLO depth-independent);
    models with heterogeneous layers (hymba's few global-attention layers
    among SWA layers) are split into contiguous *segments*, each scanned;
  * GQA head padding/duplication follows the HeadShardingPlan — padded q
    heads are masked after attention, so the padded model is exactly the
    logical model, under training too (their grads vanish);
  * kv projections hold *logical* kv heads and are expanded (duplicated) at
    apply time, so duplicate heads cannot diverge under training;
  * decode caches: rolling buffers of capacity ``window`` for SWA layers,
    full-length buffers for global/causal layers, O(1) states for mamba.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.models.attention import decode_attention, flash_attention, update_cache
from repro.models.common import (
    HeadShardingPlan,
    ModelConfig,
    apply_rope,
    cross_entropy_loss,
    dense_init,
    embed_init,
    gated_mlp_apply,
    gated_mlp_init,
    make_head_plan,
    rmsnorm,
    rope_freqs,
)
from repro.models.mamba import (
    mamba_apply,
    mamba_init,
    mamba_init_state,
    mamba_param_axes,
    mamba_step,
)
from repro.models.moe import moe_apply, moe_ep_sharded, moe_init, moe_param_axes
from repro.parallel.axes import current_mesh, shard


# ---------------------------------------------------------------------------
# Layer schedule: contiguous segments of identical layer kind
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Segment:
    start: int
    count: int
    window: Optional[int]  # None = full attention for this segment


def layer_schedule(cfg: ModelConfig) -> List[Segment]:
    L = cfg.n_layers
    if not cfg.has_attention or cfg.sliding_window is None or not cfg.global_layers:
        w = cfg.sliding_window if cfg.has_attention else None
        return [Segment(0, L, w)]
    segs: List[Segment] = []
    glob = set(cfg.global_layers)
    i = 0
    while i < L:
        if i in glob:
            segs.append(Segment(i, 1, None))
            i += 1
        else:
            j = i
            while j < L and j not in glob:
                j += 1
            segs.append(Segment(i, j - i, cfg.sliding_window))
            i = j
    return segs


# ---------------------------------------------------------------------------
# Attention sublayer
# ---------------------------------------------------------------------------


def _head_mask(plan: HeadShardingPlan) -> np.ndarray:
    m = np.zeros(plan.padded_q, np.float32)
    for s in plan.q_slot_of_logical:
        m[s] = 1.0
    return m


def attn_init(key, cfg: ModelConfig, plan: HeadShardingPlan) -> Dict[str, Any]:
    d, hd = cfg.d_model, cfg.head_dim_
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], d, plan.padded_q * hd, dt),
        "wk": dense_init(ks[1], d, plan.kv_heads * hd, dt),
        "wv": dense_init(ks[2], d, plan.kv_heads * hd, dt),
        "wo": dense_init(ks[3], plan.padded_q * hd, d, dt),
        "ln": jnp.ones((d,), dt),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((plan.padded_q * hd,), dt)
        p["bk"] = jnp.zeros((plan.kv_heads * hd,), dt)
        p["bv"] = jnp.zeros((plan.kv_heads * hd,), dt)
    return p


def attn_param_axes(cfg: ModelConfig) -> Dict[str, Any]:
    p = {
        "wq": ("embed", "heads"),
        "wk": ("embed", None),
        "wv": ("embed", None),
        "wo": ("heads", "embed"),
        "ln": (None,),
    }
    if cfg.qkv_bias:
        p.update({"bq": ("heads",), "bk": (None,), "bv": (None,)})
    return p


def _qkv(p, x, cfg: ModelConfig, plan: HeadShardingPlan, positions, inv_freq):
    B, S, _ = x.shape
    hd = cfg.head_dim_
    q = x @ p["wq"].astype(x.dtype)
    k = x @ p["wk"].astype(x.dtype)
    v = x @ p["wv"].astype(x.dtype)
    if cfg.qkv_bias:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    q = q.reshape(B, S, plan.padded_q, hd).transpose(0, 2, 1, 3)
    k = k.reshape(B, S, plan.kv_heads, hd).transpose(0, 2, 1, 3)
    v = v.reshape(B, S, plan.kv_heads, hd).transpose(0, 2, 1, 3)
    if inv_freq is not None:
        q = apply_rope(q, positions[:, None, :], inv_freq)
        k = apply_rope(k, positions[:, None, :], inv_freq)
    if not plan.kv_replicated:  # expand logical kv -> padded/duplicated kv heads
        idx = jnp.asarray(plan.kv_dup, jnp.int32)
        k = jnp.take(k, idx, axis=1)
        v = jnp.take(v, idx, axis=1)
        k = shard(k, "batch", "kv_heads", None, None)
        v = shard(v, "batch", "kv_heads", None, None)
    return q, k, v


def attn_apply(
    p,
    x,
    cfg: ModelConfig,
    plan: HeadShardingPlan,
    *,
    window: Optional[int],
    positions,
    inv_freq,
    q_offset: int = 0,
) -> Tuple[jnp.ndarray, Tuple[jnp.ndarray, jnp.ndarray]]:
    """Full-sequence attention. Returns (out, (k, v)) — k/v for cache builds."""
    B, S, _ = x.shape
    q, k, v = _qkv(p, x, cfg, plan, positions, inv_freq)
    q = shard(q, "batch", "heads", None, None)
    kv_map = plan.q_to_kv if plan.kv_replicated else None
    out = flash_attention(q, k, v, causal=cfg.causal, window=window, q_offset=q_offset,
                          kv_map=kv_map, dynamic_skip=cfg.flash_skip,
                          block_q=cfg.attn_block_q, block_k=cfg.attn_block_k)
    out = out * jnp.asarray(_head_mask(plan), out.dtype)[None, :, None, None]
    out = out.transpose(0, 2, 1, 3).reshape(B, S, plan.padded_q * cfg.head_dim_)
    return out @ p["wo"].astype(x.dtype), (k, v)


def attn_decode(
    p,
    x_t,  # (B, d)
    kcache,
    vcache,  # (B, G', C, hd)
    cache_len,  # int32: scalar, or per-row (B,) under continuous batching
    cfg: ModelConfig,
    plan: HeadShardingPlan,
    *,
    window: Optional[int],
    inv_freq,
):
    B = x_t.shape[0]
    hd = cfg.head_dim_
    rolling = window is not None and kcache.shape[2] == window
    clen = jnp.asarray(cache_len, jnp.int32)
    pos = jnp.broadcast_to(clen[:, None] if clen.ndim else clen, (B, 1))
    q, k, v = _qkv(p, x_t[:, None, :], cfg, plan, pos, inv_freq)
    kcache, vcache = update_cache(kcache, vcache, k, v, cache_len, rolling=rolling)
    kv_map = plan.q_to_kv if plan.kv_replicated else None
    out = decode_attention(
        q, kcache, vcache, cache_len + 1, window=window, rolling=rolling, kv_map=kv_map
    )
    out = out * jnp.asarray(_head_mask(plan), out.dtype)[None, :, None, None]
    out = out.transpose(0, 2, 1, 3).reshape(B, 1, plan.padded_q * hd)
    y = (out @ p["wo"].astype(x_t.dtype))[:, 0]
    return y, kcache, vcache


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------


def block_init(key, cfg: ModelConfig, plan: Optional[HeadShardingPlan], ep_size: int) -> Dict[str, Any]:
    ks = jax.random.split(key, 4)
    p: Dict[str, Any] = {}
    if cfg.has_attention:
        p["attn"] = attn_init(ks[0], cfg, plan)
    if cfg.has_ssm:
        p["mamba"] = mamba_init(ks[1], cfg)
        p["ln_m"] = jnp.ones((cfg.d_model,), jnp.dtype(cfg.param_dtype))
    if cfg.family == "moe":
        p["moe"] = moe_init(ks[2], cfg, ep_size)
        p["ln2"] = jnp.ones((cfg.d_model,), jnp.dtype(cfg.param_dtype))
    elif cfg.d_ff > 0:
        p["mlp"] = gated_mlp_init(ks[3], cfg)
        p["ln2"] = jnp.ones((cfg.d_model,), jnp.dtype(cfg.param_dtype))
    return p


def block_param_axes(cfg: ModelConfig) -> Dict[str, Any]:
    p: Dict[str, Any] = {}
    if cfg.has_attention:
        p["attn"] = attn_param_axes(cfg)
    if cfg.has_ssm:
        p["mamba"] = mamba_param_axes()
        p["ln_m"] = (None,)
    if cfg.family == "moe":
        p["moe"] = moe_param_axes()
        p["ln2"] = (None,)
    elif cfg.d_ff > 0:
        mlp = (
            {"w_gate": ("embed", "ff"), "w_up": ("embed", "ff"), "w_down": ("ff", "embed")}
            if cfg.mlp_type == "gated_silu"
            else {"w_up": ("embed", "ff"), "b_up": ("ff",), "w_down": ("ff", "embed"), "b_down": (None,)}
        )
        p["mlp"] = mlp
        p["ln2"] = (None,)
    return p


def block_apply(
    p,
    x,  # (B, S, d)
    cfg: ModelConfig,
    plan,
    *,
    window,
    positions,
    inv_freq,
    ep_size: int,
    q_offset: int = 0,
    collect_seed: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray, Any]:
    """Returns (x_out, aux_loss, cache_seed) where cache_seed carries the
    per-layer (k, v) / mamba-final-state needed to build a decode cache."""
    aux = jnp.zeros((), jnp.float32)
    seed: Dict[str, Any] = {}
    mix = jnp.zeros_like(x)
    if cfg.has_attention:
        h = rmsnorm(x, p["attn"]["ln"], cfg.norm_eps)
        h = shard(h, "batch", None, None)
        a_out, (k, v) = attn_apply(
            p["attn"], h, cfg, plan, window=window, positions=positions, inv_freq=inv_freq, q_offset=q_offset
        )
        mix = mix + a_out
        if collect_seed:
            seed["kv"] = (k, v)
    if cfg.has_ssm:
        hm = rmsnorm(x, p["ln_m"], cfg.norm_eps)
        if collect_seed:
            m_out, mstate = mamba_apply(p["mamba"], hm, cfg, chunk=cfg.ssm_chunk, return_state=True)
            seed["mamba"] = mstate
        else:
            m_out = mamba_apply(p["mamba"], hm, cfg, chunk=cfg.ssm_chunk)
        mix = mix + m_out
    if cfg.has_attention and cfg.has_ssm:
        mix = mix * 0.5  # hymba: mean of parallel attention and mamba paths
    x = x + shard(mix, "batch", None, None)
    if cfg.family == "moe":
        h = rmsnorm(x, p["ln2"], cfg.norm_eps)
        B, S, d = h.shape
        norm_topk = cfg.arch_id.startswith("mixtral")
        mesh = current_mesh()
        if mesh is not None and "data" in mesh.axis_names and cfg.moe_impl == "ep":
            y, metrics = moe_ep_sharded(p["moe"], h, cfg, mesh, norm_topk=norm_topk)
            y = y.reshape(B * S, d)
        else:
            y, metrics = moe_apply(p["moe"], h.reshape(B * S, d), cfg, ep_size=ep_size,
                                   norm_topk=norm_topk)
        x = x + y.reshape(B, S, d)
        aux = aux + metrics["aux_loss"] * cfg.router_aux_coef
    elif cfg.d_ff > 0:
        h = rmsnorm(x, p["ln2"], cfg.norm_eps)
        x = x + gated_mlp_apply(p["mlp"], h, cfg.mlp_type)
    return shard(x, "batch", None, None), aux, seed


def block_decode(
    p,
    x_t,  # (B, d)
    layer_cache: Dict[str, Any],
    cache_len,
    cfg: ModelConfig,
    plan,
    *,
    window,
    inv_freq,
    ep_size: int,
):
    aux_updates: Dict[str, Any] = {}
    mix = jnp.zeros_like(x_t)
    if cfg.has_attention:
        h = rmsnorm(x_t[:, None, :], p["attn"]["ln"], cfg.norm_eps)[:, 0]
        a_out, kc, vc = attn_decode(
            p["attn"], h, layer_cache["k"], layer_cache["v"], cache_len, cfg, plan,
            window=window, inv_freq=inv_freq,
        )
        mix = mix + a_out
        aux_updates["k"], aux_updates["v"] = kc, vc
    if cfg.has_ssm:
        hm = rmsnorm(x_t[:, None, :], p["ln_m"], cfg.norm_eps)[:, 0]
        m_out, new_state = mamba_step(p["mamba"], hm, {"conv": layer_cache["conv"], "ssm": layer_cache["ssm"]}, cfg)
        mix = mix + m_out
        aux_updates["conv"], aux_updates["ssm"] = new_state["conv"], new_state["ssm"]
    if cfg.has_attention and cfg.has_ssm:
        mix = mix * 0.5
    x_t = x_t + mix
    if cfg.family == "moe":
        h = rmsnorm(x_t[:, None, :], p["ln2"], cfg.norm_eps)[:, 0]
        y, _ = moe_apply(p["moe"], h, cfg, ep_size=ep_size, norm_topk=cfg.arch_id.startswith("mixtral"))
        x_t = x_t + y
    elif cfg.d_ff > 0:
        h = rmsnorm(x_t[:, None, :], p["ln2"], cfg.norm_eps)[:, 0]
        x_t = x_t + gated_mlp_apply(p["mlp"], h, cfg.mlp_type)
    return x_t, aux_updates
