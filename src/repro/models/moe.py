"""Mixture-of-Experts layer: top-k routing, capacity-bounded scatter dispatch.

Dispatch follows the production (GSPMD/MegaBlocks-lineage) pattern rather
than the O(T*E*C) one-hot einsum, which does not fit memory at these sizes:

  1. route: top-k experts per token, gate = softmax over the selected logits
     (Mixtral style) or over all logits (OLMoE style, ``norm_topk=False``);
  2. position-in-expert via a cumulative sum over the (T, k) assignment
     matrix; assignments beyond the expert's capacity C are dropped
     (capacity_factor configurable; drop fraction returned as a metric);
  3. scatter tokens into a (E', C, d) buffer, run all experts as one batched
     (grouped) matmul, gather back and combine with gates.

Expert parallelism on the fixed (data=16, model=16) mesh: expert weights are
laid out (E', d, f') with E' sharded over ``data`` (the "expert" logical
axis) and f' over ``model`` (TP inside each expert slot).  When E < 16
(Mixtral: 8) each expert's d_ff is f-SPLIT into E'/E chunks — one slot per
chunk; tokens visit every chunk of their routed expert and the combine sums
the partials.  Exact math, no extra parameters (see ``padded_experts``).
In the nested-partition language: the dispatch all-to-all is the boundary
exchange; the local grouped matmul is interior work that XLA overlaps with
the combine collective.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.models.common import ModelConfig, dense_init


def padded_experts(cfg: ModelConfig, ep_size: int) -> Tuple[int, int]:
    """(E', rep): physical expert slots and the f-split factor.

    When E < ep_size each logical expert's d_ff is SPLIT into rep = ep/E
    chunks, one per slot (Mixtral: 8 experts -> 16 half-experts of d_ff
    8192).  Tokens visit all rep slots of their routed expert and the
    combine step sums the partial outputs — exactly the logical expert, no
    parameter duplication, no replica divergence under training.
    """
    E = cfg.n_experts
    if E >= ep_size:
        if E % ep_size:
            raise ValueError(f"{E} experts not divisible by ep axis {ep_size}")
        return E, 1
    if ep_size % E:
        raise ValueError(f"ep axis {ep_size} not a multiple of {E} experts")
    rep = ep_size // E
    if cfg.d_ff % rep:
        raise ValueError(f"d_ff {cfg.d_ff} not divisible by f-split {rep}")
    return E * rep, rep


def moe_init(key, cfg: ModelConfig, ep_size: int) -> Dict[str, Any]:
    E_pad, rep = padded_experts(cfg, ep_size)
    d, f = cfg.d_model, cfg.d_ff
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 4)
    f_loc = f // rep

    def expert_init(k, kind: str):
        keys = jax.random.split(k, cfg.n_experts)
        mats = []
        for e in range(cfg.n_experts):
            if kind == "down":  # (f, d) split along f (rows)
                w = dense_init(keys[e], f, d, dt)
                mats.extend(jnp.split(w, rep, axis=0) if rep > 1 else [w])
            else:  # (d, f) split along f (cols)
                w = dense_init(keys[e], d, f, dt)
                mats.extend(jnp.split(w, rep, axis=1) if rep > 1 else [w])
        return jnp.stack(mats)  # (E_pad, d, f_loc) or (E_pad, f_loc, d)

    return {
        "router": dense_init(ks[0], d, cfg.n_experts, dt),
        "w_gate": expert_init(ks[1], "up"),
        "w_up": expert_init(ks[2], "up"),
        "w_down": expert_init(ks[3], "down"),
    }


def moe_param_axes() -> Dict[str, Any]:
    # "expert" (-> data axis) already provides the ZeRO/FSDP sharding role
    # for expert weights; the d_model dim must stay unsharded to avoid
    # mapping the data axis twice.
    return {
        "router": (None, None),
        "w_gate": ("expert", None, "ff"),
        "w_up": ("expert", None, "ff"),
        "w_down": ("expert", "ff", None),
    }


def moe_apply(
    params: Dict[str, Any],
    x: jnp.ndarray,  # (T, d) flat tokens
    cfg: ModelConfig,
    *,
    ep_size: int,
    capacity: Optional[int] = None,
    norm_topk: bool = True,
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Returns (out (T, d), metrics{aux_loss, drop_frac})."""
    T, d = x.shape
    E = cfg.n_experts
    k = cfg.experts_per_token
    E_pad, rep = padded_experts(cfg, ep_size)

    logits = (x @ params["router"].astype(x.dtype)).astype(jnp.float32)  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)  # (T, k)
    if norm_topk:
        gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # f-split experts: each token visits all rep slots of its routed expert;
    # the gate-weighted combine sums the partial (f-chunk) outputs
    if rep > 1:
        slot = (expert_idx[..., None] * rep + jnp.arange(rep)).reshape(T, k * rep)
        gate_vals = jnp.repeat(gate_vals, rep, axis=1)
    else:
        slot = expert_idx
    k_eff = k * rep

    if capacity is None:
        capacity = int(np.ceil(T * k / cfg.n_experts * cfg.capacity_factor))
        capacity = max(8, min(capacity, T))

    # position of each assignment within its expert slot, in token order
    onehot = jax.nn.one_hot(slot.reshape(-1), E_pad, dtype=jnp.int32)  # (T*k_eff, E')
    pos = jnp.cumsum(onehot, axis=0) - 1  # inclusive -> 0-based
    pos = (pos * onehot).sum(-1)  # (T*k_eff,)
    keep = pos < capacity

    flat_slot = slot.reshape(-1)
    flat_gate = gate_vals.reshape(-1) * keep
    safe_pos = jnp.where(keep, pos, 0)

    # dispatch: (E', C, d)
    xk = jnp.repeat(x[:, None, :], k_eff, axis=1).reshape(T * k_eff, d)
    buf = jnp.zeros((E_pad, capacity, d), x.dtype)
    buf = buf.at[flat_slot, safe_pos].add(jnp.where(keep[:, None], xk, 0))

    # grouped expert FFN (gated silu)
    g = jnp.einsum("ecd,edf->ecf", buf, params["w_gate"].astype(x.dtype))
    u = jnp.einsum("ecd,edf->ecf", buf, params["w_up"].astype(x.dtype))
    h = jax.nn.silu(g) * u
    out_buf = jnp.einsum("ecf,efd->ecd", h, params["w_down"].astype(x.dtype))

    # combine (sums over both the k routed experts and their rep f-chunks)
    gathered = out_buf[flat_slot, safe_pos]  # (T*k_eff, d)
    y = (gathered * flat_gate[:, None].astype(gathered.dtype)).reshape(T, k_eff, d).sum(axis=1)

    # Switch-style load-balancing auxiliary loss (over logical experts)
    frac_tokens = jnp.zeros(E, jnp.float32).at[expert_idx.reshape(-1)].add(1.0) / (T * k)
    frac_probs = probs.mean(axis=0)
    aux = E * jnp.sum(frac_tokens * frac_probs)
    drop_frac = 1.0 - keep.astype(jnp.float32).mean()
    return y, {"aux_loss": aux, "drop_frac": drop_frac}


# ---------------------------------------------------------------------------
# Expert-parallel dispatch in shard_map (the production path)
# ---------------------------------------------------------------------------
#
# GSPMD partitions the scatter/gather dispatch poorly (it falls back to
# "involuntary full rematerialization": replicated (tokens, d_model)
# temporaries that blow per-device memory at Mixtral scale).  The EP path
# makes the nested-partition structure explicit instead:
#
#   boundary (slow) work: two all_to_alls over the ``data`` axis moving only
#       capacity-bounded token slots (surface, not volume);
#   interior work: the grouped expert FFN, local in both ``data`` (expert
#       shard) and ``model`` (d_ff shard), overlapped by XLA's scheduler
#       with neighbouring collectives.
#
# Per (data x model) member: tokens arrive T_loc = T/dp, each shard owns
# E_loc = E'/dp experts and f_loc = d_ff/tp of every expert.


def moe_apply_ep(
    params: Dict[str, Any],
    x: jnp.ndarray,  # (T_loc, d) — this data-shard's tokens (manual view)
    cfg: ModelConfig,
    *,
    data_axis: str = "data",
    model_axis: str = "model",
    norm_topk: bool = True,
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Manual-collective MoE; call inside shard_map(manual={data, model}).

    params are the *local shards*: router (d, E) replicated,
    w_gate/w_up (E_loc, d, f_loc), w_down (E_loc, f_loc, d).
    """
    T_loc, d = x.shape
    E = cfg.n_experts
    k = cfg.experts_per_token
    from repro.jax_compat import axis_size

    dp = axis_size(data_axis)
    E_loc = params["w_gate"].shape[0]
    E_pad = E_loc * dp
    rep = E_pad // E

    logits = (x @ params["router"].astype(x.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)
    if norm_topk:
        gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)
    # f-split experts: visit all rep slots of each routed expert (see
    # padded_experts); combine sums the partial outputs
    if rep > 1:
        slot = (expert_idx[..., None] * rep + jnp.arange(rep)).reshape(T_loc, k * rep)
        gate_vals = jnp.repeat(gate_vals, rep, axis=1)
    else:
        slot = expert_idx
    k_eff = k * rep

    # local capacity per (expert slot, source shard)
    C_loc = max(4, int(np.ceil(T_loc * k / cfg.n_experts * cfg.capacity_factor)))

    onehot = jax.nn.one_hot(slot.reshape(-1), E_pad, dtype=jnp.int32)  # (T_loc*k_eff, E')
    pos = (jnp.cumsum(onehot, axis=0) - 1) * onehot
    pos = pos.sum(-1)
    keep = pos < C_loc
    flat_slot = slot.reshape(-1)
    flat_gate = gate_vals.reshape(-1) * keep
    safe_pos = jnp.where(keep, pos, 0)

    xk = jnp.repeat(x[:, None, :], k_eff, axis=1).reshape(T_loc * k_eff, d)
    buf = jnp.zeros((E_pad, C_loc, d), x.dtype)
    buf = buf.at[flat_slot, safe_pos].add(jnp.where(keep[:, None], xk, 0))

    # boundary: send each expert block to its owner; receive blocks from all
    # shards -> (E_loc, dp*C_loc, d)
    buf = lax.all_to_all(buf.reshape(dp, E_loc, C_loc, d), data_axis, 0, 0, tiled=False)
    buf = buf.transpose(1, 0, 2, 3).reshape(E_loc, dp * C_loc, d)

    # interior: grouped FFN, f sharded over model axis
    g = jnp.einsum("ecd,edf->ecf", buf, params["w_gate"].astype(x.dtype))
    u = jnp.einsum("ecd,edf->ecf", buf, params["w_up"].astype(x.dtype))
    h = jax.nn.silu(g) * u
    out = jnp.einsum("ecf,efd->ecd", h, params["w_down"].astype(x.dtype))
    out = lax.psum(out, model_axis)  # partial sums over f_loc shards

    # boundary: return slots to their source shards
    out = out.reshape(E_loc, dp, C_loc, d).transpose(1, 0, 2, 3)
    out = lax.all_to_all(out, data_axis, 0, 0, tiled=False).reshape(E_pad, C_loc, d)

    gathered = out[flat_slot, safe_pos]
    y = (gathered * flat_gate[:, None].astype(gathered.dtype)).reshape(T_loc, k_eff, d).sum(axis=1)

    frac_tokens = jnp.zeros(E, jnp.float32).at[expert_idx.reshape(-1)].add(1.0) / (T_loc * k)
    frac_probs = probs.mean(axis=0)
    aux = E * jnp.sum(frac_tokens * frac_probs)  # local estimate; psum'd by caller's mean
    drop_frac = 1.0 - keep.astype(jnp.float32).mean()
    return y, {"aux_loss": aux, "drop_frac": drop_frac}


def moe_ep_sharded(
    params: Dict[str, Any],
    h: jnp.ndarray,  # (B, S, d) global view, batch sharded over ('pod','data')
    cfg: ModelConfig,
    mesh,
    *,
    norm_topk: bool = True,
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """shard_map wrapper installing the EP dispatch on the production mesh."""
    from jax.sharding import PartitionSpec as P

    multi_pod = "pod" in mesh.axis_names
    bspec = ("pod", "data") if multi_pod else ("data",)
    B, S, d = h.shape

    pspecs = {
        "router": P(None, None),
        "w_gate": P("data", None, "model"),
        "w_up": P("data", None, "model"),
        "w_down": P("data", "model", None),
    }

    def local(pr, hl):
        T_loc = hl.shape[0] * hl.shape[1]
        x = hl.reshape(T_loc, d)
        y, met = moe_apply_ep(pr, x, cfg, norm_topk=norm_topk)
        met = {k: lax.pmean(v, bspec + ("model",)) for k, v in met.items()}
        return y.reshape(hl.shape), met

    from repro.jax_compat import shard_map

    f = shard_map(
        local,
        mesh=mesh,
        in_specs=(pspecs, P(bspec, None, None)),
        out_specs=(P(bspec, None, None), {"aux_loss": P(), "drop_frac": P()}),
        check_vma=False,
    )
    return f(params, h)
