"""Mamba-1 selective SSM block (falcon-mamba-7b; the mamba heads of hymba).

The selective scan is *chunked*: the sequence is cut into fixed chunks;
within a chunk the linear recurrence h_t = a_t * h_{t-1} + b_t is solved
with an associative scan, and the state is carried across chunks by an
outer ``lax.scan``.  This bounds the materialized (B, chunk, d_inner,
d_state) tensors (the unchunked form needs tens of GB at falcon-mamba
sizes) and is the exact 1-D analogue of the paper's partition: chunk
interiors are independent work, the carried state is the boundary.  A
cross-device version of the same decomposition (state handoff via
ppermute) is what sequence parallelism uses.

Decode keeps (conv_state (B, K-1, d_inner), ssm_state (B, d_inner, N)) and
costs O(1) per token — why the 524k-context cell is trivial for SSMs.
"""

from __future__ import annotations

import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.models.common import ModelConfig, dense_init


def mamba_init(key, cfg: ModelConfig) -> Dict[str, Any]:
    d, di, n, K, r = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_conv, cfg.dt_rank_
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 6)
    # S4D-real initialization for A; dt bias for softplus init in [1e-3, 1e-1]
    A = jnp.tile(jnp.arange(1, n + 1, dtype=jnp.float32)[None, :], (di, 1))
    dt_init = jnp.exp(
        jax.random.uniform(ks[4], (di,)) * (math.log(0.1) - math.log(1e-3)) + math.log(1e-3)
    )
    dt_bias = dt_init + jnp.log1p(-jnp.exp(-dt_init))  # inverse softplus
    return {
        "in_proj": dense_init(ks[0], d, 2 * di, dt),
        "conv_w": (jax.random.normal(ks[1], (K, di)) / math.sqrt(K)).astype(dt),
        "conv_b": jnp.zeros((di,), dt),
        "x_proj": dense_init(ks[2], di, r + 2 * n, dt),
        "dt_w": dense_init(ks[3], r, di, dt),
        "dt_b": dt_bias.astype(dt),
        "A_log": jnp.log(A).astype(dt),
        "D": jnp.ones((di,), dt),
        "out_proj": dense_init(ks[5], di, d, dt),
    }


def mamba_param_axes() -> Dict[str, Any]:
    return {
        "in_proj": ("embed", "inner"),
        "conv_w": (None, "inner"),
        "conv_b": ("inner",),
        "x_proj": ("inner", None),
        "dt_w": (None, "inner"),
        "dt_b": ("inner",),
        "A_log": ("inner", None),
        "D": ("inner",),
        "out_proj": ("inner", "embed"),
    }


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Depthwise causal conv via K shifted adds. x: (B, S, di), w: (K, di)."""
    K = w.shape[0]
    out = x * w[K - 1]
    for i in range(1, K):
        shifted = jnp.pad(x, ((0, 0), (i, 0), (0, 0)))[:, : x.shape[1]]
        out = out + shifted * w[K - 1 - i]
    return out + b


def selective_scan(
    u: jnp.ndarray,  # (B, S, di) conv+silu output
    dt: jnp.ndarray,  # (B, S, di) softplus'd
    A: jnp.ndarray,  # (di, n) negative real
    Bc: jnp.ndarray,  # (B, S, n) input-dependent B
    Cc: jnp.ndarray,  # (B, S, n)
    D: jnp.ndarray,  # (di,)
    h0: jnp.ndarray,  # (B, di, n) initial state
    chunk: int = 128,
    impl: str = "assoc",
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """y (B, S, di), h_last (B, di, n). f32 state math.

    impl="assoc": within-chunk associative scan (log-depth, materializes
    (B, chunk, di, n) operands per combine stage — fast on parallel HW).
    impl="seq": plain time scan carrying (B, di, n) — minimal HBM traffic;
    the hillclimb measures the trade (EXPERIMENTS.md §Perf)."""
    if impl == "seq":
        def t_body(h, xs):
            u_t, dt_t, B_t, C_t = xs  # (B,di),(B,di),(B,n),(B,n)
            dtf = dt_t.astype(jnp.float32)
            dA = jnp.exp(dtf[:, :, None] * A[None].astype(jnp.float32))
            dBu = (dtf * u_t.astype(jnp.float32))[:, :, None] * B_t[:, None, :].astype(jnp.float32)
            h = dA * h + dBu
            y = jnp.einsum("bdn,bn->bd", h, C_t.astype(jnp.float32))
            return h, y
        xs = (u.transpose(1, 0, 2), dt.transpose(1, 0, 2),
              Bc.transpose(1, 0, 2), Cc.transpose(1, 0, 2))
        h_last, ys = lax.scan(t_body, h0.astype(jnp.float32), xs)
        y = ys.transpose(1, 0, 2) + u.astype(jnp.float32) * D.astype(jnp.float32)
        return y.astype(u.dtype), h_last
    B_, S, di = u.shape
    n = A.shape[1]
    chunk = min(chunk, S)
    while S % chunk:  # largest divisor of S <= requested chunk
        chunk -= 1
    nc = S // chunk
    uc = u.reshape(B_, nc, chunk, di).transpose(1, 0, 2, 3)
    dtc = dt.reshape(B_, nc, chunk, di).transpose(1, 0, 2, 3)
    Bcc = Bc.reshape(B_, nc, chunk, n).transpose(1, 0, 2, 3)
    Ccc = Cc.reshape(B_, nc, chunk, n).transpose(1, 0, 2, 3)

    def chunk_body(h, xs):
        ucj, dtj, Bj, Cj = xs  # (B, Q, di), (B, Q, di), (B, Q, n), (B, Q, n)
        dtj = dtj.astype(jnp.float32)
        dA = jnp.exp(dtj[..., None] * A[None, None].astype(jnp.float32))  # (B,Q,di,n)
        dBu = (dtj * ucj.astype(jnp.float32))[..., None] * Bj[:, :, None, :].astype(jnp.float32)
        # associative scan of (a, b) -> h_t = a_t h_{t-1} + b_t along Q
        def comb(x, y):
            a1, b1 = x
            a2, b2 = y
            return a1 * a2, a2 * b1 + b2

        aP, bP = lax.associative_scan(comb, (dA, dBu), axis=1)
        h_t = aP * h[:, None] + bP  # (B, Q, di, n)
        y = jnp.einsum("bqdn,bqn->bqd", h_t, Cj.astype(jnp.float32))
        return h_t[:, -1], y

    h_last, ys = lax.scan(chunk_body, h0.astype(jnp.float32), (uc, dtc, Bcc, Ccc))
    y = ys.transpose(1, 0, 2, 3).reshape(B_, S, di)
    y = y + u.astype(jnp.float32) * D.astype(jnp.float32)
    return y.astype(u.dtype), h_last


def mamba_apply(
    params: Dict[str, Any],
    x: jnp.ndarray,  # (B, S, d)
    cfg: ModelConfig,
    chunk: int = 128,
    return_state: bool = False,
):
    # (impl selection threads through from cfg.ssm_scan)
    """Full-sequence (train / prefill) pass.

    With ``return_state`` also returns the decode state {conv, ssm} as of the
    last position (used by prefill to seed decoding)."""
    di, n, r, K = cfg.d_inner, cfg.ssm_state, cfg.dt_rank_, cfg.ssm_conv
    xz = x @ params["in_proj"].astype(x.dtype)  # (B, S, 2di)
    xr_pre, z = jnp.split(xz, 2, axis=-1)
    xr = jax.nn.silu(_causal_conv(xr_pre, params["conv_w"].astype(x.dtype), params["conv_b"].astype(x.dtype)))
    proj = xr @ params["x_proj"].astype(x.dtype)  # (B, S, r + 2n)
    dt_r, Bc, Cc = jnp.split(proj, [r, r + n], axis=-1)
    dt = jax.nn.softplus(dt_r @ params["dt_w"].astype(x.dtype) + params["dt_b"].astype(x.dtype))
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    h0 = jnp.zeros((x.shape[0], di, n), jnp.float32)
    y, h_last = selective_scan(xr, dt, A, Bc, Cc, params["D"], h0, chunk=chunk, impl=cfg.ssm_scan)
    out = (y * jax.nn.silu(z)) @ params["out_proj"].astype(x.dtype)
    if not return_state:
        return out
    # conv state: last K-1 *pre-conv* inputs, left-padded if S < K-1
    S = x.shape[1]
    if S >= K - 1:
        conv_state = xr_pre[:, S - (K - 1):]
    else:
        conv_state = jnp.pad(xr_pre, ((0, 0), (K - 1 - S, 0), (0, 0)))
    return out, {"conv": conv_state, "ssm": h_last}


def mamba_init_state(cfg: ModelConfig, batch: int, dtype) -> Dict[str, jnp.ndarray]:
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, cfg.d_inner), dtype),
        "ssm": jnp.zeros((batch, cfg.d_inner, cfg.ssm_state), jnp.float32),
    }


def mamba_step(
    params: Dict[str, Any],
    x_t: jnp.ndarray,  # (B, d) one token
    state: Dict[str, jnp.ndarray],
    cfg: ModelConfig,
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """O(1) decode step."""
    di, n, r, K = cfg.d_inner, cfg.ssm_state, cfg.dt_rank_, cfg.ssm_conv
    xz = x_t @ params["in_proj"].astype(x_t.dtype)
    xr, z = jnp.split(xz, 2, axis=-1)  # (B, di)
    # conv over [conv_state ; x]
    hist = jnp.concatenate([state["conv"], xr[:, None, :]], axis=1)  # (B, K, di)
    w = params["conv_w"].astype(x_t.dtype)
    xc = jnp.einsum("bkd,kd->bd", hist, w) + params["conv_b"].astype(x_t.dtype)
    xc = jax.nn.silu(xc)
    proj = xc @ params["x_proj"].astype(x_t.dtype)
    dt_r, Bc, Cc = jnp.split(proj, [r, r + n], axis=-1)
    dt = jax.nn.softplus(dt_r @ params["dt_w"].astype(x_t.dtype) + params["dt_b"].astype(x_t.dtype))
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    dtf = dt.astype(jnp.float32)
    dA = jnp.exp(dtf[:, :, None] * A[None])  # (B, di, n)
    dBu = (dtf * xc.astype(jnp.float32))[:, :, None] * Bc[:, None, :].astype(jnp.float32)
    h = dA * state["ssm"] + dBu
    y = jnp.einsum("bdn,bn->bd", h, Cc.astype(jnp.float32)) + xc.astype(jnp.float32) * params["D"].astype(jnp.float32)
    out = (y.astype(x_t.dtype) * jax.nn.silu(z)) @ params["out_proj"].astype(x_t.dtype)
    new_state = {"conv": hist[:, 1:], "ssm": h}
    return out, new_state
