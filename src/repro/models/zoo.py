"""The LM zoo: builds any assigned architecture from its ModelConfig.

Public surface (all pure functions of pytrees):

    lm = LM(cfg, ep_size=..., multi_pod=...)
    params            = lm.init(key)            # or jax.eval_shape(lm.init, key)
    axes              = lm.param_axes()         # logical-axes tree for sharding
    loss, metrics     = lm.loss(params, batch)
    logits, cache     = lm.prefill(params, batch)
    logits, cache     = lm.decode_step(params, cache, tokens)
    cache             = lm.init_cache(batch_size, max_len)  # zeros (or eval_shape)

Frontends: [vlm] and [audio] archs are backbone-only per the assignment —
``batch`` carries precomputed patch/frame embeddings from the (stub)
frontend; the text/feature paths merge inside ``_embed_inputs``.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.models.common import (
    ModelConfig,
    chunked_ce_loss,
    cross_entropy_loss,
    dense_init,
    embed_init,
    make_head_plan,
    rmsnorm,
    rope_freqs,
)
from repro.models.mamba import mamba_init_state
from repro.models.transformer import (
    Segment,
    block_apply,
    block_decode,
    block_init,
    block_param_axes,
    layer_schedule,
)
from repro.parallel.axes import shard

VIS_EMBED_DIM = 1024  # CLIP-L patch embedding width (llava frontend stub)


def _stack_layers(trees: List[Any]):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def _slice_layers(tree, start: int, count: int):
    return jax.tree.map(lambda x: lax.slice_in_dim(x, start, start + count, axis=0), tree)


def _squeeze0(tree):
    return jax.tree.map(lambda x: x[0], tree)


class LM:
    def __init__(self, cfg: ModelConfig, ep_size: int = 1):
        self.cfg = cfg
        self.ep_size = ep_size
        self.plan = (
            make_head_plan(cfg.n_heads, cfg.n_kv_heads, cfg.tp_size) if cfg.has_attention else None
        )
        self.segments = layer_schedule(cfg)
        self.inv_freq = (
            jnp.asarray(rope_freqs(cfg.head_dim_, cfg.rope_theta, cfg.rotary_pct))
            if cfg.has_attention
            else None
        )

    # ------------------------------------------------------------------
    # params
    # ------------------------------------------------------------------
    def init(self, key) -> Dict[str, Any]:
        cfg = self.cfg
        dt = jnp.dtype(cfg.param_dtype)
        n_extra = 5 + cfg.n_layers
        ks = list(jax.random.split(key, n_extra))
        p: Dict[str, Any] = {}
        if cfg.family == "audio":
            # input is precomputed frame embeddings at d_model; learn a conv
            # positional embedding (wav2vec2/HuBERT style, grouped conv)
            g = 16
            p["pos_conv"] = {
                "w": (jax.random.normal(ks[0], (128, cfg.d_model // g, cfg.d_model)) * 0.02).astype(dt),
                "b": jnp.zeros((cfg.d_model,), dt),
            }
        else:
            p["embed"] = embed_init(ks[0], cfg.padded_vocab, cfg.d_model, dt)
        if cfg.family == "vlm":
            p["mm_proj"] = {
                "w1": dense_init(ks[1], VIS_EMBED_DIM, cfg.d_model, dt),
                "b1": jnp.zeros((cfg.d_model,), dt),
                "w2": dense_init(ks[2], cfg.d_model, cfg.d_model, dt),
                "b2": jnp.zeros((cfg.d_model,), dt),
            }
        if cfg.n_meta_tokens:
            p["meta"] = (jax.random.normal(ks[3], (cfg.n_meta_tokens, cfg.d_model)) * 0.02).astype(dt)
        layers = [block_init(ks[5 + i], cfg, self.plan, self.ep_size) for i in range(cfg.n_layers)]
        p["layers"] = _stack_layers(layers)
        p["final_ln"] = jnp.ones((cfg.d_model,), dt)
        if not cfg.tie_embeddings:
            p["lm_head"] = dense_init(ks[4], cfg.d_model, cfg.padded_vocab, dt)
        return p

    def param_axes(self) -> Dict[str, Any]:
        cfg = self.cfg
        ax: Dict[str, Any] = {}
        if cfg.family == "audio":
            ax["pos_conv"] = {"w": (None, None, "embed"), "b": (None,)}
        else:
            # vocab-dim sharding only: an ("vocab", "embed") 2-D sharding makes
            # the token gather clash with batch sharding (GSPMD falls back to
            # full rematerialization of (B, S, d) f32 temporaries)
            ax["embed"] = ("vocab", None)
        if cfg.family == "vlm":
            ax["mm_proj"] = {"w1": (None, "embed"), "b1": (None,), "w2": ("embed", None), "b2": (None,)}
        if cfg.n_meta_tokens:
            ax["meta"] = (None, None)
        blk = block_param_axes(cfg)
        ax["layers"] = jax.tree.map(lambda t: ("layers",) + t, blk,
                                    is_leaf=lambda v: isinstance(v, tuple))
        ax["final_ln"] = (None,)
        if not cfg.tie_embeddings:
            ax["lm_head"] = (None, "vocab")
        return ax

    # ------------------------------------------------------------------
    # embedding / head
    # ------------------------------------------------------------------
    def _embed_inputs(self, params, batch) -> Tuple[jnp.ndarray, int]:
        """Returns (x (B, S_total, d), n_prefix) — prefix = meta/image tokens."""
        cfg = self.cfg
        adt = cfg.activation_dtype
        if cfg.family == "audio":
            x = batch["features"].astype(adt)
            w = params["pos_conv"]["w"].astype(adt)
            pos = lax.conv_general_dilated(
                x, w, window_strides=(1,), padding="SAME",
                dimension_numbers=("NWC", "WIO", "NWC"), feature_group_count=16,
            )
            x = x + jax.nn.gelu(pos + params["pos_conv"]["b"].astype(adt))
            return x, 0
        tokens = batch["tokens"]
        x = jnp.take(params["embed"].astype(adt), tokens, axis=0)
        n_prefix = 0
        if cfg.family == "vlm" and "patches" in batch:
            mp = params["mm_proj"]
            pe = batch["patches"].astype(adt)
            pe = jax.nn.gelu(pe @ mp["w1"].astype(adt) + mp["b1"].astype(adt))
            pe = pe @ mp["w2"].astype(adt) + mp["b2"].astype(adt)
            x = jnp.concatenate([pe, x], axis=1)
            n_prefix += pe.shape[1]
        if cfg.n_meta_tokens:
            B = x.shape[0]
            meta = jnp.broadcast_to(
                params["meta"].astype(adt)[None], (B, cfg.n_meta_tokens, cfg.d_model)
            )
            x = jnp.concatenate([meta, x], axis=1)
            n_prefix += cfg.n_meta_tokens
        return x, n_prefix

    def _logits(self, params, x) -> jnp.ndarray:
        cfg = self.cfg
        x = rmsnorm(x, params["final_ln"], cfg.norm_eps)
        head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
        logits = x @ head.astype(x.dtype)
        return shard(logits, "batch", None, "vocab")

    # ------------------------------------------------------------------
    # forward / loss
    # ------------------------------------------------------------------
    def forward(
        self, params, batch, collect_seed: bool = False, return_hidden: bool = False
    ) -> Tuple[jnp.ndarray, jnp.ndarray, List[Any], int]:
        """Returns (logits_or_hidden, aux_loss, seeds_per_segment, n_prefix)."""
        cfg = self.cfg
        x, n_prefix = self._embed_inputs(params, batch)
        x = shard(x, "batch", None, None)
        B, S, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
        aux_total = jnp.zeros((), jnp.float32)
        seeds: List[Any] = []
        for seg in self.segments:
            seg_params = _slice_layers(params["layers"], seg.start, seg.count)

            def body(carry, lp, _seg=seg):
                h, aux = carry
                h, a, seed = block_apply(
                    lp, h, cfg, self.plan, window=_seg.window, positions=positions,
                    inv_freq=self.inv_freq, ep_size=self.ep_size, collect_seed=collect_seed,
                )
                return (h, aux + a), (seed if collect_seed else None)

            if cfg.remat == "full":
                body = jax.checkpoint(body, prevent_cse=False)
            (x, aux_total), seg_seed = lax.scan(body, (x, aux_total), seg_params)
            seeds.append(seg_seed)
        if return_hidden:
            return x, aux_total, seeds, n_prefix
        logits = self._logits(params, x)
        return logits, aux_total, seeds, n_prefix

    def loss(self, params, batch) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
        cfg = self.cfg
        hidden, aux, _, n_prefix = self.forward(params, batch, return_hidden=True)
        labels = batch["labels"]
        if n_prefix:
            prefix = jnp.full(labels.shape[:1] + (n_prefix,), -1, labels.dtype)
            labels = jnp.concatenate([prefix, labels], axis=1)
        hidden = rmsnorm(hidden, params["final_ln"], cfg.norm_eps)
        head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
        ce = chunked_ce_loss(hidden, head, labels, cfg.vocab_size)
        return ce + aux, {"ce": ce, "aux": aux}

    # ------------------------------------------------------------------
    # caches / serving
    # ------------------------------------------------------------------
    def _seg_cache_capacity(self, seg: Segment, max_len: int) -> int:
        if seg.window is not None:
            return min(seg.window, max_len)
        return max_len

    def init_cache(self, batch_size: int, max_len: int) -> Dict[str, Any]:
        cfg = self.cfg
        adt = cfg.activation_dtype
        cache: Dict[str, Any] = {"len": jnp.zeros((), jnp.int32)}
        for si, seg in enumerate(self.segments):
            seg_c: Dict[str, Any] = {}
            if cfg.has_attention:
                C = self._seg_cache_capacity(seg, max_len)
                G = self.plan.padded_kv if not self.plan.kv_replicated else self.plan.kv_heads
                hd = cfg.head_dim_
                seg_c["k"] = jnp.zeros((seg.count, batch_size, G, C, hd), adt)
                seg_c["v"] = jnp.zeros((seg.count, batch_size, G, C, hd), adt)
            if cfg.has_ssm:
                st = mamba_init_state(cfg, batch_size, adt)
                seg_c["conv"] = jnp.broadcast_to(st["conv"][None], (seg.count,) + st["conv"].shape)
                seg_c["ssm"] = jnp.broadcast_to(st["ssm"][None], (seg.count,) + st["ssm"].shape)
            cache[f"seg{si}"] = seg_c
        return cache

    def cache_axes(self) -> Dict[str, Any]:
        cfg = self.cfg
        ax: Dict[str, Any] = {"len": ()}
        kv_ax = "kv_heads" if (self.plan and not self.plan.kv_replicated) else None
        for si, seg in enumerate(self.segments):
            seg_a: Dict[str, Any] = {}
            if cfg.has_attention:
                seg_a["k"] = ("layers", "batch", kv_ax, None, None)
                seg_a["v"] = ("layers", "batch", kv_ax, None, None)
            if cfg.has_ssm:
                seg_a["conv"] = ("layers", "batch", None, "inner")
                seg_a["ssm"] = ("layers", "batch", "inner", None)
            ax[f"seg{si}"] = seg_a
        return ax

    def prefill(self, params, batch, max_len: Optional[int] = None) -> Tuple[jnp.ndarray, Dict[str, Any]]:
        """Full-sequence forward that also builds the decode cache.

        Returns logits for the LAST position only (what serving samples
        from) — materializing (B, 32k, V) prefill logits is pure waste."""
        cfg = self.cfg
        hidden, _, seeds, n_prefix = self.forward(params, batch, collect_seed=True, return_hidden=True)
        logits = self._logits(params, hidden[:, -1:, :])[:, 0]
        # sequence length actually processed:
        if cfg.family == "audio":
            S = batch["features"].shape[1]
            B = batch["features"].shape[0]
        else:
            S = batch["tokens"].shape[1] + n_prefix
            B = batch["tokens"].shape[0]
        max_len = max_len or S
        cache = self.init_cache(B, max_len)
        cache["len"] = jnp.asarray(S, jnp.int32)
        for si, seg in enumerate(self.segments):
            seed = seeds[si]
            seg_c = cache[f"seg{si}"]
            if cfg.has_attention and "kv" in seed:
                k, v = seed["kv"]  # (Lseg, B, G, S, hd)
                C = seg_c["k"].shape[3]
                if S >= C:
                    # rolling layout: token t lands in slot t % C
                    last_k = k[..., S - C :, :]
                    last_v = v[..., S - C :, :]
                    slots = (S - C + jnp.arange(C)) % C
                    seg_c["k"] = jnp.zeros_like(seg_c["k"]).at[..., slots, :].set(last_k.astype(seg_c["k"].dtype))
                    seg_c["v"] = jnp.zeros_like(seg_c["v"]).at[..., slots, :].set(last_v.astype(seg_c["v"].dtype))
                else:
                    seg_c["k"] = seg_c["k"].at[..., :S, :].set(k.astype(seg_c["k"].dtype))
                    seg_c["v"] = seg_c["v"].at[..., :S, :].set(v.astype(seg_c["v"].dtype))
            if cfg.has_ssm and "mamba" in seed:
                seg_c["conv"] = seed["mamba"]["conv"].astype(seg_c["conv"].dtype)
                seg_c["ssm"] = seed["mamba"]["ssm"]
            cache[f"seg{si}"] = seg_c
        return logits, cache

    def decode_step(self, params, cache, tokens) -> Tuple[jnp.ndarray, Dict[str, Any]]:
        """One decoding step. tokens: (B,) int32. Returns (logits (B, V'), cache).

        ``cache["len"]`` may be a scalar (classic one-shot batch) or a
        per-row ``(B,)`` vector — the continuous-batching serving loop
        (``repro.runtime.serving``) keeps rows at different sequence
        positions in one batch; each row's computation is independent, so
        a row at length L matches the scalar-length path bitwise."""
        cfg = self.cfg
        adt = cfg.activation_dtype
        x = jnp.take(params["embed"].astype(adt), tokens, axis=0)  # (B, d)
        x = shard(x, "batch", None)
        clen = cache["len"]
        new_cache: Dict[str, Any] = {"len": clen + 1}
        for si, seg in enumerate(self.segments):
            seg_params = _slice_layers(params["layers"], seg.start, seg.count)
            seg_c = cache[f"seg{si}"]

            def body(h, xs, _seg=seg):
                lp, lc = xs
                h, updates = block_decode(
                    lp, h, lc, clen, cfg, self.plan, window=_seg.window,
                    inv_freq=self.inv_freq, ep_size=self.ep_size,
                )
                return h, updates

            x, updates = lax.scan(body, x, (seg_params, seg_c))
            new_cache[f"seg{si}"] = updates
        logits = self._logits(params, x[:, None, :])[:, 0]
        return logits, new_cache


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, ModelConfig] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.arch_id] = cfg
    return cfg


def get_config(arch_id: str) -> ModelConfig:
    # import configs lazily so registration happens on first use
    import repro.configs  # noqa: F401

    if arch_id not in _REGISTRY:
        raise KeyError(f"unknown arch '{arch_id}'; known: {sorted(_REGISTRY)}")
    return _REGISTRY[arch_id]


def list_archs() -> List[str]:
    import repro.configs  # noqa: F401

    return sorted(_REGISTRY)
