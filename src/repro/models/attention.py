"""Attention: blocked online-softmax (flash) in pure lax, SWA, GQA, decode.

Three implementations share one signature:
  * ``naive``   — O(S^2) materialized scores; the oracle for tests.
  * ``xla``     — blocked online softmax via ``lax.scan`` (memory O(S*Bk));
                  used on CPU and by the 512-device dry-run.
  * ``pallas``  — kernels/flash_attention.py (TPU target, same blocking).

Sliding-window attention slices only the needed KV range per q block
(static slice size ~``window + block_q``), so long-context SWA costs
O(S * window) instead of O(S^2) — this is what makes the 524k-token cells
lowerable for mixtral/hymba.

Note on causal full attention: the lax path sweeps every KV block and masks,
so compiled FLOPs are ~2x the causal minimum (visible in the roofline
useful-FLOPs ratio).  The Pallas kernel skips above-diagonal blocks via its
grid; see kernels/flash_attention.py.

Layouts: q (B, Hq, Sq, D); k, v (B, Hkv, Skv, D).  GQA is computed grouped
(fold kv-head into batch, q-per-kv into the head slot) when Hq % Hkv == 0,
otherwise via a per-q-head kv index map (replicated-kv plan, e.g. hymba).
"""

from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

NEG_INF = -1e30


def pick_block(n: int, target: int) -> int:
    """Largest divisor of n that is <= target (block sizes must tile seq)."""
    b = min(n, target)
    while n % b:
        b -= 1
    return b


def _expand_kv(k: jnp.ndarray, kv_map) -> jnp.ndarray:
    """Expand kv heads to one per q head using an index map."""
    idx = jnp.asarray(kv_map, jnp.int32)
    return jnp.take(k, idx, axis=1)


def naive_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    q_offset: int = 0,
    kv_map=None,
    scale: Optional[float] = None,
) -> jnp.ndarray:
    """Reference implementation (tests / tiny shapes only)."""
    B, Hq, Sq, D = q.shape
    Hkv, Skv = k.shape[1], k.shape[2]
    if kv_map is not None:
        k = _expand_kv(k, kv_map)
        v = _expand_kv(v, kv_map)
    elif Hq != Hkv:
        k = jnp.repeat(k, Hq // Hkv, axis=1)
        v = jnp.repeat(v, Hq // Hkv, axis=1)
    scale = scale or 1.0 / math.sqrt(D)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q, k).astype(jnp.float32) * scale
    qpos = q_offset + jnp.arange(Sq)[:, None]
    kpos = jnp.arange(Skv)[None, :]
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    logits = jnp.where(mask, logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v)


# ---------------------------------------------------------------------------
# Blocked flash in lax
# ---------------------------------------------------------------------------


def _flash_qblock(
    qb: jnp.ndarray,  # (B, G, g, Bq, D) — one q block, GQA-grouped 5-D
    k: jnp.ndarray,  # (B, G, W, D)
    v: jnp.ndarray,
    qpos: jnp.ndarray,  # (Bq,) global positions of this q block
    kpos0,  # scalar: global position of k[..., 0, :]
    *,
    causal: bool,
    window: Optional[int],
    block_k: int,
    scale: float,
) -> jnp.ndarray:
    """Online softmax over kv blocks for one q block.

    The GQA group structure is kept as separate (G, g) dims — collapsing
    (batch, kv-head) into one dim merges two mesh axes and makes GSPMD
    replicate kv heads across the model axis (observed: 16x attention FLOPs
    at micro>1; EXPERIMENTS.md §Perf mixtral iteration 1)."""
    B, G, g, Bq, D = qb.shape
    W = k.shape[2]
    nk = W // block_k
    kb = k.reshape(B, G, nk, block_k, D).transpose(2, 0, 1, 3, 4)
    vb = v.reshape(B, G, nk, block_k, D).transpose(2, 0, 1, 3, 4)

    def body(carry, kv):
        m, l, acc, j = carry
        kj, vj = kv  # (B, G, block_k, D)
        kpos = kpos0 + j * block_k + jnp.arange(block_k)
        s = jnp.einsum("bhgqd,bhkd->bhgqk", qb, kj).astype(jnp.float32) * scale
        mask = jnp.ones((Bq, block_k), bool)
        if causal:
            mask &= kpos[None, :] <= qpos[:, None]
        if window is not None:
            mask &= kpos[None, :] > qpos[:, None] - window
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l = l * alpha + p.sum(axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bhgqk,bhkd->bhgqd", p, vj.astype(jnp.float32)
        )
        return (m_new, l, acc, j + 1), None

    m0 = jnp.full((B, G, g, Bq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, G, g, Bq), jnp.float32)
    acc0 = jnp.zeros((B, G, g, Bq, D), jnp.float32)
    (m, l, acc, _), _ = lax.scan(body, (m0, l0, acc0, jnp.int32(0)), (kb, vb))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.astype(qb.dtype)


def _flash_qblock_skip(
    qb: jnp.ndarray,  # (B, G, g, Bq, D) — GQA-grouped 5-D (see _flash_qblock)
    k: jnp.ndarray,  # (B, G, Skv, D)
    v: jnp.ndarray,
    qpos: jnp.ndarray,
    q_end_hint,  # traced scalar: global start of this q block
    *,
    block_k: int,
    scale: float,
) -> jnp.ndarray:
    """Causal online softmax sweeping ONLY kv blocks at/below the diagonal
    (dynamic fori bound) — inference paths only."""
    B, G, g, Bq, D = qb.shape
    Skv = k.shape[2]
    n_blocks = (q_end_hint + Bq + block_k - 1) // block_k
    n_blocks = jnp.minimum(n_blocks, Skv // block_k).astype(jnp.int32)

    def body(j, carry):
        m, l, acc = carry
        z = jnp.zeros((), jnp.int32)
        kj = lax.dynamic_slice(k, (z, z, j * block_k, z), (B, G, block_k, D))
        vj = lax.dynamic_slice(v, (z, z, j * block_k, z), (B, G, block_k, D))
        kpos = j * block_k + jnp.arange(block_k)
        s = jnp.einsum("bhgqd,bhkd->bhgqk", qb, kj).astype(jnp.float32) * scale
        s = jnp.where(kpos[None, :] <= qpos[:, None], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l = l * alpha + p.sum(axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bhgqk,bhkd->bhgqd", p, vj.astype(jnp.float32)
        )
        return m_new, l, acc

    m0 = jnp.full((B, G, g, Bq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, G, g, Bq), jnp.float32)
    acc0 = jnp.zeros((B, G, g, Bq, D), jnp.float32)
    m, l, acc = lax.fori_loop(0, n_blocks, body, (m0, l0, acc0))
    return (acc / jnp.maximum(l, 1e-30)[..., None]).astype(qb.dtype)


def flash_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    q_offset: int = 0,
    kv_map=None,
    block_q: int = 512,
    block_k: int = 512,
    scale: Optional[float] = None,
    dynamic_skip: bool = False,
) -> jnp.ndarray:
    """Blocked attention. q (B,Hq,Sq,D), k/v (B,Hkv,Skv,D) -> (B,Hq,Sq,D).

    ``dynamic_skip``: causal KV sweep per q block runs a ``fori_loop`` with a
    *dynamic* upper bound (only blocks at/below the diagonal), cutting causal
    FLOPs ~2x vs the masked full sweep.  Inference-only (while loops with
    dynamic bounds are not reverse-mode differentiable); the Pallas kernel
    does the same skip on TPU for training too.
    """
    B, Hq, Sq, D = q.shape
    Hkv, Skv = k.shape[1], k.shape[2]
    scale = scale or 1.0 / math.sqrt(D)
    block_q = pick_block(Sq, block_q)
    block_k = pick_block(Skv, block_k)

    if kv_map is not None:
        k = _expand_kv(k, kv_map)
        v = _expand_kv(v, kv_map)
        Hkv = Hq
    if Hq % Hkv:
        raise ValueError(f"Hq {Hq} not a multiple of Hkv {Hkv}")
    g = Hq // Hkv
    q = q.reshape(B, Hkv, g, Sq, D)  # 5-D GQA-grouped layout throughout

    nq = Sq // block_q
    wpad = None
    if window is not None:
        wpad = ((window + block_q + block_k - 1) // block_k) * block_k
        if wpad >= Skv:
            wpad = None  # window covers (almost) everything: no point slicing

    def per_qblock(i):
        z = jnp.zeros((), jnp.int32)
        qs = (i * block_q).astype(jnp.int32)
        qb = lax.dynamic_slice(q, (z, z, z, qs, z), (B, Hkv, g, block_q, D))
        qpos = q_offset + qs + jnp.arange(block_q)
        if wpad is not None:
            start = jnp.clip(q_offset + qs + block_q - wpad, 0, Skv - wpad).astype(jnp.int32)
            ks = lax.dynamic_slice(k, (z, z, start, z), (B, Hkv, wpad, D))
            vs = lax.dynamic_slice(v, (z, z, start, z), (B, Hkv, wpad, D))
            kpos0 = start
        else:
            ks, vs, kpos0 = k, v, jnp.int32(0)
        if dynamic_skip and causal and window is None and wpad is None:
            return _flash_qblock_skip(
                qb, ks, vs, qpos, q_offset + qs, block_k=block_k, scale=scale
            )
        return _flash_qblock(
            qb, ks, vs, qpos, kpos0, causal=causal, window=window,
            block_k=block_k, scale=scale,
        )

    outs = lax.map(per_qblock, jnp.arange(nq))  # (nq, B, G, g, block_q, D)
    out = outs.transpose(1, 2, 3, 0, 4, 5).reshape(B, Hq, Sq, D)
    return out


# ---------------------------------------------------------------------------
# Decode (single new token against a cache)
# ---------------------------------------------------------------------------


def decode_attention(
    q: jnp.ndarray,  # (B, Hq, 1, D)
    k_cache: jnp.ndarray,  # (B, Hkv, C, D)  C = cache capacity
    v_cache: jnp.ndarray,
    cache_len: jnp.ndarray,  # int32: #tokens written so far — scalar or (B,)
    *,
    window: Optional[int] = None,
    rolling: bool = False,
    kv_map=None,
    scale: Optional[float] = None,
) -> jnp.ndarray:
    """One-step attention against a (possibly rolling/SWA) KV cache.

    With ``rolling=True`` the cache is a circular buffer of capacity C
    (== window for SWA): once cache_len >= C every slot is valid, and
    ordering does not matter for softmax(QK)V.

    ``cache_len`` may be a per-row ``(B,)`` vector (continuous-batching
    serving: rows admitted at different times sit at different positions).
    Every op here is row-independent — batched einsums contract over
    non-batch dims and the slot mask broadcasts per row — so a row at
    length L computes bitwise what the scalar path computes at length L.
    """
    B, Hq, _, D = q.shape
    Hkv, C = k_cache.shape[1], k_cache.shape[2]
    scale = scale or 1.0 / math.sqrt(D)
    if kv_map is not None:
        # replicated-kv plan (small Hkv): gather is cheap
        k_cache = _expand_kv(k_cache, kv_map)
        v_cache = _expand_kv(v_cache, kv_map)
        Hkv = Hq
    grouped = Hq != Hkv
    if grouped:
        # grouped einsum — never materialize a per-q-head cache copy
        g = Hq // Hkv
        qg = q.reshape(B, Hkv, g, D)
        s = jnp.einsum("bhgd,bhkd->bhgk", qg, k_cache).astype(jnp.float32) * scale
    else:
        s = jnp.einsum("bhqd,bhkd->bhqk", q, k_cache).astype(jnp.float32) * scale
    slots = jnp.arange(C)[None, None, None, :]
    clen = jnp.asarray(cache_len)
    if clen.ndim:  # per-row lengths -> broadcast over (B, H, q, slot)
        clen = clen[:, None, None, None]
    valid = slots < jnp.minimum(clen, C)
    if window is not None and not rolling:
        valid = valid & (slots >= clen - window)
    s = jnp.where(valid, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1).astype(v_cache.dtype)
    if grouped:
        out = jnp.einsum("bhgk,bhkd->bhgd", p, v_cache)
        return out.reshape(B, Hq, 1, D)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v_cache)


def update_cache(
    k_cache: jnp.ndarray,
    v_cache: jnp.ndarray,
    k_new: jnp.ndarray,  # (B, Hkv, 1, D)
    v_new: jnp.ndarray,
    cache_len,  # int32 tokens already in cache: scalar or per-row (B,)
    rolling: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    C = k_cache.shape[2]
    pos = jnp.asarray(cache_len) % C if rolling else jnp.asarray(cache_len)
    pos = pos.astype(jnp.int32)
    if pos.ndim:
        # per-row write slots (continuous batching): one-slot scatter per
        # row — writes the exact same k/v values the scalar slice path does
        rows = jnp.arange(k_cache.shape[0])
        k_cache = k_cache.at[rows, :, pos, :].set(k_new[:, :, 0].astype(k_cache.dtype))
        v_cache = v_cache.at[rows, :, pos, :].set(v_new[:, :, 0].astype(v_cache.dtype))
        return k_cache, v_cache
    z = jnp.zeros((), jnp.int32)
    k_cache = lax.dynamic_update_slice(k_cache, k_new.astype(k_cache.dtype), (z, z, pos, z))
    v_cache = lax.dynamic_update_slice(v_cache, v_new.astype(v_cache.dtype), (z, z, pos, z))
    return k_cache, v_cache


# ---------------------------------------------------------------------------
# Ring attention: the paper's boundary/interior halo rotation applied to
# sequence-parallel attention (context parallelism)
# ---------------------------------------------------------------------------


def ring_attention(
    q: jnp.ndarray,  # (B, Hq, S_loc, D) — this member's sequence shard
    k: jnp.ndarray,  # (B, Hkv, S_loc, D)
    v: jnp.ndarray,
    axis_name: str,
    *,
    causal: bool = True,
    scale: Optional[float] = None,
) -> jnp.ndarray:
    """Sequence-parallel attention inside ``shard_map``.

    Exactly the paper's scheme in 1-D: each ring step computes attention of
    the local queries against the KV chunk currently held (*interior* work)
    while the chunk travels to the next member via ``ppermute`` (*boundary*
    exchange); online-softmax statistics merge the steps.  P-1 ppermutes of
    the KV shard replace any all-gather of the full sequence — surface, not
    volume, over the link.
    """
    import math as _math

    B, Hq, S_loc, D = q.shape
    Hkv = k.shape[1]
    g = Hq // Hkv
    from repro.jax_compat import axis_size

    P = axis_size(axis_name)
    idx = lax.axis_index(axis_name)
    scale = scale or 1.0 / _math.sqrt(D)
    qg = q.reshape(B, Hkv, g, S_loc, D)
    qpos = idx * S_loc + jnp.arange(S_loc)

    m = jnp.full((B, Hkv, g, S_loc), NEG_INF, jnp.float32)
    l = jnp.zeros((B, Hkv, g, S_loc), jnp.float32)
    acc = jnp.zeros((B, Hkv, g, S_loc, D), jnp.float32)
    perm = [(i, (i + 1) % P) for i in range(P)]

    kc, vc = k, v
    for j in range(P):
        src = (idx - j) % P  # owner of the chunk we hold this step
        kpos = src * S_loc + jnp.arange(S_loc)
        s = jnp.einsum("bhgqd,bhkd->bhgqk", qg, kc).astype(jnp.float32) * scale
        if causal:
            s = jnp.where(kpos[None, :] <= qpos[:, None], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l = l * alpha + p.sum(axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bhgqk,bhkd->bhgqd", p, vc.astype(jnp.float32)
        )
        m = m_new
        if j < P - 1:  # boundary exchange overlaps the next interior step
            kc = lax.ppermute(kc, axis_name, perm)
            vc = lax.ppermute(vc, axis_name, perm)
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(B, Hq, S_loc, D).astype(q.dtype)
