"""Deterministic synthetic data pipeline.

Every batch is a pure function of (seed, step, shape), so training is
reproducible across restarts and across *different* numbers of hosts — a
requirement for elastic restart correctness: after a failure, step N's batch
is identical no matter which node rebuilds it (tested).

A background prefetch thread keeps ``depth`` batches ready (double
buffering), and per-partition batch weighting hooks into the load balancer:
a heterogeneous fleet can be fed asymmetric shares exactly like the paper's
CPU/MIC element split.
"""

from __future__ import annotations

import queue
import threading
from typing import Any, Dict, Iterator, Optional

import numpy as np

from repro.configs.shapes import ShapeSpec
from repro.models.common import ModelConfig

VIS_EMBED_DIM = 1024


def _rng(seed: int, step: int) -> np.random.Generator:
    return np.random.default_rng(np.random.SeedSequence([seed, step]))


def make_batch(
    cfg: ModelConfig,
    shape: ShapeSpec,
    step: int,
    *,
    seed: int = 0,
    accum: int = 1,
    micro: Optional[int] = None,
    dtype=np.float32,
) -> Dict[str, Any]:
    """Global batch for ``step`` as numpy arrays, microbatched (accum, micro, ...)."""
    g = _rng(seed, step)
    B = shape.global_batch
    S = shape.seq_len
    micro = micro or B
    assert accum * micro == B, (accum, micro, B)
    lead = (accum, micro)
    if cfg.family == "audio":
        feats = g.standard_normal(lead + (S, cfg.d_model), dtype=np.float32).astype(dtype)
        # HuBERT-style masked-prediction targets are quantized features; make
        # the synthetic labels a (learnable) quantization of channel 0 so the
        # pipeline carries real signal
        nb = min(cfg.vocab_size, 32)
        labels = np.clip(((feats[..., 0] + 2.0) / 4.0 * nb).astype(np.int32), 0, nb - 1)
        return {"features": feats, "labels": labels}
    if cfg.family == "vlm":
        ni = cfg.frontend_tokens
        toks = g.integers(0, cfg.vocab_size, lead + (S - ni,), dtype=np.int32)
        patches = g.standard_normal(lead + (ni, VIS_EMBED_DIM), dtype=np.float32).astype(dtype)
        labels = np.roll(toks, -1, axis=-1)
        labels[..., -1] = -1
        return {"tokens": toks, "patches": patches, "labels": labels}
    toks = g.integers(0, cfg.vocab_size, lead + (S,), dtype=np.int32)
    labels = np.roll(toks, -1, axis=-1)
    labels[..., -1] = -1
    return {"tokens": toks, "labels": labels}


class SyntheticPipeline:
    """Prefetching iterator of (step, batch) with restart support."""

    def __init__(
        self,
        cfg: ModelConfig,
        shape: ShapeSpec,
        *,
        seed: int = 0,
        accum: int = 1,
        micro: Optional[int] = None,
        start_step: int = 0,
        depth: int = 2,
    ):
        self.cfg, self.shape, self.seed = cfg, shape, seed
        self.accum, self.micro = accum, micro
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._step = start_step
        self._thread = threading.Thread(target=self._produce, daemon=True)
        self._thread.start()

    def _produce(self):
        s = self._step
        while not self._stop.is_set():
            b = make_batch(self.cfg, self.shape, s, seed=self.seed, accum=self.accum, micro=self.micro)
            try:
                self._q.put((s, b), timeout=0.5)
                s += 1
            except queue.Full:
                continue

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        while True:
            try:
                return self._q.get(timeout=1.0)
            except queue.Empty:
                if self._stop.is_set():
                    raise StopIteration
                continue

    def close(self):
        self._stop.set()
        self._thread.join(timeout=2.0)
