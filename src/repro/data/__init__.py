from repro.data.pipeline import SyntheticPipeline, make_batch
