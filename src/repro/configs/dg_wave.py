"""The paper's own evaluation problem: DGSEM coupled elastic-acoustic wave
propagation on a brick with a centered material discontinuity (Fig 6.1),
order N=7, 8192 elements per node (Table 6.1)."""

import dataclasses


@dataclasses.dataclass(frozen=True)
class DGConfig:
    order: int = 7
    grid: tuple = (32, 16, 16)          # 8192 elements (one node's share)
    n_nodes: int = 1                    # level-1 partitions
    accel_ratio: float = 1.6            # published K_MIC/K_CPU optimum
    # two material trees (Fig 6.1): acoustic cp=1 cs=0 | elastic cp=3 cs=2
    cp: tuple = (1.0, 3.0)
    cs: tuple = (0.0, 2.0)
    rho: tuple = (1.0, 1.0)
    dt: float = 1e-3
    final_time: float = 0.118           # 118 steps at dt=1e-3


CONFIG = DGConfig()
