"""Assigned input shapes, the 40-cell (arch x shape) grid, and smoke configs.

Skip rules (recorded per cell, per the assignment):
  * encoder-only archs have no decode step -> decode_32k / long_500k skipped;
  * long_500k needs sub-quadratic sequence mixing -> runs only for SSM /
    hybrid / SWA archs; skipped for pure full-attention archs.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional

from repro.models.common import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class Cell:
    arch_id: str
    shape: ShapeSpec
    skip: Optional[str] = None  # reason, if skipped

    @property
    def name(self) -> str:
        return f"{self.arch_id}/{self.shape.name}"


def _subquadratic(cfg: ModelConfig) -> bool:
    if cfg.has_ssm and not cfg.global_layers:
        return True
    if cfg.has_ssm and cfg.global_layers:
        return True  # hybrid: few global layers; decode is O(S) per step only there
    return cfg.sliding_window is not None and not cfg.global_layers


def cells_for(cfg: ModelConfig) -> List[Cell]:
    cells = []
    for s in SHAPES.values():
        skip = None
        if s.kind == "decode" and cfg.is_encoder_only:
            skip = "encoder-only arch: no decode step"
        elif s.name == "long_500k":
            if cfg.is_encoder_only:
                skip = "encoder-only arch: no decode step"
            elif not (cfg.has_ssm or cfg.sliding_window is not None):
                skip = "pure full-attention arch: 524k dense KV cache out of scope"
        cells.append(Cell(cfg.arch_id, s, skip))
    return cells


def smoke_config(cfg: ModelConfig) -> ModelConfig:
    """Reduced same-family config: tiny widths/depths, runnable on 1 CPU."""
    kw = dict(
        n_layers=min(cfg.n_layers, 4 if cfg.global_layers else 2),
        d_model=64,
        vocab_size=512,
        tp_size=1,
        remat="none",
        dtype="float32",
    )
    if cfg.has_attention:
        kw.update(n_heads=4, n_kv_heads=2 if cfg.n_kv_heads < cfg.n_heads else 4, head_dim=16)
    if cfg.d_ff:
        kw.update(d_ff=128)
    if cfg.n_experts:
        kw.update(n_experts=4, experts_per_token=2, capacity_factor=2.0)
    if cfg.has_ssm:
        kw.update(ssm_state=8)
    if cfg.sliding_window is not None:
        kw.update(sliding_window=32)
    if cfg.global_layers:
        kw.update(global_layers=(0, 3))
    if cfg.n_meta_tokens:
        kw.update(n_meta_tokens=8)
    if cfg.frontend_tokens:
        kw.update(frontend_tokens=16)
    if cfg.dt_rank:
        kw.update(dt_rank=8)
    return cfg.replace(**kw)
