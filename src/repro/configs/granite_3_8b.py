"""IBM Granite-3 8B — dense GQA decoder.
[hf:ibm-granite/granite-3.0-2b-base family per assignment; hf]
Note: vocab 49155 is not lane/TP-divisible; padded to 49280 internally."""

from repro.models.common import ModelConfig
from repro.models.zoo import register

CONFIG = register(ModelConfig(
    arch_id="granite-3-8b",
    family="dense",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=12800,
    vocab_size=49155,
    head_dim=128,
    rope_theta=10_000.0,
    norm_eps=1e-5,
    tp_size=16,
))
