"""LLaVA-NeXT 34B — VLM: dense GQA LM backbone + anyres image tiling.
[hf:llava-hf/llava-v1.6-mistral-7b-hf family per assignment; unverified]

Backbone-only per the assignment: the vision tower is a STUB — input_specs
provides precomputed CLIP-L patch embeddings (anyres 5 tiles x 576 = 2880
patch positions); the trained mm_proj projector is part of this model."""

from repro.models.common import ModelConfig
from repro.models.zoo import register

CONFIG = register(ModelConfig(
    arch_id="llava-next-34b",
    family="vlm",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=20480,
    vocab_size=64000,
    head_dim=128,
    rope_theta=5_000_000.0,
    norm_eps=1e-5,
    frontend_tokens=2880,  # anyres: 5 tiles x 24x24 patches
    tp_size=16,
))
