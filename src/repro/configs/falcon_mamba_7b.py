"""Falcon-Mamba-7B — attention-free mamba-1 SSM. [arXiv:2410.05355; unverified]
d_ff=0 per assignment: the mamba block carries its own 2x expansion."""

from repro.models.common import ModelConfig
from repro.models.zoo import register

CONFIG = register(ModelConfig(
    arch_id="falcon-mamba-7b",
    family="ssm",
    n_layers=64,
    d_model=4096,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab_size=65024,
    ssm_state=16,
    ssm_conv=4,
    ssm_expand=2,
    ssm_chunk=1024,  # hillclimbed: -27% HBM traffic vs chunk 128 (EXPERIMENTS §Perf)
    norm_eps=1e-5,
    tp_size=16,
))
