"""Flag-registered scenario/config registry (MuZeroGoJax `_build_config` style).

One lookup table for everything a CLI can name:

  * **archs** — every ``configs/*.py`` model architecture, delegated to the
    ``repro.models.zoo`` registry (importing ``repro.configs`` populates it);
  * **scenarios** — named DG mesh / cluster setups (grid, order, materials,
    node fleet) as zero-argument-callable factories with overridable kwargs.

``benchmarks/run.py``, ``launch/serve.py`` and ``launch/train.py`` resolve
``--arch`` / ``--scenario`` through here instead of hard-coded imports, and
``--list-scenarios`` prints :func:`format_listing`.  Registration is
decentralized: a new config module calls :func:`register_scenario` at import
time and every CLI picks it up by name.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List

__all__ = [
    "Scenario",
    "register_scenario",
    "resolve_arch",
    "resolve_scenario",
    "list_archs",
    "list_scenarios",
    "format_listing",
]


# -- archs (model configs) ---------------------------------------------------


def _zoo():
    # importing the configs package registers every arch with the zoo
    import repro.configs  # noqa: F401
    from repro.models import zoo

    return zoo


def resolve_arch(name: str):
    """Arch id -> ``ModelConfig`` (KeyError lists the known ids)."""
    return _zoo().get_config(name)


def list_archs() -> List[str]:
    return _zoo().list_archs()


# -- scenarios (DG mesh / cluster setups) ------------------------------------


@dataclasses.dataclass(frozen=True)
class Scenario:
    """A named, buildable experiment setup.

    ``factory(**overrides)`` constructs the scenario object (a solver, a
    cluster, ...); ``defaults`` documents the kwargs the factory accepts
    and their registered values — CLIs surface them, overrides replace
    them."""

    name: str
    description: str
    factory: Callable[..., Any]
    defaults: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def build(self, **overrides):
        kwargs = dict(self.defaults)
        kwargs.update(overrides)
        return self.factory(**kwargs)


_SCENARIOS: Dict[str, Scenario] = {}


def register_scenario(
    name: str,
    description: str,
    factory: Callable[..., Any],
    **defaults,
) -> Scenario:
    """Register (and return) a scenario; re-registering a name replaces it."""
    sc = Scenario(name=name, description=description, factory=factory,
                  defaults=dict(defaults))
    _SCENARIOS[name] = sc
    return sc


def resolve_scenario(name: str) -> Scenario:
    _builtin()  # make sure the built-ins are in before the lookup
    if name not in _SCENARIOS:
        raise KeyError(
            f"unknown scenario '{name}'; known: {sorted(_SCENARIOS)}"
        )
    return _SCENARIOS[name]


def list_scenarios() -> List[str]:
    _builtin()
    return sorted(_SCENARIOS)


def format_listing() -> str:
    """The ``--list-scenarios`` text: every registered arch and scenario."""
    lines = ["archs:"]
    for a in list_archs():
        lines.append(f"  {a}")
    lines.append("scenarios:")
    for name in list_scenarios():
        sc = _SCENARIOS[name]
        kv = " ".join(f"{k}={v}" for k, v in sc.defaults.items())
        lines.append(f"  {name} — {sc.description}" + (f" [{kv}]" if kv else ""))
    return "\n".join(lines)


# -- built-in scenarios ------------------------------------------------------

_BUILTIN_DONE = False


def _builtin() -> None:
    """Register the repo's standard scenarios (idempotent, lazy so that
    importing the registry stays cheap)."""
    global _BUILTIN_DONE
    if _BUILTIN_DONE:
        return
    _BUILTIN_DONE = True

    def two_tree(**kw):
        from repro.dg.solver import make_two_tree_solver

        return make_two_tree_solver(**kw)

    def paper_brick(**kw):
        from repro.configs.dg_wave import CONFIG
        from repro.dg.solver import make_two_tree_solver

        kw.setdefault("grid", CONFIG.grid)
        kw.setdefault("order", CONFIG.order)
        return make_two_tree_solver(**kw)

    def stampede(n_nodes=2, order=2, grid=(8, 4, 4), speed_skew=1.0, **kw):
        from repro.dg.solver import make_two_tree_solver
        from repro.runtime.cluster import SimulatedCluster, stampede_profile

        solver = make_two_tree_solver(grid=grid, order=order, **kw)
        profiles = [
            stampede_profile(order=order, name=f"n{i}",
                             speed=speed_skew**i)
            for i in range(n_nodes)
        ]
        return SimulatedCluster(solver, profiles)

    register_scenario(
        "dg-two-tree",
        "two-material elastic/acoustic brick (Fig 6.1 geometry, test size)",
        two_tree, grid=(8, 4, 4), order=3, extent=(2.0, 1.0, 1.0),
    )
    register_scenario(
        "dg-smoke",
        "tiny two-tree brick for CI smoke runs",
        two_tree, grid=(4, 2, 2), order=2,
    )
    register_scenario(
        "dg-paper",
        "the paper's evaluation brick (order 7, 8192 elements/node)",
        paper_brick,
    )
    register_scenario(
        "stampede-cluster",
        "simulated heterogeneous Stampede fleet on the two-tree brick",
        stampede, n_nodes=2, order=2, grid=(8, 4, 4), speed_skew=1.0,
    )
