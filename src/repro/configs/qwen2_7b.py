"""Qwen2-7B — dense GQA decoder with QKV bias. [arXiv:2407.10671; hf]"""

from repro.models.common import ModelConfig
from repro.models.zoo import register

CONFIG = register(ModelConfig(
    arch_id="qwen2-7b",
    family="dense",
    n_layers=28,
    d_model=3584,
    n_heads=28,
    n_kv_heads=4,
    d_ff=18944,
    vocab_size=152064,
    head_dim=128,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    norm_eps=1e-6,
    tp_size=16,
))
