"""Mixtral-8x22B — MoE (8 experts, top-2) with sliding-window attention.
[arXiv:2401.04088; hf]

EP note: 8 experts < 16-wide expert axis -> each expert is replicated into
2 shards (replica chosen by token parity); routing math unchanged."""

from repro.models.common import ModelConfig
from repro.models.zoo import register

CONFIG = register(ModelConfig(
    arch_id="mixtral-8x22b",
    family="moe",
    n_layers=56,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab_size=32768,
    head_dim=128,
    rope_theta=1_000_000.0,
    norm_eps=1e-5,
    sliding_window=4096,
    n_experts=8,
    experts_per_token=2,
    tp_size=16,
))
