"""OLMoE-1B-7B — MoE (64 experts, top-8), full attention, 16 kv heads.
[arXiv:2409.02060; hf]"""

from repro.models.common import ModelConfig
from repro.models.zoo import register

CONFIG = register(ModelConfig(
    arch_id="olmoe-1b-7b",
    family="moe",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1024,
    vocab_size=50304,
    head_dim=128,
    rope_theta=10_000.0,
    norm_eps=1e-5,
    n_experts=64,
    experts_per_token=8,
    tp_size=16,
))
