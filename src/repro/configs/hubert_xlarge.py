"""HuBERT X-Large — encoder-only audio transformer (w2v2 arch).
[arXiv:2106.07447; unverified]

Backbone-only per the assignment: the conv waveform frontend is a STUB;
input_specs provides precomputed frame embeddings (B, S, d_model).  The
learned convolutional positional embedding IS part of the backbone.
Encoder-only: no decode shapes (recorded skip).  Vocab 504 = masked-
prediction codebook targets; padded to 512 internally."""

from repro.models.common import ModelConfig
from repro.models.zoo import register

CONFIG = register(ModelConfig(
    arch_id="hubert-xlarge",
    family="audio",
    n_layers=48,
    d_model=1280,
    n_heads=16,
    n_kv_heads=16,
    d_ff=5120,
    vocab_size=504,
    head_dim=80,
    causal=False,
    mlp_type="gelu",
    use_conv_pos=True,
    norm_eps=1e-5,
    tp_size=16,
))
