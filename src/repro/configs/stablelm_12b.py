"""StableLM-2 12B — dense GQA decoder with partial rotary embeddings (25%).
[hf:stabilityai/stablelm-2-1_6b family per assignment; hf]"""

from repro.models.common import ModelConfig
from repro.models.zoo import register

CONFIG = register(ModelConfig(
    arch_id="stablelm-12b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    d_ff=13824,
    vocab_size=100352,
    head_dim=160,
    rotary_pct=0.25,
    rope_theta=10_000.0,
    norm_eps=1e-5,
    tp_size=16,
))
