"""Assigned-architecture configs.  Importing this package registers all of
them; ``repro.models.zoo.get_config(arch_id)`` is the lookup."""

from repro.configs import (  # noqa: F401
    dg_wave,
    falcon_mamba_7b,
    granite_3_8b,
    hubert_xlarge,
    hymba_1_5b,
    llava_next_34b,
    mixtral_8x22b,
    olmoe_1b_7b,
    qwen2_5_32b,
    qwen2_7b,
    stablelm_12b,
)
from repro.configs.shapes import SHAPES, Cell, cells_for, smoke_config  # noqa: F401
from repro.configs.registry import (  # noqa: F401
    Scenario,
    format_listing,
    list_archs,
    list_scenarios,
    register_scenario,
    resolve_arch,
    resolve_scenario,
)
