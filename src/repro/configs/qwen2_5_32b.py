"""Qwen2.5-32B — dense GQA decoder with QKV bias.
[hf:Qwen/Qwen2.5-0.5B config family scaled per assignment; hf]"""

from repro.models.common import ModelConfig
from repro.models.zoo import register

CONFIG = register(ModelConfig(
    arch_id="qwen2.5-32b",
    family="dense",
    n_layers=64,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=27648,
    vocab_size=152064,
    head_dim=128,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    norm_eps=1e-6,
    tp_size=16,
))
