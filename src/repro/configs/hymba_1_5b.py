"""Hymba-1.5B — hybrid: parallel attention + mamba heads per layer, SWA with
three global-attention layers, 128 learned meta tokens. [arXiv:2411.13676; hf]

Head-sharding note: 16 % 5 kv heads != 0 -> kv is computed replicated across
the model axis (DESIGN.md section 4); q heads padded 25 -> 32."""

from repro.models.common import ModelConfig
from repro.models.zoo import register

CONFIG = register(ModelConfig(
    arch_id="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    d_ff=5504,
    vocab_size=32001,
    head_dim=64,
    rope_theta=10_000.0,
    norm_eps=1e-5,
    sliding_window=1024,
    global_layers=(0, 15, 31),
    n_meta_tokens=128,
    ssm_state=16,
    ssm_expand=2,
    tp_size=16,
))
