"""Sharded, atomic, resharding-capable checkpointing.

Layout per step (one directory):

    ckpt_dir/step_000123/
      manifest.json       # treedef paths, shapes, dtypes, mesh, spec strings
      shard_p0.npz        # this process' addressable shards, keyed
      .complete           # commit marker (atomicity: written last)

Save is atomic (tmp dir + os.replace + marker) and optionally asynchronous
(background thread; ``wait()`` joins).  Restore rebuilds global arrays from
shard files and ``jax.device_put``s them with the *target* sharding — which
may belong to a different mesh than the one that saved: that is the elastic
restart path (tested: save on (2,2), restore on (1,4) and on 1 device).

On this single-process container every shard is addressable, but the format
and code paths are the multi-host ones (per-process shard files keyed by
global shard index).
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Dict, Optional

import jax
import numpy as np

SEP = "/"


def _flatten_with_paths(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = SEP.join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        flat[key] = leaf
    return flat


def _unflatten_like(template, flat: Dict[str, Any]):
    paths = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, _ in paths[0]:
        key = SEP.join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        leaves.append(flat[key])
    return jax.tree_util.tree_unflatten(paths[1], leaves)


def comparable_manifest(manifest: Dict[str, Any]) -> Dict[str, Any]:
    """The equality-relevant manifest payload: everything except the save
    wall timestamp, so two saves of the same state compare identical
    (manifest-level replay comparison)."""
    return {k: v for k, v in manifest.items() if k != "time"}


def save(
    ckpt_dir: str,
    step: int,
    tree,
    *,
    extra_meta: Optional[Dict[str, Any]] = None,
    process_index: int = 0,
    timestamp: Optional[float] = None,
) -> str:
    """Write a checkpoint atomically; returns the final directory.

    ``timestamp`` (default: ``time.time()`` at save) is provenance only —
    it is excluded from :func:`comparable_manifest`, so bitwise-identical
    states always yield identical comparable manifests."""
    flat = _flatten_with_paths(tree)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + f".tmp{process_index}"
    os.makedirs(tmp, exist_ok=True)

    manifest = {
        "step": step,
        "leaves": {},
        "extra": extra_meta or {},
        "time": time.time() if timestamp is None else float(timestamp),
    }
    shards: Dict[str, np.ndarray] = {}
    for key, leaf in flat.items():
        arr = np.asarray(jax.device_get(leaf))
        manifest["leaves"][key] = {"shape": list(arr.shape), "dtype": str(arr.dtype)}
        shards[key.replace(SEP, "~")] = arr
    np.savez(os.path.join(tmp, f"shard_p{process_index}.npz"), **shards)
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    # commit
    if os.path.isdir(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    with open(os.path.join(final, ".complete"), "w") as f:
        f.write("ok")
    return final


def prune(ckpt_dir: str, keep: int = 3) -> None:
    """Drop all but the ``keep`` newest complete checkpoints — the retention
    half of ``CheckpointManager`` as a standalone helper, for callers that
    write snapshots through plain ``save`` (e.g. the fault-tolerance
    supervisor's per-chunk ``(q, step, plan)`` snapshots)."""
    if not os.path.isdir(ckpt_dir):
        return
    steps = sorted(
        int(n.split("_")[1])
        for n in os.listdir(ckpt_dir)
        if n.startswith("step_") and os.path.exists(os.path.join(ckpt_dir, n, ".complete"))
    )
    for s in steps[: -keep] if keep > 0 else steps:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"), ignore_errors=True)


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and os.path.exists(os.path.join(ckpt_dir, name, ".complete")):
            steps.append(int(name.split("_")[1]))
    return max(steps) if steps else None


def restore(
    ckpt_dir: str,
    template,
    *,
    step: Optional[int] = None,
    shardings=None,
):
    """Load into the structure of ``template``; place with ``shardings``
    (a matching pytree of NamedSharding) if given — the target mesh may
    differ from the saving mesh (elastic restart)."""
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no complete checkpoint under {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    data: Dict[str, np.ndarray] = {}
    for name in sorted(os.listdir(d)):
        if name.startswith("shard_") and name.endswith(".npz"):
            with np.load(os.path.join(d, name)) as z:
                for k in z.files:
                    data[k.replace("~", SEP)] = z[k]
    flat_t = _flatten_with_paths(template)
    out: Dict[str, Any] = {}
    sh_flat = _flatten_with_paths(shardings) if shardings is not None else {}
    for key in flat_t:
        arr = data[key]
        if shardings is not None and key in sh_flat:
            out[key] = jax.device_put(arr, sh_flat[key])
        else:
            out[key] = jax.numpy.asarray(arr)
    tree = _unflatten_like(template, out)
    return tree, manifest


class CheckpointManager:
    """Async save + retention + restore-latest."""

    def __init__(self, ckpt_dir: str, keep: int = 3, async_save: bool = True):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        os.makedirs(ckpt_dir, exist_ok=True)

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _save_and_gc(self, step: int, tree, extra):
        save(self.ckpt_dir, step, tree, extra_meta=extra)
        prune(self.ckpt_dir, keep=self.keep)

    def save(self, step: int, tree, extra_meta: Optional[Dict[str, Any]] = None):
        # snapshot to host BEFORE returning (donated buffers may be reused)
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
        self.wait()
        if self.async_save:
            self._thread = threading.Thread(
                target=self._save_and_gc, args=(step, host_tree, extra_meta), daemon=True
            )
            self._thread.start()
        else:
            self._save_and_gc(step, host_tree, extra_meta)

    def restore_latest(self, template, shardings=None):
        return restore(self.ckpt_dir, template, shardings=shardings)
