from repro.checkpoint.checkpoint import CheckpointManager, latest_step, prune, restore, save
