from repro.checkpoint.checkpoint import (
    CheckpointManager,
    comparable_manifest,
    latest_step,
    prune,
    restore,
    save,
)
