"""Logical-axis sharding rules (MaxText-style), applied via a context.

Model code annotates tensors with *logical* axis names ("batch", "embed",
"heads", "ff", "inner", "vocab", "expert", "kv_heads").  The launcher
installs a rule set mapping logical names to mesh axes for the current
(mesh, shape) combination; outside any context the hints are no-ops, so the
same model code runs single-device (smoke tests) and SPMD (dry-run/train).
"""

from __future__ import annotations

import contextlib
import threading
from typing import Any, Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

AxisVal = Union[None, str, Tuple[str, ...]]

_state = threading.local()


def current_rules() -> Optional[Dict[str, AxisVal]]:
    return getattr(_state, "rules", None)


def current_mesh() -> Optional[Mesh]:
    return getattr(_state, "mesh", None)


@contextlib.contextmanager
def logical_axis_rules(rules: Dict[str, AxisVal], mesh: Optional[Mesh] = None):
    old = (current_rules(), current_mesh())
    _state.rules, _state.mesh = rules, mesh
    try:
        yield
    finally:
        _state.rules, _state.mesh = old


def spec_for(axes: Sequence[Optional[str]], rules: Optional[Dict[str, AxisVal]] = None) -> P:
    rules = rules if rules is not None else (current_rules() or {})
    return P(*(rules.get(a) if a is not None else None for a in axes))


def shard(x, *axes: Optional[str]):
    """Apply a sharding hint if rules are installed; identity otherwise."""
    rules = current_rules()
    mesh = current_mesh()
    if rules is None or mesh is None:
        return x
    spec = spec_for(axes, rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def tree_specs(axes_tree, rules: Optional[Dict[str, AxisVal]] = None):
    """Map a pytree of logical-axes tuples to a pytree of PartitionSpecs."""
    rules = rules if rules is not None else (current_rules() or {})
    return jax.tree.map(
        lambda axes: spec_for(axes, rules),
        axes_tree,
        is_leaf=lambda v: isinstance(v, tuple) and all(isinstance(a, (str, type(None))) for a in v),
    )


# Default rule sets ---------------------------------------------------------


def make_rules(
    *,
    multi_pod: bool,
    batch_shardable: bool = True,
    kv_heads_shardable: bool = True,
    fsdp: bool = True,
    seq_shard: bool = False,
) -> Dict[str, AxisVal]:
    dp: AxisVal = (("pod", "data") if multi_pod else ("data",)) if batch_shardable else None
    return {
        "batch": dp,
        "seq": ("data",) if seq_shard else None,
        "embed": "data" if fsdp else None,
        "heads": "model",
        "kv_heads": "model" if kv_heads_shardable else None,
        "ff": "model",
        "inner": "model",
        "vocab": "model",
        "expert": "data",
        "layers": None,
    }
