"""Distributed train / prefill / serve step builders.

``make_train_step`` produces a donatable, jit-able
    (params, opt_state, batch) -> (params, opt_state, metrics)
with microbatch gradient accumulation via ``lax.scan`` (batch arrives as
(accum, micro, ...)), f32 grad accumulation sharded like the params, and
AdamW.  Gradient cross-device reduction is GSPMD-automatic in ``auto`` mode;
``podwise`` mode (core of the nested-partition mapping) wraps the step in a
``shard_map`` that is *manual over the pod axis only*: gradients are
explicitly summed across the slow inter-pod link — optionally int8-
compressed with error feedback — while intra-pod sharding stays automatic.

Sharding specs for jit come from the logical-axes trees
(``LM.param_axes()``/``cache_axes()``) mapped through the active rule set.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.collectives import compressed_psum
from repro.models.common import ModelConfig
from repro.models.zoo import LM
from repro.optim import OptConfig, adamw_update
from repro.parallel.axes import logical_axis_rules, make_rules, tree_specs


# ---------------------------------------------------------------------------
# Sharding plumbing
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class StepShardings:
    params: Any  # pytree of NamedSharding
    opt: Any
    batch: Any
    cache: Any
    rules: Dict[str, Any]
    mesh: Mesh


def _named(mesh: Mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree, is_leaf=lambda x: isinstance(x, P)
    )


def batch_axes(cfg: ModelConfig, kind: str, accum: bool) -> Dict[str, tuple]:
    lead = ("accum",) if accum else ()
    if kind == "decode":
        ax: Dict[str, tuple] = {"tokens": ("batch",)}
        return ax
    if cfg.family == "audio":
        ax = {"features": lead + ("batch", None, None), "labels": lead + ("batch", None)}
    elif cfg.family == "vlm":
        ax = {
            "tokens": lead + ("batch", None),
            "patches": lead + ("batch", None, None),
            "labels": lead + ("batch", None),
        }
    else:
        ax = {"tokens": lead + ("batch", None), "labels": lead + ("batch", None)}
    if kind == "prefill":
        ax.pop("labels", None)
    return ax


def make_shardings(
    lm: LM,
    mesh: Mesh,
    *,
    kind: str,
    batch_shardable: bool = True,
    accum: bool = False,
    fsdp: bool = True,
) -> StepShardings:
    cfg = lm.cfg
    multi_pod = "pod" in mesh.axis_names
    rules = make_rules(
        multi_pod=multi_pod,
        batch_shardable=batch_shardable,
        kv_heads_shardable=(lm.plan is None or not lm.plan.kv_replicated),
        fsdp=fsdp,
    )
    rules["accum"] = None
    # elastic meshes may lack axes (e.g. resume on a data-only mesh):
    # degrade rules to whatever axes exist
    names = set(mesh.axis_names)
    for key, val in list(rules.items()):
        if isinstance(val, tuple):
            kept = tuple(a for a in val if a in names)
            rules[key] = kept if kept else None
        elif isinstance(val, str) and val not in names:
            rules[key] = None
    with logical_axis_rules(rules, mesh):
        pspecs = tree_specs(lm.param_axes())
        bspecs = tree_specs(batch_axes(cfg, kind, accum))
        cspecs = tree_specs(lm.cache_axes()) if kind == "decode" else None
    ospecs = {"m": pspecs, "v": pspecs, "step": P()}
    return StepShardings(
        params=_named(mesh, pspecs),
        opt=_named(mesh, ospecs),
        batch=_named(mesh, bspecs),
        cache=_named(mesh, cspecs) if cspecs is not None else None,
        rules=rules,
        mesh=mesh,
    )


# ---------------------------------------------------------------------------
# Train step
# ---------------------------------------------------------------------------


def make_train_step(
    lm: LM,
    opt_cfg: OptConfig,
    sh: StepShardings,
    *,
    grad_sync: str = "auto",  # auto | podwise | podwise_int8
) -> Callable:
    cfg = lm.cfg

    def loss_fn(params, mb):
        loss, metrics = lm.loss(params, mb)
        return loss, metrics

    # sharding hints use the full mesh under GSPMD-auto sync; inside the
    # manual-'pod' shard_map the ambient mesh has a Manual axis and full-mesh
    # NamedSharding hints are rejected -> hints off (outer jit shardings and
    # GSPMD propagation still pin the intra-pod layout)
    hint_mesh = sh.mesh if grad_sync == "auto" else None

    def accumulate(params, batch):
        """batch leaves: (A, micro, ...) -> mean grads/loss over A microbatches."""
        A = jax.tree.leaves(batch)[0].shape[0]

        def micro_step(carry, mb):
            gsum, lsum = carry
            with logical_axis_rules(sh.rules, hint_mesh):
                (loss, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
            gsum = jax.tree.map(lambda a, g: a + g.astype(jnp.float32), gsum, grads)
            return (gsum, lsum + loss), None

        gsum0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (gsum, lsum), _ = lax.scan(micro_step, (gsum0, jnp.zeros(())), batch)
        inv = 1.0 / A
        return jax.tree.map(lambda g: g * inv, gsum), lsum * inv

    def train_step(params, opt_state, batch):
        grads, loss = accumulate(params, batch)
        params, opt_state, om = adamw_update(params, grads, opt_state, opt_cfg)
        metrics = {"loss": loss, **om}
        return params, opt_state, metrics

    if grad_sync == "auto":
        return train_step

    # podwise: manual over the slow 'pod' axis, auto within the pod.
    if "pod" not in sh.mesh.axis_names:
        raise ValueError("podwise grad sync needs the multi-pod mesh")
    compress = grad_sync == "podwise_int8"
    auto_axes = frozenset(a for a in sh.mesh.axis_names if a != "pod")

    def podwise_step(params, opt_state, batch):
        grads, loss = accumulate(params, batch)  # grads: summed within pod (auto)
        # explicit slow-link exchange, 1/pod of bytes prepared by in-pod
        # sharding; int8 payload if requested (paper: minimize slow-link bytes)
        if compress:
            grads = jax.tree.map(lambda g: compressed_psum(g, "pod") / 2.0, grads)
        else:
            grads = jax.tree.map(lambda g: lax.pmean(g, "pod"), grads)
        params, opt_state, om = adamw_update(params, grads, opt_state, opt_cfg)
        return params, opt_state, {"loss": lax.pmean(loss, "pod"), **om}

    def _pod_only(spec: P) -> P:
        """Manual-subset shard_map specs may only mention the manual axis."""
        def f(entry):
            if entry is None:
                return None
            if isinstance(entry, (tuple, list)):
                kept = tuple(a for a in entry if a == "pod")
                return kept[0] if len(kept) == 1 else (kept if kept else None)
            return entry if entry == "pod" else None

        return P(*(f(e) for e in spec))

    def step(params, opt_state, batch):
        pod = lambda tree: jax.tree.map(
            lambda s: _pod_only(s.spec), tree, is_leaf=lambda x: isinstance(x, NamedSharding)
        )
        from repro.jax_compat import shard_map

        f = shard_map(
            podwise_step,
            mesh=sh.mesh,
            in_specs=(pod(sh.params), pod(sh.opt), pod(sh.batch)),
            out_specs=(pod(sh.params), pod(sh.opt), P()),
            check_vma=False,
            axis_names={"pod"},
        )
        return f(params, opt_state, batch)

    return step


# ---------------------------------------------------------------------------
# Serving steps
# ---------------------------------------------------------------------------


def make_prefill_step(lm: LM, sh: StepShardings) -> Callable:
    def prefill_step(params, batch):
        with logical_axis_rules(sh.rules, sh.mesh):
            logits, cache = lm.prefill(params, batch)
        return logits, cache

    return prefill_step


def make_serve_step(lm: LM, sh: StepShardings, *, masked: bool = False) -> Callable:
    """Greedy decode step.  ``masked=False`` is the classic one-shot batch
    step ``(params, cache, tokens) -> (next_tok, cache)``.

    ``masked=True`` is the continuous-batching variant the serving loop
    (``repro.runtime.serving``) drives: ``(params, cache, tokens, active)``
    where ``active`` is a per-row bool compaction/refill mask.  Inactive
    rows (finished / not-yet-refilled slots) hold their token and their
    per-row cache position (``cache["len"]``, a ``(B,)`` vector) frozen, so
    a freed row idles in place until a newly admitted request's prefill
    cache is spliced over it.  Active rows run the exact same arithmetic as
    the unmasked step — rows are computationally independent, which is what
    makes a mid-loop splice bitwise-identical to a fresh batch.
    """
    cfg = lm.cfg

    def serve_step(params, cache, tokens):
        with logical_axis_rules(sh.rules, sh.mesh):
            logits, cache = lm.decode_step(params, cache, tokens)
        # greedy over the *logical* vocab
        logits = jnp.where(
            jnp.arange(logits.shape[-1]) < cfg.vocab_size, logits, -jnp.inf
        )
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_tok, cache

    if not masked:
        return serve_step

    def serve_step_masked(params, cache, tokens, active):
        next_tok, new_cache = serve_step(params, cache, tokens)
        next_tok = jnp.where(active, next_tok, tokens)
        # inactive rows do not advance their cache position (their slot-len
        # write above lands harmlessly and is fully overwritten on refill)
        new_cache["len"] = jnp.where(active, new_cache["len"], cache["len"])
        return next_tok, new_cache

    return serve_step_masked


# ---------------------------------------------------------------------------
# Microbatch layout helper
# ---------------------------------------------------------------------------


def accum_layout(global_batch: int, dp: int, target_per_device: int = 1) -> Tuple[int, int]:
    """(accum_steps, micro_batch): micro spread over dp, ~target/device."""
    micro = max(dp * target_per_device, 1)
    micro = min(micro, global_batch)
    while global_batch % micro:
        micro -= 1
    return global_batch // micro, micro
