"""Data pipeline determinism — the property elastic restart relies on."""

import numpy as np

from repro.configs.shapes import ShapeSpec, smoke_config
from repro.data import SyntheticPipeline, make_batch
from repro.models.zoo import get_config

SHAPE = ShapeSpec("t", seq_len=32, global_batch=8, kind="train")


def test_batches_deterministic_across_builders():
    cfg = smoke_config(get_config("qwen2-7b"))
    b1 = make_batch(cfg, SHAPE, 17, seed=3, accum=2, micro=4)
    b2 = make_batch(cfg, SHAPE, 17, seed=3, accum=2, micro=4)
    for k in b1:
        np.testing.assert_array_equal(b1[k], b2[k])
    b3 = make_batch(cfg, SHAPE, 18, seed=3, accum=2, micro=4)
    assert not np.array_equal(b1["tokens"], b3["tokens"])


def test_labels_are_next_token():
    cfg = smoke_config(get_config("qwen2-7b"))
    b = make_batch(cfg, SHAPE, 0, accum=1, micro=8)
    np.testing.assert_array_equal(b["labels"][..., :-1], b["tokens"][..., 1:])
    assert (b["labels"][..., -1] == -1).all()


def test_pipeline_matches_direct_and_resumes():
    cfg = smoke_config(get_config("qwen2-7b"))
    p = SyntheticPipeline(cfg, SHAPE, seed=1, accum=1, micro=8, start_step=5)
    try:
        s, b = next(p)
        assert s == 5
        direct = make_batch(cfg, SHAPE, 5, seed=1, accum=1, micro=8)
        np.testing.assert_array_equal(b["tokens"], direct["tokens"])
        s2, _ = next(p)
        assert s2 == 6
    finally:
        p.close()


def test_modalities():
    for arch in ("llava-next-34b", "hubert-xlarge"):
        cfg = smoke_config(get_config(arch))
        b = make_batch(cfg, SHAPE, 0, accum=1, micro=8)
        if cfg.family == "vlm":
            assert b["patches"].shape == (1, 8, cfg.frontend_tokens, 1024)
            assert b["tokens"].shape == (1, 8, SHAPE.seq_len - cfg.frontend_tokens)
        else:
            assert b["features"].shape == (1, 8, SHAPE.seq_len, cfg.d_model)
