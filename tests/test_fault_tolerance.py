"""Chaos tests for the fault-tolerant elastic runtime (RunSupervisor).

The acceptance invariant, in every scenario: a supervised fused run that
suffers injected failures, restores, straggler ejections or node
join/leave lands on a final ``q`` BITWISE identical to an uninterrupted
fused run — because the field update is split-independent (a nested
partition is a reordering, never an approximation) and the LSRK stage
residual resets every step (any chunk boundary is bitwise-safe).  And the
recovery machinery never un-fuses the loop: the supervisor's dispatch
ledger stays at exactly one dispatch (one volume + one surface launch) per
chunk, replays included.
"""

import time

import numpy as np
import pytest

import jax.numpy as jnp

from hypothesis_shim import given, settings, st
from repro.dg.mesh import make_brick
from repro.dg.solver import DGSolver
from repro.runtime import (
    FailureInjector,
    InjectedFailure,
    NodeProfile,
    RunSupervisor,
    SimulatedCluster,
    StepTimer,
    resume_engine,
)
from repro.runtime.executor import BlockedDGEngine, NestedPartitionExecutor

N_STEPS = 8


def _solver(grid=(4, 4, 2)):
    mesh = make_brick(grid, (1.0, 1.0, 0.5), periodic=True)
    K = mesh.K
    return DGSolver(mesh=mesh, order=2, rho=np.ones(K), lam=np.ones(K), mu=np.zeros(K))


def _rand_state(solver, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(
        rng.standard_normal((solver.mesh.K, 9, solver.M, solver.M, solver.M))
    )


@pytest.fixture(scope="module")
def setup():
    """One solver + the uninterrupted fused reference shared by every chaos
    scenario (bitwise targets; compiles are the expensive part)."""
    solver = _solver()
    q0 = _rand_state(solver)
    dt = solver.cfl_dt()
    ref_eng = _engine(solver)
    q_ref = np.asarray(ref_eng.run(q0, N_STEPS, dt=dt, observe=True))
    return solver, q0, dt, q_ref


def _engine(solver, P=3, rebalance_every=2):
    ex = NestedPartitionExecutor(solver.mesh.K, P, grid_dims=solver.mesh.grid,
                                 bucket=8, rebalance_every=rebalance_every,
                                 smoothing=1.0)
    return BlockedDGEngine(solver, ex)


def _cluster(solver, P=3, **kw):
    return SimulatedCluster(solver, [NodeProfile(name=f"n{i}") for i in range(P)],
                            rebalance_every=2, **kw)


# ---------------------------------------------------------------------------
# checkpoint / replay
# ---------------------------------------------------------------------------


def test_transient_failure_retried_bitwise(setup):
    """A transient chunk failure is absorbed by retry (no restore) and the
    final q is bitwise the uninterrupted run's."""
    solver, q0, dt, q_ref = setup
    sup = RunSupervisor(_engine(solver), injector=FailureInjector({2: "transient"}),
                        max_retries=2)
    q = np.asarray(sup.run(q0, N_STEPS, dt=dt))
    assert (q == q_ref).all()
    assert sup.retries == 1 and sup.restarts == 0


def test_restore_replay_bitwise_in_memory(setup):
    """With retries exhausted the supervisor restores the last snapshot and
    replays — still bitwise, exactly one restart."""
    solver, q0, dt, q_ref = setup
    sup = RunSupervisor(_engine(solver), injector=FailureInjector({4: "node-loss"}),
                        max_retries=0, ckpt_every_chunks=1)
    q = np.asarray(sup.run(q0, N_STEPS, dt=dt))
    assert (q == q_ref).all()
    assert sup.restarts == 1 and sup.retries == 0


def test_restore_replay_bitwise_on_disk(setup, tmp_path):
    """Same, with snapshots persisted through repro.checkpoint: the replayed
    steps are accounted, and retention keeps the directory pruned."""
    from repro.checkpoint import latest_step

    solver, q0, dt, q_ref = setup
    d = str(tmp_path / "ck")
    sup = RunSupervisor(_engine(solver), ckpt_dir=d, ckpt_every_chunks=2, keep=2,
                        injector=FailureInjector({6: "preempt"}), max_retries=0)
    q = np.asarray(sup.run(q0, N_STEPS, dt=dt))
    assert (q == q_ref).all()
    assert sup.restarts == 1
    # failed at step 6, last snapshot at step 4 (every 2 chunks of 2): the
    # 2 steps in between were replayed
    assert sup.replayed_steps == 2
    assert latest_step(d) == N_STEPS
    import os

    assert sum(n.startswith("step_") for n in os.listdir(d)) <= 2


def test_resume_in_new_engine_with_different_partition_count(setup, tmp_path):
    """The elastic-restart property lifted to the DG engines: a snapshot
    written by a P=2 fleet is resumed by a P=3 fleet (q is split-
    independent) and finishes bitwise."""
    solver, q0, dt, q_ref = setup
    d = str(tmp_path / "ck")
    sup_a = RunSupervisor(_engine(solver, P=2), ckpt_dir=d, ckpt_every_chunks=1)
    sup_a.run(q0, 4, dt=dt)

    eng_b = _engine(solver, P=3)
    q_mid, step, meta = resume_engine(d, eng_b.executor)
    assert step == 4 and meta["counts"] and len(meta["counts"]) == 2
    sup_b = RunSupervisor(eng_b, ckpt_dir=d)
    q = np.asarray(sup_b.run(q_mid, N_STEPS - step, dt=dt, start_step=step))
    assert (q == q_ref).all()


# ---------------------------------------------------------------------------
# straggler ejection / readmission
# ---------------------------------------------------------------------------


def test_straggler_ejected_and_work_rehomed(setup):
    """A persistent straggler (simulated 10x slowdown) is flagged by the
    StepTimer, ejected (weight -> 0, zero cells) and the survivors absorb
    its work — with the final q still bitwise."""
    solver, q0, dt, q_ref = setup
    cl = _cluster(solver)
    cl.inject_straggler(1, 10.0)
    sup = RunSupervisor(cl, timer=StepTimer(alpha=1.0, straggler_factor=1.5),
                        eject_after=1)
    q = np.asarray(sup.run(q0, N_STEPS, dt=dt))
    assert (q == q_ref).all()
    assert sup.ejected == [1]
    counts = cl.executor.counts
    assert counts[1] == 0 and counts.sum() == solver.mesh.K


def test_ejection_is_not_sticky_readmit_resplices(setup):
    """readmit() undoes an ejection: the node gets cells again and the run
    stays bitwise (recovery path of satellite (a))."""
    solver, q0, dt, q_ref = setup
    cl = _cluster(solver)
    cl.inject_straggler(1, 10.0)
    # eject_after=2 so one stale-EWMA chunk after readmission can't
    # immediately re-eject while the executor's smoothing decays
    sup = RunSupervisor(cl, timer=StepTimer(alpha=1.0, straggler_factor=1.5),
                        eject_after=2)
    sup.at_step(6, lambda: (cl.clear_stragglers(), sup.readmit(1)))
    q = np.asarray(sup.run(q0, N_STEPS, dt=dt))
    assert (q == q_ref).all()
    assert cl.executor.counts[1] > 0 and not cl.executor.ejected


def test_eject_never_empties_the_fleet(setup):
    """The executor refuses to eject the last live partition."""
    solver, q0, dt, _ = setup
    cl = _cluster(solver, P=2)
    cl.executor.eject(0)
    with pytest.raises(RuntimeError):
        cl.executor.eject(1)


# ---------------------------------------------------------------------------
# elastic membership
# ---------------------------------------------------------------------------


def test_node_join_and_leave_mid_run_bitwise(setup):
    """add_node / remove_node between chunks: the fleet grows to 4 then
    shrinks to 3 mid-run, every chunk stays one dispatch, q is bitwise."""
    solver, q0, dt, q_ref = setup
    cl = _cluster(solver)
    sup = RunSupervisor(cl)
    sup.at_step(3, lambda: cl.add_node(NodeProfile(name="n3")))
    sup.at_step(6, lambda: cl.remove_node(1))
    q = np.asarray(sup.run(q0, N_STEPS, dt=dt))
    assert (q == q_ref).all()
    assert cl.n_nodes == 3
    assert cl.executor.counts.sum() == solver.mesh.K
    led = sup.ledger()
    assert led["dispatches"] == led["chunks_run"] == sup.chunks_run


def test_node_fault_injected_inside_cluster_dispatch(setup):
    """The injector generalized into SimulatedCluster: a targeted node
    fault raised at the node's dispatch is retried by the supervisor."""
    solver, q0, dt, q_ref = setup
    cl = _cluster(solver, injector=FailureInjector({2: ("transient", 1)}))
    sup = RunSupervisor(cl, max_retries=2)
    q = np.asarray(sup.run(q0, N_STEPS, dt=dt))
    assert (q == q_ref).all()
    assert sup.retries == 1 and cl.injector.injected == 1


# ---------------------------------------------------------------------------
# the dispatch ledger: recovery never un-fuses
# ---------------------------------------------------------------------------


def test_recovery_never_unfuses_the_loop(setup):
    """After retries, a restore AND a membership change, the ledger still
    shows exactly one dispatch per chunk run (replays included) and one
    volume + one surface launch inside each."""
    solver, q0, dt, q_ref = setup
    cl = _cluster(solver)
    sup = RunSupervisor(cl, injector=FailureInjector({4: "node-loss"}), max_retries=0,
                        ckpt_every_chunks=1)
    sup.at_step(6, lambda: cl.add_node(NodeProfile(name="n3")))
    q = np.asarray(sup.run(q0, N_STEPS, dt=dt))
    assert (q == q_ref).all()
    assert sup.restarts == 1
    led = sup.ledger()
    assert led["dispatches"] == sup.chunks_run
    assert led["observe_chunks"] == sup.chunks_run
    assert led["kernel_launches"] == {"volume": 1, "surface": 1}


# ---------------------------------------------------------------------------
# retry / timeout / backoff mechanics (pure-python fake engine)
# ---------------------------------------------------------------------------


class _FakeExecutor:
    def __init__(self, rebalance_every=2):
        self.counts = np.array([4])
        self.weights = np.array([1.0])
        self.round = 0
        self._step = 0
        self.ejected = set()
        self._ewma = None
        self.rebalance_every = rebalance_every
        self.n_partitions = 1

    def restore_state(self, state):
        self._step = int(state["exec_step"])


class _FakeEngine:
    """q' = q + n: enough to check the supervisor's control flow exactly."""

    def __init__(self, sleep_first=0.0):
        self.executor = _FakeExecutor()
        self.calls = 0
        self.sleep_first = sleep_first

    def run(self, q, n, dt=None, observe=True, fused=True):
        self.calls += 1
        if self.calls == 1 and self.sleep_first:
            time.sleep(self.sleep_first)
        self.executor._step += n
        return q + n


def test_chunk_timeout_escalates_to_restore():
    """A chunk overrunning chunk_timeout_s counts as a failure: retried,
    then restored — and the replay (fast) completes the run."""
    eng = _FakeEngine(sleep_first=0.25)
    sup = RunSupervisor(eng, chunk_timeout_s=0.1, max_retries=0)
    q = sup.run(0.0, 6)
    assert q == 6.0
    assert sup.timeouts >= 1 and sup.restarts >= 1


def test_backoff_sleeps_between_retries():
    eng = _FakeEngine()
    sup = RunSupervisor(eng, injector=FailureInjector({0: "flaky"}),
                        max_retries=2, backoff_s=0.01, backoff_factor=2.0)
    q = sup.run(0.0, 4)
    assert q == 4.0
    assert sup.retries == 1 and sup.recovery_s >= 0.01


def test_injected_failure_carries_class_and_node():
    inj = FailureInjector({3: ("preempt", 2)})
    with pytest.raises(InjectedFailure) as e:
        inj.maybe_fail(3, node=2)
    assert e.value.step == 3 and e.value.kind == "preempt" and e.value.node == 2
    inj.maybe_fail(3, node=2)  # fires at most once per step


# ---------------------------------------------------------------------------
# property: ANY failure/eject/join sequence is bitwise
# ---------------------------------------------------------------------------


@settings(max_examples=4, deadline=None)
@given(
    fail_steps=st.lists(st.integers(min_value=0, max_value=N_STEPS - 1),
                        max_size=2, unique=True),
    event=st.sampled_from(["none", "join", "leave", "eject"]),
    persist=st.booleans(),
)
def test_any_chaos_sequence_lands_bitwise(setup, tmp_path_factory, fail_steps,
                                          event, persist):
    """Fuzz the whole machine: an arbitrary mix of injected chunk failures
    (forcing restores), a membership event and snapshot persistence must
    always land on the uninterrupted run's q, with the ledger fused."""
    solver, q0, dt, q_ref = setup
    cl = _cluster(solver)
    kw = {}
    if persist:
        kw["ckpt_dir"] = str(tmp_path_factory.mktemp("chaos"))
    sup = RunSupervisor(cl, injector=FailureInjector({s: "chaos" for s in fail_steps}),
                        max_retries=0, ckpt_every_chunks=1, **kw)
    if event == "join":
        sup.at_step(3, lambda: cl.add_node(NodeProfile(name="nx")))
    elif event == "leave":
        sup.at_step(4, lambda: cl.remove_node(1))
    elif event == "eject":
        sup.at_step(2, lambda: cl.executor.eject(1))
    q = np.asarray(sup.run(q0, N_STEPS, dt=dt))
    assert (q == q_ref).all()
    # only failures landing on a chunk start are probed (membership events
    # shift the boundaries), so restarts is bounded, not exact
    assert sup.restarts <= len(fail_steps)
    led = sup.ledger()
    assert led["dispatches"] == sup.chunks_run == led["observe_chunks"]
