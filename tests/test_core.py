"""Core nested-partition library: invariants, load balancing, cost models."""

import numpy as np
import pytest

from hypothesis_shim import given, settings, st

from repro.core import (
    build_nested_partition,
    face_neighbors,
    hierarchical_splice,
    morton_order,
    rebalance_from_measurements,
    solve_multiway,
    solve_two_way,
    splice,
    surface_faces,
)
from repro.core.cost_model import (
    DGWorkModel,
    offload_volume_bytes,
    shared_face_bytes,
    stampede_node_models,
    transfer_time_fn,
)
grids = st.tuples(st.integers(2, 6), st.integers(2, 6), st.integers(2, 6))


@given(grids)
@settings(max_examples=20, deadline=None)
def test_morton_is_permutation(grid):
    order = morton_order(grid)
    K = int(np.prod(grid))
    assert sorted(order.tolist()) == list(range(K))


@given(st.integers(1, 500), st.integers(1, 8))
@settings(max_examples=30, deadline=None)
def test_splice_conserves(n, p):
    offs = splice(n, n_parts=p)
    sizes = np.diff(offs)
    assert sizes.sum() == n and (sizes >= 0).all()
    assert sizes.max() - sizes.min() <= 1  # equal weights -> near-equal parts


@given(st.integers(10, 300), st.lists(st.floats(0.1, 10), min_size=2, max_size=5))
@settings(max_examples=30, deadline=None)
def test_splice_proportional(n, weights):
    offs = splice(n, weights)
    sizes = np.diff(offs)
    assert sizes.sum() == n
    ideal = n * np.asarray(weights) / np.sum(weights)
    assert np.abs(sizes - ideal).max() < 1.0 + 1e-9  # largest-remainder bound


@given(grids, st.integers(1, 6), st.floats(0.0, 1.0))
@settings(max_examples=25, deadline=None)
def test_nested_partition_invariants(grid, n_nodes, frac):
    K = int(np.prod(grid))
    if K < n_nodes:
        n_nodes = K
    part = build_nested_partition(grid, n_nodes, accel_fraction=frac)
    part.validate()  # every element exactly once; accel subset of interior
    # boundary definition: face neighbour on another node
    nbr = face_neighbors(grid)
    for e in range(K):
        nbrs = nbr[e][nbr[e] >= 0]
        is_b = (part.node_of[nbrs] != part.node_of[e]).any() if len(nbrs) else False
        assert bool(part.boundary_mask[e]) == bool(is_b)


def test_morton_locality_beats_random():
    """Morton splices should cut fewer faces than random assignment."""
    grid = (8, 8, 8)
    nbr = face_neighbors(grid)
    part = build_nested_partition(grid, 8)
    cut_m = sum(
        surface_faces(np.isin(np.arange(512), p.elements), nbr) for p in part.nodes
    )
    rng = np.random.default_rng(0)
    assign = rng.integers(0, 8, 512)
    cut_r = sum(surface_faces(assign == i, nbr) for i in range(8))
    assert cut_m < 0.6 * cut_r, (cut_m, cut_r)


def test_hierarchical_splice_nests():
    levels = hierarchical_splice(100, [[1, 1], [1, 1, 1]])
    assert levels[0][0][-1] == 100
    total = sum(int(o[-1] - o[0]) for o in levels[1])
    assert total == 100


def test_hierarchical_splice_degenerate_level():
    """A single-part level is a pass-through: the chunks below it are the
    same as if the level were absent."""
    levels = hierarchical_splice(97, [[1], [2, 1, 1]])
    # level 0 is the whole array in one chunk
    np.testing.assert_array_equal(levels[0][0], [0, 97])
    flat = hierarchical_splice(97, [[2, 1, 1]])
    np.testing.assert_array_equal(levels[1][0], flat[0][0])
    # degenerate level at the bottom: every chunk survives unsplit
    levels2 = hierarchical_splice(97, [[2, 1, 1], [1]])
    sizes_top = np.diff(levels2[0][0])
    sizes_bot = [int(o[-1] - o[0]) for o in levels2[1]]
    np.testing.assert_array_equal(sizes_top, sizes_bot)


def test_choose_accel_block_empty_and_full():
    """n_accel=0 offloads nothing; n_accel=len(interior) offloads all of it
    (the two clamp ends of the paper's level-2 split)."""
    from repro.core.partition import _choose_accel_block

    grid = (4, 4, 4)
    nbr = face_neighbors(grid)
    interior = np.arange(64, dtype=np.int64)
    accel, rest = _choose_accel_block(interior, 0, nbr)
    assert len(accel) == 0
    np.testing.assert_array_equal(rest, interior)
    accel, rest = _choose_accel_block(interior, 64, nbr)
    np.testing.assert_array_equal(accel, interior)
    assert len(rest) == 0
    # over-asking is clamped the same as asking for everything
    accel, rest = _choose_accel_block(interior, 100, nbr)
    np.testing.assert_array_equal(accel, interior)


def test_build_partition_accel_extremes():
    """build_nested_partition at accel_fraction 0 and 1: the offload is
    empty / exactly the interior, and the invariants still hold."""
    part0 = build_nested_partition((6, 4, 4), 3, accel_fraction=0.0)
    part0.validate()
    assert part0.accel_mask.sum() == 0
    part1 = build_nested_partition((6, 4, 4), 3, accel_fraction=1.0)
    part1.validate()
    for node in part1.nodes:
        # everything offloadable (= the whole interior) is offloaded
        assert len(node.host_interior) == 0
        np.testing.assert_array_equal(np.sort(node.accel), np.sort(node.interior))


def test_partition_boundary_interior_disjoint_cover_and_halo():
    """Each node's boundary/interior sets are a disjoint cover of its chunk,
    and the halo is exactly the remote face-adjacent elements."""
    grid = (6, 4, 4)
    part = build_nested_partition(grid, 4, accel_fraction=0.4)
    part.validate()  # includes the cover + halo invariants
    nbr = face_neighbors(grid)
    for node in part.nodes:
        both = np.concatenate([node.boundary, node.interior])
        assert len(np.unique(both)) == len(both)  # disjoint
        np.testing.assert_array_equal(np.sort(both), np.sort(node.elements))
        # every boundary element really owns a cross-node face
        for e in node.boundary:
            nbrs = nbr[e][nbr[e] >= 0]
            assert (part.node_of[nbrs] != node.node).any()
        # halo elements live on other nodes and touch this chunk
        assert (part.node_of[node.halo] != node.node).all()
        in_chunk = np.zeros(part.n_elements, dtype=bool)
        in_chunk[node.elements] = True
        for h in node.halo:
            hn = nbr[h][nbr[h] >= 0]
            assert in_chunk[hn].any()


# ---------------------------------------------------------------------------
# Load balancing (paper section 5.6)
# ---------------------------------------------------------------------------


def test_stampede_split_matches_paper():
    """The published optimum: K_MIC/K_CPU ~= 1.6 on the paper's node."""
    t_cpu, t_mic, xfer = stampede_node_models(order=7)
    res = solve_two_way(t_cpu, t_mic, 8192, transfer=xfer)
    assert 1.45 <= res.ratio <= 1.85, res.ratio
    assert res.imbalance < 1.01  # both sides finish together


def test_two_way_caps_at_interior():
    t_cpu, t_mic, xfer = stampede_node_models(order=7)
    res = solve_two_way(t_cpu, t_mic, 8192, transfer=xfer, K_accel_max=1000)
    assert res.counts[1] == 1000  # accelerator capped by interior count


@given(st.integers(100, 5000), st.lists(st.floats(0.2, 5.0), min_size=2, max_size=6))
@settings(max_examples=25, deadline=None)
def test_multiway_equalizes(K, speeds):
    fns = [lambda k, s=s: k / s for s in speeds]
    res = solve_multiway(fns, K)
    assert sum(res.counts) == K
    times = [fns[i](res.counts[i]) for i in range(len(speeds))]
    # near-equal finish (integer rounding slack)
    assert max(times) - min(times) <= max(1.0 / min(speeds), 0.02 * max(times))


@given(st.floats(1.1, 10.0))
@settings(max_examples=20, deadline=None)
def test_two_way_monotone_in_speed(speedup):
    t1 = lambda k: k * 1.0
    t2 = lambda k: k / speedup
    res = solve_two_way(t1, t2, 1000)
    assert res.counts[1] > res.counts[0]  # faster device gets more work
    res_faster = solve_two_way(t1, lambda k: k / (speedup * 2), 1000)
    assert res_faster.counts[1] >= res.counts[1]


def test_rebalance_from_measurements_shifts_work():
    w = rebalance_from_measurements([100, 100], [2.0, 1.0], smoothing=1.0)
    assert w[1] > w[0]  # the 2x-faster partition gets more
    np.testing.assert_allclose(w.sum(), 1.0)


# --- solve_multiway edge cases ---------------------------------------------


def test_multiway_single_partition_fleet():
    res = solve_multiway([lambda k: k * 2.0], 77)
    assert res.counts == (77,)
    assert res.makespan == pytest.approx(154.0)


def test_multiway_zero_weight_partition():
    """A partition whose fixed cost exceeds any useful finish time gets no
    work; the others split everything."""
    fns = [lambda k: k, lambda k: k, lambda k: 1e12 + k]
    res = solve_multiway(fns, 1000)
    assert sum(res.counts) == 1000
    assert res.counts[2] == 0
    assert abs(res.counts[0] - res.counts[1]) <= 1


def test_multiway_all_equal_speeds_splits_evenly():
    res = solve_multiway([lambda k: k] * 4, 1000)
    assert sum(res.counts) == 1000
    assert max(res.counts) - min(res.counts) <= 1


def test_rebalance_zero_count_partition_gets_prior():
    """A partition that had zero work gets the mean throughput as a prior
    instead of a division blow-up."""
    w = rebalance_from_measurements([0, 100], [1.0, 1.0], smoothing=1.0)
    assert np.isfinite(w).all() and w.sum() == pytest.approx(1.0)
    assert w[0] > 0


def test_rebalance_all_zero_counts_keeps_prior():
    w = rebalance_from_measurements([0, 0], [1.0, 1.0], smoothing=1.0)
    np.testing.assert_allclose(w, [0.5, 0.5])
    w2 = rebalance_from_measurements([0, 0], [1.0, 1.0], prev_weights=[0.3, 0.7])
    np.testing.assert_allclose(w2, [0.3, 0.7])


def test_rebalance_converges_on_injected_straggler():
    """The paper's equalizer, iterated: a 2x straggler is rebalanced to a
    near-optimal split within 3 rounds (EWMA smoothing 0.5)."""
    K = 512
    speeds = np.array([0.5, 1.0])  # p0 suffers a 2x slowdown
    counts = np.array([K // 2, K // 2])
    weights = np.array([0.5, 0.5])
    optimum = K / speeds.sum()
    for _ in range(3):
        times = counts / speeds
        weights = rebalance_from_measurements(counts, times, smoothing=0.5,
                                              prev_weights=weights)
        counts = np.diff(splice(K, weights))
    makespan = float((counts / speeds).max())
    assert makespan <= 1.10 * optimum, (makespan, optimum)


# --- solve_hierarchical: the cluster level, golden values ------------------


def _stampede_nodes(n, order=7, inter=None):
    from repro.core import NodeModel

    t_cpu, t_mic, xfer = stampede_node_models(order)
    return [NodeModel(t_host=t_cpu, t_accel=t_mic, transfer=xfer,
                      inter_transfer=inter)] * n


def test_hierarchical_reproduces_paper_ratio_for_any_node_count():
    """Golden value: the published per-node optimum K_MIC/K_CPU ~= 1.6 is a
    *node* property — the hierarchical solve must reproduce it regardless of
    how many nodes the fleet has."""
    from repro.core import solve_hierarchical

    for n in (1, 2, 4, 8):
        hs = solve_hierarchical(_stampede_nodes(n), 8192)
        assert sum(hs.node_counts) == 8192
        for r in hs.ratios:
            assert 1.45 <= r <= 1.85, (n, r)
        # uniform nodes -> near-uniform level-1 split
        assert max(hs.node_counts) - min(hs.node_counts) <= 1


def test_hierarchical_makespan_monotone_in_nodes():
    """Golden shape: on uniform work the modeled makespan decreases strictly
    monotonically as nodes are added (strong scaling of the model)."""
    from repro.core import solve_hierarchical

    prev = None
    for n in (1, 2, 4, 8, 16):
        hs = solve_hierarchical(_stampede_nodes(n), 8192)
        if prev is not None:
            assert hs.makespan < prev, (n, hs.makespan, prev)
        prev = hs.makespan


def test_hierarchical_n1_equals_single_node_two_way():
    """The N=1 hierarchical solve IS the existing single-node calibrated
    solve — same split, same makespan."""
    from repro.core import solve_hierarchical

    t_cpu, t_mic, xfer = stampede_node_models(order=7)
    two_way = solve_two_way(t_cpu, t_mic, 8192, transfer=xfer)
    hs = solve_hierarchical(_stampede_nodes(1), 8192)
    assert hs.node_counts == (8192,)
    assert hs.node_splits[0].counts == two_way.counts
    assert hs.makespan == pytest.approx(two_way.makespan, rel=1e-12)


def test_hierarchical_heterogeneous_nodes_split_by_throughput():
    """A node twice as fast (host and accel both) gets ~2x the elements at
    level 1, and both nodes keep the per-node optimum at level 2."""
    from repro.core import NodeModel, solve_hierarchical

    t_cpu, t_mic, xfer = stampede_node_models(order=7)
    fast = NodeModel(t_host=lambda k: t_cpu(k) / 2, t_accel=lambda k: t_mic(k) / 2,
                     transfer=xfer)
    slow = NodeModel(t_host=t_cpu, t_accel=t_mic, transfer=xfer)
    hs = solve_hierarchical([slow, fast], 8192)
    assert hs.node_counts[1] / hs.node_counts[0] == pytest.approx(2.0, rel=0.15)
    assert hs.imbalance < 1.05


def test_hierarchical_host_only_node_degenerates():
    """A node without an accelerator is a valid degenerate NodeModel: its
    inner split offloads nothing and its time model is plain t_host."""
    from repro.core import NodeModel, solve_hierarchical

    nodes = [NodeModel(t_host=lambda k: k * 1e-6),
             NodeModel(t_host=lambda k: k * 1e-6, t_accel=lambda k: k * 1e-6)]
    hs = solve_hierarchical(nodes, 1000)
    assert hs.node_splits[0].counts[1] == 0  # nothing offloaded
    assert hs.node_counts[1] > hs.node_counts[0]  # the accel node is faster
    assert sum(hs.node_counts) == 1000
    with pytest.raises(ValueError):
        solve_hierarchical([], 100)


def test_weak_scaling_benchmark_n1_anchors_to_single_node():
    """Acceptance: the table6_1 weak-scaling N=1 row matches the existing
    single-node calibrated makespan, and speedup decays monotonically as
    communication enters."""
    from benchmarks.table6_1_speedup import weak_scaling_rows

    t_cpu, t_mic, xfer = stampede_node_models(order=7)
    single = solve_two_way(t_cpu, t_mic, 8192, transfer=xfer).makespan
    rows = weak_scaling_rows(node_counts=(1, 2, 4))
    n1 = rows[0]
    assert n1[0] == 1
    assert n1[2] == pytest.approx(single, rel=1e-9)
    speedups = [b / o for _, b, o, _ in rows]
    assert all(s > 1.0 for s in speedups)
    assert speedups == sorted(speedups, reverse=True)  # decays with nodes


def test_surface_vs_volume_transfer():
    """The paper's core argument: interior-offload face bytes << task-offload
    volume bytes (O(K^2/3) vs O(K))."""
    K, order = 8192, 7
    assert shared_face_bytes(K, order) < 0.05 * offload_volume_bytes(K, order)


def test_workmodel_scaling():
    w7, w3 = DGWorkModel(order=7), DGWorkModel(order=3)
    assert w7.total_flops_per_element() > w3.total_flops_per_element() * 8
    # per-step transfer is monotone in K
    xfer = transfer_time_fn(7)
    assert xfer(1000) < xfer(4000) and xfer(0) == 0.0
