import jax

# f64 for the DG physics tests; LM smoke configs set their dtypes explicitly.
# NOTE: no xla_force_host_platform_device_count here — tests see 1 real
# device; multi-device tests spawn subprocesses with their own XLA_FLAGS.
jax.config.update("jax_enable_x64", True)

import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_with_devices(code: str, n_devices: int = 4, timeout: int = 600) -> str:
    """Run a python snippet in a subprocess with fake devices; returns stdout.
    Raises on nonzero exit (assertion failures propagate)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    r = subprocess.run(
        [sys.executable, "-c", code], env=env, capture_output=True, text=True, timeout=timeout
    )
    if r.returncode != 0:
        raise AssertionError(f"subprocess failed:\nSTDOUT:{r.stdout}\nSTDERR:{r.stderr}")
    return r.stdout


@pytest.fixture
def subproc():
    return run_with_devices
