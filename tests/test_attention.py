"""Flash attention (lax path) vs the naive oracle across modes and shapes."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import (
    decode_attention,
    flash_attention,
    naive_attention,
    pick_block,
    update_cache,
)


def _rand(key, *shape):
    return jax.random.normal(key, shape, jnp.float32)


@pytest.mark.parametrize("Hq,Hkv", [(4, 4), (4, 2), (8, 1)])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_matches_naive(Hq, Hkv, causal):
    k0, k1, k2 = jax.random.split(jax.random.PRNGKey(0), 3)
    B, S, D = 2, 192, 32
    q = _rand(k0, B, Hq, S, D)
    k = _rand(k1, B, Hkv, S, D)
    v = _rand(k2, B, Hkv, S, D)
    o1 = naive_attention(q, k, v, causal=causal)
    o2 = flash_attention(q, k, v, causal=causal, block_q=64, block_k=64)
    np.testing.assert_allclose(o1, o2, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("window", [16, 48, 200])
def test_swa_matches_naive(window):
    k0, k1, k2 = jax.random.split(jax.random.PRNGKey(1), 3)
    B, H, S, D = 1, 2, 256, 16
    q, k, v = _rand(k0, B, H, S, D), _rand(k1, B, H, S, D), _rand(k2, B, H, S, D)
    o1 = naive_attention(q, k, v, causal=True, window=window)
    o2 = flash_attention(q, k, v, causal=True, window=window, block_q=32, block_k=32)
    np.testing.assert_allclose(o1, o2, rtol=2e-4, atol=2e-4)


def test_kv_map_matches_naive():
    k0, k1, k2 = jax.random.split(jax.random.PRNGKey(2), 3)
    B, S, D = 2, 128, 16
    q = _rand(k0, B, 5, S, D)
    k = _rand(k1, B, 2, S, D)
    v = _rand(k2, B, 2, S, D)
    kv_map = [0, 0, 0, 1, 1]
    o1 = naive_attention(q, k, v, causal=True, kv_map=kv_map)
    o2 = flash_attention(q, k, v, causal=True, kv_map=kv_map, block_q=32, block_k=32)
    np.testing.assert_allclose(o1, o2, rtol=2e-4, atol=2e-4)


def test_pick_block_divides():
    for n in (48, 100, 4224, 524288):
        for t in (32, 128, 512):
            b = pick_block(n, t)
            assert n % b == 0 and 1 <= b <= t


def test_rolling_cache_decode():
    k0, k1, k2 = jax.random.split(jax.random.PRNGKey(3), 3)
    B, H, S, D, W = 2, 2, 96, 16, 32
    q = _rand(k0, B, H, S, D)
    k = _rand(k1, B, H, S, D)
    v = _rand(k2, B, H, S, D)
    kr = jnp.zeros((B, H, W, D))
    vr = jnp.zeros((B, H, W, D))
    for t in range(S):
        kr, vr = update_cache(kr, vr, k[:, :, t : t + 1], v[:, :, t : t + 1], t, rolling=True)
    od = decode_attention(q[:, :, -1:], kr, vr, jnp.int32(S), window=W, rolling=True)
    ow = naive_attention(q, k, v, causal=True, window=W)[:, :, -1:]
    np.testing.assert_allclose(od, ow, rtol=2e-4, atol=2e-4)


def test_dynamic_skip_matches_naive():
    k0, k1, k2 = jax.random.split(jax.random.PRNGKey(9), 3)
    B, H, S, D = 2, 4, 256, 32
    q, k, v = _rand(k0, B, H, S, D), _rand(k1, B, 2, S, D), _rand(k2, B, 2, S, D)
    o1 = naive_attention(q, k, v, causal=True)
    o2 = flash_attention(q, k, v, causal=True, block_q=64, block_k=64, dynamic_skip=True)
    np.testing.assert_allclose(o1, o2, rtol=2e-4, atol=2e-4)


def test_ring_attention_matches_naive(subproc):
    """The paper's halo rotation as sequence-parallel attention."""
    subproc(
        """
import jax, jax.numpy as jnp, numpy as np
from functools import partial
from jax.sharding import PartitionSpec as P
from repro.models.attention import naive_attention, ring_attention
from repro.jax_compat import make_mesh, shard_map
mesh = make_mesh((4,), ("data",))
ks = jax.random.split(jax.random.PRNGKey(0), 3)
q = jax.random.normal(ks[0], (2, 4, 256, 32), jnp.float32)
k = jax.random.normal(ks[1], (2, 2, 256, 32), jnp.float32)
v = jax.random.normal(ks[2], (2, 2, 256, 32), jnp.float32)
for causal in (True, False):
    f = jax.jit(shard_map(partial(ring_attention, axis_name="data", causal=causal),
        mesh=mesh, in_specs=(P(None, None, "data", None),) * 3,
        out_specs=P(None, None, "data", None), check_vma=False))
    np.testing.assert_allclose(f(q, k, v), naive_attention(q, k, v, causal=causal),
                               rtol=3e-4, atol=3e-4)
print("OK")
""",
        n_devices=4,
    )
