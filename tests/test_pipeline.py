"""Fused scan-compiled pipeline vs the unfused four-phase schedule.

The acceptance invariant: ``FusedStepPipeline`` — one donated program,
``lax.scan`` over steps and stages, same-bucket blocks batched into single
kernel launches — is BITWISE identical to the per-block schedule path, on
periodic meshes, across bucket sizes, before and after an executor
resplice, and with the Pallas kernels (``kernel_impl='interpret'``) inside
the fused program.
"""

import numpy as np
import pytest

import jax.numpy as jnp

from hypothesis_shim import given, settings, st
from repro.dg.mesh import make_brick
from repro.dg.rk import LSRK_A, LSRK_B, lsrk45_step, lsrk_coeffs
from repro.dg.solver import DGSolver, gaussian_pulse, make_two_tree_solver
from repro.runtime.executor import BlockedDGEngine, NestedPartitionExecutor


def _periodic_solver(grid=(4, 4, 2), kernel_impl="xla", order=2):
    mesh = make_brick(grid, (1.0, 1.0, 0.5), periodic=True)
    K = mesh.K
    return DGSolver(mesh=mesh, order=order, rho=np.ones(K), lam=np.ones(K),
                    mu=np.zeros(K), kernel_impl=kernel_impl)


def _rand_state(solver, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(
        rng.standard_normal((solver.mesh.K, 9, solver.M, solver.M, solver.M))
    )


def _unfused_run(eng, q, n_steps, dt):
    """The unfused schedule, compiled per step: jit traces the scan stage
    loop over the per-block rhs — the same per-step program the fused
    pipeline's step loop iterates, so fused vs unfused is bitwise."""
    import jax

    step = jax.jit(lambda q, res: lsrk45_step(q, res, eng.rhs, dt))
    res = jnp.zeros_like(q)
    for _ in range(n_steps):
        q, res = step(q, res)
    return q


# ---------------------------------------------------------------------------
# rk: the scan-compiled stage loop
# ---------------------------------------------------------------------------


def test_lsrk_eager_is_exact_reference_loop():
    """Called eagerly (concrete arrays), lsrk45_step runs the historical
    Python stage loop — bitwise identical, no per-call re-trace."""
    rng = np.random.default_rng(3)
    q = jnp.asarray(rng.standard_normal((7, 9, 3, 3, 3)))
    res = jnp.asarray(rng.standard_normal((7, 9, 3, 3, 3)))
    rhs = lambda x: x * 1.25 - 0.5
    dt = 1e-3
    q_ref, res_ref = q, res
    for s in range(5):
        res_ref = LSRK_A[s] * res_ref + dt * rhs(q_ref)
        q_ref = q_ref + LSRK_B[s] * res_ref
    q_s, res_s = lsrk45_step(q, res, rhs, dt)
    assert (np.asarray(q_s) == np.asarray(q_ref)).all()
    assert (np.asarray(res_s) == np.asarray(res_ref)).all()


def test_lsrk_scan_under_jit_matches_loop():
    """Under a trace the stage loop is a lax.scan; it equals the eager loop
    up to FMA contraction (the compiled body may fuse a*res + dt*rhs into a
    single-rounding fma; ~1 ulp on O(1) fields)."""
    import jax

    rng = np.random.default_rng(3)
    q = jnp.asarray(rng.standard_normal((7, 9, 3, 3, 3)))
    res = jnp.asarray(rng.standard_normal((7, 9, 3, 3, 3)))
    rhs = lambda x: x * 1.25 - 0.5
    dt = 1e-3
    q_ref, res_ref = lsrk45_step(q, res, rhs, dt)  # eager = reference loop
    q_s, res_s = jax.jit(lambda q, res: lsrk45_step(q, res, rhs, dt))(q, res)
    np.testing.assert_allclose(np.asarray(q_s), np.asarray(q_ref), rtol=1e-13, atol=1e-15)
    np.testing.assert_allclose(np.asarray(res_s), np.asarray(res_ref), rtol=1e-13, atol=1e-15)


def test_lsrk_coeffs_dtype_stable():
    for dt in ("float32", "float64"):
        c = lsrk_coeffs(dt)
        assert c.shape == (5, 2) and str(c.dtype) == dt
    q32 = jnp.zeros((2, 9, 3, 3, 3), jnp.float32)
    q, res = lsrk45_step(q32, jnp.zeros_like(q32), lambda x: x + 1, 1e-3)
    assert q.dtype == jnp.float32 and res.dtype == jnp.float32


# ---------------------------------------------------------------------------
# fused == unfused, bitwise
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("bucket", [4, 8])
def test_fused_rhs_bitwise_on_periodic_mesh(bucket):
    """Fused rhs == unfused schedule == flat solver, on a periodic brick,
    for two bucket (padded-shape) sizes."""
    solver = _periodic_solver()
    K = solver.mesh.K
    q0 = _rand_state(solver)
    ex = NestedPartitionExecutor(K, 3, grid_dims=solver.mesh.grid, bucket=bucket)
    eng = BlockedDGEngine(solver, ex)
    pipe = eng.pipeline()
    r_flat = np.asarray(solver.rhs(q0))
    r_unfused = np.asarray(eng.rhs(q0))
    r_fused = np.asarray(pipe.rhs(q0))
    assert (r_unfused == r_flat).all()
    assert (r_fused == r_unfused).all(), np.abs(r_fused - r_unfused).max()


@pytest.mark.parametrize("bucket", [4, 8])
def test_fused_run_bitwise_before_and_after_resplice(bucket):
    """The scan-compiled donated run matches the unfused step loop bitwise,
    then still does after the executor re-splices the block split."""
    solver = _periodic_solver()
    K = solver.mesh.K
    q0 = _rand_state(solver)
    dt = solver.cfl_dt()
    ex = NestedPartitionExecutor(K, 3, grid_dims=solver.mesh.grid, bucket=bucket)
    eng = BlockedDGEngine(solver, ex)

    q_fused = np.asarray(eng.run(q0, 3, dt=dt))
    q_unfused = np.asarray(_unfused_run(eng, q0, 3, dt))
    assert (q_fused == q_unfused).all(), np.abs(q_fused - q_unfused).max()

    # resplice: move work between partitions, then compare again
    ex.observe(np.array([0.02, 0.01, 0.01]))
    ex.rebalance()
    assert eng.pipeline().invalidate in ex._resplice_hooks  # hook wired
    q_fused2 = np.asarray(eng.run(q0, 3, dt=dt))
    q_unfused2 = np.asarray(_unfused_run(eng, q0, 3, dt))
    assert (q_fused2 == q_unfused2).all()
    # the resplice genuinely moved the split AND the pipeline saw it
    assert not np.array_equal(ex.counts, np.full(3, K // 3))


def test_fused_pipeline_with_pallas_kernels_interpret():
    """kernel_impl='interpret' threads BOTH Pallas kernels (volume + flux)
    through the fused program; fused == unfused bitwise."""
    solver = _periodic_solver(kernel_impl="interpret")
    K = solver.mesh.K
    q0 = _rand_state(solver)
    ex = NestedPartitionExecutor(K, 2, grid_dims=solver.mesh.grid, bucket=8)
    eng = BlockedDGEngine(solver, ex)
    pipe = eng.pipeline()
    r_unfused = np.asarray(eng.rhs(q0))
    r_fused = np.asarray(pipe.rhs(q0))
    assert (r_fused == r_unfused).all(), np.abs(r_fused - r_unfused).max()
    dt = solver.cfl_dt()
    q_fused = np.asarray(eng.run(q0, 2, dt=dt))
    q_unfused = np.asarray(_unfused_run(eng, q0, 2, dt))
    assert (q_fused == q_unfused).all()


def test_fused_run_is_one_dispatch_and_preserves_inputs():
    """run() advances n steps in ONE host dispatch, with the caller's buffer
    left intact despite the donated carry."""
    solver = make_two_tree_solver(grid=(6, 4, 4), order=2, extent=(2.0, 1.0, 1.0))
    q0 = gaussian_pulse(solver, center=(0.5, 0.5, 0.5))
    ex = NestedPartitionExecutor(96, 3, grid_dims=(6, 4, 4), bucket=8)
    eng = BlockedDGEngine(solver, ex)
    pipe = eng.pipeline()
    d0, s0 = pipe.dispatches, pipe.steps_run
    q1 = eng.run(q0, 5)
    assert pipe.dispatches == d0 + 1  # 5 steps, one dispatch
    assert pipe.steps_run == s0 + 5
    # q0 not consumed by donation
    assert np.isfinite(np.asarray(q0)).all()
    assert q1.shape == q0.shape


def test_fused_pipeline_batches_same_bucket_blocks():
    """Same-padded-size partitions land in ONE bucket (one launch), and the
    compiled program is reused when a resplice keeps the signature."""
    solver = _periodic_solver(grid=(4, 4, 4))
    K = solver.mesh.K  # 64 -> 4 partitions of 16, bucket 16: one bucket of 4
    ex = NestedPartitionExecutor(K, 4, grid_dims=solver.mesh.grid, bucket=16)
    eng = BlockedDGEngine(solver, ex)
    pipe = eng.pipeline()
    sig = pipe.bucket_signature
    assert sum(B for (_, _, B, _) in sig) == 4
    assert len(sig) < 4  # strictly fewer launches than blocks
    # a no-op resplice keeps the signature -> compiled run fn is reused
    n_fns = len(pipe._run_fns)
    q0 = _rand_state(solver)
    eng.run(q0, 2)
    assert len(pipe._run_fns) == n_fns + 1
    ex.set_accel_counts(None)  # forces a resplice with identical counts
    eng.run(q0, 2)
    assert pipe.bucket_signature == sig
    assert len(pipe._run_fns) == n_fns + 1  # no recompilation


def test_fused_observe_path_feeds_executor():
    """run(observe=True) stays fused: the in-scan accumulator + chunked
    wall time feed the executor one CalibrationReport per rebalance chunk
    (4 steps / chunks of 2 = 2 observations), and it rebalances on
    schedule."""
    solver = make_two_tree_solver(grid=(6, 4, 4), order=2, extent=(2.0, 1.0, 1.0))
    q0 = gaussian_pulse(solver, center=(0.5, 0.5, 0.5))
    ex = NestedPartitionExecutor(96, 3, grid_dims=(6, 4, 4), bucket=8,
                                 rebalance_every=2, smoothing=1.0)
    eng = BlockedDGEngine(solver, ex)
    q1 = eng.run(q0, 4, observe=True)
    assert ex._n_obs == 2 and ex.round >= 1
    assert eng.pipeline().stats.observe_chunks == 2
    assert np.isfinite(np.asarray(q1)).all()


def test_observe_report_straggler_moves_split():
    """The acceptance loop end to end: the chunk-boundary report enters the
    executor, the injected straggler inflates partition 0's observed
    seconds (inside observe — the single injection point) and the solved
    split visibly moves work off the straggler."""
    solver = make_two_tree_solver(grid=(6, 4, 4), order=2, extent=(2.0, 1.0, 1.0))
    q0 = gaussian_pulse(solver, center=(0.5, 0.5, 0.5))
    ex = NestedPartitionExecutor(96, 3, grid_dims=(6, 4, 4), bucket=8,
                                 rebalance_every=2, smoothing=1.0)
    eng = BlockedDGEngine(solver, ex)
    c0 = int(ex.counts[0])
    ex.inject_straggler(0, 8.0)
    eng.run(q0, 4, observe=True)
    assert ex.round >= 1
    assert int(ex.counts[0]) < c0, (c0, ex.counts)
    assert int(ex.counts.sum()) == 96


def test_observe_report_straggler_applied_exactly_once():
    """run_observed's report carries UNfactored times; observe applies the
    straggler multipliers — so a measure->observe round counts them exactly
    once (the executor invariant, now under the in-scan channel)."""
    solver = make_two_tree_solver(grid=(6, 4, 4), order=2, extent=(2.0, 1.0, 1.0))
    q0 = gaussian_pulse(solver, center=(0.5, 0.5, 0.5))
    ex = NestedPartitionExecutor(96, 3, grid_dims=(6, 4, 4), bucket=8,
                                 rebalance_every=0, smoothing=1.0)
    eng = BlockedDGEngine(solver, ex)
    pipe = eng.pipeline()
    price = np.array([1e-3, 2e-3, 3e-3])
    ex.inject_straggler(0, 5.0)
    _, report = pipe.run_observed(q0, 2, price=price, attribute_wall=False)
    # the channel itself is factor-free...
    np.testing.assert_allclose(report.step_s, price, rtol=1e-6)
    ex.observe_chunk(report, 2)
    # ...and the EWMA carries the factor exactly once
    np.testing.assert_allclose(ex._ewma, price * np.array([5.0, 1.0, 1.0]),
                               rtol=1e-6)


def test_observe_true_bitwise_identical_to_observe_false():
    """observe=True (chunked priced programs, mid-run resplices) yields q
    BITWISE identical to observe=False (one plain program): the priced
    family performs the same field arithmetic, the accumulator only rides
    the carry, and resplices preserve the trajectory."""
    solver = _periodic_solver()
    K = solver.mesh.K
    q0 = _rand_state(solver)
    dt = solver.cfl_dt()
    ex_a = NestedPartitionExecutor(K, 3, grid_dims=solver.mesh.grid, bucket=8,
                                   rebalance_every=2, smoothing=1.0)
    q_plain = np.asarray(BlockedDGEngine(solver, ex_a).run(q0, 6, dt=dt))
    ex_b = NestedPartitionExecutor(K, 3, grid_dims=solver.mesh.grid, bucket=8,
                                   rebalance_every=2, smoothing=1.0)
    q_obs = np.asarray(
        BlockedDGEngine(solver, ex_b).run(q0, 6, dt=dt, observe=True)
    )
    assert (q_plain == q_obs).all(), np.abs(q_plain - q_obs).max()


def test_scatter_base_hoisted_across_calls():
    """The (K+1,...) scatter target is built once per resplice, not per rhs
    evaluation."""
    solver = make_two_tree_solver(grid=(4, 2, 2), order=2)
    ex = NestedPartitionExecutor(16, 2, grid_dims=(4, 2, 2), bucket=4)
    eng = BlockedDGEngine(solver, ex)
    q0 = gaussian_pulse(solver, center=(1.0, 0.5, 0.5))
    base1 = eng.scatter_base(q0)
    eng.rhs(q0)
    base2 = eng.scatter_base(q0)
    assert base1 is base2
    assert base1.shape == (17, 9, 3, 3, 3)


# ---------------------------------------------------------------------------
# the flux kernel reached from the solver (satellite: dg_flux wiring)
# ---------------------------------------------------------------------------


def test_flux_kernel_reachable_from_solver():
    """kernel_impl='interpret' routes surface_rhs through dg_flux_pallas;
    the solver rhs stays allclose to the jnp reference path."""
    s_x = make_two_tree_solver(grid=(4, 2, 2), order=3)
    s_i = make_two_tree_solver(grid=(4, 2, 2), order=3, kernel_impl="interpret")
    q = gaussian_pulse(s_x, center=(1.0, 0.5, 0.5))
    np.testing.assert_allclose(s_x.rhs(q), s_i.rhs(q), rtol=1e-10, atol=1e-12)


def test_surface_rhs_interpret_matches_xla_on_periodic():
    from repro.dg.operators import surface_rhs

    solver = _periodic_solver(order=2)
    q = _rand_state(solver, seed=5)
    a = surface_rhs(q, solver.neighbors, solver.lift, solver.rho_j, solver.lam_j,
                    solver.mu_j, solver.cp_j, solver.cs_j)
    b = surface_rhs(q, solver.neighbors, solver.lift, solver.rho_j, solver.lam_j,
                    solver.mu_j, solver.cp_j, solver.cs_j, kernel_impl="interpret")
    np.testing.assert_allclose(a, b, rtol=1e-10, atol=1e-12)


# ---------------------------------------------------------------------------
# dispatch-count regression: the hot path must never re-Python-loop
# ---------------------------------------------------------------------------


def _wrap_counting(cache, key, fn):
    """Replace a cached compiled callable with a call-counting wrapper;
    returns the counter list."""
    calls = []

    def wrapper(*a, **k):
        calls.append(1)
        return fn(*a, **k)

    cache[key] = wrapper
    return calls


def test_dispatch_count_fused_run_one_per_run():
    """run() is ONE invocation of ONE compiled program, for every horizon —
    counted on the compiled callable itself, so a future edit that quietly
    re-Python-loops the step driver fails here."""
    solver = _periodic_solver()
    K = solver.mesh.K
    q0 = _rand_state(solver)
    ex = NestedPartitionExecutor(K, 3, grid_dims=solver.mesh.grid, bucket=8)
    eng = BlockedDGEngine(solver, ex)
    pipe = eng.pipeline()
    sig = pipe.bucket_signature
    run_calls = _wrap_counting(pipe._run_fns, sig, pipe._run_fn(sig))
    step_calls = _wrap_counting(pipe._step_fns, sig, pipe._step_fn(sig))
    for n in (1, 4, 9):
        before = len(run_calls)
        d0 = pipe.dispatches
        eng.run(q0, n)
        assert len(run_calls) - before == 1, (n, len(run_calls) - before)
        assert len(step_calls) == 0  # never falls back to per-step stepping
        assert pipe.dispatches - d0 == 1
        # inside the one compiled program: exactly one volume + one surface
        # kernel launch per rhs evaluation (the envelope layout's invariant,
        # counted at trace time on the DispatchStats ledger)
        assert pipe.stats.kernel_launches == {"volume": 1, "surface": 1}
    assert pipe.stats.dispatches_per_step < 1.0


def test_dispatch_count_observe_path_one_per_chunk():
    """run(observe=True) costs exactly ONE dispatch of the priced compiled
    program per rebalance chunk — never one per step, never a fallback to
    per-step stepping — counted on the compiled callables themselves."""
    solver = make_two_tree_solver(grid=(6, 4, 4), order=2, extent=(2.0, 1.0, 1.0))
    q0 = gaussian_pulse(solver, center=(0.5, 0.5, 0.5))
    ex = NestedPartitionExecutor(96, 3, grid_dims=(6, 4, 4), bucket=8,
                                 rebalance_every=2, smoothing=1.0)
    eng = BlockedDGEngine(solver, ex)
    pipe = eng.pipeline()
    sig = pipe.bucket_signature
    step_calls = _wrap_counting(pipe._step_fns, sig, pipe._step_fn(sig))
    run_calls = _wrap_counting(pipe._run_fns, sig, pipe._run_fn(sig))
    priced_calls = _wrap_counting(pipe._priced_run_fns, sig,
                                  pipe._priced_run_fn(sig))
    eng.run(q0, 6, observe=True)
    assert len(priced_calls) == 3  # 6 steps / chunks of 2, by the ledger too
    assert pipe.stats.observe_chunks == 3 and pipe.stats.steps_run == 6
    assert len(step_calls) == 0 and len(run_calls) == 0
    # the priced program carries exactly one launch of each kernel
    assert pipe.stats.kernel_launches == {"volume": 1, "surface": 1}
    # rebalance_every=0 disables the schedule: the whole horizon is ONE
    # observed chunk (one dispatch, one report)
    ex2 = NestedPartitionExecutor(96, 3, grid_dims=(6, 4, 4), bucket=8,
                                  rebalance_every=0)
    eng2 = BlockedDGEngine(solver, ex2)
    pipe2 = eng2.pipeline()
    sig2 = pipe2.bucket_signature
    priced2 = _wrap_counting(pipe2._priced_run_fns, sig2,
                             pipe2._priced_run_fn(sig2))
    eng2.run(q0, 4, observe=True)
    assert len(priced2) == 1 and ex2._n_obs == 1


# ---------------------------------------------------------------------------
# hypothesis properties: random shapes, buckets, resplice sequences
# ---------------------------------------------------------------------------


def _scatter_coverage(eng):
    """The fused scatter rows must cover each element exactly once (dump row
    K excluded) — the disjointness that makes bucket batching exact."""
    K = eng.solver.mesh.K
    rows = np.concatenate(
        [np.asarray(b["scat"]) for b in eng._blocks if b is not None]
    )
    real = rows[rows < K]
    assert len(np.unique(real)) == len(real), "overlapping scatter rows"
    assert set(real.tolist()) == set(range(K)), "scatter rows miss elements"
    assert (rows[rows >= K] == K).all()  # pad rows all hit the dump row


@settings(max_examples=8, deadline=None)
@given(st.integers(2, 4), st.integers(1, 3), st.integers(1, 3),
       st.integers(1, 4), st.sampled_from([2, 4, 8, 16]))
def test_fused_pipeline_property_random_mesh_and_buckets(nx, ny, nz, P, bucket):
    """Property: for randomized mesh shapes, partition counts and bucket
    sizes, the fused pipeline stays bitwise-identical to the unfused
    schedule and its scatter rows cover the field disjointly."""
    grid = (nx, ny, nz)
    mesh = make_brick(grid, (1.0, 1.0, 0.5), periodic=True)
    K = mesh.K
    P = min(P, K)
    solver = DGSolver(mesh=mesh, order=1, rho=np.ones(K), lam=np.ones(K),
                      mu=np.zeros(K))
    ex = NestedPartitionExecutor(K, P, grid_dims=grid, bucket=bucket)
    eng = BlockedDGEngine(solver, ex)
    pipe = eng.pipeline()
    _scatter_coverage(eng)
    q0 = _rand_state(solver, seed=nx * 100 + ny * 10 + nz + P + bucket)
    r_fused = np.asarray(pipe.rhs(q0))
    r_unfused = np.asarray(eng.rhs(q0))
    assert (r_fused == r_unfused).all(), np.abs(r_fused - r_unfused).max()


@settings(max_examples=6, deadline=None)
@given(st.lists(st.lists(st.floats(0.2, 5.0), min_size=3, max_size=3),
                min_size=1, max_size=4),
       st.sampled_from([4, 8]))
def test_fused_pipeline_property_resplice_sequences(times_seq, bucket):
    """Property: any sequence of observe->rebalance resplices preserves
    fused==unfused bitwise equality and disjoint scatter coverage."""
    solver = _periodic_solver(grid=(4, 4, 2), order=1)
    K = solver.mesh.K
    ex = NestedPartitionExecutor(K, 3, grid_dims=solver.mesh.grid,
                                 bucket=bucket, smoothing=1.0)
    eng = BlockedDGEngine(solver, ex)
    pipe = eng.pipeline()
    q0 = _rand_state(solver, seed=bucket)
    for times in times_seq:
        ex.observe(np.asarray(times))
        ex.rebalance()
        _scatter_coverage(eng)
        r_fused = np.asarray(pipe.rhs(q0))
        r_unfused = np.asarray(eng.rhs(q0))
        assert (r_fused == r_unfused).all(), np.abs(r_fused - r_unfused).max()
        assert int(ex.counts.sum()) == K


def test_fused_pipeline_grouped_buckets_stay_bitwise():
    """A partition->group map splits buckets under layout="grouped"
    (same-profile cluster batching) without changing the arithmetic:
    grouped fused == envelope fused == unfused, bitwise, and the grouped
    signature separates the groups while the envelope stays one bucket."""
    solver = _periodic_solver(grid=(4, 4, 4))
    K = solver.mesh.K
    ex = NestedPartitionExecutor(K, 4, grid_dims=solver.mesh.grid, bucket=16)
    eng = BlockedDGEngine(solver, ex)
    plain = eng.pipeline()
    grouped = eng.pipeline(groups=[0, 1, 0, 1], layout="grouped")
    gids = sorted(set(g for (_, _, _, g) in grouped.bucket_signature))
    assert gids == [0, 1]
    assert len(grouped.bucket_signature) > len(plain.bucket_signature)
    q0 = _rand_state(solver)
    r_plain = np.asarray(plain.rhs(q0))
    r_grouped = np.asarray(grouped.rhs(q0))
    r_unfused = np.asarray(eng.rhs(q0))
    assert (r_plain == r_unfused).all()
    assert (r_grouped == r_unfused).all()


# ---------------------------------------------------------------------------
# envelope layout: one volume + one surface launch regardless of the split
# ---------------------------------------------------------------------------


def _uneven_engine(kernel_impl="xla", order=2, weights=(5.0, 1.0, 1.0, 1.0),
                   grid=(4, 4, 4), bucket=8):
    """An engine whose split lands in MULTIPLE buckets under the grouped
    layout (uneven weights -> distinct padded sizes)."""
    mesh = make_brick(grid, (1.0, 1.0, 1.0), periodic=True)
    K = mesh.K
    solver = DGSolver(mesh=mesh, order=order, rho=np.ones(K), lam=np.ones(K),
                      mu=np.zeros(K), kernel_impl=kernel_impl)
    ex = NestedPartitionExecutor(K, len(weights), grid_dims=grid, bucket=bucket)
    ex.apply(ex.solve(list(weights)))
    return solver, BlockedDGEngine(solver, ex)


@pytest.mark.parametrize("kernel_impl", ["xla", "interpret"])
def test_envelope_collapses_multibucket_split_to_one_launch(kernel_impl):
    """The tentpole invariant: an uneven split that the grouped layout
    batches into MULTIPLE launch pairs compiles to exactly ONE volume + ONE
    surface launch per rhs under the envelope layout — bitwise identical to
    both the grouped path and the unfused schedule."""
    order = 1 if kernel_impl == "interpret" else 2
    solver, eng = _uneven_engine(kernel_impl=kernel_impl, order=order)
    env = eng.pipeline()
    grp = eng.pipeline(layout="grouped")
    assert len(grp.bucket_signature) > 1  # the split is genuinely ragged
    assert len(env.bucket_signature) == 1
    assert sum(B for (_, _, B, _) in env.bucket_signature) == sum(
        B for (_, _, B, _) in grp.bucket_signature
    )
    q0 = _rand_state(solver)
    r_env = np.asarray(env.rhs(q0))
    r_grp = np.asarray(grp.rhs(q0))
    r_unf = np.asarray(eng.rhs(q0))
    assert (r_env == r_unf).all(), np.abs(r_env - r_unf).max()
    assert (r_grp == r_unf).all()
    assert env.stats.kernel_launches == {"volume": 1, "surface": 1}
    assert grp.stats.kernel_launches["volume"] == len(grp.bucket_signature)
    # the fused run trajectory agrees too (scan over stages, donated carry)
    dt = solver.cfl_dt()
    q_env = np.asarray(env.run(q0, 3, dt=dt))
    q_grp = np.asarray(grp.run(q0, 3, dt=dt))
    q_unf = np.asarray(_unfused_run(eng, q0, 3, dt))
    assert (q_env == q_unf).all()
    assert (q_grp == q_unf).all()
    assert env.stats.kernel_launches == {"volume": 1, "surface": 1}


@pytest.mark.parametrize("kernel_impl", ["xla", "interpret"])
@pytest.mark.parametrize("split", ["giant", "singletons"])
def test_envelope_degenerate_splits_bitwise(kernel_impl, split):
    """Degenerate extremes: one giant bucket (P=1 holds everything) and P
    singleton partitions (bucket=1 -> every block its own size class) both
    stay bitwise under the envelope layout: the single rhs vs the unfused
    path, and the multi-step trajectory vs the per-bucket-group (grouped)
    fused path.  (The trajectory reference is the grouped FUSED run, not the
    eager per-step loop: on some tiny meshes XLA fuses the compiled scan
    differently from the per-step jit — an FMA artifact shared by every
    fused layout — while envelope vs grouped is exactly the batching change
    this test pins.)"""
    grid = (2, 2, 2) if split == "singletons" else (4, 4, 2)
    mesh = make_brick(grid, (1.0, 1.0, 0.5), periodic=True)
    K = mesh.K
    solver = DGSolver(mesh=mesh, order=1, rho=np.ones(K), lam=np.ones(K),
                      mu=np.zeros(K), kernel_impl=kernel_impl)
    if split == "giant":
        ex = NestedPartitionExecutor(K, 1, grid_dims=grid, bucket=8)
    else:
        ex = NestedPartitionExecutor(K, K, grid_dims=grid, bucket=1)
    eng = BlockedDGEngine(solver, ex)
    env = eng.pipeline()
    grp = eng.pipeline(layout="grouped")
    assert len(env.bucket_signature) == 1
    q0 = _rand_state(solver, seed=7)
    r_env = np.asarray(env.rhs(q0))
    r_unf = np.asarray(eng.rhs(q0))
    assert (r_env == r_unf).all(), np.abs(r_env - r_unf).max()
    assert env.stats.kernel_launches == {"volume": 1, "surface": 1}
    dt = solver.cfl_dt()
    q_env = np.asarray(env.run(q0, 2, dt=dt))
    q_grp = np.asarray(grp.run(q0, 2, dt=dt))
    assert (q_env == q_grp).all(), np.abs(q_env - q_grp).max()
    assert env.stats.kernel_launches == {"volume": 1, "surface": 1}


@settings(max_examples=8, deadline=None)
@given(st.lists(st.floats(0.2, 6.0), min_size=2, max_size=4),
       st.sampled_from([1, 2, 4, 8]))
def test_envelope_property_random_splits_bitwise(weights, bucket):
    """Property: ANY random bucket split — whatever ragged mix of padded
    sizes the weights produce — collapses to one launch pair under the
    envelope layout and keeps the q trajectory bitwise identical to the
    grouped reference."""
    grid = (4, 4, 2)
    mesh = make_brick(grid, (1.0, 1.0, 0.5), periodic=True)
    K = mesh.K
    solver = DGSolver(mesh=mesh, order=1, rho=np.ones(K), lam=np.ones(K),
                      mu=np.zeros(K))
    ex = NestedPartitionExecutor(K, len(weights), grid_dims=grid, bucket=bucket)
    ex.apply(ex.solve(list(weights)))
    eng = BlockedDGEngine(solver, ex)
    env = eng.pipeline()
    grp = eng.pipeline(layout="grouped")
    assert len(env.bucket_signature) == 1
    q0 = _rand_state(solver, seed=int(bucket + sum(w * 10 for w in weights)) % 97)
    r_env = np.asarray(env.rhs(q0))
    r_grp = np.asarray(grp.rhs(q0))
    assert (r_env == r_grp).all(), np.abs(r_env - r_grp).max()
    assert env.stats.kernel_launches == {"volume": 1, "surface": 1}
    dt = solver.cfl_dt()
    q_env = np.asarray(env.run(q0, 2, dt=dt))
    q_grp = np.asarray(grp.run(q0, 2, dt=dt))
    assert (q_env == q_grp).all()


def test_sharded_pipeline_single_device_mesh():
    """ShardedStepPipeline on a 1-device mesh (no fake-device flags needed):
    the same shard_map program structure, bitwise vs the flat solver, one
    dispatch per run — the in-process twin of tests/test_multidevice.py."""
    import jax

    from repro.dg.partitioned import PartitionedDG

    solver = _periodic_solver()
    q0 = _rand_state(solver)
    dt = solver.cfl_dt()
    mesh = jax.make_mesh((1,), ("data",))
    pdg = PartitionedDG(solver=solver, mesh_axes=mesh)
    pipe = pdg.pipeline()
    qp = pdg.permute_in(q0)
    r_flat = np.asarray(jax.jit(solver.rhs)(q0))
    r_shard = pdg.permute_out(np.asarray(pipe.rhs(qp)))
    assert (r_flat == r_shard).all(), np.abs(r_flat - r_shard).max()
    q_flat = np.asarray(solver.run(q0, 3, dt))
    d0 = pipe.dispatches
    q_shard = pdg.permute_out(np.asarray(pipe.run(qp, 3, dt=dt)))
    assert pipe.dispatches - d0 == 1 and pipe.steps_run >= 3
    assert (q_flat == q_shard).all(), np.abs(q_flat - q_shard).max()
    # the eager reference driver and the fused step agree with the program
    q_eager = pdg.permute_out(np.asarray(pdg.run(qp, 3, dt=dt, fused=False)))
    assert (q_eager == q_shard).all()
    # donated single fused step consumes its operands but not the original
    res = jnp.zeros_like(qp)
    q1, res1 = pipe.step(pipe._sharded_copy(qp), pipe._sharded_copy(res), dt)
    assert np.isfinite(np.asarray(q1)).all()
    assert np.isfinite(np.asarray(qp)).all()


def test_sharded_run_observed_in_scan_channel():
    """ShardedStepPipeline.run_observed on a 1-device mesh: per-shard
    accumulators psum-reduced inside the program, q bitwise vs the plain
    fused run, and the deterministic (attribute_wall=False) report carries
    the price itself; the wall-attributed report sums to positive
    seconds."""
    import jax

    from repro.dg.partitioned import PartitionedDG

    solver = _periodic_solver()
    q0 = _rand_state(solver)
    dt = solver.cfl_dt()
    mesh = jax.make_mesh((1,), ("data",))
    pdg = PartitionedDG(solver=solver, mesh_axes=mesh)
    pipe = pdg.pipeline()
    qp = pdg.permute_in(q0)
    q_plain = np.asarray(pipe.run(qp, 3, dt=dt))
    price = np.array([2e-3])
    q_obs, report = pipe.run_observed(qp, 3, dt=dt, price=price,
                                      attribute_wall=False)
    assert (np.asarray(q_obs) == q_plain).all()
    np.testing.assert_allclose(report.step_s, price, rtol=1e-6)
    q_obs2, report2 = pipe.run_observed(qp, 3, dt=dt)
    assert (np.asarray(q_obs2) == q_plain).all()
    assert (np.asarray(report2.step_s) > 0).all()
    assert pipe.stats.observe_chunks == 2
    # observe=True through PartitionedDG.run: one report per chunk feeds
    # the bound executor
    ex = pdg.bind_executor(pdg.make_executor(rebalance_every=2))
    q3 = pdg.run(qp, 4, dt=dt, observe=True)
    assert ex._n_obs == 2 and np.isfinite(np.asarray(q3)).all()


def test_fused_run_priced_accumulates_in_scan():
    """run(price=...) returns the same field as the unpriced run plus the
    per-partition cost accumulated inside the compiled loop (price * n)."""
    solver = _periodic_solver()
    K = solver.mesh.K
    q0 = _rand_state(solver)
    dt = solver.cfl_dt()
    ex = NestedPartitionExecutor(K, 3, grid_dims=solver.mesh.grid, bucket=8)
    eng = BlockedDGEngine(solver, ex)
    pipe = eng.pipeline()
    price = np.array([1e-3, 2e-3, 3e-3])
    q_plain = np.asarray(pipe.run(q0, 4, dt=dt))
    q_priced, acc = pipe.run(q0, 4, dt=dt, price=price)
    assert (np.asarray(q_priced) == q_plain).all()
    np.testing.assert_allclose(np.asarray(acc), price * 4, rtol=1e-12)


@settings(max_examples=6, deadline=None)
@given(st.lists(st.floats(1e-4, 5e-3), min_size=3, max_size=3),
       st.lists(st.integers(1, 3), min_size=1, max_size=4))
def test_priced_accumulator_chunking_property(price, chunks):
    """Property: splitting an observed run into arbitrary rebalance chunks
    preserves the accumulated totals — the sum over chunks of each chunk's
    in-scan accumulator equals the per-step sum ``price * n`` and the
    single-run accumulator (allclose, not bitwise: float addition order
    differs across chunk boundaries)."""
    solver = _periodic_solver(grid=(4, 2, 2), order=1)
    K = solver.mesh.K
    ex = NestedPartitionExecutor(K, 3, grid_dims=solver.mesh.grid, bucket=4)
    eng = BlockedDGEngine(solver, ex)
    pipe = eng.pipeline()
    q0 = _rand_state(solver)
    dt = solver.cfl_dt()
    price = np.asarray(price)
    n = sum(chunks)
    total = np.zeros(len(price))
    q = q0
    for c in chunks:
        q, acc = pipe.run(q, c, dt=dt, price=price)
        total += np.asarray(acc)
    _, acc_single = pipe.run(q0, n, dt=dt, price=price)
    np.testing.assert_allclose(total, np.asarray(acc_single), rtol=1e-9)
    np.testing.assert_allclose(total, price * n, rtol=1e-9)
