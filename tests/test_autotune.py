"""Kernel block-size autotuner + measured-roofline cost-model feedback.

Covers the sweep machinery (interpret mode, tiny shapes), the JSON cache
roundtrip, block-size invariance of the kernels under ``activate``, and the
planner loop: an autotune cache entry consumed through
``CalibrationTable.from_autotune`` / ``NodeModel.from_tables`` /
``measured_launch_overhead`` must actually change planner decisions vs the
analytic model, and ``roofline_time_fn``'s 20 µs fallback must stay pinned
when no cache is present.
"""

import json

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.cost_model import (
    DEFAULT_LAUNCH_OVERHEAD,
    CalibrationTable,
    DGWorkModel,
    measured_launch_overhead,
    roofline_time_fn,
    stampede_calibration,
)
from repro.core.load_balance import NodeModel, solve_two_way
from repro.core.topology import STAMPEDE_SNB_SOCKET
from repro.kernels import autotune as at


def _entry(device_kind="test-device", order=3, be=16, bf=128,
           vol=2e-7, flux=1e-7, overhead=55e-6):
    return {
        "device_kind": device_kind,
        "order": order,
        "n_fields": 9,
        "dtype": "float32",
        "interpret": True,
        "be": be,
        "bf": bf,
        "sec_per_element": {"volume_loop": vol, "int_flux": flux},
        "launch_overhead_s": overhead,
    }


# ---------------------------------------------------------------------------
# cache roundtrip
# ---------------------------------------------------------------------------


def test_cache_save_load_lookup_roundtrip(tmp_path):
    path = str(tmp_path / "autotune.json")
    assert at.load_cache(path) == {}  # missing file -> empty, no raise
    e1 = _entry(order=2)
    e2 = _entry(order=4, be=32)
    at.save_entry(e1, path)
    at.save_entry(e2, path)
    cache = at.load_cache(path)
    assert set(cache) == {at.entry_key("test-device", 2),
                          at.entry_key("test-device", 4)}
    hit = at.lookup("test-device", 4, path=path)
    assert hit["be"] == 32
    assert at.lookup("test-device", 9, path=path) is None  # unknown order
    # order=None: any entry for the device class
    assert at.lookup("test-device", path=path)["device_kind"] == "test-device"
    assert at.best_blocks("test-device", 2, path=path) == (16, 128)
    assert at.best_blocks("absent-device", 2, path=path) == (None, None)
    # re-saving the same key overwrites, not duplicates
    at.save_entry(_entry(order=2, be=8), path)
    assert at.lookup("test-device", 2, path=path)["be"] == 8
    assert len(at.load_cache(path)) == 2


def test_cache_corrupt_file_degrades_to_empty(tmp_path):
    path = str(tmp_path / "autotune.json")
    with open(path, "w") as f:
        f.write("{not json")
    assert at.load_cache(path) == {}


# ---------------------------------------------------------------------------
# the sweep (interpret mode, tiny shapes)
# ---------------------------------------------------------------------------


def test_autotune_sweep_interpret_smoke(tmp_path):
    path = str(tmp_path / "autotune.json")
    entry = at.autotune(
        order=1,
        device_kind="ci-interpret",
        be_candidates=(8, 16),
        bf_candidates=(16, 32),
        interpret=True,
        reps=1,
        size_factor=2,
        cache_path=path,
    )
    assert entry["be"] in (8, 16) and entry["bf"] in (16, 32)
    assert set(entry["sec_per_element"]) == {"volume_loop", "int_flux"}
    assert entry["sec_per_element"]["volume_loop"] >= 0.0
    assert entry["launch_overhead_s"] >= 0.0
    assert len(entry["volume_sweep"]) == 2 and len(entry["flux_sweep"]) == 2
    # the sweep saved itself; the cache is immediately consumable
    cached = at.lookup("ci-interpret", 1, path=path)
    assert cached["be"] == entry["be"] and cached["bf"] == entry["bf"]
    tab = CalibrationTable.from_autotune(cached)
    assert tab.time_fn()(100) > 0.0


def test_activate_changes_blocks_and_results_stay_bitwise():
    """activate() installs the winners module-wide; the kernels are
    block-invariant, so any activated BE/BF reproduces the default output
    bitwise (the property the envelope pipeline's bitwise guarantee rests
    on)."""
    from repro.dg.basis import diff_matrix, lgl_nodes_weights
    from repro.kernels import dg_flux, dg_volume

    order, K, F = 1, 12, 20
    M = order + 1
    x, _ = lgl_nodes_weights(order)
    D = jnp.asarray(diff_matrix(x), jnp.float32)
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.standard_normal((K, 9, M, M, M)), jnp.float32)
    ones = jnp.ones(K, jnp.float32)
    mu = jnp.zeros(K, jnp.float32)
    Sm = jnp.asarray(rng.standard_normal((F, 6, M, M)), jnp.float32)
    vm = jnp.asarray(rng.standard_normal((F, 3, M, M)), jnp.float32)
    Sp = jnp.asarray(rng.standard_normal((F, 6, M, M)), jnp.float32)
    vp = jnp.asarray(rng.standard_normal((F, 3, M, M)), jnp.float32)
    mats = jnp.asarray(np.abs(rng.standard_normal((F, 8))) + 0.5, jnp.float32)

    ref_v = np.asarray(dg_volume.dg_volume_pallas(
        q, D, (2.0, 2.0, 2.0), ones, ones, mu, interpret=True))
    ref_e, ref_f = dg_flux.dg_flux_pallas(Sm, vm, Sp, vp, mats, 0, 1.0,
                                          interpret=True)
    try:
        at.activate(_entry(be=4, bf=8))
        assert dg_volume.block_elems() == 4 and dg_flux.block_faces() == 8
        got_v = np.asarray(dg_volume.dg_volume_pallas(
            q, D, (2.0, 2.0, 2.0), ones, ones, mu, interpret=True))
        got_e, got_f = dg_flux.dg_flux_pallas(Sm, vm, Sp, vp, mats, 0, 1.0,
                                              interpret=True)
        assert (got_v == ref_v).all()
        assert (np.asarray(got_e) == np.asarray(ref_e)).all()
        assert (np.asarray(got_f) == np.asarray(ref_f)).all()
    finally:
        at.activate(None)
    assert dg_volume.block_elems() == dg_volume.BE
    assert dg_flux.block_faces() == dg_flux.BF


# ---------------------------------------------------------------------------
# cost-model feedback
# ---------------------------------------------------------------------------


def test_from_autotune_fills_shares_and_overhead():
    entry = _entry(vol=4e-7, flux=2e-7, overhead=77e-6)
    tab = CalibrationTable.from_autotune(entry)
    assert tab.device_name == "test-device" and tab.order == 3
    assert tab.overhead == pytest.approx(77e-6)
    assert tab.sec_per_element["volume_loop"] == pytest.approx(4e-7)
    assert tab.sec_per_element["int_flux"] == pytest.approx(2e-7)
    # unmeasured kernels filled from the Fig 4.1 shares anchored to the
    # MEASURED volume_loop: rk share 0.10 vs volume share 0.40 -> 1/4 ratio
    assert tab.sec_per_element["rk"] == pytest.approx(4e-7 * 0.10 / 0.40)
    assert set(tab.sec_per_element) >= {"volume_loop", "int_flux", "rk",
                                        "lift", "interp_q"}
    bare = CalibrationTable.from_autotune(entry, fill_shares=False)
    assert set(bare.sec_per_element) == {"volume_loop", "int_flux"}


def test_roofline_overhead_fallback_pinned(tmp_path, monkeypatch):
    """With no autotune cache present the 20 µs constant survives exactly."""
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE",
                       str(tmp_path / "does-not-exist.json"))
    assert DEFAULT_LAUNCH_OVERHEAD == pytest.approx(20e-6)
    assert measured_launch_overhead("whatever") == pytest.approx(20e-6)
    work = DGWorkModel(order=3)
    T = roofline_time_fn(work, STAMPEDE_SNB_SOCKET)
    T_explicit = roofline_time_fn(work, STAMPEDE_SNB_SOCKET, overhead=20e-6)
    assert T(0) == 0.0
    for K in (1, 64, 4096):
        assert T(K) == pytest.approx(T_explicit(K))


def test_roofline_overhead_measured_when_cache_present(tmp_path, monkeypatch):
    path = str(tmp_path / "autotune.json")
    at.save_entry(_entry(device_kind=STAMPEDE_SNB_SOCKET.name,
                         overhead=300e-6), path)
    at.save_entry(_entry(device_kind="other-device", overhead=1e-6), path)
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", path)
    # device-matched entry wins over the other device's
    assert measured_launch_overhead(STAMPEDE_SNB_SOCKET.name) == pytest.approx(300e-6)
    assert measured_launch_overhead("other-device") == pytest.approx(1e-6)
    # unmatched device falls back over all cached entries (median)
    assert measured_launch_overhead("unknown") in (pytest.approx(300e-6),
                                                   pytest.approx(1e-6))
    work = DGWorkModel(order=3)
    T = roofline_time_fn(work, STAMPEDE_SNB_SOCKET)
    T_const = roofline_time_fn(work, STAMPEDE_SNB_SOCKET, overhead=20e-6)
    assert T(64) - T_const(64) == pytest.approx(280e-6)
    # explicit path param bypasses the env var
    T_miss = roofline_time_fn(work, STAMPEDE_SNB_SOCKET,
                              autotune_path=str(tmp_path / "nope.json"))
    assert T_miss(64) == pytest.approx(T_const(64))


def test_autotuned_tables_change_planner_decision():
    """The acceptance loop: a measured autotune entry, consumed via
    CalibrationTable.from_autotune -> NodeModel.from_tables, must move the
    solve_two_way split vs the analytic (reconstructed-Stampede) model —
    planning on observed rooflines, not assumed ones."""
    order, K = 7, 8192
    tabs = stampede_calibration(order)
    analytic = NodeModel.from_tables(tabs["snb-socket"], tabs["xeon-phi"])
    base = analytic.solve(K)
    # the autotuner measured this accelerator much faster than the
    # reconstructed table assumed (and the host as reconstructed)
    host_meas = _entry(device_kind="host", order=order,
                       vol=tabs["snb-socket"].sec_per_element["volume_loop"],
                       flux=tabs["snb-socket"].sec_per_element["int_flux"],
                       overhead=tabs["snb-socket"].overhead)
    accel_meas = _entry(device_kind="accel", order=order,
                        vol=tabs["xeon-phi"].sec_per_element["volume_loop"] / 4,
                        flux=tabs["xeon-phi"].sec_per_element["int_flux"] / 4,
                        overhead=tabs["xeon-phi"].overhead)
    measured = NodeModel.from_tables(
        CalibrationTable.from_autotune(host_meas),
        CalibrationTable.from_autotune(accel_meas),
    )
    tuned = measured.solve(K)
    # a 4x faster measured accelerator absorbs strictly more elements
    assert tuned.counts[1] > base.counts[1]
    assert tuned.counts != base.counts
    assert tuned.makespan < base.makespan
    # the same tables drive solve_two_way directly
    direct = solve_two_way(
        CalibrationTable.from_autotune(host_meas).time_fn(),
        CalibrationTable.from_autotune(accel_meas).time_fn(),
        K,
    )
    assert direct.counts == tuned.counts
