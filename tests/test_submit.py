"""launch/submit.py: batch-system script generation for a RoundPlan —
golden dry-run output for both dialects, job coverage, the auto-assigned
stdout/stderr rule, and dependency threading."""

import json
import os

import pytest

from repro.launch.submit import (
    BATCH_SYSTEMS,
    main,
    materialize,
    render_script,
    submit_command,
)
from repro.runtime.rounds import RoundPlan, RoundWorker, plan_rounds


@pytest.fixture(scope="module")
def plan():
    return plan_rounds(64, [RoundWorker(f"n{i}", r) for i, r in enumerate([4, 2, 1, 1])])


def test_materialize_covers_every_job_both_systems(plan, tmp_path):
    specs = plan.job_specs()
    for system in BATCH_SYSTEMS:
        wd = str(tmp_path / system)
        out = materialize(plan, system, wd, dry_run=True)
        assert [j["name"] for j, _, _ in out] == [j["name"] for j in specs]
        for job, path, argv in out:
            assert os.path.exists(path) and os.access(path, os.X_OK)
            text = open(path).read()
            assert text.startswith("#!/bin/bash")
            # payload re-reads the shared plan and runs this job's step
            assert f"--worker-step {job['round']}:{job['slot']}" in text
            assert os.path.join(wd, "plan.json") in text
        # the serialized plan round-trips to the same schedule
        with open(os.path.join(wd, "plan.json")) as f:
            assert RoundPlan.from_json(json.load(f)) == plan


def test_slurm_golden_headers_and_dependencies(plan, tmp_path):
    wd = str(tmp_path)
    out = materialize(plan, "slurm", wd, batch_options=["--partition=batch", "--mem", "4G"])
    first = open(out[0][1]).read().splitlines()
    assert first[:6] == [
        "#!/bin/bash",
        "#SBATCH --job-name=round0_worker0",
        f"#SBATCH --output={wd}/logs/round0_worker0.out",
        f"#SBATCH --error={wd}/logs/round0_worker0.err",
        f"#SBATCH --chdir={wd}",
        "#SBATCH --partition=batch",
    ]
    assert first[6] == "#SBATCH --mem 4G"  # multi-token extras stay on one line
    # round-0 jobs submit bare; merge jobs ride --dependency=afterok with
    # per-dependency placeholders in a dry run
    for job, _, argv in out:
        assert argv[0] == "sbatch"
        if job["round"] == 0:
            assert not any(a.startswith("--dependency") for a in argv)
        else:
            dep = [a for a in argv if a.startswith("--dependency=afterok:")]
            assert len(dep) == 1
            assert all(f"<jobid:{d}>" in dep[0] for d in job["depends"])


def test_sge_golden_headers_and_holds(plan, tmp_path):
    wd = str(tmp_path)
    out = materialize(plan, "sge", wd, batch_options=["-q", "long.q"])
    merge = next(j for j, _, _ in out if j["round"] > 0)
    text = open(next(p for j, p, _ in out if j is merge)).read().splitlines()
    assert text[:7] == [
        "#!/bin/bash",
        f"#$ -N {merge['name']}",
        f"#$ -o {wd}/logs/{merge['name']}.out",
        f"#$ -e {wd}/logs/{merge['name']}.err",
        f"#$ -wd {wd}",
        "#$ -S /bin/bash",
        f"#$ -hold_jid {','.join(merge['depends'])}",
    ]
    assert "#$ -q long.q" in text
    # sge dependencies are name-holds in the script, not argv flags
    for _, path, argv in out:
        assert argv == ["qsub", path]


@pytest.mark.parametrize("system,opt", [
    ("slurm", "-o"), ("slurm", "--output=x.log"), ("slurm", "--error"),
    ("sge", "-o"), ("sge", "-e"),
])
def test_stdout_stderr_overrides_rejected(plan, tmp_path, system, opt):
    """Per-job stdout/stderr paths are auto-assigned under <workdir>/logs/
    (the merge rounds parse them); user overrides must be refused."""
    with pytest.raises(ValueError, match="auto-assigned"):
        materialize(plan, system, str(tmp_path), batch_options=[opt])


def test_unknown_batch_system_rejected(plan, tmp_path):
    with pytest.raises(ValueError, match="unknown batch system"):
        materialize(plan, "pbs", str(tmp_path))
    with pytest.raises(ValueError, match="unknown batch system"):
        render_script("pbs", plan.job_specs()[0], str(tmp_path))


def test_non_dry_run_threads_slurm_job_ids(plan, tmp_path):
    """Submitted slurm job ids are parsed from sbatch stdout and threaded
    into later rounds' afterok lists (no placeholders remain)."""
    submitted = []

    class Proc:
        def __init__(self, stdout):
            self.stdout = stdout

    def runner(argv):
        submitted.append(argv)
        return Proc(f"Submitted batch job {1000 + len(submitted)}")

    out = materialize(plan, "slurm", str(tmp_path), dry_run=False, runner=runner)
    assert len(submitted) == len(plan.job_specs())
    name_to_id = {job["name"]: str(1001 + i) for i, (job, _, _) in enumerate(out)}
    for job, _, argv in out:
        if job["depends"]:
            dep = next(a for a in argv if a.startswith("--dependency=afterok:"))
            ids = dep.split("afterok:", 1)[1].split(":")
            assert ids == [name_to_id[d] for d in job["depends"]]
            assert not any("<jobid" in i for i in ids)


def test_cli_dry_run_prints_scripts_and_submits_nothing(plan, tmp_path, capsys):
    plan_path = tmp_path / "plan.json"
    plan_path.write_text(json.dumps(plan.to_json()))
    main(["--batch-system", "sge", "--workdir", str(tmp_path / "wd"),
          "--plan-json", str(plan_path), "--dry-run"])
    text = capsys.readouterr().out
    assert "(dry run: nothing submitted)" in text
    for job in plan.job_specs():
        assert f"-N {job['name']}" in text
    assert f"{len(plan.job_specs())} jobs" in text
