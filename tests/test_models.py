"""Per-arch smoke tests + model-math correctness (decode==prefill, MoE,
mamba, head plans).  All on reduced same-family configs, 1 CPU device."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.shapes import SHAPES, cells_for, smoke_config
from repro.models.common import ModelConfig, make_head_plan
from repro.models.zoo import LM, get_config, list_archs

ALL_ARCHS = [
    "qwen2.5-32b", "granite-3-8b", "stablelm-12b", "qwen2-7b", "llava-next-34b",
    "hymba-1.5b", "mixtral-8x22b", "olmoe-1b-7b", "falcon-mamba-7b", "hubert-xlarge",
]


def _smoke_batch(cfg, key, B=2, S=64):
    kt, kl, kp = jax.random.split(key, 3)
    if cfg.family == "audio":
        return {
            "features": jax.random.normal(kp, (B, S, cfg.d_model), jnp.float32),
            "labels": jax.random.randint(kl, (B, S), 0, cfg.vocab_size),
        }
    if cfg.family == "vlm":
        ni = cfg.frontend_tokens
        return {
            "tokens": jax.random.randint(kt, (B, S - ni), 0, cfg.vocab_size),
            "patches": jax.random.normal(kp, (B, ni, 1024), jnp.float32),
            "labels": jax.random.randint(kl, (B, S - ni), 0, cfg.vocab_size),
        }
    return {
        "tokens": jax.random.randint(kt, (B, S), 0, cfg.vocab_size),
        "labels": jax.random.randint(kl, (B, S), 0, cfg.vocab_size),
    }


def test_registry_has_all_assigned_archs():
    assert set(ALL_ARCHS) <= set(list_archs())


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_arch_smoke_forward_and_train_step(arch):
    """Reduced config: one forward + one grad step; shapes + finiteness."""
    cfg = smoke_config(get_config(arch))
    lm = LM(cfg, ep_size=2 if cfg.n_experts else 1)
    key = jax.random.PRNGKey(0)
    params = lm.init(key)
    batch = _smoke_batch(cfg, jax.random.PRNGKey(1))
    loss, metrics = lm.loss(params, batch)
    assert jnp.isfinite(loss), arch
    logits, _, _, npre = lm.forward(params, batch)
    B = 2
    S_tot = (batch.get("tokens").shape[1] if "tokens" in batch else batch["features"].shape[1]) + npre
    assert logits.shape == (B, S_tot, cfg.padded_vocab)
    assert jnp.isfinite(logits).all()
    grads = jax.grad(lambda p: lm.loss(p, batch)[0])(params)
    gn = sum(jnp.sum(jnp.square(g)) for g in jax.tree.leaves(grads)) ** 0.5
    assert jnp.isfinite(gn) and gn > 0


@pytest.mark.parametrize("arch", ["qwen2.5-32b", "mixtral-8x22b", "falcon-mamba-7b", "hymba-1.5b", "olmoe-1b-7b", "llava-next-34b"])
def test_decode_matches_prefill(arch):
    cfg = smoke_config(get_config(arch))
    lm = LM(cfg, ep_size=2 if cfg.n_experts else 1)
    params = lm.init(jax.random.PRNGKey(0))
    B, S = 2, 48
    if cfg.family == "vlm":
        ni = cfg.frontend_tokens
        toks = jax.random.randint(jax.random.PRNGKey(1), (B, S - ni), 0, cfg.vocab_size)
        patches = jax.random.normal(jax.random.PRNGKey(2), (B, ni, 1024), jnp.float32)
        full_batch = {"tokens": toks, "patches": patches}
        n_txt = S - ni
    else:
        toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
        full_batch = {"tokens": toks}
        n_txt = S
    full_logits, _, _, npre = lm.forward(params, full_batch)
    S0 = n_txt - 6
    pre_batch = dict(full_batch, tokens=toks[:, :S0])
    logits_p, cache = lm.prefill(params, pre_batch, max_len=S + npre + 8)
    errs = [float(jnp.abs(logits_p - full_logits[:, npre + S0 - 1]).max())]
    for t in range(S0, n_txt):
        logits_d, cache = lm.decode_step(params, cache, toks[:, t])
        errs.append(float(jnp.abs(logits_d - full_logits[:, npre + t]).max()))
    assert max(errs) < 3e-3, (arch, errs)


def test_encoder_only_skips_decode_cells():
    cells = {c.shape.name: c for c in cells_for(get_config("hubert-xlarge"))}
    assert cells["decode_32k"].skip and cells["long_500k"].skip
    assert not cells["train_4k"].skip and not cells["prefill_32k"].skip


def test_long500k_skip_rules():
    for arch, should_run in [
        ("qwen2.5-32b", False), ("granite-3-8b", False), ("stablelm-12b", False),
        ("qwen2-7b", False), ("llava-next-34b", False),
        ("mixtral-8x22b", True), ("hymba-1.5b", True), ("falcon-mamba-7b", True),
        ("olmoe-1b-7b", False),
    ]:
        c = {c.shape.name: c for c in cells_for(get_config(arch))}["long_500k"]
        assert (c.skip is None) == should_run, (arch, c.skip)


def test_head_plans_cover_zoo():
    for arch in ALL_ARCHS:
        cfg = get_config(arch)
        if not cfg.has_attention:
            continue
        plan = make_head_plan(cfg.n_heads, cfg.n_kv_heads, 16)
        assert plan.padded_q % 16 == 0
        if not plan.kv_replicated:
            assert plan.padded_kv % 16 == 0 or 16 % plan.padded_kv == 0
            # every logical q head maps to its original kv head
            q_per_g = cfg.n_heads // cfg.n_kv_heads
            for h in range(cfg.n_heads):
                slot = plan.q_slot_of_logical[h]
                kv_padded = plan.q_to_kv[slot]
                assert plan.kv_dup[kv_padded] == h // q_per_g, (arch, h)


def test_padded_heads_are_exact():
    """A tp_size-padded model must equal the unpadded (tp=1) model."""
    base = smoke_config(get_config("qwen2.5-32b")).replace(n_heads=5, n_kv_heads=1, head_dim=16)
    lm1 = LM(base.replace(tp_size=1))
    lm4 = LM(base.replace(tp_size=4))
    p1 = lm1.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, base.vocab_size)
    # copy p1 weights into the padded layout of lm4
    p4 = lm4.init(jax.random.PRNGKey(0))
    plan1, plan4 = lm1.plan, lm4.plan
    hd = base.head_dim_

    def remap_q(w1, w4):
        w4 = np.array(w4)
        w4[:] = 0.0
        for h in range(base.n_heads):
            s1, s4 = plan1.q_slot_of_logical[h], plan4.q_slot_of_logical[h]
            w4[:, s4 * hd : (s4 + 1) * hd] = np.asarray(w1[:, s1 * hd : (s1 + 1) * hd])
        return jnp.asarray(w4)

    def remap_o(w1, w4):
        w4 = np.array(w4)
        w4[:] = 0.0
        for h in range(base.n_heads):
            s1, s4 = plan1.q_slot_of_logical[h], plan4.q_slot_of_logical[h]
            w4[s4 * hd : (s4 + 1) * hd, :] = np.asarray(w1[s1 * hd : (s1 + 1) * hd, :])
        return jnp.asarray(w4)

    import copy
    p4 = jax.tree.map(lambda x: x, p1)  # same non-attention weights
    lay1 = p1["layers"]["attn"]
    p4["layers"] = dict(p1["layers"])
    p4["layers"]["attn"] = dict(lay1)
    p4["layers"]["attn"]["wq"] = jnp.stack([remap_q(lay1["wq"][l], np.zeros((base.d_model, plan4.padded_q * hd))) for l in range(base.n_layers)])
    p4["layers"]["attn"]["wo"] = jnp.stack([remap_o(lay1["wo"][l], np.zeros((plan4.padded_q * hd, base.d_model))) for l in range(base.n_layers)])
    if base.qkv_bias:
        def remap_b(b1, n4):
            b4 = np.zeros(n4)
            for h in range(base.n_heads):
                s1, s4 = plan1.q_slot_of_logical[h], plan4.q_slot_of_logical[h]
                b4[s4 * hd : (s4 + 1) * hd] = np.asarray(b1[s1 * hd : (s1 + 1) * hd])
            return jnp.asarray(b4)
        p4["layers"]["attn"]["bq"] = jnp.stack([remap_b(lay1["bq"][l], plan4.padded_q * hd) for l in range(base.n_layers)])
    l1, _, _, _ = lm1.forward(p1, {"tokens": toks})
    l4, _, _, _ = lm4.forward(p4, {"tokens": toks})
    np.testing.assert_allclose(l1, l4, rtol=2e-4, atol=2e-4)
