"""Differential harness for the multi-device fused pipeline.

The acceptance invariant of the sharded fused driver
(``runtime.pipeline.ShardedStepPipeline`` — ONE donated shard_map program:
step loop, stage scan, and the ring ppermute halo exchange all inside):

* **bitwise identical** (``kernel_impl='xla'``) to (a) the flat
  ``DGSolver``, (b) the eager per-step ``PartitionedDG`` loop, and (c) the
  single-device ``FusedStepPipeline`` at the rhs level — on periodic
  meshes, across slab counts;
* under ``kernel_impl='interpret'`` the Pallas bodies lower through jnp
  into the *surrounding* program, so FMA contraction may differ between
  differently-shaped programs (the repo's existing interpret tests compare
  the solver level with allclose for the same reason) — the drivers must
  agree to ~1 ulp;
* **O(1) host dispatches per run** — independent of device count, slab
  count and step horizon — counted on the actual compiled-function calls,
  so a future edit cannot silently re-Python-loop the hot path.

All tests run in a subprocess with XLA_FLAGS=--xla_force_host_platform_
device_count=4 (the conftest ``subproc`` fixture), so they pass in the
single-device tier-1 lane and the multi-device CI lane alike.
"""


DIFFERENTIAL = r"""
import jax
jax.config.update("jax_enable_x64", True)
import numpy as np, jax.numpy as jnp
from repro.dg.mesh import make_brick
from repro.dg.solver import DGSolver
from repro.dg.partitioned import PartitionedDG
from repro.runtime.executor import BlockedDGEngine, NestedPartitionExecutor

def periodic_solver(grid, impl, order=2, lam=1.0, mu=0.0):
    # the unit-material acoustic brick (rho=lam=1, mu=0) is the mesh family
    # the repo's bitwise invariants use (tests/test_pipeline.py): there
    # XLA's FMA contraction is identical across differently-shaped compiled
    # programs; non-unit/elastic materials are checked separately at ~1 ulp
    mesh = make_brick(grid, (1.0, 1.0, 0.5), periodic=True)
    K = mesh.K
    return DGSolver(mesh=mesh, order=order, rho=np.ones(K),
                    lam=np.full(K, lam), mu=np.full(K, mu),
                    kernel_impl=impl)

def check(a, b, what, bitwise):
    a, b = np.asarray(a), np.asarray(b)
    if bitwise:
        assert (a == b).all(), (what, np.abs(a - b).max())
    else:  # interpret: Pallas-in-jnp is not FMA-stable across program shapes
        np.testing.assert_allclose(a, b, rtol=1e-12, atol=1e-13, err_msg=what)

n_checked = 0
for impl in ("xla", "interpret"):
    bitwise = impl == "xla"
    for grid, slabs in (((4, 2, 2), 2), ((4, 2, 2), 4), ((4, 4, 2), 2)):
        solver = periodic_solver(grid, impl)
        K = solver.mesh.K
        rng = np.random.default_rng(7)
        q0 = jnp.asarray(rng.standard_normal((K, 9, solver.M, solver.M, solver.M)))
        dt = solver.cfl_dt()
        mesh = jax.make_mesh((slabs,), ("data",))
        pdg = PartitionedDG(solver=solver, mesh_axes=mesh)
        pipe = pdg.pipeline()
        qp = pdg.permute_in(q0)

        # --- rhs level: all four paths --------------------------------
        r_flat = solver.rhs(q0)                                  # (a)
        r_eager = pdg.permute_out(np.asarray(pdg.rhs(qp)))       # (b)
        r_shard = pdg.permute_out(np.asarray(pipe.rhs(qp)))      # sharded fused
        ex = NestedPartitionExecutor(K, slabs, grid_dims=grid, bucket=4)
        eng = BlockedDGEngine(solver, ex)
        r_blk = eng.pipeline().rhs(q0)                           # (c)
        check(r_flat, r_shard, f"{impl} {grid} P={slabs}: sharded rhs vs flat", bitwise)
        check(r_eager, r_shard, f"{impl} {grid} P={slabs}: sharded rhs vs eager", bitwise)
        check(r_blk, r_shard, f"{impl} {grid} P={slabs}: sharded rhs vs blocked fused", bitwise)

        # --- run level: 3 steps through every driver ------------------
        q_flat = np.asarray(solver.run(q0, 3, dt))               # (a)
        q_shard = pdg.permute_out(np.asarray(pipe.run(qp, 3, dt=dt)))
        q_eager = pdg.permute_out(np.asarray(pdg.run(qp, 3, dt=dt, fused=False)))
        q_blk = np.asarray(eng.run(q0, 3, dt=dt))                # (c)
        check(q_flat, q_shard, f"{impl} {grid} P={slabs}: sharded run vs flat", bitwise)
        check(q_eager, q_shard, f"{impl} {grid} P={slabs}: sharded run vs eager", bitwise)
        # (c) across compiled programs: the blocked program's bucket
        # gather/scatter changes XLA's FMA choices in the lsrk update by
        # ~1 ulp per step (documented in repro/dg/rk.py) — rhs above IS
        # bitwise; the run agrees to contraction noise
        np.testing.assert_allclose(q_blk, q_shard, rtol=1e-12, atol=1e-13,
                                   err_msg=f"{impl} {grid} P={slabs}: blocked run")
        n_checked += 1
assert n_checked == 6

# coupled elastic materials: non-unit lam/mu open FMA-contraction choices
# that differ between compiled programs, so the cross-program agreement is
# ~1 ulp instead of bitwise (same as the repo's existing solver-level
# interpret tests)
solver = periodic_solver((4, 2, 2), "xla", lam=1.1, mu=0.3)
K = solver.mesh.K
rng = np.random.default_rng(7)
q0 = jnp.asarray(rng.standard_normal((K, 9, solver.M, solver.M, solver.M)))
mesh = jax.make_mesh((2,), ("data",))
pdg = PartitionedDG(solver=solver, mesh_axes=mesh)
qp = pdg.permute_in(q0)
dt = solver.cfl_dt()
q_flat = np.asarray(solver.run(q0, 3, dt))
q_shard = pdg.permute_out(np.asarray(pdg.pipeline().run(qp, 3, dt=dt)))
np.testing.assert_allclose(q_shard, q_flat, rtol=1e-12, atol=1e-13,
                           err_msg="elastic periodic: sharded run vs flat")
print("OK", n_checked)
"""


DISPATCH = r"""
import jax
jax.config.update("jax_enable_x64", True)
import numpy as np, jax.numpy as jnp
from repro.dg.mesh import make_brick
from repro.dg.solver import DGSolver
from repro.dg.partitioned import PartitionedDG

mesh_b = make_brick((4, 2, 2), (1.0, 1.0, 0.5), periodic=True)
K = mesh_b.K
solver = DGSolver(mesh=mesh_b, order=2, rho=np.ones(K), lam=np.ones(K),
                  mu=np.zeros(K))
rng = np.random.default_rng(0)
q0 = jnp.asarray(rng.standard_normal((K, 9, 3, 3, 3)))
dt = solver.cfl_dt()

for slabs in (2, 4):
    mesh = jax.make_mesh((slabs,), ("data",))
    pdg = PartitionedDG(solver=solver, mesh_axes=mesh)
    pipe = pdg.pipeline()
    qp = pdg.permute_in(q0)
    # count ACTUAL compiled-program invocations, not the self-reported stat
    calls = []
    orig = pipe._run_fn()
    pipe._run_c = lambda *a, **k: (calls.append(1), orig(*a, **k))[1]
    for n in (1, 3, 7):  # three horizons, ONE compiled program
        before = len(calls)
        d0 = pipe.stats.dispatches
        pipe.run(qp, n, dt=dt)
        assert len(calls) - before == 1, (slabs, n, len(calls) - before)
        assert pipe.stats.dispatches - d0 == 1
    # executor-segmented fused observe run: one dispatch per rebalance
    # chunk, now through the in-scan observation channel (run_observed)
    ex = pdg.bind_executor(pdg.make_executor(rebalance_every=2))
    obs_calls = []
    orig_obs = pipe._priced_run_fn()
    pipe._priced_run_c = lambda *a, **k: (obs_calls.append(1), orig_obs(*a, **k))[1]
    pdg.run(qp, 4, dt=dt, observe=True)
    assert len(obs_calls) == 2, len(obs_calls)  # 4 steps / chunks of 2
    assert pipe.stats.observe_chunks == 2
    assert ex.round >= 1  # the executor rebalanced on schedule
print("OK")
"""


def test_sharded_fused_differential(subproc):
    """Sharded fused == flat == eager slab loop == blocked fused, periodic
    meshes, >= 2 slab counts, both kernel_impl settings (see module doc)."""
    out = subproc(DIFFERENTIAL, n_devices=4)
    assert "OK 6" in out


def test_sharded_dispatch_counts(subproc):
    """1 host dispatch per run — for every horizon and device count — and
    one dispatch per rebalance chunk on the executor path, counted on the
    compiled callable itself."""
    out = subproc(DISPATCH, n_devices=4)
    assert "OK" in out


def test_sharded_run_with_heterogeneous_devices_counts(subproc):
    """The same program serves 2-of-4 and 4-of-4 device meshes in one
    process (per-decomposition compile caches are independent)."""
    out = subproc(
        r"""
import jax
jax.config.update("jax_enable_x64", True)
import numpy as np, jax.numpy as jnp
from repro.dg.solver import make_two_tree_solver, gaussian_pulse
from repro.dg.partitioned import PartitionedDG

solver = make_two_tree_solver(grid=(8, 4, 4), order=2, extent=(2.0, 1.0, 1.0))
q0 = gaussian_pulse(solver, center=(0.5, 0.5, 0.5))
dt = solver.cfl_dt()
q_ref = None
for slabs in (2, 4):
    mesh = jax.make_mesh((slabs,), ("data",))
    pdg = PartitionedDG(solver=solver, mesh_axes=mesh)
    q = pdg.permute_out(np.asarray(pdg.run(pdg.permute_in(q0), 4, dt=dt)))
    if q_ref is None:
        q_ref = q
    else:
        assert (q == q_ref).all(), np.abs(q - q_ref).max()
print("OK")
""",
        n_devices=4,
    )
    assert "OK" in out
