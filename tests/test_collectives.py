"""Hierarchy-aware collectives and overlap primitives (multi-device, via
subprocess with fake devices)."""

def test_overlap_primitives(subproc):
    subproc(
        """
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from functools import partial
from repro.jax_compat import make_mesh, shard_map
from repro.core.overlap import ring_allgather_matmul, matmul_ring_reducescatter, halo_exchange_1d
mesh = make_mesh((4,), ("x",))
key = jax.random.PRNGKey(0)
x = jax.random.normal(key, (16, 8), jnp.float32)
w = jax.random.normal(jax.random.PRNGKey(1), (8, 6), jnp.float32)
f = jax.jit(shard_map(partial(ring_allgather_matmul, axis_name="x"),
    mesh=mesh, in_specs=(P("x", None), P(None, None)), out_specs=P(None, None), check_vma=False))
np.testing.assert_allclose(f(x, w), x @ w, rtol=1e-5)
x2 = jax.random.normal(key, (16, 12), jnp.float32)
w2 = jax.random.normal(jax.random.PRNGKey(2), (12, 6), jnp.float32)
g = jax.jit(shard_map(partial(matmul_ring_reducescatter, axis_name="x"),
    mesh=mesh, in_specs=(P(None, "x"), P("x", None)), out_specs=P("x", None), check_vma=False))
np.testing.assert_allclose(g(x2, w2), x2 @ w2, rtol=1e-4, atol=1e-4)
print("OK")
""",
        n_devices=4,
    )


def test_hierarchical_and_compressed_psum(subproc):
    subproc(
        """
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.jax_compat import make_mesh, shard_map
from repro.core.collectives import hierarchical_psum, hierarchical_psum_compressed
mesh2 = make_mesh((2, 2), ("s", "f"))
y = jax.random.normal(jax.random.PRNGKey(0), (4, 6, 5), jnp.float32)
h = jax.jit(shard_map(lambda v: hierarchical_psum(v, "f", "s"), mesh=mesh2,
    in_specs=P(("s", "f")), out_specs=P(("s", "f")), check_vma=False))
ref = jax.jit(shard_map(lambda v: jax.lax.psum(v, ("s", "f")), mesh=mesh2,
    in_specs=P(("s", "f")), out_specs=P(("s", "f")), check_vma=False))
np.testing.assert_allclose(h(y), ref(y), rtol=1e-5)
hc = jax.jit(shard_map(lambda v: hierarchical_psum_compressed(v, "f", "s"), mesh=mesh2,
    in_specs=P(("s", "f")), out_specs=P(("s", "f")), check_vma=False))
err = np.abs(np.asarray(hc(y)) - np.asarray(ref(y))).max() / np.abs(np.asarray(ref(y))).max()
assert err < 0.02, err
print("OK")
""",
        n_devices=4,
    )


def test_moe_ep_sharded_matches_gspmd(subproc):
    """The shard_map EP dispatch must equal the single-device reference."""
    subproc(
        """
import jax, jax.numpy as jnp, numpy as np
from repro.models.common import ModelConfig
from repro.models.moe import moe_init, moe_apply, moe_ep_sharded
from repro.jax_compat import make_mesh
cfg = ModelConfig(arch_id="m", family="moe", n_layers=1, d_model=16, n_heads=2,
                  n_kv_heads=2, d_ff=32, vocab_size=64, n_experts=4,
                  experts_per_token=2, capacity_factor=8.0)
mesh = make_mesh((2, 2), ("data", "model"))
p = moe_init(jax.random.PRNGKey(0), cfg, ep_size=2)
x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, 16))  # (B, S, d)
ref, _ = moe_apply(p, x.reshape(32, 16), cfg, ep_size=2)
out, met = moe_ep_sharded(p, x, cfg, mesh)
np.testing.assert_allclose(np.asarray(out).reshape(32, 16), np.asarray(ref), rtol=1e-4, atol=1e-4)
print("OK drop:", float(met["drop_frac"]))
""",
        n_devices=4,
    )
