"""Cluster-level nested partitioning: the Morton inter-node splice
(``ClusterPartition``), the hierarchical two-level solve, and the simulated
heterogeneous cluster (``SimulatedCluster``).

Property-based invariants (hypothesis, skipped gracefully when absent):
  * node element sets are a disjoint cover of the mesh;
  * each node's set is contiguous in Morton curve order;
  * every node's boundary/interior/halo sets remain a validated disjoint
    cover under random meshes, node counts and weights.

Differential invariant: the N-node ``SimulatedCluster`` step matches the
flat single-partition solver bitwise on periodic meshes — the cluster level
is a reordering, never an approximation (the same invariant
``tests/test_executor.py`` pins for the single-node engine).
"""

import numpy as np
import pytest

from hypothesis_shim import given, settings, st

from repro.core import (
    build_cluster_partition,
    curve_rank,
    face_cut_matrix,
    face_neighbors,
    is_curve_contiguous,
    morton_order,
    node_weights_from_devices,
)
from repro.core.topology import STAMPEDE_MIC, STAMPEDE_SNB_SOCKET

grids = st.tuples(st.integers(2, 6), st.integers(2, 6), st.integers(2, 6))


# ---------------------------------------------------------------------------
# Morton curve helpers
# ---------------------------------------------------------------------------


def test_curve_rank_is_inverse_permutation():
    order = morton_order((4, 3, 2))
    rank = curve_rank(order)
    np.testing.assert_array_equal(order[rank], np.arange(24))
    np.testing.assert_array_equal(rank[order], np.arange(24))


def test_is_curve_contiguous():
    order = morton_order((4, 4, 2))
    assert is_curve_contiguous(order, order[5:17])  # a curve run
    assert is_curve_contiguous(order, order[:0])  # empty set is trivially so
    assert not is_curve_contiguous(order, order[[0, 2]])  # gap on the curve


# ---------------------------------------------------------------------------
# ClusterPartition invariants (property-based)
# ---------------------------------------------------------------------------


@given(grids, st.integers(1, 6), st.floats(0.0, 1.0))
@settings(max_examples=25, deadline=None)
def test_cluster_partition_invariants(grid, n_nodes, frac):
    """Random meshes and node counts: disjoint cover, curve contiguity,
    per-node boundary/interior/halo validated covers."""
    K = int(np.prod(grid))
    n_nodes = min(n_nodes, K)
    part = build_cluster_partition(grid, n_nodes, accel_fraction=frac)
    part.validate()  # cover + contiguity + splice-follows-weights + nested
    # explicit disjoint-cover re-check (independent of validate's internals)
    counts = np.zeros(K, dtype=np.int64)
    for npart in part.nodes:
        counts[npart.elements] += 1
        assert is_curve_contiguous(part.order, npart.elements)
        both = np.concatenate([npart.boundary, npart.interior])
        assert len(np.unique(both)) == len(both)
        np.testing.assert_array_equal(np.sort(both), np.sort(npart.elements))
        if npart.halo is not None and len(npart.halo):
            assert (part.node_of[npart.halo] != npart.node).all()
    assert (counts == 1).all()


@given(grids, st.lists(st.floats(0.1, 10.0), min_size=1, max_size=5))
@settings(max_examples=25, deadline=None)
def test_cluster_partition_weighted_invariants(grid, weights):
    """Heterogeneous (throughput-weighted) splices keep every invariant and
    track the weights to within the largest-remainder bound."""
    K = int(np.prod(grid))
    if K < len(weights):
        weights = weights[:K]
    part = build_cluster_partition(grid, node_weights=weights, accel_fraction=0.3)
    part.validate()
    sizes = np.array([n.n_elements for n in part.nodes])
    ideal = K * np.asarray(weights) / np.sum(weights)
    assert np.abs(sizes - ideal).max() < 1.0 + 1e-9


def test_cluster_partition_from_device_classes():
    """Level-0 weights from per-node DeviceClass throughput: the faster
    device class owns proportionally more of the curve."""
    w = node_weights_from_devices([STAMPEDE_SNB_SOCKET, STAMPEDE_MIC])
    assert w[1] > w[0]  # the MIC's sustained flops exceed the socket's
    np.testing.assert_allclose(w.sum(), 1.0)
    part = build_cluster_partition((6, 6, 4), node_devices=[STAMPEDE_SNB_SOCKET, STAMPEDE_MIC])
    part.validate()
    sizes = [n.n_elements for n in part.nodes]
    assert sizes[1] > sizes[0]
    with pytest.raises(ValueError):
        build_cluster_partition((4, 4, 4), node_devices=[STAMPEDE_MIC], node_weights=[1.0])
    with pytest.raises(ValueError):
        build_cluster_partition((4, 4, 4))  # no node count at all


def test_face_cut_matrix_symmetry_and_halo_bytes():
    """Structured meshes: every cross-node face is seen from both sides, so
    the cut matrix is symmetric, has an empty diagonal, and prices the halo."""
    grid = (6, 4, 4)
    part = build_cluster_partition(grid, 4)
    M = part.face_cuts()
    np.testing.assert_array_equal(M, M.T)
    assert (np.diag(M) == 0).all()
    # consistency with the per-node halo: a node with exchange partners has
    # nonzero priced bytes and at least one peer
    nbytes = part.halo_bytes(order=3)
    peers = part.halo_peers()
    for i in range(4):
        assert (nbytes[i] > 0) == (peers[i] > 0) == (M[i].sum() > 0)
    # the raw helper agrees with the partition's own neighbour table
    M2 = face_cut_matrix(part.node_of, face_neighbors(grid), 4)
    np.testing.assert_array_equal(M, M2)


def test_single_node_cluster_has_no_cuts():
    part = build_cluster_partition((4, 4, 2), 1, accel_fraction=0.5)
    part.validate()
    assert part.face_cuts().sum() == 0
    assert part.halo_bytes(order=3).sum() == 0


# ---------------------------------------------------------------------------
# Hierarchical solve (golden values on the paper's profile live in
# test_core.py; here: plumbing into the cluster partition)
# ---------------------------------------------------------------------------


def test_hierarchical_split_builds_valid_cluster_partition():
    from repro.core import NodeModel, solve_hierarchical
    from repro.core.cost_model import stampede_node_models

    t_cpu, t_mic, xfer = stampede_node_models(order=7)
    nodes = [NodeModel(t_host=t_cpu, t_accel=t_mic, transfer=xfer)] * 4
    hs = solve_hierarchical(nodes, 512)
    part = build_cluster_partition(
        (8, 8, 8), node_weights=np.maximum(hs.node_counts, 1e-9),
        accel_counts=hs.accel_counts,
    )
    part.validate()
    np.testing.assert_array_equal(
        [n.n_elements for n in part.nodes], hs.node_counts
    )
    # solved accel blocks land in the partition (clamped to interior)
    for npart, ka in zip(part.nodes, hs.accel_counts):
        assert len(npart.accel) == min(ka, len(npart.interior))


# ---------------------------------------------------------------------------
# SimulatedCluster: differential bitwise invariant + two-level loop
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def periodic_setup():
    import jax.numpy as jnp

    from repro.dg.mesh import make_brick
    from repro.dg.solver import DGSolver

    mesh = make_brick((4, 4, 2), (1.0, 1.0, 0.5), periodic=True)
    K = mesh.K
    solver = DGSolver(mesh=mesh, order=2, rho=np.ones(K), lam=np.ones(K), mu=np.zeros(K))
    rng = np.random.default_rng(0)
    q0 = jnp.asarray(rng.standard_normal((K, 9, solver.M, solver.M, solver.M)))
    return solver, q0


def _flat_reference(solver, q0, n_steps, dt):
    import jax
    import jax.numpy as jnp

    from repro.dg.rk import lsrk45_step

    rhs = jax.jit(solver.rhs)
    q, res = q0, jnp.zeros_like(q0)
    for _ in range(n_steps):
        q, res = lsrk45_step(q, res, rhs, dt)
    return q


def test_cluster_rhs_matches_flat_bitwise_on_periodic_mesh(periodic_setup):
    """Acceptance: the N-node cluster step equals the flat solver bitwise on
    a periodic mesh (wrap-around cross-node faces enter the halos)."""
    from repro.runtime.cluster import NodeProfile, SimulatedCluster

    solver, q0 = periodic_setup
    cl = SimulatedCluster(solver, [NodeProfile(name=f"n{i}") for i in range(3)])
    cl.cluster_partition().validate()
    r_flat = np.asarray(solver.rhs(q0))
    r_cl = np.asarray(cl.rhs(q0))
    assert (r_flat == r_cl).all(), np.abs(r_flat - r_cl).max()


def test_cluster_run_matches_flat_after_rebalance(periodic_setup):
    """The differential invariant survives the online loop: observe
    simulated heterogeneous times, rebalance the inter-node splice, step —
    still bitwise (up to float reassociation across steps, as in
    test_executor.py)."""
    from repro.runtime.cluster import NodeProfile, SimulatedCluster

    solver, q0 = periodic_setup
    # speed ratio 1:3 so the common-finish split (8, 24) sits exactly on
    # bucket multiples — K=32 is too coarse to represent e.g. 1:2 within 10%
    cl = SimulatedCluster(solver, [NodeProfile(speed=1.0), NodeProfile(speed=3.0)])
    rounds = cl.run_until_balanced(rtol=0.10, max_rounds=8)
    assert rounds <= 3, rounds
    assert cl.counts[1] > cl.counts[0]  # the 3x node absorbed work
    assert cl.counts[1] / max(1, cl.counts[0]) == pytest.approx(3.0, rel=0.35)
    cl.cluster_partition().validate()

    dt = solver.cfl_dt()
    q_flat = np.asarray(_flat_reference(solver, q0, 2, dt))
    q_cl = np.asarray(cl.run(q0, 2, dt=dt))
    np.testing.assert_allclose(q_cl, q_flat, rtol=1e-12, atol=1e-14)
    # single rhs evaluation stays exactly bitwise
    assert (np.asarray(solver.rhs(q0)) == np.asarray(cl.rhs(q0))).all()


def test_cluster_straggler_hook_rebalances(periodic_setup):
    """The existing straggler hook at cluster level: a slow node sheds work
    through the same equalizer (3x so the optimum is bucket-representable)."""
    from repro.runtime.cluster import NodeProfile, SimulatedCluster

    solver, q0 = periodic_setup
    cl = SimulatedCluster(solver, [NodeProfile(), NodeProfile()])
    cl.inject_straggler(0, 3.0)
    rounds = cl.run_until_balanced(rtol=0.10, max_rounds=8)
    assert rounds <= 4, rounds
    assert cl.counts[0] < cl.counts[1]
    assert (np.asarray(solver.rhs(q0)) == np.asarray(cl.rhs(q0))).all()


def test_cluster_two_level_resolve(periodic_setup):
    """resolve() re-solves both levels from a per-node CalibrationReport:
    level 1 moves the inter-node splice, level 2 installs per-node accel
    counts — and the partition stays valid and bitwise."""
    from repro.runtime.cluster import SimulatedCluster, stampede_profile

    solver, q0 = periodic_setup
    cl = SimulatedCluster(
        solver, [stampede_profile(order=2, name=f"n{i}") for i in range(2)]
    )
    rep = cl.calibrate(q0, reps=1)
    assert (rep.step_s > 0).all()
    # the wire model enters the transfer phase
    assert (rep.transfer_s >= cl.comm_times()).all()
    plan = cl.resolve(rep)
    assert int(plan.counts.sum()) == solver.mesh.K
    assert cl.executor.accel_counts is not None  # level 2 ran
    cl.cluster_partition().validate()
    assert (np.asarray(solver.rhs(q0)) == np.asarray(cl.rhs(q0))).all()


def test_cluster_summary_and_plan_format(periodic_setup):
    from repro.runtime.cluster import NodeProfile, SimulatedCluster, format_cluster_plan

    solver, _ = periodic_setup
    cl = SimulatedCluster(solver, [NodeProfile(name="a"), NodeProfile(name="b", speed=2.0)])
    s = cl.summary()
    assert "a[0]" in s and "b[1]" in s and "comm=" in s
    plan = format_cluster_plan((8, 8, 4), 4, order=7)
    assert "level 0 (Morton inter-node splice)" in plan
    assert plan.count("node") >= 4 and "K_acc/K_host=" in plan
    het = format_cluster_plan((8, 8, 4), 2, order=7, speeds=[1.0, 2.0])
    assert "speed 2" in het
    with pytest.raises(ValueError):
        format_cluster_plan((8, 8, 4), 2, speeds=[1.0])


def test_cluster_rejects_bad_profiles(periodic_setup):
    from repro.runtime.cluster import NodeProfile, SimulatedCluster

    solver, _ = periodic_setup
    with pytest.raises(ValueError):
        SimulatedCluster(solver, [])
    with pytest.raises(ValueError):
        NodeProfile(speed=0.0)
    cl = SimulatedCluster(solver, [NodeProfile()])
    with pytest.raises(RuntimeError):
        cl.node_models()  # profile carries no calibrated models


# ---------------------------------------------------------------------------
# Executor: per-partition accel counts (the level-2 installation path)
# ---------------------------------------------------------------------------


def test_executor_set_accel_counts_resplices():
    from repro.runtime.executor import NestedPartitionExecutor

    ex = NestedPartitionExecutor(512, 2, grid_dims=(8, 8, 8), bucket=8)
    ex.set_accel_counts([60, 40])
    for p, want in zip(ex.partition.nodes, (60, 40)):
        assert len(p.accel) == min(want, len(p.interior))
    ex.partition.validate()
    with pytest.raises(ValueError):
        ex.set_accel_counts([1, 2, 3])  # wrong arity
    with pytest.raises(ValueError):
        ex.set_accel_counts([-1, 4])
    ex.set_accel_counts(None)  # revert to accel_fraction (0.0)
    assert ex.partition.accel_mask.sum() == 0


# ---------------------------------------------------------------------------
# Fused cluster driver: grouped batching + in-scan link pricing
# ---------------------------------------------------------------------------


def test_cluster_fused_run_matches_eager_and_flat(periodic_setup):
    """run(fused=True) — every node's block inside one donated scan-compiled
    program — matches the eager per-step cluster driver and the flat solver
    (to the documented ~1 ulp lsrk FMA contraction of repro/dg/rk.py)."""
    from repro.runtime.cluster import NodeProfile, SimulatedCluster

    solver, q0 = periodic_setup
    cl = SimulatedCluster(
        solver,
        [NodeProfile(name="a"), NodeProfile(name="b", speed=2.0), NodeProfile(name="a")],
    )
    dt = solver.cfl_dt()
    q_eager = np.asarray(cl.run(q0, 2, dt=dt, fused=False))
    q_fused = np.asarray(cl.run(q0, 2, dt=dt))
    np.testing.assert_allclose(q_fused, q_eager, rtol=1e-12, atol=1e-14)
    q_flat = np.asarray(_flat_reference(solver, q0, 2, dt))
    np.testing.assert_allclose(q_fused, q_flat, rtol=1e-12, atol=1e-14)
    # single fused rhs evaluation stays exactly bitwise vs the flat solver
    pipe = cl.fused_pipeline()
    assert (np.asarray(pipe.rhs(q0)) == np.asarray(solver.rhs(q0))).all()


def test_cluster_fused_groups_by_profile(periodic_setup):
    """The default envelope layout collapses ALL profile groups into ONE
    volume + ONE surface launch per rhs; layout="grouped" (the differential
    reference) still batches each (name, speed) profile class separately."""
    from repro.runtime.cluster import NodeProfile, SimulatedCluster

    solver, q0 = periodic_setup
    cl = SimulatedCluster(
        solver,
        [NodeProfile(name="a"), NodeProfile(name="b", speed=2.0), NodeProfile(name="a")],
    )
    np.testing.assert_array_equal(cl.profile_groups(), [0, 1, 0])
    env = cl.fused_pipeline()
    assert len(env.bucket_signature) == 1
    assert sum(B for (_, _, B, _) in env.bucket_signature) == 3
    grouped = cl.fused_pipeline(layout="grouped")
    sig = grouped.bucket_signature
    assert sorted(set(g for (_, _, _, g) in sig)) == [0, 1]
    # the "a" nodes may share launches; "b" never rides with them
    assert sum(B for (_, _, B, g) in sig if g == 1) == 1
    # one launch pair even across profile classes, and bitwise-identical
    r_env = np.asarray(env.rhs(q0))
    r_grp = np.asarray(grouped.rhs(q0))
    assert (r_env == r_grp).all()
    assert env.stats.kernel_launches == {"volume": 1, "surface": 1}
    assert grouped.stats.kernel_launches["volume"] == len(sig)


def test_cluster_fused_prices_link_inside_scan(periodic_setup):
    """The simulated per-node step price (compute/speed + alpha-beta link on
    the exact face cuts) is accumulated inside the compiled scan and feeds
    the executor on the observe path."""
    from repro.runtime.cluster import NodeProfile, SimulatedCluster

    solver, q0 = periodic_setup
    cl = SimulatedCluster(solver, [NodeProfile(speed=1.0), NodeProfile(speed=3.0)],
                          rebalance_every=2)
    dt = solver.cfl_dt()
    expect = cl.step_times()
    cl.run(q0, 2, dt=dt)
    np.testing.assert_allclose(cl.last_sim_times, expect, rtol=1e-12)
    # comm is priced in: the accumulated times exceed pure compute/speed
    assert (cl.last_sim_times >= cl.comm_times()).all()
    # observe path: the in-scan prices enter the EWMA and the executor
    # rebalances on schedule toward the fast node
    q1 = cl.run(q0, 4, dt=dt, observe=True)
    assert cl.executor.round >= 1
    assert cl.counts[1] > cl.counts[0]
    assert np.isfinite(np.asarray(q1)).all()
