"""StepSchedule + CalibrationReport: the four-phase boundary/interior
decomposition, the overlap-aware step model, and the generic overlap_map
pipeline the ring collectives are built on."""

import numpy as np
import pytest

from repro.core.load_balance import solve_multiway, solve_two_way
from repro.runtime.schedule import CalibrationReport, StepSchedule


# ---------------------------------------------------------------------------
# StepSchedule composition
# ---------------------------------------------------------------------------


def test_schedule_composes_in_phase_order():
    trace = []

    sched = StepSchedule(
        boundary=lambda st: (trace.append("boundary"), st * 2)[1],
        exchange=lambda send, st: (trace.append("exchange"), send + 1)[1],
        interior=lambda st: (trace.append("interior"), st + 10)[1],
        correction=lambda part, recv, st: (trace.append("correction"), part + recv)[1],
    )
    # state=3: send=6, recv=7, part=13, out=20
    assert sched.rhs(3) == 20
    # exchange is issued BEFORE interior — the overlap order
    assert trace == ["boundary", "exchange", "interior", "correction"]


def test_schedule_phase_names():
    assert StepSchedule.PHASES == ("boundary", "exchange", "interior", "correction")


# ---------------------------------------------------------------------------
# overlap_map: the generic compute-over-communication pipeline
# ---------------------------------------------------------------------------


def test_overlap_map_rounds_and_final_compute():
    from repro.core.overlap import overlap_map

    events = []

    def compute(i, c):
        events.append(("c", i))
        return c + [i]

    def communicate(i, c):
        events.append(("x", i))
        return c

    out = overlap_map(3, compute, communicate, [])
    assert out == [0, 1, 2]
    # every round except the last communicates; the final round only computes
    assert events == [("c", 0), ("x", 0), ("c", 1), ("x", 1), ("c", 2)]


def test_overlap_map_single_round_never_communicates():
    from repro.core.overlap import overlap_map

    def boom(i, c):
        raise AssertionError("single round must not communicate")

    assert overlap_map(1, lambda i, c: c + 1, boom, 41) == 42
    with pytest.raises(ValueError):
        overlap_map(0, lambda i, c: c, lambda i, c: c, None)


# ---------------------------------------------------------------------------
# CalibrationReport: overlap-aware step model
# ---------------------------------------------------------------------------


def _report():
    return CalibrationReport(
        boundary_s=np.array([0.1, 0.2]),
        interior_s=np.array([1.0, 0.3]),
        transfer_s=np.array([0.4, 0.6]),
        correction_s=np.array([0.05, 0.05]),
    )


def test_report_step_models():
    r = _report()
    np.testing.assert_allclose(r.step_s, [1.55, 1.15])
    # overlapped: boundary + max(interior, transfer) + correction
    np.testing.assert_allclose(r.overlapped_s, [1.15, 0.85])
    np.testing.assert_allclose(r.hidden_s, [0.4, 0.3])
    # p0 hides all of its transfer; p1 only the interior's worth
    np.testing.assert_allclose(r.overlap_efficiency, [1.0, 0.5])


def test_report_defaults_and_from_totals():
    r = CalibrationReport(boundary_s=np.ones(2), interior_s=np.ones(2),
                          transfer_s=np.zeros(2))
    np.testing.assert_allclose(r.correction_s, 0.0)
    # no transfer at all -> trivially fully hidden
    np.testing.assert_allclose(r.overlap_efficiency, 1.0)

    t = CalibrationReport.from_totals([0.5, 0.7])
    np.testing.assert_allclose(t.step_s, [0.5, 0.7])
    np.testing.assert_allclose(t.boundary_s, 0.0)
    np.testing.assert_allclose(t.transfer_s, 0.0)


def test_report_median():
    a = CalibrationReport.from_totals([1.0, 1.0])
    b = CalibrationReport.from_totals([3.0, 5.0])
    c = CalibrationReport.from_totals([2.0, 9.0])
    med = CalibrationReport.median([a, b, c])
    np.testing.assert_allclose(med.interior_s, [2.0, 5.0])
    # a lazily-consumed iterable works too (it is materialized internally)
    med2 = CalibrationReport.median(r for r in (a, b, c))
    np.testing.assert_allclose(med2.interior_s, med.interior_s)


def test_report_median_empty_raises_clear_error():
    """Regression: an empty input must raise a clear ValueError, not numpy's
    opaque 'need at least one array to stack' — including the generator
    case that used to slip past the truthiness check."""
    with pytest.raises(ValueError, match="at least one report"):
        CalibrationReport.median([])
    with pytest.raises(ValueError, match="at least one report"):
        CalibrationReport.median(r for r in [])
    with pytest.raises(ValueError, match="at least one report"):
        CalibrationReport.median(iter(()))


def test_report_summary_has_overlap_efficiency_column():
    s = _report().summary()
    assert "overlap-eff=100%" in s and "overlap-eff=50%" in s
    assert "correction=" in s and "overlapped=" in s


def test_time_models_dead_partition_gets_fleet_prior():
    """A partition with no calibrated work (count was 0 when measured) must
    not get an identically-zero model — the waterfilling solve would dump
    the whole workload on it.  It gets the fleet-mean phases instead."""
    rep = CalibrationReport(
        boundary_s=np.array([0.01, 0.0]),
        interior_s=np.array([0.10, 0.0]),
        transfer_s=np.array([0.02, 0.0]),
    )
    fns = rep.time_models([100, 0], overlap=True)
    assert fns[1](100) > 0.0
    res = solve_multiway(fns, 200)
    # the dead partition is treated as fleet-average, not infinitely fast
    assert 0 < res.counts[1] <= 150, res.counts
    # all-dead fleet degrades to an even split rather than blowing up
    dead = CalibrationReport.from_totals([0.0, 0.0])
    res2 = solve_multiway(dead.time_models([1, 1]), 100)
    assert sum(res2.counts) == 100


def test_time_models_credit_hidden_transfer():
    """The overlap model yields a strictly lower solved makespan than the
    sequential model when a partition's transfer can hide under interior."""
    r = _report()
    counts = [100, 100]
    seq = solve_multiway(r.time_models(counts, overlap=False), 200)
    ov = solve_multiway(r.time_models(counts, overlap=True), 200)
    assert ov.makespan < seq.makespan
    # the model evaluated at the calibrated counts reproduces the report
    fns = r.time_models(counts, overlap=True)
    np.testing.assert_allclose([fns[p](100) for p in range(2)], r.overlapped_s)
    assert fns[0](0) == 0.0


# ---------------------------------------------------------------------------
# solve_two_way overlap mode (the fig5_3 --overlap model)
# ---------------------------------------------------------------------------


def test_two_way_overlap_strictly_lower_for_transfer_bound():
    t_host = lambda k: k * 1.0
    t_accel = lambda k: k * 0.5
    xfer = lambda k: k * 0.4  # transfer-bound: a large per-item link cost
    off = solve_two_way(t_host, t_accel, 1000, transfer=xfer, overlap=False)
    on = solve_two_way(t_host, t_accel, 1000, transfer=xfer, overlap=True)
    assert on.makespan < off.makespan
    # the transfer is charged to the host side; hiding it makes the host
    # side cheaper, so the host keeps more work than in the sequential model
    assert on.counts[0] >= off.counts[0]


def test_two_way_overlap_noop_without_transfer():
    t_host = lambda k: k * 1.0
    t_accel = lambda k: k * 0.5
    off = solve_two_way(t_host, t_accel, 999, overlap=False)
    on = solve_two_way(t_host, t_accel, 999, overlap=True)
    assert on.counts == off.counts
    assert on.makespan == pytest.approx(off.makespan)


def test_fig5_3_overlap_makespans_strictly_lower():
    """Acceptance: the benchmark's modeled makespan with the overlap
    schedule on is strictly lower than off for transfer-bound shapes."""
    from benchmarks.fig5_3_transfer import _overlap_makespans

    for K in (2048, 8192):
        off, on = _overlap_makespans(K, order=7, per_stage=True)
        assert on.makespan < off.makespan, (K, on.makespan, off.makespan)
