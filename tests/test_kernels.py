"""Pallas kernels (interpret mode) vs pure-jnp oracles: shape/dtype sweeps."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.dg.basis import diff_matrix, lgl_nodes_weights
from repro.kernels import ref
from repro.kernels.dg_flux import dg_flux_pallas
from repro.kernels.dg_volume import dg_volume_pallas
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.ops import dg_flux, dg_volume, flash_attention_op

RNG = np.random.default_rng(7)


def _tol(dt):
    return dict(rtol=5e-4, atol=5e-4) if dt == "float32" else dict(rtol=1e-11, atol=1e-11)


@pytest.mark.parametrize("K,order", [(16, 7), (24, 3), (7, 5), (1, 2)])
@pytest.mark.parametrize("dt", ["float32", "float64"])
def test_dg_volume_kernel(K, order, dt):
    M = order + 1
    x, _ = lgl_nodes_weights(order)
    D = jnp.asarray(diff_matrix(x), dt)
    q = jnp.asarray(RNG.standard_normal((K, 9, M, M, M)), dt)
    rho = jnp.asarray(RNG.uniform(0.5, 2, K), dt)
    lam = jnp.asarray(RNG.uniform(0.5, 2, K), dt)
    mu = jnp.asarray(RNG.uniform(0, 2, K), dt)
    metrics = (2.0, 3.0, 4.0)
    out = dg_volume_pallas(q, D, metrics, rho, lam, mu, interpret=True)
    want = ref.dg_volume_ref(q, D, metrics, rho, lam, mu)
    np.testing.assert_allclose(out, want, **_tol(dt))


@pytest.mark.parametrize("F,M", [(10, 8), (200, 4), (128, 8)])
@pytest.mark.parametrize("dt", ["float32", "float64"])
@pytest.mark.parametrize("axis,sign", [(0, 1.0), (1, -1.0), (2, 1.0)])
def test_dg_flux_kernel(F, M, dt, axis, sign):
    Sm = jnp.asarray(RNG.standard_normal((F, 6, M, M)), dt)
    vm = jnp.asarray(RNG.standard_normal((F, 3, M, M)), dt)
    Sp = jnp.asarray(RNG.standard_normal((F, 6, M, M)), dt)
    vp = jnp.asarray(RNG.standard_normal((F, 3, M, M)), dt)
    mats = np.abs(RNG.standard_normal((F, 8))) + 0.5
    mats[: F // 3, 3] = 0.0  # acoustic minus side -> k1 = 0 branch
    mats = jnp.asarray(mats, dt)
    FE1, Fv1 = dg_flux_pallas(Sm, vm, Sp, vp, mats, axis, sign, interpret=True)
    FE2, Fv2 = ref.dg_flux_ref(Sm, vm, Sp, vp, mats, axis, sign)
    np.testing.assert_allclose(FE1, FE2, **_tol(dt))
    np.testing.assert_allclose(Fv1, Fv2, **_tol(dt))


@pytest.mark.parametrize("S,D,blocks", [(256, 64, (64, 64)), (192, 32, (64, 32)), (128, 128, (128, 128))])
@pytest.mark.parametrize("mode", ["causal", "encoder", "swa"])
@pytest.mark.parametrize("dt", ["float32", "bfloat16"])
def test_flash_kernel(S, D, blocks, mode, dt):
    B, H = 2, 2
    ks = jax.random.split(jax.random.PRNGKey(5), 3)
    q = jax.random.normal(ks[0], (B, H, S, D), dt)
    k = jax.random.normal(ks[1], (B, H, S, D), dt)
    v = jax.random.normal(ks[2], (B, H, S, D), dt)
    kw = dict(causal=(mode != "encoder"), window=(S // 4 if mode == "swa" else None))
    out = flash_attention_pallas(q, k, v, block_q=blocks[0], block_k=blocks[1],
                                 interpret=True, **kw)
    want = ref.flash_attention_ref(q, k, v, **kw)
    tol = dict(rtol=5e-4, atol=5e-4) if dt == "float32" else dict(rtol=2e-2, atol=2e-2)
    np.testing.assert_allclose(np.asarray(out, np.float32), np.asarray(want, np.float32), **tol)


def test_ops_impl_switch():
    """xla / interpret impls agree through the ops wrappers."""
    order = 3
    M = order + 1
    x, _ = lgl_nodes_weights(order)
    D = jnp.asarray(diff_matrix(x), "float32")
    q = jnp.asarray(RNG.standard_normal((8, 9, M, M, M)), "float32")
    rho = jnp.ones(8, jnp.float32)
    lam = jnp.ones(8, jnp.float32)
    mu = jnp.ones(8, jnp.float32)
    a = dg_volume(q, D, (2.0, 2.0, 2.0), rho, lam, mu, impl="xla")
    b = dg_volume(q, D, (2.0, 2.0, 2.0), rho, lam, mu, impl="interpret")
    np.testing.assert_allclose(a, b, rtol=5e-4, atol=5e-4)
