"""Fault tolerance: retry, checkpoint-restore replay, straggler detection."""

import numpy as np
import pytest

from repro.runtime import FailureInjector, StepTimer, TrainSupervisor
from repro.core.load_balance import rebalance_from_measurements


def _make_harness(fail_at=None, max_retries=0):
    """Tiny deterministic 'training': state = sum of batches consumed."""
    saves = {}
    log = []

    def batch_fn(step):
        return float(step + 1)

    def step_fn(state, step, batch):
        if fail_at is not None:
            injector.maybe_fail(step)
        return state + batch, {"state": state + batch}

    def save_fn(step, state):
        saves[step] = state

    def restore_fn():
        if not saves:
            return 0, 0.0
        s = max(saves)
        return s, saves[s]

    injector = FailureInjector({fail_at: "preempt"} if fail_at is not None else {})
    sup = TrainSupervisor(
        step_fn, batch_fn, save_fn, restore_fn,
        ckpt_every=3, max_retries=max_retries, injector=injector,
        on_metrics=lambda step, m, dt, st: log.append(step),
    )
    return sup, saves, log


def test_supervisor_plain_run():
    sup, saves, log = _make_harness()
    step, state = sup.run(0.0, 0, 10)
    assert step == 10 and state == sum(range(1, 11))
    assert sup.restarts == 0


def test_supervisor_retry_absorbs_transient():
    sup, saves, log = _make_harness(fail_at=4, max_retries=1)
    step, state = sup.run(0.0, 0, 10)
    assert state == sum(range(1, 11))
    assert sup.retries == 1 and sup.restarts == 0


def test_supervisor_restore_replays_identically():
    """With no retries, a failure forces restore + replay; the deterministic
    pipeline must land on the exact same final state."""
    sup, saves, log = _make_harness(fail_at=7, max_retries=0)
    step, state = sup.run(0.0, 0, 12)
    assert sup.restarts == 1
    assert state == sum(range(1, 13))  # bit-identical replay


def test_steptimer_flags_stragglers():
    t = StepTimer(alpha=1.0, straggler_factor=1.4)
    flags = t.update({"n0": 1.0, "n1": 1.0, "n2": 1.0, "n3": 2.0})
    assert flags == ["n3"]
    w = t.rebalance([100, 100, 100, 100], ["n0", "n1", "n2", "n3"])
    assert w[3] < w[0]  # the straggler gets less work


def test_rebalance_equalizes_predicted_times():
    counts = np.array([100, 100])
    times = np.array([1.0, 3.0])
    w = rebalance_from_measurements(counts, times, smoothing=1.0)
    new_counts = 200 * w
    thr = counts / times
    predicted = new_counts / thr
    assert abs(predicted[0] - predicted[1]) / predicted.max() < 1e-6


def test_injector_dict_form_fires_once_per_step():
    inj = FailureInjector({5: "preempt"})
    with pytest.raises(Exception):
        inj.maybe_fail(5)
    inj.maybe_fail(5)  # a retried step succeeds: the fault was transient
    assert inj.injected == 1


def test_injector_probabilistic_is_seed_deterministic():
    """The Bernoulli form is keyed on (seed, step): the same seed injects
    the identical failure pattern regardless of probe/retry interleaving."""

    def pattern(probe_twice):
        inj = FailureInjector(seed=11, p_fail=0.25)
        hits = []
        for s in range(40):
            for _ in range(2 if probe_twice else 1):
                try:
                    inj.maybe_fail(s)
                except Exception:
                    hits.append(s)
        return hits

    a, b = pattern(False), pattern(True)
    assert a == b and 0 < len(a) < 40
    other = FailureInjector(seed=12, p_fail=0.25)
    hits = []
    for s in range(40):
        try:
            other.maybe_fail(s)
        except Exception:
            hits.append(s)
    assert hits != a  # a different seed draws a different schedule


def test_injector_max_failures_caps_injection():
    inj = FailureInjector(seed=0, p_fail=1.0, max_failures=3)
    n = 0
    for s in range(10):
        try:
            inj.maybe_fail(s)
        except Exception:
            n += 1
    assert n == 3 and inj.injected == 3


def test_steptimer_flags_are_not_sticky():
    """Hysteresis: a straggler that recovers is unflagged (its streak
    resets) — the recovery half of the ejection loop."""
    t = StepTimer(alpha=1.0, straggler_factor=1.4)
    assert t.update({"n0": 1.0, "n1": 1.0, "n2": 1.0, "n3": 2.0}) == ["n3"]
    assert t.streak["n3"] == 1
    assert t.update({"n0": 1.0, "n1": 1.0, "n2": 1.0, "n3": 2.0}) == ["n3"]
    assert t.persistent(2) == ["n3"]
    assert t.update({"n0": 1.0, "n1": 1.0, "n2": 1.0, "n3": 1.0}) == []
    assert t.streak["n3"] == 0 and t.persistent(1) == []


def test_steptimer_recovery_factor_hysteresis():
    """With a recovery_factor below the straggler threshold, a key between
    the two stays flagged (no flapping at the boundary)."""
    t = StepTimer(alpha=1.0, straggler_factor=1.5, recovery_factor=1.1)
    t.update({"a": 1.0, "b": 1.0, "c": 2.0})
    assert "c" in t.flagged
    flags = t.update({"a": 1.0, "b": 1.0, "c": 1.3})  # between 1.1x and 1.5x
    assert flags == ["c"] and t.streak["c"] == 2
    assert t.update({"a": 1.0, "b": 1.0, "c": 1.0}) == []
