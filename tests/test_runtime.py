"""Fault tolerance: retry, checkpoint-restore replay, straggler detection."""

import numpy as np
import pytest

from repro.runtime import FailureInjector, StepTimer, TrainSupervisor
from repro.core.load_balance import rebalance_from_measurements


def _make_harness(fail_at=None, max_retries=0):
    """Tiny deterministic 'training': state = sum of batches consumed."""
    saves = {}
    log = []

    def batch_fn(step):
        return float(step + 1)

    def step_fn(state, step, batch):
        if fail_at is not None:
            injector.maybe_fail(step)
        return state + batch, {"state": state + batch}

    def save_fn(step, state):
        saves[step] = state

    def restore_fn():
        if not saves:
            return 0, 0.0
        s = max(saves)
        return s, saves[s]

    injector = FailureInjector({fail_at: "preempt"} if fail_at is not None else {})
    sup = TrainSupervisor(
        step_fn, batch_fn, save_fn, restore_fn,
        ckpt_every=3, max_retries=max_retries, injector=injector,
        on_metrics=lambda step, m, dt, st: log.append(step),
    )
    return sup, saves, log


def test_supervisor_plain_run():
    sup, saves, log = _make_harness()
    step, state = sup.run(0.0, 0, 10)
    assert step == 10 and state == sum(range(1, 11))
    assert sup.restarts == 0


def test_supervisor_retry_absorbs_transient():
    sup, saves, log = _make_harness(fail_at=4, max_retries=1)
    step, state = sup.run(0.0, 0, 10)
    assert state == sum(range(1, 11))
    assert sup.retries == 1 and sup.restarts == 0


def test_supervisor_restore_replays_identically():
    """With no retries, a failure forces restore + replay; the deterministic
    pipeline must land on the exact same final state."""
    sup, saves, log = _make_harness(fail_at=7, max_retries=0)
    step, state = sup.run(0.0, 0, 12)
    assert sup.restarts == 1
    assert state == sum(range(1, 13))  # bit-identical replay


def test_steptimer_flags_stragglers():
    t = StepTimer(alpha=1.0, straggler_factor=1.4)
    flags = t.update({"n0": 1.0, "n1": 1.0, "n2": 1.0, "n3": 2.0})
    assert flags == ["n3"]
    w = t.rebalance([100, 100, 100, 100], ["n0", "n1", "n2", "n3"])
    assert w[3] < w[0]  # the straggler gets less work


def test_rebalance_equalizes_predicted_times():
    counts = np.array([100, 100])
    times = np.array([1.0, 3.0])
    w = rebalance_from_measurements(counts, times, smoothing=1.0)
    new_counts = 200 * w
    thr = counts / times
    predicted = new_counts / thr
    assert abs(predicted[0] - predicted[1]) / predicted.max() < 1e-6
