"""DG solver physics + the nested-partition equivalence (paper's claim)."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.dg.basis import diff_matrix, lgl_nodes_weights
from repro.dg.mesh import make_brick, two_tree_materials
from repro.dg.solver import DGSolver, gaussian_pulse, make_two_tree_solver


def test_lgl_quadrature_exactness():
    for N in (1, 2, 4, 7):
        x, w = lgl_nodes_weights(N)
        for k in range(2 * N):
            exact = 2 / (k + 1) if k % 2 == 0 else 0.0
            assert abs(np.sum(w * x**k) - exact) < 1e-12


def test_diff_matrix_exact_on_polynomials():
    for N in (2, 4, 7):
        x, _ = lgl_nodes_weights(N)
        D = diff_matrix(x)
        for k in range(1, N + 1):
            np.testing.assert_allclose(D @ x**k, k * x ** (k - 1), atol=1e-9)


@pytest.mark.parametrize(
    "name,cp,cs,comp",
    [("acoustic", (1.0, 1.0), (0.0, 0.0), 6),
     ("coupled", (1.0, 3.0), (0.0, 2.0), 6),
     ("elastic", (2.0, 2.0), (1.0, 1.0), 7)],
)
def test_energy_never_grows(name, cp, cs, comp):
    s = make_two_tree_solver(grid=(6, 4, 4), order=3, extent=(1.5, 1.0, 1.0), cp=cp, cs=cs)
    q0 = gaussian_pulse(s, center=(0.75, 0.5, 0.5), component=comp)
    e0 = s.energy(q0)
    q = s.run(q0, 30)
    e1 = s.energy(q)
    assert np.isfinite(e1) and e1 <= e0 * 1.0001, (name, e0, e1)


def test_plane_wave_p_convergence():
    """Spectral convergence of a periodic acoustic traveling wave."""
    errs = {}
    for order in (2, 4):
        mesh = make_brick((4, 2, 2), (1.0, 0.5, 0.5), periodic=True)
        K = mesh.K
        s = DGSolver(mesh=mesh, order=order, rho=np.ones(K), lam=np.ones(K), mu=np.zeros(K))
        xyz = s.node_coords()
        f = lambda x: np.sin(2 * np.pi * x)
        q0 = np.zeros((K, 9, s.M, s.M, s.M))
        q0[:, 6] = f(xyz[..., 0])
        q0[:, 0] = -f(xyz[..., 0])
        T = 0.2
        dt = s.cfl_dt(0.2)
        n = int(np.ceil(T / dt))
        q = s.run(jnp.asarray(q0), n, T / n)
        qe = np.zeros_like(q0)
        qe[:, 6] = f(xyz[..., 0] - T)
        qe[:, 0] = -f(xyz[..., 0] - T)
        errs[order] = float(jnp.abs(q - qe).max())
    assert errs[4] < errs[2] / 20, errs


def test_acoustic_region_has_zero_shear():
    """mu=0 in the acoustic half: the Riemann flux must use the k1=0 branch
    and shear stress stays ~0 there."""
    s = make_two_tree_solver(grid=(8, 4, 4), order=3, extent=(2.0, 1.0, 1.0))
    q0 = gaussian_pulse(s, center=(0.5, 0.5, 0.5), component=6)
    q = s.run(q0, 30)
    acoustic = np.asarray(s.mu == 0)
    shear = np.asarray(jnp.abs(q[:, 3:6]))  # E_yz, E_xz, E_xy
    # strain can be nonzero, but stress 2*mu*E == 0; check mu=0 elements
    assert np.isfinite(shear).all()
    S_shear = 2 * s.mu[:, None, None, None, None] * shear
    assert np.abs(S_shear[acoustic]).max() == 0.0


def test_nested_partition_equals_flat(subproc):
    subproc(
        """
import jax
jax.config.update("jax_enable_x64", True)
import numpy as np, jax.numpy as jnp
from repro.dg.solver import make_two_tree_solver, gaussian_pulse
from repro.dg.partitioned import PartitionedDG
mesh = jax.make_mesh((4,), ("data",))
s = make_two_tree_solver(grid=(8,4,4), order=3, extent=(2.,1.,1.))
pdg = PartitionedDG(solver=s, mesh_axes=mesh)
rng = np.random.default_rng(0)
q0 = jnp.asarray(rng.standard_normal((s.mesh.K, 9, s.M, s.M, s.M)))
err = np.abs(np.asarray(s.rhs(q0)) - pdg.permute_out(np.asarray(pdg.rhs(pdg.permute_in(q0))))).max()
assert err < 1e-11, err
qg = gaussian_pulse(s, center=(0.9,0.5,0.5), component=6)
qf = s.run(qg, 30)
qp = pdg.run(pdg.permute_in(qg), 30)
err = float(jnp.abs(qf - pdg.permute_out(np.asarray(qp))).max())
assert err < 1e-10, err
print("OK")
""",
        n_devices=4,
    )


def test_two_tree_materials_split():
    mesh = make_brick((8, 4, 4), (2.0, 1.0, 1.0))
    rho, lam, mu, region = two_tree_materials(mesh)
    assert (mu[region == 0] == 0).all()  # acoustic half
    assert (mu[region == 1] > 0).all()  # elastic half
    assert region.sum() == mesh.K // 2


def test_solver_with_pallas_kernel_matches_xla():
    """kernel_impl='interpret' (the Pallas volume_loop body) == jnp path."""
    s1 = make_two_tree_solver(grid=(4, 2, 2), order=3)
    s2 = make_two_tree_solver(grid=(4, 2, 2), order=3, kernel_impl="interpret")
    q = gaussian_pulse(s1, center=(1.0, 0.5, 0.5))
    np.testing.assert_allclose(s1.rhs(q), s2.rhs(q), rtol=1e-10, atol=1e-10)
