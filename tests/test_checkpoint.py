"""Checkpointing: atomic roundtrip, retention, async, elastic resharding."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, latest_step, restore, save


def _tree(key):
    k1, k2 = jax.random.split(key)
    return {
        "a": jax.random.normal(k1, (8, 16)),
        "nested": {"b": jax.random.normal(k2, (4,)), "step": jnp.int32(3)},
    }


def test_roundtrip(tmp_path):
    t = _tree(jax.random.PRNGKey(0))
    save(str(tmp_path), 7, t)
    assert latest_step(str(tmp_path)) == 7
    t2, manifest = restore(str(tmp_path), t)
    assert manifest["step"] == 7
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(t2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_incomplete_checkpoint_ignored(tmp_path):
    t = _tree(jax.random.PRNGKey(0))
    save(str(tmp_path), 5, t)
    # fake a partial (crashed) save: directory without the commit marker
    os.makedirs(tmp_path / "step_00000009")
    assert latest_step(str(tmp_path)) == 5


def test_manager_retention_and_async(tmp_path):
    m = CheckpointManager(str(tmp_path), keep=2, async_save=True)
    t = _tree(jax.random.PRNGKey(1))
    for s in (10, 20, 30, 40):
        m.save(s, t)
    m.wait()
    steps = sorted(int(n.split("_")[1]) for n in os.listdir(tmp_path) if n.startswith("step_"))
    assert steps == [30, 40]
    (t2, manifest) = m.restore_latest(t)
    assert manifest["step"] == 40


def test_elastic_reshard(tmp_path, subproc):
    """Save sharded on a (2,2) mesh, restore onto a (4,) mesh and 1 device."""
    subproc(
        f"""
import jax, numpy as np, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.checkpoint import save, restore
mesh = jax.make_mesh((2, 2), ("data", "model"))
x = jnp.arange(64.0).reshape(8, 8)
xs = jax.device_put(x, NamedSharding(mesh, P("data", "model")))
save(r"{tmp_path}", 1, {{"w": xs}})
# restore to a different mesh
mesh2 = jax.make_mesh((4,), ("data",))
sh2 = {{"w": NamedSharding(mesh2, P("data", None))}}
t2, _ = restore(r"{tmp_path}", {{"w": x}}, shardings=sh2)
np.testing.assert_array_equal(np.asarray(t2["w"]), np.asarray(x))
assert len(t2["w"].sharding.device_set) == 4
# restore fully replicated (single logical device view)
t3, _ = restore(r"{tmp_path}", {{"w": x}})
np.testing.assert_array_equal(np.asarray(t3["w"]), np.asarray(x))
print("OK")
""",
        n_devices=4,
    )


def test_same_state_saves_yield_identical_comparable_manifests(tmp_path):
    """Regression: the save wall timestamp used to be baked into the
    manifest, so two bitwise-identical checkpoints compared unequal at the
    manifest level.  The timestamp is provenance only (injectable, excluded
    from comparable_manifest) — same state must compare identical."""
    import json
    import time

    from repro.checkpoint import comparable_manifest

    t = _tree(jax.random.PRNGKey(2))
    save(str(tmp_path / "a"), 7, t, extra_meta={"seed": 0})
    time.sleep(0.01)  # distinct wall timestamps
    save(str(tmp_path / "b"), 7, t, extra_meta={"seed": 0})
    manifests = []
    for d in ("a", "b"):
        with open(tmp_path / d / "step_00000007" / "manifest.json") as f:
            manifests.append(json.load(f))
    ma, mb = manifests
    assert ma["time"] != mb["time"]  # provenance still recorded, and distinct
    assert ma != mb  # raw manifests differ only by it...
    assert comparable_manifest(ma) == comparable_manifest(mb)  # ...replay-comparable
    assert "time" not in comparable_manifest(ma)
    assert comparable_manifest(ma)["leaves"] and comparable_manifest(ma)["step"] == 7

    # injectable timestamp: replay tooling can pin it for bitwise manifests
    save(str(tmp_path / "c"), 7, t, extra_meta={"seed": 0}, timestamp=123.5)
    with open(tmp_path / "c" / "step_00000007" / "manifest.json") as f:
        assert json.load(f)["time"] == 123.5
