"""End-to-end behaviour tests: train-to-convergence smoke, failure/restart
equivalence, serving, and the dry-run machinery on a small mesh."""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.shapes import ShapeSpec, smoke_config
from repro.data import make_batch
from repro.launch.mesh import debug_mesh
from repro.models.zoo import LM, get_config
from repro.optim import OptConfig, init_opt_state
from repro.parallel.steps import accum_layout, make_serve_step, make_shardings, make_train_step

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _train(arch: str, steps: int = 8, fail_at=None, tmp=None):
    cfg = smoke_config(get_config(arch))
    shape = ShapeSpec("s", seq_len=64, global_batch=4, kind="train")
    mesh = debug_mesh()
    lm = LM(cfg, ep_size=2 if cfg.n_experts else 1)
    sh = make_shardings(lm, mesh, kind="train", accum=True, batch_shardable=False)
    step_fn = make_train_step(lm, OptConfig(peak_lr=1e-3, warmup_steps=2, total_steps=steps), sh)
    jitted = jax.jit(step_fn, donate_argnums=(0, 1))
    params = lm.init(jax.random.PRNGKey(0))
    opt = init_opt_state(params)
    losses = []
    for s in range(steps):
        batch = make_batch(cfg, shape, s, accum=2, micro=2)
        params, opt, m = jitted(params, opt, batch)
        losses.append(float(m["loss"]))
    return losses, params


@pytest.mark.parametrize("arch", ["qwen2-7b", "olmoe-1b-7b", "falcon-mamba-7b", "hubert-xlarge"])
def test_training_reduces_loss(arch):
    losses, _ = _train(arch, steps=8)
    assert all(np.isfinite(losses))
    assert losses[-1] < losses[0], losses


def test_failure_restart_replays_identically(tmp_path):
    """A killed-and-resumed run must produce the same final loss as an
    uninterrupted one (deterministic pipeline + checkpoint restore)."""
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    base = [sys.executable, "-m", "repro.launch.train", "--arch", "qwen2-7b",
            "--smoke", "--steps", "14", "--batch", "4", "--seq-len", "64",
            "--ckpt-every", "4"]
    r1 = subprocess.run(base + ["--metrics-out", str(tmp_path / "a.jsonl")],
                        env=env, capture_output=True, text=True, timeout=600)
    assert r1.returncode == 0, r1.stdout + r1.stderr
    r2 = subprocess.run(
        base + ["--metrics-out", str(tmp_path / "b.jsonl"),
                "--ckpt-dir", str(tmp_path / "ck"), "--fail-at", "9"],
        env=env, capture_output=True, text=True, timeout=600)
    assert r2.returncode == 0, r2.stdout + r2.stderr
    import json
    a = {json.loads(l)["step"]: json.loads(l)["loss"] for l in open(tmp_path / "a.jsonl")}
    b = {json.loads(l)["step"]: json.loads(l)["loss"] for l in open(tmp_path / "b.jsonl")}
    last = max(a)
    assert abs(a[last] - b[last]) < 1e-4, (a[last], b[last])


def test_serving_greedy_decode():
    cfg = smoke_config(get_config("qwen2-7b"))
    lm = LM(cfg)
    mesh = debug_mesh()
    params = lm.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab_size)
    sh = make_shardings(lm, mesh, kind="decode", batch_shardable=False)
    serve = jax.jit(make_serve_step(lm, sh), donate_argnums=(1,))
    logits, cache = lm.prefill(params, {"tokens": toks}, max_len=32)
    tok = jnp.argmax(jnp.where(jnp.arange(logits.shape[-1]) < cfg.vocab_size, logits, -jnp.inf), -1).astype(jnp.int32)
    outs = [tok]
    for _ in range(7):
        tok, cache = serve(params, cache, tok)
        outs.append(tok)
    gen = np.stack(outs, 1)
    assert gen.shape == (2, 8)
    assert (gen >= 0).all() and (gen < cfg.vocab_size).all()


def test_accum_layout():
    assert accum_layout(256, 16) == (16, 16)
    assert accum_layout(256, 32) == (8, 32)
    assert accum_layout(1, 16) == (1, 1)
    a, m = accum_layout(30, 4)
    assert a * m == 30


def test_dryrun_machinery_small_mesh(subproc):
    """The dry-run path (lower+compile+analysis) on an 8-device mesh with a
    smoke config — exercises the exact code the 512-device run uses."""
    subproc(
        """
import jax, jax.numpy as jnp
from repro.configs.shapes import ShapeSpec, smoke_config
from repro.models.zoo import LM, get_config
from repro.optim import OptConfig, init_opt_state
from repro.parallel.steps import accum_layout, make_shardings, make_train_step
from repro.launch.specs import train_input_specs
from repro.launch.hlo_analysis import analyze
from repro.jax_compat import make_mesh
mesh = make_mesh((4, 2), ("data", "model"))
cfg = smoke_config(get_config("qwen2-7b")).replace(tp_size=2, dtype="bfloat16")
lm = LM(cfg)
shape = ShapeSpec("t", seq_len=64, global_batch=8, kind="train")
accum, micro = accum_layout(8, 4)
sh = make_shardings(lm, mesh, kind="train", accum=True)
batch = train_input_specs(cfg, shape, accum, micro)
params = jax.eval_shape(lm.init, jax.random.PRNGKey(0))
opt = jax.eval_shape(init_opt_state, params)
step = make_train_step(lm, OptConfig(), sh)
jitted = jax.jit(step, in_shardings=(sh.params, sh.opt, sh.batch), out_shardings=(sh.params, sh.opt, None), donate_argnums=(0,1))
compiled = jitted.lower(params, opt, batch).compile()
r = analyze(compiled.as_text())
assert r["flops"] > 0 and r["collective_bytes_total"] > 0, r
print("OK flops=%.3g coll=%.3g" % (r["flops"], r["collective_bytes_total"]))
""",
        n_devices=8,
    )


# ---------------------------------------------------------------------------
# Determinism of the fused launch drivers (serve --fused-decode,
# train --fused-steps) — the donated-carry paths, end to end
# ---------------------------------------------------------------------------


def _run_cli(args, timeout=600):
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    r = subprocess.run([sys.executable, "-m"] + args, env=env,
                       capture_output=True, text=True, timeout=timeout)
    assert r.returncode == 0, r.stdout + r.stderr
    return r.stdout


def test_serve_fused_decode_deterministic(tmp_path):
    """Two serve runs from the same seed emit identical token matrices, and
    the fused scan-compiled decode agrees with the per-token Python loop."""
    base = ["repro.launch.serve", "--arch", "qwen2-7b", "--smoke",
            "--batch", "2", "--prompt-len", "16", "--gen", "8", "--seed", "3"]
    _run_cli(base + ["--out", str(tmp_path / "a.npy")])
    _run_cli(base + ["--out", str(tmp_path / "b.npy")])
    _run_cli(base + ["--no-fused-decode", "--out", str(tmp_path / "c.npy")])
    a = np.load(tmp_path / "a.npy")
    b = np.load(tmp_path / "b.npy")
    c = np.load(tmp_path / "c.npy")
    assert (a == b).all(), "same-seed fused decode runs diverged"
    assert (a == c).all(), "fused decode != per-token loop"
    assert a.shape == (2, 8)


def test_train_fused_steps_deterministic(tmp_path):
    """Two --fused-steps runs from the same seed produce identical metrics,
    and the fused chunk driver lands on the same final loss as the per-step
    driver (PR4's identical-final-loss claim, pinned end to end)."""
    import json

    base = ["repro.launch.train", "--arch", "qwen2-7b", "--smoke",
            "--steps", "8", "--batch", "4", "--seq-len", "64", "--seed", "1"]
    o1 = _run_cli(base + ["--fused-steps", "4",
                          "--metrics-out", str(tmp_path / "a.jsonl")])
    o2 = _run_cli(base + ["--fused-steps", "4",
                          "--metrics-out", str(tmp_path / "b.jsonl")])
    o3 = _run_cli(base + ["--metrics-out", str(tmp_path / "c.jsonl")])

    def final_loss(out):
        lines = [l for l in out.splitlines() if l.startswith("final_loss=")]
        assert len(lines) == 1, out
        return float(lines[0].split("=", 1)[1])

    def records(path):
        # drop the wall-clock field: everything else must match bitwise
        out = []
        for line in open(path):
            r = json.loads(line)
            r.pop("sec")
            out.append(r)
        return out

    assert records(tmp_path / "a.jsonl") == records(tmp_path / "b.jsonl"), \
        "same-seed fused-steps runs diverged"
    assert final_loss(o1) == final_loss(o2)
    # fused chunks vs per-step driver: same optimizer trajectory
    a_last = records(tmp_path / "a.jsonl")[-1]
    c_last = records(tmp_path / "c.jsonl")[-1]
    assert a_last["step"] == c_last["step"] == 7
    assert a_last["loss"] == c_last["loss"], (a_last, c_last)


# ---------------------------------------------------------------------------
# Monotonic-clock regressions (launch-side twins of the serving
# test_decode_batch_uses_monotonic_clock)
# ---------------------------------------------------------------------------


def test_train_wall_survives_backwards_clock(subproc):
    """Regression: launch/train.py's wall duration must come from
    perf_counter — under a wall clock stepping BACKWARD (NTP adjustment)
    the reported wall seconds stay non-negative."""
    subproc(
        """
import contextlib, io, itertools, re, sys, time
ticks = itertools.count()
time.time = lambda: 1e9 - 10.0 * next(ticks)  # strictly decreasing
sys.argv = ["train", "--arch", "qwen2-7b", "--smoke",
            "--steps", "2", "--batch", "2", "--seq-len", "16"]
from repro.launch.train import main
buf = io.StringIO()
with contextlib.redirect_stdout(buf):
    main()
m = re.search(r"wall=([-0-9.]+)s", buf.getvalue())
assert m, buf.getvalue()
assert float(m.group(1)) >= 0.0, f"negative wall under backwards clock: {m.group(1)}"
print("train wall ok:", m.group(1))
""",
        n_devices=1,
    )


def test_dryrun_durations_survive_backwards_clock(subproc):
    """Regression: launch/dryrun.py's lower_s/compile_s must come from
    perf_counter.  build_cell is stubbed (no 512-device compile); only the
    timed path around lower()/compile() runs, under a backwards clock."""
    subproc(
        """
import itertools, time
import repro.launch.dryrun as dryrun_mod
ticks = itertools.count()
time.time = lambda: 1e9 - 10.0 * next(ticks)  # strictly decreasing

class Compiled:
    def memory_analysis(self): return object()
    def cost_analysis(self): return {"flops": 1.0}
class Lowered:
    def compile(self): return Compiled()
class Jitted:
    def lower(self, *a): return Lowered()

dryrun_mod.build_cell = lambda *a, **k: (Jitted(), (), None, None)
rec = dryrun_mod.run_cell("qwen2-7b", "train_4k", False, full_analysis=False)
assert rec["lower_s"] >= 0.0 and rec["compile_s"] >= 0.0, rec
print("dryrun durations ok:", rec["lower_s"], rec["compile_s"])
""",
        n_devices=1,
    )
