"""Multi-round re-aggregation scheduler tests (runtime/rounds.py +
core.load_balance.solve_rounds): plan shape, equal-cost rounds, merge-tree
bitwise identity, serialization, and the batch-job enumeration."""

import json

import numpy as np
import pytest

from hypothesis_shim import given, settings, st

from repro.core.load_balance import solve_rounds
from repro.runtime.rounds import (
    RoundPlan,
    RoundWorker,
    plan_rounds,
    run_rounds,
    single_aggregator,
    workers_from_profiles,
    workers_from_report,
)


def _workers(rates):
    return [RoundWorker(f"n{i}", r) for i, r in enumerate(rates)]


def _rates(seed, n):
    g = np.random.default_rng(seed)
    return (10.0 ** g.uniform(-1, 1, n)).tolist()


# ---------------------------------------------------------------------------
# properties (hypothesis, degrading to skip without it)
# ---------------------------------------------------------------------------


@settings(max_examples=40, deadline=None)
@given(st.integers(1, 40), st.integers(1, 10_000), st.floats(1.05, 4.0),
       st.integers(0, 2**31 - 1))
def test_worker_counts_shrink_geometrically(n, k, shrink, seed):
    """Each round runs max(1, min(prev-1, round(prev/shrink))) workers, so
    the fleet shrinks geometrically to exactly one final aggregator."""
    plan = plan_rounds(k, _workers(_rates(seed, n)), shrink=shrink)
    wc = plan.worker_counts
    assert wc[0] == n and wc[-1] == 1
    for a, b in zip(wc, wc[1:]):
        assert b == max(1, min(a - 1, int(round(a / shrink))))


@settings(max_examples=40, deadline=None)
@given(st.integers(1, 40), st.integers(1, 10_000), st.floats(1.05, 4.0),
       st.integers(0, 2**31 - 1))
def test_every_round_costs_the_same(n, k, shrink, seed):
    """The cache-credit discount is chosen so every round's modeled makespan
    equals round 1's — the partiscontainer sizing rule, by construction."""
    plan = plan_rounds(k, _workers(_rates(seed, n)), shrink=shrink)
    t1 = plan.round_makespans[0]
    for t in plan.round_makespans:
        assert t == pytest.approx(t1, rel=1e-9)
    assert plan.makespan == pytest.approx(t1 * plan.n_rounds, rel=1e-9)


@settings(max_examples=40, deadline=None)
@given(st.integers(1, 24), st.integers(1, 10_000), st.floats(0.1, 10.0))
def test_equal_throughput_workers_split_evenly(n, k, rate):
    """Degenerate case: identical rates must apportion round 1 as evenly as
    integer counts allow (max spread 1 item)."""
    plan = plan_rounds(k, _workers([rate] * n))
    counts = plan.counts_by_worker(0)
    assert int(counts.sum()) == k
    assert counts.max() - counts.min() <= 1


@settings(max_examples=40, deadline=None)
@given(st.integers(1, 16), st.integers(2, 9), st.floats(1.05, 4.0),
       st.integers(0, 2**31 - 1))
def test_merge_tree_bitwise_matches_single_aggregator(n, width, shrink, seed):
    """An associative merge through the round tree re-brackets the same
    left-to-right fold, so the result is BITWISE the single-aggregator one
    — the invariant the serving loop's --rounds mode rides on."""
    g = np.random.default_rng(seed)
    plan = plan_rounds(max(n, 1) * 7, _workers(_rates(seed, n)), shrink=shrink)
    shards = [g.standard_normal((g.integers(0, 4), width)) for _ in range(n)]
    merge = lambda a, b: np.concatenate([a, b], axis=0)  # noqa: E731
    tree = run_rounds(plan, shards, merge)
    flat = single_aggregator(shards, merge)
    assert tree.dtype == flat.dtype and tree.shape == flat.shape
    assert np.array_equal(tree, flat)


@settings(max_examples=40, deadline=None)
@given(st.integers(1, 20), st.integers(1, 10_000), st.integers(0, 2**31 - 1))
def test_plan_json_roundtrip(n, k, seed):
    plan = plan_rounds(k, _workers(_rates(seed, n)))
    doc = json.loads(json.dumps(plan.to_json(), allow_nan=False))
    assert RoundPlan.from_json(doc) == plan


@settings(max_examples=40, deadline=None)
@given(st.integers(2, 20), st.integers(1, 10_000), st.integers(0, 2**31 - 1))
def test_job_specs_dependency_closure(n, k, seed):
    """Every merge job depends on previous-round jobs that exist, and each
    round's dependency groups partition the previous round's slots — no
    shard is dropped or folded twice."""
    plan = plan_rounds(k, _workers(_rates(seed, n)))
    jobs = plan.job_specs()
    names = {j["name"] for j in jobs}
    assert len(names) == len(jobs)
    for j in jobs:
        assert (j["round"] == 0) == (not j["depends"])
        assert all(d in names for d in j["depends"])
    for r in range(1, plan.n_rounds):
        merged = sorted(
            s for j in jobs if j["round"] == r
            for s in (int(d.rsplit("worker", 1)[1]) for d in j["depends"])
        )
        assert merged == list(range(plan.rounds[r - 1].n_workers))


# ---------------------------------------------------------------------------
# deterministic unit tests (no hypothesis needed)
# ---------------------------------------------------------------------------


def test_round1_counts_proportional_to_rates():
    plan = plan_rounds(800, _workers([4.0, 2.0, 1.0, 1.0]))
    counts = plan.counts_by_worker(0)
    assert int(counts.sum()) == 800
    assert counts.tolist() == [400, 200, 100, 100]
    # equal modeled finish time within the round
    times = plan.rounds[0].times
    assert max(times) == pytest.approx(min(times), rel=1e-9)


def test_survivors_are_the_fastest_workers():
    """Later rounds keep the fastest prefix: the slow tail drops first and
    the final aggregator is the single fastest worker."""
    plan = plan_rounds(640, _workers([1.0, 8.0, 2.0, 4.0, 0.5]))
    final = plan.rounds[-1]
    assert final.n_workers == 1
    assert plan.workers[final.workers[0]].rate == 8.0
    for prev, cur in zip(plan.rounds, plan.rounds[1:]):
        assert set(cur.workers) <= set(prev.workers)


def test_merge_groups_cover_and_never_starve():
    plan = plan_rounds(4096, _workers([16.0] + [1.0] * 11))
    for r in range(1, plan.n_rounds):
        groups = plan.merge_groups(r)
        assert all(len(g) >= 1 for g in groups)
        flat = [s for g in groups for s in g]
        assert flat == list(range(plan.rounds[r - 1].n_workers))


def test_single_worker_plan_is_one_round():
    plan = plan_rounds(100, _workers([3.0]))
    assert plan.n_rounds == 1 and plan.worker_counts == (1,)
    assert plan.makespan == pytest.approx(100 / 3.0)
    out = run_rounds(plan, [np.arange(5)], lambda a, b: np.concatenate([a, b]))
    assert np.array_equal(out, np.arange(5))


def test_wide_mild_skew_beats_single_aggregator():
    """The acceptance mix: a wide fleet with mild skew must model faster
    through the round tree than one aggregator folding everything."""
    for rates in ([1.0] * 12, [2.0, 2.0, 2.0] + [1.0] * 9, [2.0] * 4 + [1.0] * 8):
        plan = plan_rounds(4096, _workers(rates))
        assert plan.speedup_vs_single_round > 1.0, rates


def test_validation_errors():
    with pytest.raises(ValueError):
        plan_rounds(100, [])
    with pytest.raises(ValueError):
        plan_rounds(0, _workers([1.0]))
    with pytest.raises(ValueError):
        RoundWorker("w", 0.0)
    with pytest.raises(ValueError):
        solve_rounds([lambda k: k], 10, shrink=1.0)
    plan = plan_rounds(10, _workers([1.0, 1.0]))
    with pytest.raises(ValueError):
        run_rounds(plan, [np.zeros(1)], lambda a, b: a)
    with pytest.raises(ValueError):
        plan.merge_groups(0)


def test_solve_rounds_memoizes_time_models():
    """Each (worker, count) evaluation hits the wrapped model once — the
    solve_hierarchical memo pattern applied to the round solver."""
    calls = [[], []]

    def make(i):
        def t(k):
            calls[i].append(int(round(k)))
            return float(k) * 1e-3

        return t

    solve_rounds([make(0), make(1)], 1000)
    for per_worker in calls:
        assert len(per_worker) == len(set(per_worker))


def test_workers_from_profiles_and_report():
    from repro.runtime.cluster import NodeProfile

    ws = workers_from_profiles(
        [NodeProfile(name="node", speed=2.0), NodeProfile(name="node", speed=1.0)],
        unit_rate=10.0,
    )
    assert [w.name for w in ws] == ["node0", "node1"]
    assert [w.rate for w in ws] == [20.0, 10.0]

    class FakeReport:
        step_s = [0.1, 0.2, 0.0]  # partition 2 never measured

    ws = workers_from_report(FakeReport(), [10, 10, 10])
    assert ws[0].rate == pytest.approx(100.0)
    assert ws[1].rate == pytest.approx(50.0)
    assert ws[2].rate == pytest.approx((100.0 + 50.0) / 2)  # fleet-mean prior
