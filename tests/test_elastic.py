"""Elastic rescale end-to-end: train on one mesh, resume on another.

The loss trajectory of (train 4 steps on mesh A) + (resume 4 steps on mesh
B) must equal an uninterrupted 8-step run — the checkpoint reshard, the
sharding recomputation, and the deterministic pipeline must all line up.
"""


def test_elastic_rescale_trajectory(subproc):
    subproc(
        """
import jax, numpy as np, tempfile, os
import jax.numpy as jnp
from repro.checkpoint import save
from repro.configs.shapes import ShapeSpec, smoke_config
from repro.data import make_batch
from repro.models.zoo import LM, get_config
from repro.optim import OptConfig, init_opt_state
from repro.parallel.steps import make_shardings, make_train_step
from repro.runtime.elastic import rescale_plan
from repro.jax_compat import make_mesh

cfg = smoke_config(get_config("qwen2-7b")).replace(tp_size=2)
lm = LM(cfg)
shape = ShapeSpec("t", 64, 8, "train")
opt_cfg = OptConfig(peak_lr=1e-3, warmup_steps=2, total_steps=8)

def run_steps(mesh, params, opt, start, n):
    sh = make_shardings(lm, mesh, kind="train", accum=True)
    step = jax.jit(make_train_step(lm, opt_cfg, sh),
                   in_shardings=(sh.params, sh.opt, sh.batch),
                   out_shardings=(sh.params, sh.opt, None))
    losses = []
    for s in range(start, start + n):
        params, opt, m = step(params, opt, make_batch(cfg, shape, s, accum=2, micro=4))
        losses.append(float(m["loss"]))
    return params, opt, losses

mesh_a = make_mesh((2, 2), ("data", "model"))
mesh_b = make_mesh((4,), ("data",))

# uninterrupted reference on mesh A
p0 = lm.init(jax.random.PRNGKey(0))
o0 = init_opt_state(p0)
_, _, ref = run_steps(mesh_a, p0, o0, 0, 8)

# elastic: 4 steps on (2,2), checkpoint, resume on (4,)
p1 = lm.init(jax.random.PRNGKey(0))
o1 = init_opt_state(p1)
p1, o1, first = run_steps(mesh_a, p1, o1, 0, 4)
ck = tempfile.mkdtemp()
save(ck, 4, (p1, o1))
p2, o2, step, sh2 = rescale_plan(ck, lm, mesh_b)
assert step == 4
assert len(jax.tree.leaves(p2)[0].sharding.device_set) == 4
_, _, second = run_steps(mesh_b, p2, o2, 4, 4)
got = first + second
np.testing.assert_allclose(got, ref, rtol=2e-4, atol=2e-4)
print("elastic (2,2)->(4,) trajectory matches uninterrupted run")

# shrink to a single device
mesh_c = make_mesh((1,), ("data",))
p3, o3, step, _ = rescale_plan(ck, lm, mesh_c)
_, _, second_c = run_steps(mesh_c, p3, o3, 4, 4)
np.testing.assert_allclose(first + second_c, ref, rtol=2e-4, atol=2e-4)
print("elastic shrink to 1 device matches too")
""",
        n_devices=4,
        timeout=900,
    )
