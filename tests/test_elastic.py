"""Elastic rescale end-to-end: train on one mesh, resume on another.

The loss trajectory of (train 4 steps on mesh A, under the fault-tolerant
supervisor with an injected failure forcing a checkpoint restore) +
(resume 4 steps on mesh B) must equal an uninterrupted 8-step run — the
checkpoint reshard, the sharding recomputation, the deterministic
pipeline AND the supervisor's restore+replay must all line up.
"""


def test_elastic_rescale_trajectory(subproc):
    subproc(
        """
import jax, numpy as np, tempfile, os
import jax.numpy as jnp
from repro.checkpoint import restore as ck_restore, save
from repro.configs.shapes import ShapeSpec, smoke_config
from repro.data import make_batch
from repro.models.zoo import LM, get_config
from repro.optim import OptConfig, init_opt_state
from repro.parallel.steps import make_shardings, make_train_step
from repro.runtime import FailureInjector, TrainSupervisor
from repro.runtime.elastic import rescale_plan
from repro.jax_compat import make_mesh

cfg = smoke_config(get_config("qwen2-7b")).replace(tp_size=2)
lm = LM(cfg)
shape = ShapeSpec("t", 64, 8, "train")
opt_cfg = OptConfig(peak_lr=1e-3, warmup_steps=2, total_steps=8)

def run_steps(mesh, params, opt, start, n):
    sh = make_shardings(lm, mesh, kind="train", accum=True)
    step = jax.jit(make_train_step(lm, opt_cfg, sh),
                   in_shardings=(sh.params, sh.opt, sh.batch),
                   out_shardings=(sh.params, sh.opt, None))
    losses = []
    for s in range(start, start + n):
        params, opt, m = step(params, opt, make_batch(cfg, shape, s, accum=2, micro=4))
        losses.append(float(m["loss"]))
    return params, opt, losses

mesh_a = make_mesh((2, 2), ("data", "model"))
mesh_b = make_mesh((4,), ("data",))

# uninterrupted reference on mesh A
p0 = lm.init(jax.random.PRNGKey(0))
o0 = init_opt_state(p0)
_, _, ref = run_steps(mesh_a, p0, o0, 0, 8)

# elastic: 4 steps on (2,2) THROUGH the fault-tolerant supervisor — an
# injected failure at step 3 forces restore (from the step-2 checkpoint)
# + deterministic replay — then checkpoint and resume on (4,)
p1 = lm.init(jax.random.PRNGKey(0))
o1 = init_opt_state(p1)
sh_a = make_shardings(lm, mesh_a, kind="train", accum=True)
step_a = jax.jit(make_train_step(lm, opt_cfg, sh_a),
                 in_shardings=(sh_a.params, sh_a.opt, sh_a.batch),
                 out_shardings=(sh_a.params, sh_a.opt, None))
ck = tempfile.mkdtemp()
seen = {}

def step_fn(state, step, batch):
    p, o, m = step_a(state[0], state[1], batch)
    return (p, o), m

def restore_fn():
    state, manifest = ck_restore(ck, (p1, o1))
    return manifest["step"], state

sup = TrainSupervisor(
    step_fn,
    lambda step: make_batch(cfg, shape, step, accum=2, micro=4),
    lambda step, state: save(ck, step, state),
    restore_fn,
    ckpt_every=2, max_retries=0,
    injector=FailureInjector({3: "preempt"}),
    on_metrics=lambda s, m, dt, st: seen.__setitem__(s, float(m["loss"])),
)
end, (p1, o1) = sup.run((p1, o1), 0, 4)
assert end == 4 and sup.restarts == 1
first = [seen[s] for s in range(4)]  # replayed steps overwrite bitwise
save(ck, 4, (p1, o1))
p2, o2, step, sh2 = rescale_plan(ck, lm, mesh_b)
assert step == 4
assert len(jax.tree.leaves(p2)[0].sharding.device_set) == 4
_, _, second = run_steps(mesh_b, p2, o2, 4, 4)
got = first + second
np.testing.assert_allclose(got, ref, rtol=2e-4, atol=2e-4)
print("elastic (2,2)->(4,) through supervisor restore matches uninterrupted run")

# shrink to a single device
mesh_c = make_mesh((1,), ("data",))
p3, o3, step, _ = rescale_plan(ck, lm, mesh_c)
_, _, second_c = run_steps(mesh_c, p3, o3, 4, 4)
np.testing.assert_allclose(first + second_c, ref, rtol=2e-4, atol=2e-4)
print("elastic shrink to 1 device matches too")
""",
        n_devices=4,
        timeout=900,
    )
