"""Continuous-batching serving loop + Engine-protocol conformance.

Covers the PR-6 acceptance bars:
  * a request spliced into a running loop yields the bitwise-identical
    token row it gets in a fresh one-shot batch;
  * the loop stays fused — exactly one dispatch per decode chunk
    (``DispatchStats``);
  * SLO accounting degrades (and sheds appear) under an injected straggler
    partition;
  * shed rate is monotone in offered load and zero at sub-capacity load;
  * all four engines (`DGSolver`, `PartitionedDG`, `BlockedDGEngine`,
    `SimulatedCluster`) satisfy the shared ``Engine`` protocol.
"""

import numpy as np
import pytest

from repro.runtime import Engine
from repro.runtime.schedule import CalibrationReport
from repro.runtime.serving import (
    SLO,
    ContinuousBatchingLoop,
    ServeKernels,
    build_lm,
    decode_batch,
    poisson_trace,
)

PROMPT_LEN = 8
MAX_NEW = 4


@pytest.fixture(scope="module")
def served():
    """One built model + kernel set shared by every loop test (compiles are
    the expensive part; the loop itself is cheap)."""
    cfg, lm, params, mesh = build_lm("qwen2-7b", smoke=True, seed=0)
    kernels = ServeKernels(lm, mesh, max_len=PROMPT_LEN + MAX_NEW)
    return cfg, kernels, params


def _report(p=1, prefill=0.010, decode=0.020):
    """Synthetic phase-resolved calibration: fully deterministic pricing
    (decode seconds are for calib_gen-1 = 2 steps at the calibrated
    counts)."""
    return CalibrationReport(
        boundary_s=np.full(p, prefill),
        interior_s=np.full(p, decode),
        transfer_s=np.zeros(p),
    )


def _trace(cfg, n, rate, seed=3, max_new=MAX_NEW):
    return poisson_trace(
        n, rate, prompt_len=PROMPT_LEN, vocab=cfg.vocab_size,
        max_new=max_new, seed=seed,
    )


# ---------------------------------------------------------------------------
# continuous batching
# ---------------------------------------------------------------------------


def test_splice_bitwise_and_fused(served):
    """Requests admitted mid-loop (capacity 2, 6 requests -> 4 refills)
    produce bitwise the token rows of fresh one-shot batches, and every
    decode chunk is exactly one fused dispatch."""
    cfg, kernels, params = served
    trace = _trace(cfg, 6, rate=2.0)
    loop = ContinuousBatchingLoop(
        kernels, params, capacity=2, chunk=2, calib_gen=3,
        report=_report(), slo=SLO(ttft_s=1e9, tok_s=1e9),
    )
    summary = loop.run(trace)
    assert summary.n_done == 6 and summary.n_shed == 0

    # the loop never un-fuses: 1 dispatch per decode chunk, by ledger
    assert summary.dispatches_per_chunk == 1.0
    assert loop.stats.dispatches == loop.n_chunks
    assert loop.n_chunks >= 6 * (MAX_NEW - 1) / 2 / 2  # >= total work / (chunk*capacity)

    # one-shot reference at the loop's batch width: row independence means
    # each request's row is identical whether its neighbours are other live
    # requests (loop) or any other rows (fresh batch)
    for a, b in [(0, 1), (2, 3), (4, 5)]:
        block = np.stack([trace[a].prompt, trace[b].prompt])
        ref, _, _ = decode_batch(kernels, params, block, MAX_NEW)
        assert trace[a].tokens == ref[0].tolist(), f"rid {a} diverged"
        assert trace[b].tokens == ref[1].tolist(), f"rid {b} diverged"

    # SLO ledger is complete for served requests
    for r in trace:
        assert r.state == "done"
        assert r.arrival_s <= r.admitted_s <= r.first_token_s <= r.done_s
        assert len(r.tokens) == MAX_NEW


def test_splice_mid_loop_vs_solo_batch(served):
    """The stronger form: a late request decoded alongside an in-flight one
    matches its own solo one-shot run bitwise (cross-batch-composition
    invariance of the row)."""
    cfg, kernels, params = served
    trace = _trace(cfg, 4, rate=5.0, seed=11)
    loop = ContinuousBatchingLoop(
        kernels, params, capacity=2, chunk=2, calib_gen=3,
        report=_report(), slo=SLO(ttft_s=1e9, tok_s=1e9),
    )
    loop.run(trace)
    for r in trace:
        solo, _, _ = decode_batch(kernels, params, r.prompt[None, :], MAX_NEW)
        assert r.tokens == solo[0].tolist(), f"rid {r.rid} != solo run"


def test_straggler_inflates_slo_accounting(served):
    """Injecting a straggler partition into the executor inflates the
    modeled pricing: virtual-clock TTFT/latency grow and the (tight) SLO
    starts shedding requests that the healthy fleet serves."""
    cfg, kernels, params = served

    def run_with(factor):
        loop = ContinuousBatchingLoop(
            kernels, params, capacity=2, chunk=2, partitions=2, calib_gen=3,
            report=_report(p=2), slo=SLO(ttft_s=0.5, tok_s=1e9),
        )
        loop.executor.inject_straggler(0, factor)
        trace = _trace(cfg, 8, rate=50.0, seed=7)
        summary = loop.run(trace)
        return summary

    healthy = run_with(1.0)
    slow = run_with(40.0)
    assert healthy.ttft_p50_s < slow.ttft_p50_s or healthy.n_shed < slow.n_shed
    assert slow.elapsed_s > healthy.elapsed_s  # straggler slows the virtual fleet
    # deterministic virtual clock: the healthy run is reproducible exactly
    again = run_with(1.0)
    assert again.to_dict() == healthy.to_dict()


def test_shed_rate_monotone_in_offered_load(served):
    """Same seed, rising offered load -> the same arrival pattern
    compressed -> shed rate must be monotone, and zero at sub-capacity."""
    cfg, kernels, params = served

    def shed_rate(load_rps):
        loop = ContinuousBatchingLoop(
            kernels, params, capacity=2, chunk=2, calib_gen=3,
            report=_report(), slo=SLO(ttft_s=0.2, tok_s=1e9),
        )
        trace = _trace(cfg, 10, rate=load_rps, seed=5)
        return loop.run(trace).shed_rate

    rates = [shed_rate(r) for r in (2.0, 50.0, 500.0)]
    assert rates[0] == 0.0  # sub-capacity: nothing shed
    assert rates[0] <= rates[1] <= rates[2]
    assert rates[2] > 0.0  # heavy oversubscription does shed


def test_downgrade_trims_generation(served):
    """A finite latency budget downgrades (trims) requests instead of
    shedding them outright when at least min_new tokens still fit."""
    cfg, kernels, params = served
    loop = ContinuousBatchingLoop(
        kernels, params, capacity=2, chunk=2, calib_gen=3,
        report=_report(decode=0.2),
        slo=SLO(ttft_s=10.0, tok_s=1e9, latency_s=0.5, min_new=1),
    )
    trace = _trace(cfg, 4, rate=100.0, seed=9, max_new=MAX_NEW)
    summary = loop.run(trace)
    assert summary.n_downgraded > 0
    for r in trace:
        if r.state == "done" and r.downgraded:
            assert 1 <= len(r.tokens) < r.max_new


def test_trace_records_roundtrip(served, tmp_path):
    cfg, kernels, params = served
    loop = ContinuousBatchingLoop(
        kernels, params, capacity=2, chunk=2, calib_gen=3, report=_report(),
    )
    loop.run(_trace(cfg, 3, rate=2.0))
    path = tmp_path / "trace.json"
    loop.write_trace(str(path))
    import json

    rows = json.loads(path.read_text())
    assert len(rows) == 3
    assert {"rid", "state", "ttft_s", "latency_s", "n_tokens"} <= set(rows[0])


def test_rounds_loop_bitwise_and_ledgered(served):
    """--rounds acceptance bar: the row pool sharded across heterogeneous
    node groups and re-aggregated through the multi-round merge tree yields
    token rows BITWISE identical to the plain single-aggregator loop, at
    exactly one fused dispatch per worker per chunk (DispatchStats-gated),
    deterministic under the virtual clock."""
    from repro.runtime.cluster import NodeProfile
    from repro.runtime.rounds import workers_from_profiles

    cfg, kernels, params = served
    workers = workers_from_profiles(
        [NodeProfile(name="node", speed=2.0), NodeProfile(name="node", speed=1.0)]
    )

    def run_with(rounds):
        trace = _trace(cfg, 6, rate=2.0)
        loop = ContinuousBatchingLoop(
            kernels, params, capacity=4, chunk=2, calib_gen=3,
            report=_report(), slo=SLO(ttft_s=1e9, tok_s=1e9), rounds=rounds,
        )
        return loop, loop.run(trace), trace

    loop_r, summary_r, trace_r = run_with(workers)
    loop_p, summary_p, trace_p = run_with(None)

    # pool rows apportioned by calibrated speed (4 rows over 2:1 workers)
    assert loop_r.rounds_plan.counts_by_worker(0).tolist() == [3, 1]
    assert loop_r.n_round_workers == summary_r.n_round_workers == 2
    # the ledger: one fused dispatch per WORKER per chunk, nothing hidden
    assert summary_r.dispatches_per_chunk == 2.0
    assert loop_r.stats.dispatches == 2 * loop_r.n_chunks
    assert summary_p.dispatches_per_chunk == 1.0

    # bitwise: every request's token row identical across the two paths
    assert summary_r.n_done == summary_p.n_done == 6
    for a, b in zip(trace_r, trace_p):
        assert a.state == b.state == "done"
        assert a.tokens == b.tokens, f"rid {a.rid} diverged under --rounds"

    # deterministic under VirtualClock: a rerun reproduces the summary
    _, again, _ = run_with(workers)
    assert again.to_dict() == summary_r.to_dict()


def test_fully_shed_trace_serializes_strict_json(served, tmp_path):
    """Regression: a fully-shed trace has no TTFT/latency samples, so the
    percentiles are NaN — they must serialize as null (strict JSON), never
    as the bare NaN literal that breaks downstream parsers."""
    import json

    cfg, kernels, params = served
    loop = ContinuousBatchingLoop(
        kernels, params, capacity=2, chunk=2, calib_gen=3,
        report=_report(), slo=SLO(ttft_s=1e-9, tok_s=1e9),
    )
    trace = _trace(cfg, 4, rate=1000.0, seed=11)
    summary = loop.run(trace)
    assert summary.n_done == 0 and summary.n_shed == len(trace)
    assert np.isnan(summary.ttft_p50_s)  # in-process floats stay NaN...

    d = summary.to_dict()
    assert d["ttft_p50_s"] is None and d["ttft_p99_s"] is None  # ...JSON gets null
    text = json.dumps(d, allow_nan=False)  # strict mode must not raise
    assert "NaN" not in text
    assert json.loads(text)["ttft_p50_s"] is None

    path = tmp_path / "shed_trace.json"
    loop.write_trace(str(path))  # write_trace is allow_nan=False-gated too
    rows = json.loads(path.read_text())
    assert len(rows) == 4 and all(r["state"] == "shed" for r in rows)


# ---------------------------------------------------------------------------
# Engine protocol conformance
# ---------------------------------------------------------------------------


def _engines():
    """(name, engine, state) for all four execution engines on a tiny
    brick."""
    import jax

    from repro.dg.partitioned import PartitionedDG
    from repro.dg.solver import gaussian_pulse, make_two_tree_solver
    from repro.runtime import BlockedDGEngine, NestedPartitionExecutor, SimulatedCluster
    from repro.runtime.cluster import NodeProfile

    solver = make_two_tree_solver(grid=(4, 2, 2), order=2)
    q0 = gaussian_pulse(solver, width=0.25)

    out = [("DGSolver", solver, q0)]

    ex = NestedPartitionExecutor(solver.mesh.K, 2, grid_dims=solver.mesh.grid,
                                 bucket=4, rebalance_every=0)
    eng = BlockedDGEngine(solver, ex)
    out.append(("BlockedDGEngine", eng, q0))

    cl = SimulatedCluster(solver, [NodeProfile(speed=1.0), NodeProfile(speed=2.0)])
    out.append(("SimulatedCluster", cl, q0))

    from jax.sharding import Mesh

    mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
    pdg = PartitionedDG(solver, mesh)
    out.append(("PartitionedDG", pdg, pdg.permute_in(q0)))
    return out


def test_engine_protocol_conformance():
    """All four engines satisfy the structural protocol AND behave: run
    accepts the unified keyword set, calibrate returns a CalibrationReport,
    resplice applies a plan without breaking a subsequent run."""
    for name, eng, q in _engines():
        assert isinstance(eng, Engine), f"{name} missing protocol methods"
        out = eng.run(q, 2, fused=True, observe=False)
        assert out.shape == q.shape, name
        out2 = eng.run(q, 2, fused=False, observe=False)
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(out2), rtol=1e-10, atol=1e-12,
            err_msg=f"{name}: fused != eager",
        )
        rep = eng.calibrate(q)
        assert isinstance(rep, CalibrationReport), name
        assert np.all(rep.step_s >= 0), name

        executor = getattr(eng, "executor", None) or getattr(eng, "_executor", None)
        if executor is None and hasattr(eng, "bind_executor"):
            executor = eng.bind_executor()
        if executor is not None:
            plan = executor.solve(np.ones(executor.n_partitions))
            eng.resplice(plan)
        else:
            eng.resplice(None)  # flat solver: documented no-op
        out3 = eng.run(q, 2)
        assert out3.shape == q.shape, f"{name} broken after resplice"


def test_partitioned_dg_executor_kwarg_removed():
    """The pre-protocol PartitionedDG.run(executor=...) shim expired after
    its one-release window: the kwarg is gone, and the bind_executor +
    observe=True spelling is the only one."""
    import jax
    from jax.sharding import Mesh

    from repro.dg.partitioned import PartitionedDG
    from repro.dg.solver import gaussian_pulse, make_two_tree_solver

    solver = make_two_tree_solver(grid=(4, 2, 2), order=2)
    mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
    pdg = PartitionedDG(solver, mesh)
    ex = pdg.make_executor(bucket=4, rebalance_every=0)
    q = pdg.permute_in(gaussian_pulse(solver, width=0.25))
    with pytest.raises(TypeError, match="executor"):
        pdg.run(q, 2, executor=ex)
    pdg.bind_executor(ex)
    out = pdg.run(q, 2, observe=True)
    assert out.shape == q.shape
    assert ex._n_obs >= 1  # the in-scan channel fed the bound executor


def test_decode_batch_uses_monotonic_clock(served, monkeypatch):
    """Regression: decode_batch must time with perf_counter, not the
    non-monotonic wall clock — under a clock that steps BACKWARD (NTP
    adjustment) its prefill/decode seconds stay non-negative."""
    import repro.runtime.serving as serving_mod

    import itertools

    cfg, kernels, params = served
    ticks = itertools.count()
    monkeypatch.setattr(
        serving_mod.time, "time", lambda: 1e9 - 10.0 * next(ticks)
    )
    rows = np.stack([_trace(cfg, 1, rate=1.0)[0].prompt])
    _, t_prefill, t_decode = decode_batch(kernels, params, rows, MAX_NEW)
    assert t_prefill >= 0.0 and t_decode >= 0.0


def test_loop_observes_every_decode_chunk(served):
    """The serving loop feeds the executor one chunk-grained observation
    per decode chunk (zero extra dispatches), so the calibrate→solve→
    resplice loop keeps running under load — and the deterministic virtual
    clock keeps the observations deterministic."""
    cfg, kernels, params = served
    trace = _trace(cfg, 4, rate=2.0)
    loop = ContinuousBatchingLoop(
        kernels, params, capacity=2, chunk=2, calib_gen=3,
        report=_report(), slo=SLO(ttft_s=1e9, tok_s=1e9),
    )
    summary = loop.run(trace)
    assert summary.n_done == 4
    n0 = loop.executor._n_obs
    assert loop.stats.observe_chunks == loop.n_chunks > 0
    assert n0 >= loop.n_chunks  # calibration obs + one per chunk
    assert loop.last_chunk_report is not None
    assert np.all(np.asarray(loop.last_chunk_report.step_s) >= 0)
    # still exactly one dispatch per decode chunk — observation is free
    assert summary.dispatches_per_chunk == 1.0


def test_list_scenarios_enumerates_everything():
    """--list-scenarios output covers every registered arch and scenario
    (the benchmark/CI entry points resolve through the same registry)."""
    from repro.configs.registry import (
        format_listing,
        list_archs,
        list_scenarios,
        resolve_arch,
        resolve_scenario,
    )

    listing = format_listing()
    archs, scenarios = list_archs(), list_scenarios()
    assert archs and scenarios
    for a in archs:
        assert a in listing
        assert resolve_arch(a).arch_id == a
    for s in scenarios:
        assert s in listing
        assert resolve_scenario(s).name == s
    # the scenarios CI/benchmarks use by name must exist
    assert {"dg-two-tree", "dg-smoke", "stampede-cluster"} <= set(scenarios)
    # scenario factories actually build
    sv = resolve_scenario("dg-smoke").build()
    assert sv.mesh.K == 4 * 2 * 2


def test_decode_chunk_fault_retried_without_unfusing(served):
    """A transient fault injected at a decode-chunk boundary is retried in
    place: the probe fires BEFORE the dispatch, so service is identical to
    a clean run and the loop stays one dispatch per chunk."""
    from repro.runtime import FailureInjector

    cfg, kernels, params = served

    def run_loop(injector=None, max_retries=1):
        loop = ContinuousBatchingLoop(
            kernels, params, capacity=2, chunk=2, calib_gen=3,
            report=_report(), slo=SLO(ttft_s=1e9, tok_s=1e9),
            injector=injector, max_retries=max_retries,
        )
        return loop, loop.run(_trace(cfg, 4, rate=2.0))

    loop, faulty = run_loop(FailureInjector({1: "transient"}))
    assert loop.chunk_retries == 1
    _, clean = run_loop()
    assert faulty.to_dict() == clean.to_dict()
    assert faulty.dispatches_per_chunk == 1.0


def test_decode_chunk_fault_escalates_past_max_retries(served):
    from repro.runtime import FailureInjector
    from repro.runtime.fault_tolerance import InjectedFailure

    cfg, kernels, params = served
    loop = ContinuousBatchingLoop(
        kernels, params, capacity=2, chunk=2, calib_gen=3,
        report=_report(), slo=SLO(ttft_s=1e9, tok_s=1e9),
        injector=FailureInjector({0: "node-loss"}),
        max_retries=0,
    )
    with pytest.raises(InjectedFailure):
        loop.run(_trace(cfg, 4, rate=2.0))
