"""Online auto-rebalancing nested-partition executor: the calibrate ->
solve -> resplice loop (paper section 5.6 closed at runtime).

The acceptance invariants:
  * after injecting a 2x slowdown on one partition, <=3 rebalance rounds
    bring the predicted makespan within 10% of the common-finish-time
    optimum;
  * the rebalanced partitioned run still matches the flat solver bitwise
    (the partition is a reordering, never an approximation);
  * respliced chunk sizes stay on bucket multiples so jit caches hit.
"""

import numpy as np
import pytest

from repro.runtime.executor import (
    NestedPartitionExecutor,
    PlanCache,
    bucket_counts,
    pad_to_bucket,
    plan_key,
)


def _linear_models(speeds):
    return [lambda k, s=s: k / s for s in speeds]


# ---------------------------------------------------------------------------
# bucketing
# ---------------------------------------------------------------------------


def test_bucket_counts_conserves_total_and_buckets():
    counts = bucket_counts([100, 200, 212], bucket=16)
    assert counts.sum() == 512
    # every partition except the tail-absorber is a bucket multiple
    off_bucket = [int(c) % 16 for c in counts]
    assert sum(1 for r in off_bucket if r) <= 1


def test_bucket_counts_tiny_total():
    counts = bucket_counts([3, 2], bucket=16)
    assert counts.sum() == 5 and counts.max() == 5


def test_pad_to_bucket():
    assert pad_to_bucket(0, 16) == 0
    assert pad_to_bucket(1, 16) == 16
    assert pad_to_bucket(16, 16) == 16
    assert pad_to_bucket(17, 16) == 32


# ---------------------------------------------------------------------------
# calibrate -> solve -> resplice convergence
# ---------------------------------------------------------------------------


def test_straggler_rebalances_within_three_rounds():
    """Acceptance: 2x straggler -> <=3 rounds -> within 10% of optimum."""
    ex = NestedPartitionExecutor(
        512, 2, grid_dims=(8, 8, 8), bucket=8, time_models=_linear_models([1.0, 1.0])
    )
    ex.calibrate(n_steps=2)
    ex.inject_straggler(0, 2.0)
    rounds = ex.run_until_balanced(rtol=0.10, max_rounds=8)
    assert rounds <= 3, rounds
    assert ex.predicted_makespan() <= 1.10 * ex.optimal_makespan()
    # work moved away from the straggler
    assert ex.counts[0] < ex.counts[1]


def test_straggler_rebalance_four_partitions():
    ex = NestedPartitionExecutor(
        512, 4, grid_dims=(8, 8, 8), bucket=8, time_models=_linear_models([1.0] * 4)
    )
    ex.calibrate(n_steps=1)
    ex.inject_straggler(2, 2.0)
    rounds = ex.run_until_balanced(rtol=0.10, max_rounds=8)
    assert rounds <= 3, rounds
    assert ex.counts[2] == min(ex.counts)


def test_heterogeneous_fleet_matches_solver_optimum():
    """With a 3x-faster accelerator partition the solved split approaches
    the 3:1 common-finish split."""
    ex = NestedPartitionExecutor(
        512, 2, grid_dims=(8, 8, 8), bucket=8, time_models=_linear_models([1.0, 3.0])
    )
    ex.calibrate(n_steps=1)
    ex.run_until_balanced(rtol=0.05, max_rounds=10)
    assert ex.counts[1] / max(1, ex.counts[0]) == pytest.approx(3.0, rel=0.25)


def test_resplice_keeps_partition_valid_and_bucketed():
    ex = NestedPartitionExecutor(512, 3, grid_dims=(8, 8, 8), bucket=16,
                                 time_models=_linear_models([1.0, 2.0, 4.0]))
    ex.calibrate(n_steps=1)
    ex.rebalance()
    ex.partition.validate()  # permutation + host/accel invariants hold
    assert int(np.diff(ex.partition.offsets).sum()) == 512
    np.testing.assert_array_equal(np.diff(ex.partition.offsets), ex.counts)
    # chunk pads are bucket multiples (jit-cache-stable shapes)
    assert all(p % 16 == 0 or p == 0 for p in ex.chunk_pads)


def test_observe_total_is_neutral_without_skew():
    """Synchronous-step attribution carries no skew: the split stays put."""
    ex = NestedPartitionExecutor(512, 2, grid_dims=(8, 8, 8), bucket=8)
    before = ex.counts.copy()
    for _ in range(3):
        ex.observe_total(0.1)
        ex.rebalance()
    np.testing.assert_array_equal(ex.counts, before)


def test_drive_step_driver_rebalances_on_schedule():
    ex = NestedPartitionExecutor(512, 2, grid_dims=(8, 8, 8), bucket=8,
                                 rebalance_every=2, smoothing=1.0)
    calls = []

    def step_fn(state):
        calls.append(state)
        return state + 1

    # per-partition attribution: p0 always twice as slow per item
    def times_fn(executor, dt):
        return executor.counts / np.array([0.5, 1.0])

    out = ex.drive(0, step_fn, 6, times_fn=times_fn)
    assert out == 6 and len(calls) == 6
    assert ex.round >= 2  # rebalanced on the every-2-steps schedule
    assert ex.counts[0] < ex.counts[1]


# ---------------------------------------------------------------------------
# plan cache (persisted via repro.checkpoint)
# ---------------------------------------------------------------------------


def test_plan_key_stable_and_weight_sensitive():
    k1 = plan_key((8, 8, 8), 512, 2, 8, 0.0, [0.5, 0.5])
    k2 = plan_key((8, 8, 8), 512, 2, 8, 0.0, [1.0, 1.0])  # same normalized
    k3 = plan_key((8, 8, 8), 512, 2, 8, 0.0, [0.4, 0.6])
    assert k1 == k2 and k1 != k3


def test_plan_cache_roundtrip(tmp_path):
    ex = NestedPartitionExecutor(512, 2, grid_dims=(8, 8, 8), bucket=8,
                                 plan_cache_dir=str(tmp_path))
    plan = ex.solve([0.4, 0.6])
    assert ex.plan_cache.misses == 1
    again = ex.solve([0.4, 0.6])
    assert ex.plan_cache.hits == 1
    np.testing.assert_array_equal(plan.counts, again.counts)

    # a fresh executor (fresh process analogue) reuses the persisted plan
    ex2 = NestedPartitionExecutor(512, 2, grid_dims=(8, 8, 8), bucket=8,
                                  plan_cache_dir=str(tmp_path))
    hits0 = ex2.plan_cache.hits
    plan2 = ex2.solve([0.4, 0.6])
    assert ex2.plan_cache.hits == hits0 + 1
    np.testing.assert_array_equal(plan.counts, plan2.counts)


def test_rebalance_every_zero_disables_schedule():
    ex = NestedPartitionExecutor(512, 2, grid_dims=(8, 8, 8), bucket=8,
                                 rebalance_every=0)
    ex.observe_total(0.1)
    assert ex.advance() is None  # no ZeroDivisionError, no rebalance
    assert ex.round == 0


def test_plan_cache_restart_resumes_calibrated_split(tmp_path):
    """A restarted executor adopts the last applied plan, not the naive
    50/50 split."""
    ex = NestedPartitionExecutor(512, 2, grid_dims=(8, 8, 8), bucket=8,
                                 smoothing=1.0, plan_cache_dir=str(tmp_path),
                                 time_models=_linear_models([1.0, 3.0]))
    ex.calibrate(n_steps=1)
    ex.rebalance()
    calibrated = ex.counts.copy()
    assert calibrated[1] > calibrated[0]

    restarted = NestedPartitionExecutor(512, 2, grid_dims=(8, 8, 8), bucket=8,
                                        plan_cache_dir=str(tmp_path))
    np.testing.assert_array_equal(restarted.counts, calibrated)


def test_plan_cache_direct(tmp_path):
    from repro.runtime.executor import Plan

    cache = PlanCache(str(tmp_path))
    p = Plan(key="abc", weights=np.array([0.25, 0.75]),
             counts=np.array([128, 384]), predicted_times=np.array([1.0, 1.0]), round=3)
    cache.put(p)
    got = cache.get("abc", 2)
    assert got is not None and got.round == 3
    np.testing.assert_array_equal(got.counts, p.counts)
    assert cache.get("missing", 2) is None


# ---------------------------------------------------------------------------
# blocked DG engine: bitwise-identical execution + jit-stable resplice
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def dg_setup():
    import jax.numpy as jnp  # noqa: F401 — ensures jax configured via conftest

    from repro.dg.solver import gaussian_pulse, make_two_tree_solver

    solver = make_two_tree_solver(grid=(6, 4, 4), order=2, extent=(2.0, 1.0, 1.0))
    q0 = gaussian_pulse(solver, center=(0.5, 0.5, 0.5))
    return solver, q0


def _flat_reference(solver, q0, n_steps, dt):
    """The flat solver stepped with the same eager LSRK loop the engine
    uses (identical update arithmetic, global single-array rhs)."""
    import jax
    import jax.numpy as jnp

    from repro.dg.rk import lsrk45_step

    rhs = jax.jit(solver.rhs)
    q, res = q0, jnp.zeros_like(q0)
    for _ in range(n_steps):
        q, res = lsrk45_step(q, res, rhs, dt)
    return q


def test_blocked_engine_matches_flat_bitwise(dg_setup):
    from repro.runtime.executor import BlockedDGEngine

    solver, q0 = dg_setup
    ex = NestedPartitionExecutor(96, 3, grid_dims=(6, 4, 4), bucket=8)
    eng = BlockedDGEngine(solver, ex)
    dt = solver.cfl_dt()

    # a single rhs evaluation is exactly bitwise identical
    r_flat = np.asarray(solver.rhs(q0))
    r_blk = np.asarray(eng.rhs(q0))
    assert (r_flat == r_blk).all(), np.abs(r_flat - r_blk).max()

    # across steps XLA may retile the per-batch-size gemms, reassociating
    # sub-noise-floor cancellations (observed ~1e-22 on O(1) fields) — the
    # repo's invariant: bitwise up to float reassociation
    q_flat = np.asarray(_flat_reference(solver, q0, 3, dt))
    q_blk = np.asarray(eng.run(q0, 3, dt=dt))
    np.testing.assert_allclose(q_blk, q_flat, rtol=1e-12, atol=1e-14)


def test_blocked_engine_bitwise_after_rebalance(dg_setup):
    """Acceptance: the REBALANCED partitioned run still matches the flat
    solver bitwise, and the resplice only uses bucketed shapes."""
    from repro.runtime.executor import BlockedDGEngine

    solver, q0 = dg_setup
    ex = NestedPartitionExecutor(96, 3, grid_dims=(6, 4, 4), bucket=8)
    eng = BlockedDGEngine(solver, ex)
    dt = solver.cfl_dt()

    # calibrate on real timings, then force a skewed rebalance
    eng.calibrate(q0, reps=1)
    ex.observe(np.array([0.02, 0.01, 0.01]))
    ex.rebalance()
    assert not np.array_equal(ex.counts, [32, 32, 32])  # the split moved

    q_flat = np.asarray(_flat_reference(solver, q0, 3, dt))
    q_blk = np.asarray(eng.run(q0, 3, dt=dt))
    np.testing.assert_allclose(q_blk, q_flat, rtol=1e-12, atol=1e-14)
    assert all(p % 8 == 0 for p in eng.pads_seen)


def test_blocked_engine_calibration_report(dg_setup):
    """Acceptance: calibrate() resolves nonzero, distinct boundary /
    interior / transfer components on the DG engine (no 'whole step is
    interior' fallback)."""
    from repro.runtime.executor import BlockedDGEngine

    solver, q0 = dg_setup
    ex = NestedPartitionExecutor(96, 2, grid_dims=(6, 4, 4), bucket=8)
    eng = BlockedDGEngine(solver, ex)
    rep = eng.calibrate(q0, reps=1)
    for comp in (rep.boundary_s, rep.interior_s, rep.transfer_s, rep.correction_s):
        assert (comp > 0).all()
    # the components are genuinely distinct measurements, not one value
    # smeared across fields
    for p in range(2):
        vals = {rep.boundary_s[p], rep.interior_s[p], rep.transfer_s[p]}
        assert len(vals) == 3, vals
    assert (rep.step_s >= rep.interior_s).all()
    assert (rep.overlapped_s <= rep.step_s).all()
    assert (rep.overlap_efficiency >= 0).all() and (rep.overlap_efficiency <= 1).all()
    assert "overlap-eff=" in rep.summary()
    assert ex._ewma is not None  # calibration seeds the measurement loop


def test_executor_calibrate_passes_reports_through(dg_setup):
    """NestedPartitionExecutor.calibrate with a phase-resolved measure_fn
    returns the component median, not an interior-only fallback — and each
    sample enters the EWMA exactly once even though the bound engine
    calibrate observes internally."""
    from repro.runtime.executor import BlockedDGEngine

    solver, q0 = dg_setup
    ex = NestedPartitionExecutor(96, 2, grid_dims=(6, 4, 4), bucket=8)
    eng = BlockedDGEngine(solver, ex)
    rep = ex.calibrate(measure_fn=lambda: eng.calibrate(q0, reps=1), n_steps=2)
    assert (rep.boundary_s > 0).all() and (rep.transfer_s > 0).all()
    assert ex._ewma is not None
    assert ex._n_obs == 2  # one observation per calibration step, not two


def test_blocked_engine_periodic_mesh_matches_flat():
    """Regression: on a periodic brick the wrap-around cross-node faces must
    enter the halo (the partition is built from the SOLVER mesh's neighbour
    table, not the default non-periodic grid table)."""
    import jax.numpy as jnp

    from repro.dg.mesh import make_brick
    from repro.dg.solver import DGSolver
    from repro.runtime.executor import BlockedDGEngine

    mesh = make_brick((4, 4, 2), (1.0, 1.0, 0.5), periodic=True)
    K = mesh.K
    solver = DGSolver(mesh=mesh, order=2, rho=np.ones(K), lam=np.ones(K), mu=np.zeros(K))
    rng = np.random.default_rng(0)
    q0 = jnp.asarray(rng.standard_normal((K, 9, solver.M, solver.M, solver.M)))
    ex = NestedPartitionExecutor(K, 2, grid_dims=(4, 4, 2), bucket=8)
    eng = BlockedDGEngine(solver, ex)
    ex.partition.validate()  # halo invariants under the periodic topology
    r_flat = np.asarray(solver.rhs(q0))
    r_blk = np.asarray(eng.rhs(q0))
    assert (r_flat == r_blk).all(), np.abs(r_flat - r_blk).max()


def test_executor_calibrate_totals_path():
    """Whole-step time models still calibrate: totals are carried as an
    unresolved report (components make no claim, step_s is the total)."""
    ex = NestedPartitionExecutor(
        512, 2, grid_dims=(8, 8, 8), bucket=8, time_models=_linear_models([1.0, 2.0])
    )
    rep = ex.calibrate(n_steps=2)
    np.testing.assert_allclose(rep.step_s, ex.simulated_times())
    np.testing.assert_allclose(rep.boundary_s, 0.0)
    np.testing.assert_allclose(rep.transfer_s, 0.0)


def test_plan_from_report_credits_hidden_transfer():
    """The overlap-aware solve gives the transfer-hiding partition at least
    as much work as the sequential solve, and a lower predicted makespan."""
    from repro.runtime.schedule import CalibrationReport

    ex = NestedPartitionExecutor(512, 2, grid_dims=(8, 8, 8), bucket=8)
    ex.observe_total(0.1)
    # p1 has a big transfer fully hideable under its interior compute
    rep = CalibrationReport(
        boundary_s=np.array([0.01, 0.01]),
        interior_s=np.array([0.10, 0.10]),
        transfer_s=np.array([0.00, 0.08]),
    )
    seq = ex.plan_from_report(rep, overlap=False, apply=False)
    ov = ex.plan_from_report(rep, overlap=True, apply=True)
    assert int(ov.counts.sum()) == 512
    assert ov.counts[1] > seq.counts[1]  # hidden transfer credited to p1
    # only the APPLIED solve counts as a round; the what-if solve does not
    assert ex.round == 1 and np.array_equal(ex.counts, ov.counts)
    ex.partition.validate()


def test_blocked_engine_run_after_overlap_plan(dg_setup):
    """A resplice driven by the overlap-aware plan still runs bitwise."""
    from repro.runtime.executor import BlockedDGEngine

    solver, q0 = dg_setup
    ex = NestedPartitionExecutor(96, 3, grid_dims=(6, 4, 4), bucket=8)
    eng = BlockedDGEngine(solver, ex)
    rep = eng.calibrate(q0, reps=1)
    ex.plan_from_report(rep)
    dt = solver.cfl_dt()
    q_flat = np.asarray(_flat_reference(solver, q0, 2, dt))
    q_blk = np.asarray(eng.run(q0, 2, dt=dt))
    np.testing.assert_allclose(q_blk, q_flat, rtol=1e-12, atol=1e-14)


def test_partitioned_dg_run_with_executor(subproc):
    """The SPMD slab path adopts the executor step-driver API."""
    subproc(
        """
import jax
jax.config.update("jax_enable_x64", True)
import jax.numpy as jnp
import numpy as np
from repro.dg.partitioned import PartitionedDG
from repro.dg.solver import gaussian_pulse, make_two_tree_solver

solver = make_two_tree_solver(grid=(8, 4, 4), order=3, extent=(2.0, 1.0, 1.0))
q0 = gaussian_pulse(solver, center=(0.5, 0.5, 0.5))
mesh = jax.make_mesh((4,), ("data",))
pdg = PartitionedDG(solver=solver, mesh_axes=mesh)
ex = pdg.bind_executor(pdg.make_executor(rebalance_every=2))
qp = pdg.run(pdg.permute_in(q0), 4, observe=True)
qf = solver.run(q0, 4)
err = float(jnp.abs(qf - pdg.permute_out(np.asarray(qp))).max())
assert err < 1e-10, err
assert ex.round >= 1  # the executor rebalanced on schedule
print("OK", ex.counts.tolist())
""",
        n_devices=4,
    )
