"""Shared graceful-degrade shim for ``hypothesis``.

Property-based tests import ``given`` / ``settings`` / ``st`` from here so
the tier-1 suite still collects and runs (with the property tests skipping)
in containers without hypothesis installed (see requirements-dev.txt).
Kept as a plain module next to the tests — pytest's rootdir insertion makes
it importable from every test file without an ``__init__.py``.
"""

import types

import pytest

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        def deco(fn):
            # plain zero-arg replacement: pytest must not see the property
            # arguments (it would look for fixtures of the same name)
            def skipper():
                pytest.skip("hypothesis not installed (see requirements-dev.txt)")

            skipper.__name__ = fn.__name__
            skipper.__doc__ = fn.__doc__
            return skipper

        return deco

    def settings(*_args, **_kwargs):
        return lambda fn: fn

    def _stub(*_args, **_kwargs):
        return None

    st = types.SimpleNamespace(tuples=_stub, integers=_stub, floats=_stub, lists=_stub,
                               sampled_from=_stub, booleans=_stub)
